//! N-body simulation (Listing 1) on an in-process 2-node × 2-device
//! cluster, with numerics validated against the sequential golden model.
//!
//!     cargo run --release --example nbody [-- <bodies> <steps>]

use celerity::apps::nbody;
use celerity::driver::{run_cluster, ClusterConfig};
use celerity::executor::Registry;
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(10);

    let registry = Registry::new();
    nbody::register_reference_kernels(&registry);
    let cfg = ClusterConfig { num_nodes: 2, num_devices: 2, registry, ..Default::default() };

    let results = Arc::new(Mutex::new(Vec::new()));
    let rc = results.clone();
    let t0 = Instant::now();
    let reports = run_cluster(cfg, move |q| {
        let (p, _v) = nbody::submit(q, n, steps).expect("submit nbody");
        // Typed fence: Vec<[f32; 3]>, flattened for the golden-model diff.
        let got: Vec<f32> = q.fence(p).expect("fence").into_iter().flatten().collect();
        rc.lock().unwrap().push(got);
    });
    let wall = t0.elapsed();

    let want = nbody::reference(n as usize, steps);
    let mut max_err = 0f32;
    for got in results.lock().unwrap().iter() {
        for i in 0..want.len() {
            max_err = max_err.max((got[i] - want[i]).abs());
        }
    }
    println!("nbody: N={n} steps={steps} on 2 nodes x 2 devices");
    println!("  wall time {wall:?}, max |err| vs golden model = {max_err:e}");
    for r in &reports {
        println!(
            "  {}: {} instrs, {} resizes, peak arena {} B, {} eager issues",
            r.node,
            r.instructions_generated,
            r.resizes_emitted,
            r.executor.peak_arena_bytes,
            r.executor.issued_eager
        );
        assert!(r.errors.is_empty(), "{:?}", r.errors);
    }
    assert!(max_err < 1e-3, "numerics diverged: {max_err}");
    println!("nbody OK");
}
