//! Quickstart: the smallest complete celerity-idag program, written
//! against the typed command-group API.
//!
//! One node, two (simulated) devices: create typed buffers, run two
//! dependent data-parallel kernels through the full TDAG → CDAG → IDAG →
//! executor pipeline, read the result back with a typed fence.
//!
//!     cargo run --release --example quickstart

use celerity::driver::{run_cluster, ClusterConfig};
use celerity::executor::{KernelCtx, Registry};
use celerity::grid::{Point, Range};
use celerity::task::RangeMapper;
use std::sync::{Arc, Mutex};

fn main() {
    let registry = Registry::new();
    // Kernels are plain Rust closures here; the e2e_driver example runs
    // AOT-compiled JAX/Pallas artifacts instead.
    registry.register_kernel(
        "iota",
        Arc::new(|ctx: &KernelCtx| {
            let out = ctx.view(0);
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                out.write_f32(Point::d1(i), i as f32);
            }
        }),
    );
    registry.register_kernel(
        "prefix_mean",
        Arc::new(|ctx: &KernelCtx| {
            // out[i] = mean(in[0..=i]) — needs the whole input (all-read).
            let inp = ctx.view(0);
            let out = ctx.view(1);
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                let mut acc = 0f32;
                for j in 0..=i {
                    acc += inp.read_f32(Point::d1(j));
                }
                out.write_f32(Point::d1(i), acc / (i + 1) as f32);
            }
        }),
    );

    let cfg = ClusterConfig { num_nodes: 1, num_devices: 2, registry, ..Default::default() };
    let result = Arc::new(Mutex::new(Vec::new()));
    let rc = result.clone();

    let reports = run_cluster(cfg, move |q| {
        let n = Range::d1(1024);
        // Typed buffers: the runtime derives element size, allocations and
        // transfers from the handle's type — no raw byte counts anywhere.
        let a = q.create_buffer::<f32>("A", n);
        let b = q.create_buffer::<f32>("B", n);
        // A command group scopes accessor declarations and the kernel
        // launch into one closure (Listing 1's `q.submit`).
        q.submit(|cgh| {
            cgh.discard_write(a, RangeMapper::OneToOne);
            cgh.parallel_for("iota", n);
        })
        .expect("submit iota");
        q.submit(|cgh| {
            cgh.read(a, RangeMapper::All); // all-gather pattern
            cgh.discard_write(b, RangeMapper::OneToOne);
            cgh.parallel_for("prefix_mean", n);
        })
        .expect("submit prefix_mean");
        // Typed fence: shape/dtype mismatches come back as QueueError.
        *rc.lock().unwrap() = q.fence(b).expect("fence");
    });

    let got = result.lock().unwrap();
    assert!((got[0] - 0.0).abs() < 1e-6);
    assert!((got[1023] - 511.5).abs() < 1e-3, "{}", got[1023]);
    let r = &reports[0];
    println!("quickstart OK: mean[1023] = {}", got[1023]);
    println!(
        "  {} commands → {} instructions; executor issued {} direct / {} eager",
        r.commands_generated,
        r.instructions_generated,
        r.executor.issued_direct,
        r.executor.issued_eager
    );
    assert!(r.errors.is_empty(), "{:?}", r.errors);
}
