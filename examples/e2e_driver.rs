//! End-to-end validation driver: the full three-layer stack on a real
//! workload.
//!
//! All three benchmark applications run on 1 node × 4 devices with their
//! **AOT-compiled JAX/Pallas kernels** executed through the PJRT CPU client
//! (L1/L2), scheduled by the instruction-graph runtime (L3). Results are
//! checked element-wise against sequential golden models and throughput is
//! reported. Requires `make artifacts` and the `pjrt` feature:
//!
//!     cargo run --release --features pjrt --example e2e_driver

use celerity::apps::{nbody, rsim, wavesim};
use celerity::driver::{run_cluster, ClusterConfig};
use celerity::executor::Registry;
use celerity::runtime::RuntimeClient;
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn check(name: &str, got: &[f32], want: &[f32], tol: f32) -> f32 {
    assert_eq!(got.len(), want.len(), "{name}: length mismatch");
    let mut max_err = 0f32;
    for i in 0..want.len() {
        let err = (got[i] - want[i]).abs() / want[i].abs().max(1.0);
        max_err = max_err.max(err);
        assert!(
            err < tol,
            "{name}: element {i}: got {} want {} (rel err {err})",
            got[i],
            want[i]
        );
    }
    max_err
}

fn main() {
    let dir = celerity::runtime::default_artifacts_dir();
    let rt = Arc::new(RuntimeClient::load(&dir).expect("run `make artifacts` first"));
    println!("e2e driver: PJRT platform = {}, kernels = {:?}", rt.platform, {
        let mut k = rt.kernel_names();
        k.sort();
        k
    });

    // ── N-body: 256 bodies, 20 steps, artifacts sharded for 4 devices ────
    {
        let registry = Registry::new();
        nbody::register_pjrt_kernels(&registry, &rt);
        let cfg = ClusterConfig { num_nodes: 1, num_devices: 4, registry, ..Default::default() };
        let results = Arc::new(Mutex::new(Vec::new()));
        let rc = results.clone();
        let t0 = Instant::now();
        let reports = run_cluster(cfg, move |q| {
            let (p, _) = nbody::submit(q, 256, 20).expect("submit nbody");
            let got: Vec<f32> = q.fence(p).expect("fence").into_iter().flatten().collect();
            rc.lock().unwrap().push(got);
        });
        let wall = t0.elapsed();
        let r = &reports[0];
        assert!(r.errors.is_empty(), "{:?}", r.errors);
        let got = results.lock().unwrap().pop().unwrap();
        let want = nbody::reference(256, 20);
        let err = check("nbody", &got, &want, 1e-3);
        let interactions = 256u64 * 256 * 20;
        println!(
            "nbody   OK: 20 steps x 256 bodies on 4 devices | wall {wall:?} | {:.1} Minteractions/s | rel err {err:.2e} | {} instrs, {} eager",
            interactions as f64 / wall.as_secs_f64() / 1e6,
            r.instructions_generated,
            r.executor.issued_eager
        );
    }

    // ── WaveSim: 64×64 field, 12 steps ───────────────────────────────────
    {
        let registry = Registry::new();
        wavesim::register_pjrt_kernels(&registry, &rt);
        let cfg = ClusterConfig { num_nodes: 1, num_devices: 4, registry, ..Default::default() };
        let results = Arc::new(Mutex::new(Vec::new()));
        let rc = results.clone();
        let t0 = Instant::now();
        let reports = run_cluster(cfg, move |q| {
            let out = wavesim::submit(q, 64, 64, 12).expect("submit wavesim");
            let got = q.fence(out).expect("fence");
            rc.lock().unwrap().push(got);
        });
        let wall = t0.elapsed();
        let r = &reports[0];
        assert!(r.errors.is_empty(), "{:?}", r.errors);
        let got = results.lock().unwrap().pop().unwrap();
        let want = wavesim::reference(64, 64, 12);
        let err = check("wavesim", &got, &want, 1e-3);
        println!(
            "wavesim OK: 12 steps x 64x64 on 4 devices | wall {wall:?} | {:.1} Mcell-updates/s | rel err {err:.2e}",
            (64u64 * 64 * 12) as f64 / wall.as_secs_f64() / 1e6
        );
    }

    // ── RSim: 32 rows x 64 width, growing pattern + lookahead ───────────
    {
        let registry = Registry::new();
        rsim::register_pjrt_kernels(&registry, &rt);
        let cfg = ClusterConfig { num_nodes: 1, num_devices: 4, registry, ..Default::default() };
        let results = Arc::new(Mutex::new(Vec::new()));
        let rc = results.clone();
        let t0 = Instant::now();
        let reports = run_cluster(cfg, move |q| {
            let (rbuf, _) = rsim::submit(q, 32, 64, false).expect("submit rsim");
            let got = q.fence(rbuf).expect("fence");
            rc.lock().unwrap().push(got);
        });
        let wall = t0.elapsed();
        let r = &reports[0];
        assert!(r.errors.is_empty(), "{:?}", r.errors);
        let got = results.lock().unwrap().pop().unwrap();
        let want = rsim::reference(32, 64);
        let err = check("rsim", &got, &want, 1e-2);
        println!(
            "rsim    OK: 32 rows x 64 width on 4 devices | wall {wall:?} | rel err {err:.2e} | {} resizes (lookahead)",
            r.resizes_emitted
        );
        assert_eq!(r.resizes_emitted, 0, "lookahead must elide resizes");
    }

    println!("\ne2e driver: all three applications validated through PJRT. ✓");
}
