//! WaveSim: 2-D five-point stencil with halo exchange on a 2-node × 2-device
//! cluster — the latency-sensitive workload of §5.
//!
//!     cargo run --release --example wavesim [-- <rows> <cols> <steps>]

use celerity::apps::wavesim;
use celerity::driver::{run_cluster, ClusterConfig};
use celerity::executor::Registry;
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let rows: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let cols: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let steps: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(8);

    let registry = Registry::new();
    wavesim::register_reference_kernels(&registry);
    let cfg = ClusterConfig { num_nodes: 2, num_devices: 2, registry, ..Default::default() };

    let results = Arc::new(Mutex::new(Vec::new()));
    let rc = results.clone();
    let t0 = Instant::now();
    let reports = run_cluster(cfg, move |q| {
        let out = wavesim::submit(q, rows, cols, steps).expect("submit wavesim");
        // Fence before taking the shared lock: nodes must be free to
        // communicate while each other's fences drain.
        let got = q.fence(out).expect("fence");
        rc.lock().unwrap().push(got);
    });
    let wall = t0.elapsed();

    let want = wavesim::reference(rows as usize, cols as usize, steps);
    let mut max_err = 0f32;
    for got in results.lock().unwrap().iter() {
        for i in 0..want.len() {
            max_err = max_err.max((got[i] - want[i]).abs());
        }
    }
    println!("wavesim: {rows}x{cols} field, {steps} steps, 2 nodes x 2 devices");
    println!("  wall {wall:?}, max |err| vs golden model = {max_err:e}");
    for r in &reports {
        println!(
            "  {}: {} instrs generated, max lookahead queue {}",
            r.node, r.instructions_generated, r.max_queue_len
        );
        assert!(r.errors.is_empty(), "{:?}", r.errors);
    }
    assert!(max_err < 1e-3);
    println!("wavesim OK");
}
