//! RSim radiosity: the growing access pattern that motivates scheduler
//! lookahead (§4.3). Runs the same program three ways on the live runtime
//! and reports resize counts, allocated bytes and wall time:
//!
//! - IDAG + lookahead (proposed): no resizes;
//! - IDAG without lookahead: one resize per step;
//! - IDAG without lookahead + the §5.2 user workaround kernel.
//!
//!     cargo run --release --example rsim [-- <steps> <width>]

use celerity::apps::rsim;
use celerity::driver::{run_cluster, ClusterConfig};
use celerity::executor::Registry;
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn run(lookahead: bool, workaround: bool, steps: u64, width: u64) -> (f64, u64, u64, Vec<f32>) {
    let registry = Registry::new();
    rsim::register_reference_kernels(&registry);
    let cfg = ClusterConfig { num_nodes: 1, num_devices: 2, lookahead, registry, ..Default::default() };
    let results = Arc::new(Mutex::new(Vec::new()));
    let rc = results.clone();
    let t0 = Instant::now();
    let reports = run_cluster(cfg, move |q| {
        let (r, _) = rsim::submit(q, steps, width, workaround).expect("submit rsim");
        let got = q.fence(r).expect("fence");
        rc.lock().unwrap().push(got);
    });
    let wall = t0.elapsed().as_secs_f64();
    let r = &reports[0];
    assert!(r.errors.is_empty(), "{:?}", r.errors);
    let out = results.lock().unwrap().pop().unwrap();
    (wall, r.resizes_emitted, r.bytes_allocated, out)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let width: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);

    println!("rsim: {steps} time steps, row width {width}, 1 node x 2 devices\n");
    println!("{:<28} {:>9} {:>8} {:>12}", "configuration", "wall (s)", "resizes", "alloc bytes");
    let want = rsim::reference(steps as usize, width as usize);
    for (name, la, wa) in [
        ("idag + lookahead", true, false),
        ("idag, no lookahead", false, false),
        ("no lookahead + workaround", false, true),
    ] {
        let (wall, resizes, bytes, got) = run(la, wa, steps, width);
        println!("{name:<28} {wall:>9.4} {resizes:>8} {bytes:>12}");
        // All three configurations must agree with the golden model.
        for i in 0..want.len() {
            assert!(
                (got[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                "{name}: i={i} {} vs {}",
                got[i],
                want[i]
            );
        }
    }
    println!("\nall configurations numerically identical; lookahead eliminates resizes");
}
