//! Figure 7: single-node (4 GPU) runtime timelines for the three example
//! applications — scheduler work overlapping execution.
//!
//! The simulator records per-instruction (resource, start, end) spans; this
//! harness renders them as ASCII swimlanes per resource, showing how
//! command/instruction generation (scheduler lane) overlaps kernel, copy
//! and communication execution, and how RSim's lookahead defers instruction
//! availability until the whole command graph is queued.
//!
//!     cargo bench --bench fig7_timelines

use celerity::grid::{GridBox, Range, Region};
use celerity::sim::{simulate, SimConfig, TraceEvent};
use celerity::task::RangeMapper;
use std::collections::BTreeMap;

const WIDTH: usize = 100;

fn render(app: &str, trace: &[TraceEvent], makespan: f64) {
    println!("\n== Fig 7: {app} timeline (1 node x 4 GPUs) ==");
    println!("   makespan {:.3} ms; each column = {:.1} µs", makespan * 1e3, makespan / WIDTH as f64 * 1e6);
    let mut lanes: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    for e in trace {
        lanes.entry(e.resource.clone()).or_default().push((e.start, e.end));
    }
    for (lane, spans) in lanes {
        let mut row = vec!['.'; WIDTH];
        let mut busy = 0.0;
        for (s, e) in &spans {
            busy += e - s;
            let a = ((s / makespan) * WIDTH as f64) as usize;
            let b = (((e / makespan) * WIDTH as f64).ceil() as usize).min(WIDTH);
            for c in row.iter_mut().take(b.max(a + 1)).skip(a) {
                *c = '#';
            }
        }
        println!(
            "  {:<22} |{}| {:>5.1}% busy ({} spans)",
            lane,
            row.iter().collect::<String>(),
            busy / makespan * 100.0,
            spans.len()
        );
    }
}

fn main() {
    let cfg = SimConfig { num_nodes: 1, num_devices: 4, record_trace: true, ..Default::default() };

    // N-body, small problem (paper: "small problem sizes").
    let r = simulate(&cfg, |tm| {
        let range = Range::d1(4096);
        let p = tm.create_buffer::<[f32; 3]>("P", range, true);
        let v = tm.create_buffer::<[f32; 3]>("V", range, true);
        for _ in 0..6 {
            tm.submit_group(|cgh| {
                cgh.read(p, RangeMapper::All);
                cgh.read_write(v, RangeMapper::OneToOne);
                cgh.parallel_for("timestep", range).work_per_item(4096.0 * 20.0);
            })
            .expect("submit timestep");
            tm.submit_group(|cgh| {
                cgh.read(v, RangeMapper::OneToOne);
                cgh.read_write(p, RangeMapper::OneToOne);
                cgh.parallel_for("update", range).work_per_item(2.0);
            })
            .expect("submit update");
        }
    });
    render("N-body", &r.trace, r.makespan);

    // RSim: scheduler queues the entire command graph (§4.3) before the
    // first instruction executes.
    let r = simulate(&cfg, |tm| {
        let (steps, width) = (24u64, 4096u64);
        let rb = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
        let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
        for t in 1..steps {
            let prev = Region::from(GridBox::d2((0, 0), (t, width)));
            tm.submit_group(|cgh| {
                cgh.read(rb, RangeMapper::Fixed(prev));
                cgh.read(vis, RangeMapper::All);
                cgh.write(rb, RangeMapper::RowSlice(t));
                cgh.parallel_for("radiosity", Range::d1(width))
                    .work_per_item(t as f64 * 500.0);
            })
            .expect("submit radiosity");
        }
    });
    render("RSim", &r.trace, r.makespan);

    // WaveSim: short kernels, frequent halo copies.
    let r = simulate(&cfg, |tm| {
        let range = Range::d2(512, 256);
        let bufs = [
            tm.create_buffer::<f32>("U0", range, true),
            tm.create_buffer::<f32>("U1", range, true),
            tm.create_buffer::<f32>("U2", range, true),
        ];
        for s in 0..10usize {
            let prev = bufs[s % 3];
            let curr = bufs[(s + 1) % 3];
            let next = bufs[(s + 2) % 3];
            tm.submit_group(|cgh| {
                cgh.read(prev, RangeMapper::Neighborhood(Range::d2(1, 0)));
                cgh.read(curr, RangeMapper::Neighborhood(Range::d2(1, 0)));
                cgh.write(next, RangeMapper::OneToOne);
                cgh.parallel_for("wavesim", range).work_per_item(10.0);
            })
            .expect("submit wavesim");
        }
    });
    render("WaveSim", &r.trace, r.makespan);
}
