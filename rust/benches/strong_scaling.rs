//! Strong scaling of the LIVE cluster — real bytes, real threads, real
//! sockets — across node counts and transports.
//!
//! Where `fig6_strong_scaling` reproduces the paper's curves on the
//! discrete-event simulator (virtual time, no data movement),
//! this harness runs nbody/wavesim/rsim through the full
//! TDAG→CDAG→IDAG→executor pipeline with the pure-Rust reference kernels,
//! at 1/2/4/8 simulated nodes over both transports (in-process channels
//! and loopback TCP). It measures what the simulator cannot: executor
//! latency, receive-arbitration overhead and the wire cost of the pilot
//! protocol. nbody — the all-gather workload — additionally runs a
//! collectives-on/off ablation ("nbody" vs "nbody-p2p" rows): ring
//! lowering vs the original O(n²) push/await-push pairs. The p2p rows run
//! a second ablation for direct device transfers ("-staged" suffix =
//! `--no-direct-comm`): sends/receives staged through pinned host memory
//! vs reading/landing in device allocations directly. wavesim — the
//! stencil-exchange workload — additionally runs a fault-recovery
//! ablation ("wavesim-faulty", TCP only): a fixed seeded fault plan
//! (drops, dups, corruption) so the gate prices the CRC/retransmit
//! machinery's overhead against the clean "wavesim" TCP rows. A final
//! multi-tenant section runs N concurrent jobs (nbody + wavesim) sharing
//! one cluster per node: "multijob" rows report aggregate throughput,
//! "multijob-jJ-<app>" rows the per-job p99 fence latency, and the
//! "-fifo" variants re-run everything with fair-share dispatch off (the
//! global-FIFO ablation where a heavy tenant head-of-line-blocks a light
//! one).
//!
//!     cargo bench --bench strong_scaling            # full run
//!     BENCH_QUICK=1 cargo bench --bench strong_scaling   # CI smoke: 1+2 nodes
//!
//! Results go to stdout and, machine-readable, to
//! `BENCH_strong_scaling.local.json` at the repo root (gitignored;
//! override the path with `BENCH_STRONG_SCALING_JSON` — CI sets it to the
//! canonical `BENCH_strong_scaling.json` and uploads the artifact).

#[path = "support/mod.rs"]
mod support;

use celerity::apps;
use celerity::comm::Transport;
use celerity::driver::{run_cluster, run_cluster_jobs, ClusterConfig, JobProgram, Queue};
use std::sync::{Arc, Mutex};
use std::time::Instant;

struct Row {
    /// App name; ablations suffix the key ("-p2p" = collectives off,
    /// "-staged" = direct device transfers off) so the bench gate keys
    /// each lowering separately.
    app: String,
    transport: Transport,
    nodes: u64,
    devices: u64,
    /// Collective-group lowering enabled for this row?
    collectives: bool,
    /// Direct device transfers (p2p staging elision) enabled for this row?
    direct: bool,
    /// Ran under a seeded fault plan (the "-faulty" recovery ablation)?
    fault: bool,
    wall_s: f64,
    /// Total grid-cell updates performed by the workload (throughput unit).
    cells: u64,
    cells_per_s: f64,
    /// Speedup vs the same app+transport+lowering at 1 node.
    speedup_vs_1: f64,
}

struct Workload {
    app: &'static str,
    /// Cell updates this workload performs (for ops/s).
    cells: u64,
    /// Shared so each cluster run can move a clone into its node threads.
    submit: std::sync::Arc<dyn Fn(&mut Queue) + Send + Sync>,
}

fn workloads(quick: bool) -> Vec<Workload> {
    // Sizes chosen so the full matrix finishes in minutes on a laptop and
    // the quick matrix in seconds on a 2-core CI runner; big enough that
    // per-node work dominates constant overheads at 8 nodes.
    let (nbody_n, nbody_steps) = if quick { (256u64, 2usize) } else { (512, 4) };
    let (ws_rows, ws_cols, ws_steps) = if quick { (64u64, 32u64, 4usize) } else { (128, 64, 8) };
    let (rs_rows, rs_width) = if quick { (12u64, 128u64) } else { (24, 256) };
    vec![
        Workload {
            app: "nbody",
            cells: nbody_n * nbody_steps as u64,
            submit: std::sync::Arc::new(move |q: &mut Queue| {
                let (p, _v) = apps::nbody::submit(q, nbody_n, nbody_steps).expect("submit nbody");
                let _ = q.fence_bytes(p.id()).expect("fence P");
            }),
        },
        Workload {
            app: "wavesim",
            cells: ws_rows * ws_cols * ws_steps as u64,
            submit: std::sync::Arc::new(move |q: &mut Queue| {
                let out =
                    apps::wavesim::submit(q, ws_rows, ws_cols, ws_steps).expect("submit wavesim");
                let _ = q.fence_bytes(out.id()).expect("fence U");
            }),
        },
        Workload {
            app: "rsim",
            cells: (rs_rows - 1) * rs_width,
            submit: std::sync::Arc::new(move |q: &mut Queue| {
                let (r, _vis) =
                    apps::rsim::submit(q, rs_rows, rs_width, false).expect("submit rsim");
                let _ = q.fence_bytes(r.id()).expect("fence R");
            }),
        },
    ]
}

/// Fixed fault plan for the "-faulty" ablation rows: mild sustained
/// drop/dup/corrupt pressure that the CRC/ack-retransmit layer repairs
/// transparently. Deterministic by construction (seeded), so row-to-row
/// noise is the transport's, not the injector's.
const FAULTY_PLAN: &str = "seed=42 drop=0.01 dup=0.005 corrupt=0.002";

fn run_once(
    w: &Workload,
    transport: Transport,
    nodes: u64,
    devices: u64,
    collectives: bool,
    direct: bool,
    fault: bool,
) -> f64 {
    let cfg = ClusterConfig {
        num_nodes: nodes,
        num_devices: devices,
        registry: apps::reference_registry(),
        transport,
        collectives,
        direct_comm: direct,
        fault_plan: fault
            .then(|| celerity::fault::FaultPlan::parse(FAULTY_PLAN).expect("valid fault plan")),
        ..Default::default()
    };
    let submit = w.submit.clone();
    let t0 = Instant::now();
    let reports = run_cluster(cfg, move |q| submit(q));
    let wall = t0.elapsed().as_secs_f64();
    for r in &reports {
        assert!(r.errors.is_empty(), "node {}: {:?}", r.node, r.errors);
    }
    wall
}

/// p99 over latency samples (milliseconds); sorts in place.
fn p99_ms(samples: &mut [f64]) -> f64 {
    assert!(!samples.is_empty(), "no latency samples collected");
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let idx = ((samples.len() as f64) * 0.99).ceil() as usize;
    samples[idx.saturating_sub(1).min(samples.len() - 1)]
}

fn write_json(rows: &[Row], extra_rows: &[String], quick: bool) {
    let path = support::out_path("BENCH_STRONG_SCALING_JSON", "strong_scaling");
    let mut s = support::json_header("strong_scaling", quick);
    s.push_str("  \"rows\": [\n");
    let total = rows.len() + extra_rows.len();
    let mut emitted = 0usize;
    for r in rows {
        emitted += 1;
        s.push_str(&format!(
            "    {{\"app\": \"{}\", \"transport\": \"{}\", \"nodes\": {}, \"devices\": {}, \"collectives\": {}, \"direct\": {}, \"fault\": {}, \"wall_s\": {:.6}, \"cells\": {}, \"cells_per_s\": {:.1}, \"speedup_vs_1\": {:.3}}}{}\n",
            r.app,
            r.transport.name(),
            r.nodes,
            r.devices,
            r.collectives,
            r.direct,
            r.fault,
            r.wall_s,
            r.cells,
            r.cells_per_s,
            r.speedup_vs_1,
            if emitted < total { "," } else { "" }
        ));
    }
    // Pre-formatted rows with a different shape (the multi-tenant per-job
    // fence-latency rows: "p99_fence_ms" instead of a throughput field).
    for e in extra_rows {
        emitted += 1;
        s.push_str(&format!("    {e}{}\n", if emitted < total { "," } else { "" }));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let node_counts: &[u64] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let devices = 2u64;
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();

    println!("== strong_scaling: live cluster, both transports ==");
    println!(
        "{:>16} {:>9} {:>6} {:>11} {:>7} {:>10} {:>14} {:>9}",
        "app", "transport", "nodes", "collectives", "direct", "wall (s)", "cells/s", "speedup"
    );
    let mut rows: Vec<Row> = Vec::new();
    let mut extra_rows: Vec<String> = Vec::new();
    let ws = workloads(quick);
    for w in &ws {
        if !filter.is_empty() && filter != w.app {
            continue;
        }
        // Ablations, keyed by app-name suffix so the bench gate tracks
        // every lowering separately:
        //   - collectives on/off ("-p2p"): only nbody's all-gather pattern
        //     triggers collective lowering;
        //   - direct device transfers on/off ("-staged"): measured on the
        //     p2p paths they specialize (wavesim's stencil exchange and
        //     nbody's p2p lowering; the collective ring always stages);
        //   - fault recovery on/off ("-faulty", TCP only — the channel
        //     fabric has no retransmit layer, so injected drops would
        //     hang it): wavesim under FAULTY_PLAN vs the clean rows.
        let variants: &[(&str, bool, bool, bool)] = match w.app {
            "nbody" => &[
                ("", true, true, false),
                ("-p2p", false, true, false),
                ("-p2p-staged", false, false, false),
            ],
            "wavesim" => &[
                ("", true, true, false),
                ("-staged", true, false, false),
                ("-faulty", true, true, true),
            ],
            _ => &[("", true, true, false)],
        };
        for &(suffix, collectives, direct, fault) in variants {
            for &transport in &[Transport::Channel, Transport::Tcp] {
                if fault && transport == Transport::Channel {
                    continue;
                }
                let mut base = f64::NAN;
                for &nodes in node_counts {
                    let wall =
                        run_once(w, transport, nodes, devices, collectives, direct, fault);
                    if nodes == 1 {
                        base = wall;
                    }
                    let row = Row {
                        app: format!("{}{}", w.app, suffix),
                        transport,
                        nodes,
                        devices,
                        collectives,
                        direct,
                        fault,
                        wall_s: wall,
                        cells: w.cells,
                        cells_per_s: w.cells as f64 / wall,
                        speedup_vs_1: base / wall,
                    };
                    println!(
                        "{:>16} {:>9} {:>6} {:>11} {:>7} {:>10.4} {:>14.0} {:>9.2}",
                        row.app,
                        row.transport.name(),
                        row.nodes,
                        row.collectives,
                        row.direct,
                        row.wall_s,
                        row.cells_per_s,
                        row.speedup_vs_1
                    );
                    rows.push(row);
                }
            }
        }
    }
    // ---- multi-tenant: concurrent jobs sharing one cluster per node ----
    //
    // N app instances run as jobs of ONE cluster per node (shared scheduler
    // thread, shared executor lanes/arenas), each fencing `iters` times.
    // Rows:
    //   - "multijob" / "multijob-fifo": aggregate throughput across all
    //     jobs, fair-share weighted-round-robin dispatch vs the global-FIFO
    //     ablation (head-of-line blocking between tenants);
    //   - "multijob[-fifo]-jJ-<app>": per-job fence-latency percentiles
    //     (p99), the tenant-visible cost of sharing — the fair-vs-fifo
    //     delta on the light job is the starvation headroom.
    if filter.is_empty() || filter == "multijob" {
        let iters = if quick { 3usize } else { 6 };
        // Indices into `ws`: nbody (heavy all-gather) + wavesim (light
        // stencil), doubled up in the full matrix.
        let picks: &[usize] = if quick { &[0, 1] } else { &[0, 1, 0, 1] };
        let mj_nodes: &[u64] = if quick { &[1, 2] } else { &[1, 2, 4] };
        println!(
            "\n== strong_scaling: multi-tenant ({} concurrent jobs per cluster) ==",
            picks.len()
        );
        println!(
            "{:>16} {:>9} {:>6} {:>6} {:>10} {:>14} {:>9}",
            "mode", "transport", "nodes", "jobs", "wall (s)", "cells/s", "speedup"
        );
        for &(suffix, fair) in &[("", true), ("-fifo", false)] {
            for &transport in &[Transport::Channel, Transport::Tcp] {
                let mut base = f64::NAN;
                for &nodes in mj_nodes {
                    let lats: Vec<Arc<Mutex<Vec<f64>>>> =
                        picks.iter().map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
                    let programs: Vec<JobProgram> = picks
                        .iter()
                        .zip(&lats)
                        .map(|(&k, lat)| {
                            let submit = ws[k].submit.clone();
                            let lat = lat.clone();
                            Arc::new(move |q: &mut Queue| {
                                for _ in 0..iters {
                                    let t = Instant::now();
                                    submit(q);
                                    lat.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
                                }
                            }) as JobProgram
                        })
                        .collect();
                    let cfg = ClusterConfig::builder()
                        .num_nodes(nodes)
                        .num_devices(devices)
                        .registry(apps::reference_registry())
                        .transport(transport)
                        .fair_share(fair)
                        .build();
                    let t0 = Instant::now();
                    let reports =
                        run_cluster_jobs(cfg, programs).expect("bring up cluster transport");
                    let wall = t0.elapsed().as_secs_f64();
                    for r in &reports {
                        for jr in &r.jobs {
                            assert!(
                                jr.errors.is_empty(),
                                "node {} job {}: {:?}",
                                r.node,
                                jr.job,
                                jr.errors
                            );
                        }
                    }
                    let cells: u64 = picks.iter().map(|&k| ws[k].cells * iters as u64).sum();
                    if nodes == 1 {
                        base = wall;
                    }
                    let row = Row {
                        app: format!("multijob{suffix}"),
                        transport,
                        nodes,
                        devices,
                        collectives: true,
                        direct: true,
                        fault: false,
                        wall_s: wall,
                        cells,
                        cells_per_s: cells as f64 / wall,
                        speedup_vs_1: base / wall,
                    };
                    println!(
                        "{:>16} {:>9} {:>6} {:>6} {:>10.4} {:>14.0} {:>9.2}",
                        row.app,
                        row.transport.name(),
                        row.nodes,
                        picks.len(),
                        row.wall_s,
                        row.cells_per_s,
                        row.speedup_vs_1
                    );
                    rows.push(row);
                    for (j, (&k, lat)) in picks.iter().zip(&lats).enumerate() {
                        let mut samples = std::mem::take(&mut *lat.lock().unwrap());
                        let p99 = p99_ms(&mut samples);
                        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
                        println!(
                            "                 job {j} ({}): {} fences, mean {:.2} ms, p99 {:.2} ms",
                            ws[k].app,
                            samples.len(),
                            mean,
                            p99
                        );
                        extra_rows.push(format!(
                            "{{\"app\": \"multijob{suffix}-j{j}-{}\", \"transport\": \"{}\", \"nodes\": {nodes}, \"devices\": {devices}, \"job\": {j}, \"fair\": {fair}, \"fences\": {}, \"mean_fence_ms\": {mean:.3}, \"p99_fence_ms\": {p99:.3}}}",
                            ws[k].app,
                            transport.name(),
                            samples.len(),
                        ));
                    }
                }
            }
        }
    }
    println!("\n(live run with reference kernels: wall time includes scheduling, transfers and the transport; tiny problem sizes mean sub-linear speedup is expected — the claim is the *trend*, the channel-vs-tcp delta, nbody's collectives-vs-p2p delta, the direct-vs-staged delta on the p2p rows, wavesim's faulty-vs-clean tcp delta pricing the recovery layer, and the multijob fair-vs-fifo p99 delta pricing tenant isolation)");
    write_json(&rows, &extra_rows, quick);
}
