//! Helpers shared by the bench harnesses (included via `#[path]`, not a
//! bench target itself): git metadata, dependency-free date formatting,
//! JSON escaping, and the output-path convention.
//!
//! Output-path convention: every bench writes its machine-readable JSON to
//! `BENCH_<name>.local.json` at the repository root by default — gitignored,
//! so casual local `cargo bench` runs never dirty the working tree. CI (and
//! anyone refreshing the committed baseline deliberately) opts into the
//! canonical `BENCH_<name>.json` path via the bench's `BENCH_*_JSON` env
//! var.

/// Short git revision of the working tree, or "unknown".
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Days-since-epoch → (year, month, day), proleptic Gregorian
/// (Howard Hinnant's civil_from_days), to avoid a date-crate dependency.
pub fn civil_from_unix(secs: u64) -> (i64, u64, u64) {
    let z = (secs / 86_400) as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097) as u64;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe as i64 + era * 400 + i64::from(m <= 2);
    (y, m, d)
}

pub fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Seconds since the Unix epoch (0 if the clock is broken).
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// The JSON header fields every bench schema shares.
pub fn json_header(bench: &str, quick: bool) -> String {
    let unix_time = unix_now();
    let (y, m, d) = civil_from_unix(unix_time);
    format!(
        "{{\n  \"bench\": \"{}\",\n  \"schema\": 1,\n  \"git_rev\": \"{}\",\n  \"date\": \"{y:04}-{m:02}-{d:02}\",\n  \"unix_time\": {unix_time},\n  \"quick\": {quick},\n",
        json_escape(bench),
        json_escape(&git_rev()),
    )
}

/// Resolve the output path: `env_var` if set, else
/// `<repo root>/BENCH_<name>.local.json` (gitignored).
pub fn out_path(env_var: &str, name: &str) -> String {
    std::env::var(env_var).unwrap_or_else(|_| {
        format!("{}/../BENCH_{name}.local.json", env!("CARGO_MANIFEST_DIR"))
    })
}
