//! Microbenchmarks of the latency-critical components (§4.1: "as little
//! time as possible must be spent in either" instruction selection or
//! polling). Real wall-clock measurements on this machine:
//!
//! - out-of-order engine admit+retire latency,
//! - IDAG generation throughput (instructions/s),
//! - spsc queue round-trip throughput,
//! - region-algebra and region-map ops (the scheduler's inner loop).
//!
//!     cargo bench --bench micro_scheduler
//!
//! Besides the stdout table, results are written as machine-readable JSON
//! to `BENCH_scheduler.local.json` at the repository root — gitignored, so
//! local runs never dirty the committed baseline. CI (and deliberate
//! baseline refreshes) opt into the canonical `BENCH_scheduler.json` path
//! via `BENCH_SCHEDULER_JSON`; `scripts/bench_gate.py` compares the fresh
//! run against the committed baseline and fails on a >25% throughput drop.
//! Set `BENCH_QUICK=1` for a fast smoke run (CI): same components, reduced
//! op counts.

#[path = "support/mod.rs"]
mod support;

use celerity::command::{CdagGenerator, SplitHint};
use celerity::executor::ooo::OooEngine;
use celerity::grid::{GridBox, Range, Region, RegionMap};
use celerity::instruction::{IdagConfig, IdagGenerator};
use celerity::scheduler::{Scheduler, SchedulerConfig};
use celerity::task::{RangeMapper, TaskManager};
use celerity::util::{spsc, JobId, NodeId};
use celerity::verify::Verifier;
use std::time::Instant;

struct BenchResult {
    name: &'static str,
    ops_per_s: f64,
    ns_per_op: f64,
    ops: u64,
}

/// Warmup + best-of-N (median would need more runs; min is stable for
/// CPU-bound loops).
fn bench(
    results: &mut Vec<BenchResult>,
    repeats: u32,
    name: &'static str,
    mut f: impl FnMut() -> u64,
) {
    f();
    let mut best = f64::MAX;
    let mut ops = 0;
    for _ in 0..repeats {
        let t0 = Instant::now();
        ops = f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
    }
    let ops_per_s = ops as f64 / best;
    let ns_per_op = best / ops as f64 * 1e9;
    println!("{name:<44} {ops_per_s:>12.0} ops/s   ({ns_per_op:>8.1} ns/op, {ops} ops)");
    results.push(BenchResult { name, ops_per_s, ns_per_op, ops });
}

fn write_json(results: &[BenchResult], quick: bool) {
    let path = support::out_path("BENCH_SCHEDULER_JSON", "scheduler");
    let mut s = support::json_header("micro_scheduler", quick);
    s.push_str("  \"components\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"ops_per_s\": {:.1}, \"ns_per_op\": {:.2}, \"ops\": {}}}{}\n",
            support::json_escape(r.name),
            r.ops_per_s,
            r.ns_per_op,
            r.ops,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    // Scale divides inner-loop op counts; quick mode is a CI smoke run.
    let scale: u64 = if quick { 16 } else { 1 };
    let repeats: u32 = if quick { 1 } else { 3 };
    let mut results: Vec<BenchResult> = Vec::new();
    let res = &mut results;
    println!("== micro_scheduler: latency-critical component benchmarks ==\n");

    // 1. OoO engine: admit + retire a linear chain (worst case: every
    //    retire unblocks exactly one successor).
    bench(res, repeats, "ooo admit+retire (chain, eager path)", || {
        let n = 100_000u64 / scale;
        let mut e = OooEngine::new(4);
        let mut pending = Vec::with_capacity(n as usize);
        for i in 0..n {
            let deps: Vec<u64> = if i == 0 { vec![] } else { vec![i - 1] };
            let instr = std::sync::Arc::new(celerity::instruction::Instruction {
                id: celerity::util::InstructionId(i),
                kind: celerity::instruction::InstructionKind::DeviceKernel {
                    device: celerity::util::DeviceId(0),
                    chunk: GridBox::d1(0, 1),
                    bindings: vec![],
                    work_per_item: 1.0,
                    kernel: None,
                },
                deps: deps
                    .into_iter()
                    .map(|d| (celerity::util::InstructionId(d), celerity::dag::DepKind::Dataflow))
                    .collect(),
                task: None,
            });
            if let Some((i, _)) = e.admit(instr) {
                pending.push(i.id);
            }
        }
        for i in 0..n {
            let _ = e.retire(celerity::util::InstructionId(i));
        }
        n * 2
    });

    // 2. IDAG generation throughput on the N-body pattern (4 devices).
    bench(res, repeats, "idag generation (nbody, 4 devices)", || {
        let steps = 200 / scale.min(8);
        let mut tm = TaskManager::new();
        let range = Range::d1(1 << 16);
        let p = tm.create_buffer::<[f32; 3]>("P", range, true);
        let v = tm.create_buffer::<[f32; 3]>("V", range, true);
        for _ in 0..steps {
            tm.submit_group(|cgh| {
                cgh.read(p, RangeMapper::All);
                cgh.read_write(v, RangeMapper::OneToOne);
                cgh.parallel_for("timestep", range);
            })
            .expect("submit timestep");
            tm.submit_group(|cgh| {
                cgh.read(v, RangeMapper::OneToOne);
                cgh.read_write(p, RangeMapper::OneToOne);
                cgh.parallel_for("update", range);
            })
            .expect("submit update");
        }
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(
            SchedulerConfig { num_devices: 4, ..Default::default() },
            tm.buffers().clone(),
        );
        // Batched pipeline: one wakeup per run of available tasks.
        let (i, _) = sched.process_batch(&tasks);
        let mut total = i.len() as u64;
        let (i, _) = sched.flush_now();
        total += i.len() as u64;
        total
    });

    // 3. CDAG generation throughput at 32 nodes (the distributed split) —
    //    once with the original p2p lowering (n−1 pushes per step) and once
    //    with collective lowering (one command + the pattern check), so the
    //    gate tracks both paths.
    let cdag_nbody = |collectives: bool, scale: u64| {
        let steps = 50 / scale.min(5);
        let mut tm = TaskManager::new();
        let range = Range::d1(1 << 16);
        let p = tm.create_buffer::<[f32; 3]>("P", range, true);
        let v = tm.create_buffer::<[f32; 3]>("V", range, true);
        for _ in 0..steps {
            tm.submit_group(|cgh| {
                cgh.read(p, RangeMapper::All);
                cgh.read_write(v, RangeMapper::OneToOne);
                cgh.parallel_for("timestep", range);
            })
            .expect("submit timestep");
            tm.submit_group(|cgh| {
                cgh.read(v, RangeMapper::OneToOne);
                cgh.read_write(p, RangeMapper::OneToOne);
                cgh.parallel_for("update", range);
            })
            .expect("submit update");
        }
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), 32, SplitHint::D1, tm.buffers().clone());
        cg.set_collectives(collectives);
        let mut total = 0;
        for t in &tasks {
            cg.compile(t);
            total += cg.take_new_commands().len() as u64;
        }
        total
    };
    bench(res, repeats, "cdag generation (nbody p2p, node 0 of 32)", || {
        cdag_nbody(false, scale)
    });
    bench(res, repeats, "cdag generation (nbody collective, node 0 of 32)", || {
        cdag_nbody(true, scale)
    });

    // 4. spsc queue round trip (the Fig-5 thread fabric).
    bench(res, repeats, "spsc send+recv round trip", || {
        let n = 500_000u64 / scale;
        let (tx, rx) = spsc::channel::<u64>(1024);
        let t = std::thread::spawn(move || {
            for i in 0..n {
                tx.send(i).unwrap();
            }
        });
        let mut got = 0;
        while got < n {
            if rx.recv().is_ok() {
                got += 1;
            }
        }
        t.join().unwrap();
        n
    });

    // 5. Region algebra (scheduler inner loop).
    bench(res, repeats, "region union+intersect+difference (2D)", || {
        let n = 50_000u64 / scale;
        let a =
            Region::from_boxes([GridBox::d2((0, 0), (64, 64)), GridBox::d2((64, 32), (128, 96))]);
        let b = Region::from(GridBox::d2((32, 32), (96, 96)));
        let mut acc = 0u64;
        for _ in 0..n {
            acc += a.union(&b).area() + a.intersection(&b).area() + a.difference(&b).area();
        }
        std::hint::black_box(acc);
        n * 3
    });

    // 6. Region map: the RSim row pattern that fragments last-writer
    //    tracking — per-row updates against a growing fragment list, plus
    //    the prefix queries the generator issues per command. This is the
    //    structure the interval index exists for.
    bench(res, repeats, "region map update+query (rsim rows, 2D)", || {
        let (rows, width, reps) = (256u64, 4096u64, 40 / scale.clamp(1, 8));
        let mut acc = 0u64;
        for _ in 0..reps {
            let mut m = RegionMap::new(Range::d2(rows, width), 0u64);
            for t in 0..rows {
                m.update_box(&GridBox::d2((t, 0), (t + 1, width)), t + 1);
                let prev = GridBox::d2((0, 0), (t.max(1), width));
                m.for_each_intersecting(&prev, |b, v| acc += b.area() + v);
            }
        }
        std::hint::black_box(acc);
        256 * 2 * reps
    });

    // 7. Region map: reader-set tracking (`Vec` payloads) under
    //    apply_to_region — the op that used to deep-clone every list.
    bench(res, repeats, "region map apply (reader sets, 1D)", || {
        let n = 2_000u64 / scale.clamp(1, 8);
        let ext = 1u64 << 16;
        let mut m = RegionMap::new(Range::d1(ext), Vec::<u64>::new());
        // Pre-fragment: 64 disjoint writer stripes.
        for i in 0..64 {
            m.update_box(&GridBox::d1(i * (ext / 64), i * (ext / 64) + ext / 128), vec![i]);
        }
        for i in 0..n {
            let lo = (i * 977) % (ext - 1024);
            let r = Region::from(GridBox::d1(lo, lo + 1024));
            m.apply_to_region(&r, |rs| {
                let mut rs = rs.clone();
                rs.push(i);
                rs
            });
            if i % 64 == 63 {
                // Horizon-style reset keeps fragment counts bounded.
                m.update_box(&GridBox::d1(0, ext), Vec::new());
            }
        }
        std::hint::black_box(m.fragments());
        n
    });

    // 8. RSim lookahead scheduling cost (queue + flush).
    bench(res, repeats, "scheduler lookahead (rsim 64 steps)", || {
        let mut tm = TaskManager::new();
        let (steps, width) = (64u64 / scale.min(4), 4096u64);
        let r = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
        let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
        for t in 1..steps {
            let prev = Region::from(GridBox::d2((0, 0), (t, width)));
            tm.submit_group(|cgh| {
                cgh.read(r, RangeMapper::Fixed(prev));
                cgh.read(vis, RangeMapper::All);
                cgh.write(r, RangeMapper::RowSlice(t));
                cgh.parallel_for("radiosity", Range::d1(width));
            })
            .expect("submit radiosity");
        }
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(
            SchedulerConfig { num_devices: 4, ..Default::default() },
            tm.buffers().clone(),
        );
        let (i, _) = sched.process_batch(&tasks);
        let mut total = i.len() as u64;
        let (i, _) = sched.flush_now();
        total += i.len() as u64;
        total
    });

    // 9. Trace recorder, disabled path: the guard every hot-path call site
    //    pays when `--trace` is off — one relaxed atomic load and an early
    //    return. Tracked by the gate so instrumentation creep (work done
    //    before the guard) shows up as a throughput drop here instead of as
    //    a silent tax on every scheduler/executor row above.
    bench(res, repeats, "trace instant (tracing off, guard only)", || {
        let n = 2_000_000u64 / scale;
        assert!(!celerity::trace::enabled(), "this row measures the disabled path");
        for i in 0..n {
            celerity::trace::instant(
                0,
                celerity::trace::Track::Executor,
                celerity::trace::EventKind::Issue { instr: i },
            );
        }
        n
    });

    // 10. Wire framing: CRC32 + sequence stamping on the TCP data path —
    //     encode a 4 KiB data frame and decode it back through the checked
    //     reader. This is the per-frame tax the fault-recovery layer added;
    //     the gate catches regressions (e.g. an accidental extra copy or a
    //     slower CRC) before they show up as cluster-level slowdowns.
    bench(res, repeats, "wire frame encode+decode (4 KiB, crc+seq)", || {
        use celerity::comm::wire;
        let n = 20_000u64 / scale;
        let payload = vec![0xA5u8; 4096];
        let mut acc = 0u64;
        for i in 0..n {
            let frame =
                wire::encode_data(NodeId(0), celerity::util::MessageId(i), &payload, i);
            let mut cur = std::io::Cursor::new(frame);
            match wire::read_frame(&mut cur) {
                Ok(Some(wire::WireMsg::Msg { seq, .. })) => acc += seq,
                other => panic!("round trip must decode a data frame, got {other:?}"),
            }
        }
        std::hint::black_box(acc);
        n
    });

    // 11. Static graph verification (--verify): the same RSim stream as
    //     row 8 compiled with the in-core verifier enabled; ops = the
    //     instructions the verifier priced, so the row tracks the analysis
    //     cost per instruction (race/lifetime/coherence/pilot checks).
    //     Rows 2 and 8 run with `verify: false` (the default), so the gate
    //     also pins the off-path — one branch per scheduler batch.
    bench(res, repeats, "verify (rsim stream, per instruction)", || {
        let mut tm = TaskManager::new();
        let (steps, width) = (64u64 / scale.min(4), 4096u64);
        let r = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
        let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
        for t in 1..steps {
            let prev = Region::from(GridBox::d2((0, 0), (t, width)));
            tm.submit_group(|cgh| {
                cgh.read(r, RangeMapper::Fixed(prev));
                cgh.read(vis, RangeMapper::All);
                cgh.write(r, RangeMapper::RowSlice(t));
                cgh.parallel_for("radiosity", Range::d1(width));
            })
            .expect("submit radiosity");
        }
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(
            SchedulerConfig { num_devices: 4, verify: true, ..Default::default() },
            tm.buffers().clone(),
        );
        let _ = sched.process_batch(&tasks);
        let _ = sched.flush_now();
        let violations = sched.take_verify_errors();
        assert!(violations.is_empty(), "rsim stream must verify clean: {violations:?}");
        sched.instructions_verified()
    });

    // 12. Incremental vs from-scratch re-verification. When `--verify` is
    //     on, every new scheduler batch triggers a re-check of the stream.
    //     The incremental core substitutes its dense tracking state at
    //     each verified horizon/epoch boundary, so a re-check costs work
    //     proportional to the invalidated span; a from-scratch pass pays
    //     the whole prefix every time. ops = re-check rounds, so ns/op is
    //     the per-batch re-check latency — compare the two rows directly.
    let reverify_stream = || {
        // Tight horizons (step 4) so the incremental mode compacts many
        // times over the stream — the shape the comparison exists for.
        let mut tm = TaskManager::with_horizon_step(4);
        let steps = 96u64 / scale.min(4);
        let range = Range::d1(1 << 14);
        let p = tm.create_buffer::<[f32; 3]>("P", range, true);
        let v = tm.create_buffer::<[f32; 3]>("V", range, true);
        for _ in 0..steps {
            tm.submit_group(|cgh| {
                cgh.read(p, RangeMapper::All);
                cgh.read_write(v, RangeMapper::OneToOne);
                cgh.parallel_for("timestep", range);
            })
            .expect("submit timestep");
            tm.submit_group(|cgh| {
                cgh.read(v, RangeMapper::OneToOne);
                cgh.read_write(p, RangeMapper::OneToOne);
                cgh.parallel_for("update", range);
            })
            .expect("submit update");
        }
        tm.shutdown();
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(
            SchedulerConfig { num_devices: 4, ..Default::default() },
            tm.buffers().clone(),
        );
        let (mut instrs, _) = sched.process_batch(&tasks);
        let (tail, _) = sched.flush_now();
        instrs.extend(tail);
        (instrs, tm.buffers().clone())
    };
    let (stream, stream_buffers) = reverify_stream();
    let batch = 48usize;
    bench(res, repeats, "verify incremental re-check (per batch)", || {
        let mut v = Verifier::incremental(JobId(0), NodeId(0), stream_buffers.clone());
        let mut rounds = 0u64;
        for chunk in stream.chunks(batch) {
            v.absorb_batch(chunk, &[]);
            rounds += 1;
        }
        let violations = v.take_violations();
        assert!(violations.is_empty(), "stream must verify clean: {violations:?}");
        assert!(v.compacted_below() > 0, "incremental mode must have compacted");
        rounds
    });
    bench(res, repeats, "verify from-scratch re-check (per batch)", || {
        let mut rounds = 0u64;
        let mut end = 0usize;
        while end < stream.len() {
            end = (end + batch).min(stream.len());
            let mut v = Verifier::new(JobId(0), NodeId(0), stream_buffers.clone());
            v.absorb_batch(&stream[..end], &[]);
            let violations = v.take_violations();
            assert!(violations.is_empty(), "prefix must verify clean: {violations:?}");
            rounds += 1;
        }
        rounds
    });

    // Sanity anchor: an IdagGenerator must stay usable for the suite.
    let _ = IdagGenerator::new(IdagConfig::default(), celerity::buffer::BufferPool::new());
    println!("\ntargets (DESIGN.md §7): ooo < 2 µs/instr; idag gen > 10k instr/s");

    write_json(&results, quick);
}
