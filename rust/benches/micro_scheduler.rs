//! Microbenchmarks of the latency-critical components (§4.1: "as little
//! time as possible must be spent in either" instruction selection or
//! polling). Real wall-clock measurements on this machine:
//!
//! - out-of-order engine admit+retire latency,
//! - IDAG generation throughput (instructions/s),
//! - spsc queue round-trip throughput,
//! - region-algebra ops (the scheduler's inner loop).
//!
//!     cargo bench --bench micro_scheduler

use celerity::command::{CdagGenerator, SplitHint};
use celerity::executor::ooo::OooEngine;
use celerity::grid::{GridBox, Range, Region};
use celerity::instruction::{IdagConfig, IdagGenerator};
use celerity::scheduler::{Scheduler, SchedulerConfig};
use celerity::task::{RangeMapper, TaskManager};
use celerity::util::{spsc, NodeId};
use std::time::Instant;

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // Warmup + best-of-3 (median would need more runs; min is stable for
    // CPU-bound loops).
    f();
    let mut best = f64::MAX;
    let mut ops = 0;
    for _ in 0..3 {
        let t0 = Instant::now();
        ops = f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
    }
    println!(
        "{name:<44} {:>12.0} ops/s   ({:>8.1} ns/op, {ops} ops)",
        ops as f64 / best,
        best / ops as f64 * 1e9
    );
}

fn main() {
    println!("== micro_scheduler: latency-critical component benchmarks ==\n");

    // 1. OoO engine: admit + retire a linear chain (worst case: every
    //    retire unblocks exactly one successor).
    bench("ooo admit+retire (chain, eager path)", || {
        let n = 100_000u64;
        let mut e = OooEngine::new(4);
        let mut pending = Vec::with_capacity(n as usize);
        for i in 0..n {
            let deps: Vec<u64> = if i == 0 { vec![] } else { vec![i - 1] };
            let instr = std::sync::Arc::new(celerity::instruction::Instruction {
                id: celerity::util::InstructionId(i),
                kind: celerity::instruction::InstructionKind::DeviceKernel {
                    device: celerity::util::DeviceId(0),
                    chunk: GridBox::d1(0, 1),
                    bindings: vec![],
                    work_per_item: 1.0,
                    kernel: None,
                },
                deps: deps
                    .into_iter()
                    .map(|d| (celerity::util::InstructionId(d), celerity::dag::DepKind::Dataflow))
                    .collect(),
                task: None,
            });
            if let Some((i, _)) = e.admit(instr) {
                pending.push(i.id);
            }
        }
        for i in 0..n {
            let _ = e.retire(celerity::util::InstructionId(i));
        }
        n * 2
    });

    // 2. IDAG generation throughput on the N-body pattern (4 devices).
    bench("idag generation (nbody, 4 devices)", || {
        let mut tm = TaskManager::new();
        let range = Range::d1(1 << 16);
        let p = tm.create_buffer::<[f32; 3]>("P", range, true);
        let v = tm.create_buffer::<[f32; 3]>("V", range, true);
        for _ in 0..200 {
            tm.submit_group(|cgh| {
                cgh.read(p, RangeMapper::All);
                cgh.read_write(v, RangeMapper::OneToOne);
                cgh.parallel_for("timestep", range);
            })
            .expect("submit timestep");
            tm.submit_group(|cgh| {
                cgh.read(v, RangeMapper::OneToOne);
                cgh.read_write(p, RangeMapper::OneToOne);
                cgh.parallel_for("update", range);
            })
            .expect("submit update");
        }
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(
            SchedulerConfig { num_devices: 4, ..Default::default() },
            tm.buffers().clone(),
        );
        let mut total = 0;
        for t in &tasks {
            let (i, _) = sched.process(t);
            total += i.len() as u64;
        }
        let (i, _) = sched.flush_now();
        total + i.len() as u64
    });

    // 3. CDAG generation throughput at 32 nodes (the distributed split).
    bench("cdag generation (nbody, node 0 of 32)", || {
        let mut tm = TaskManager::new();
        let range = Range::d1(1 << 16);
        let p = tm.create_buffer::<[f32; 3]>("P", range, true);
        let v = tm.create_buffer::<[f32; 3]>("V", range, true);
        for _ in 0..50 {
            tm.submit_group(|cgh| {
                cgh.read(p, RangeMapper::All);
                cgh.read_write(v, RangeMapper::OneToOne);
                cgh.parallel_for("timestep", range);
            })
            .expect("submit timestep");
            tm.submit_group(|cgh| {
                cgh.read(v, RangeMapper::OneToOne);
                cgh.read_write(p, RangeMapper::OneToOne);
                cgh.parallel_for("update", range);
            })
            .expect("submit update");
        }
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), 32, SplitHint::D1, tm.buffers().clone());
        let mut total = 0;
        for t in &tasks {
            cg.compile(t);
            total += cg.take_new_commands().len() as u64;
        }
        total
    });

    // 4. spsc queue round trip (the Fig-5 thread fabric).
    bench("spsc send+recv round trip", || {
        let n = 500_000u64;
        let (tx, rx) = spsc::channel::<u64>(1024);
        let t = std::thread::spawn(move || {
            for i in 0..n {
                tx.send(i).unwrap();
            }
        });
        let mut got = 0;
        while got < n {
            if rx.recv().is_ok() {
                got += 1;
            }
        }
        t.join().unwrap();
        n
    });

    // 5. Region algebra (scheduler inner loop).
    bench("region union+intersect+difference (2D)", || {
        let n = 50_000u64;
        let a = Region::from_boxes([GridBox::d2((0, 0), (64, 64)), GridBox::d2((64, 32), (128, 96))]);
        let b = Region::from(GridBox::d2((32, 32), (96, 96)));
        let mut acc = 0u64;
        for _ in 0..n {
            acc += a.union(&b).area() + a.intersection(&b).area() + a.difference(&b).area();
        }
        std::hint::black_box(acc);
        n * 3
    });

    // 6. RSim lookahead scheduling cost (queue + flush).
    bench("scheduler lookahead (rsim 64 steps)", || {
        let mut tm = TaskManager::new();
        let (steps, width) = (64u64, 4096u64);
        let r = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
        let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
        for t in 1..steps {
            let prev = Region::from(GridBox::d2((0, 0), (t, width)));
            tm.submit_group(|cgh| {
                cgh.read(r, RangeMapper::Fixed(prev));
                cgh.read(vis, RangeMapper::All);
                cgh.write(r, RangeMapper::RowSlice(t));
                cgh.parallel_for("radiosity", Range::d1(width));
            })
            .expect("submit radiosity");
        }
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(
            SchedulerConfig { num_devices: 4, ..Default::default() },
            tm.buffers().clone(),
        );
        let mut total = 0;
        for t in &tasks {
            let (i, _) = sched.process(t);
            total += i.len() as u64;
        }
        let (i, _) = sched.flush_now();
        total + i.len() as u64
    });

    // Sanity anchor: an IdagGenerator must stay usable for the suite.
    let _ = IdagGenerator::new(IdagConfig::default(), celerity::buffer::BufferPool::new());
    println!("\ntargets (DESIGN.md §7): ooo < 2 µs/instr; idag gen > 10k instr/s");
}
