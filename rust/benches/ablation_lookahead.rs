//! Ablation of the §4.3 scheduler lookahead on the RSim growing pattern:
//! resize count, allocated bytes and virtual makespan across four
//! configurations (per-experiment index entry A1 in DESIGN.md).
//!
//!     cargo bench --bench ablation_lookahead

use celerity::grid::{GridBox, Range, Region};
use celerity::sim::{simulate, ExecModel, SimConfig};
use celerity::task::{RangeMapper, TaskManager};

fn rsim(steps: u64, width: u64, workaround: bool) -> impl Fn(&mut TaskManager) {
    move |tm| {
        let r = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
        let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
        if workaround {
            tm.submit_group(|cgh| {
                cgh.read_write(r, RangeMapper::Fixed(Region::full(Range::d2(steps, width))));
                cgh.parallel_for("touch", Range::d1(width)).work_per_item(1.0);
            })
            .expect("submit touch");
        }
        for t in 1..steps {
            let prev = Region::from(GridBox::d2((0, 0), (t, width)));
            tm.submit_group(|cgh| {
                cgh.read(r, RangeMapper::Fixed(prev));
                cgh.read(vis, RangeMapper::All);
                cgh.write(r, RangeMapper::RowSlice(t));
                cgh.parallel_for("radiosity", Range::d1(width))
                    .work_per_item(t as f64 * 100.0);
            })
            .expect("submit radiosity");
        }
    }
}

fn main() {
    let (steps, width) = (96u64, 8192u64);
    println!("RSim lookahead ablation: {steps} steps, width {width}, 1 node x 4 GPUs\n");
    println!(
        "{:<34} {:>10} {:>8} {:>14} {:>12}",
        "configuration", "t_sim (ms)", "resizes", "alloc bytes", "instrs"
    );
    let build = rsim(steps, width, false);
    let build_wa = rsim(steps, width, true);
    let cases: [(&str, ExecModel, bool, &dyn Fn(&mut TaskManager)); 4] = [
        ("idag + lookahead (proposed)", ExecModel::Idag, true, &build),
        ("idag, lookahead off", ExecModel::Idag, false, &build),
        ("baseline (ad-hoc, §2.5)", ExecModel::Baseline, false, &build),
        ("baseline + workaround (§5.2)", ExecModel::Baseline, false, &build_wa),
    ];
    for (name, exec, lookahead, b) in cases {
        let cfg = SimConfig {
            num_nodes: 1,
            num_devices: 4,
            exec,
            lookahead,
            ..Default::default()
        };
        let r = simulate(&cfg, b);
        println!(
            "{name:<34} {:>10.3} {:>8} {:>14} {:>12}",
            r.makespan * 1e3,
            r.resizes,
            r.allocated_bytes,
            r.instructions
        );
    }
    println!("\nExpected shape: proposed = 0 resizes + least memory + fastest;");
    println!("workaround trades peak memory for resize elimination on the baseline.");
}
