//! Figure 6: strong-scaling of N-body, RSim and WaveSim, baseline vs
//! instruction-graph scheduling, 4 → 128 GPUs (1 → 32 nodes × 4 GPUs).
//!
//! Regenerates the paper's three speedup curves on the discrete-event
//! cluster simulator (DESIGN.md §Substitutions): the real TDAG/CDAG/IDAG
//! generators run unmodified; execution time is virtual. Expected shape:
//! IDAG ≥ baseline everywhere; RSim baseline degraded by per-step resizes,
//! partially recovered by the §5.2 workaround; WaveSim exposes executor
//! latency as kernels shrink.
//!
//!     cargo bench --bench fig6_strong_scaling [-- nbody|rsim|wavesim]

use celerity::grid::{GridBox, Range, Region};
use celerity::sim::{simulate, ExecModel, SimConfig};
use celerity::task::{RangeMapper, TaskManager};

const GPUS: &[u64] = &[4, 8, 16, 32, 64, 128];
const DEVS_PER_NODE: u64 = 4;

fn nbody(n: u64, steps: usize) -> impl Fn(&mut TaskManager) {
    move |tm| {
        let range = Range::d1(n);
        let p = tm.create_buffer::<[f32; 3]>("P", range, true);
        let v = tm.create_buffer::<[f32; 3]>("V", range, true);
        for _ in 0..steps {
            tm.submit_group(|cgh| {
                cgh.read(p, RangeMapper::All);
                cgh.read_write(v, RangeMapper::OneToOne);
                cgh.parallel_for("timestep", range).work_per_item(n as f64 * 20.0);
            })
            .expect("submit timestep");
            tm.submit_group(|cgh| {
                cgh.read(v, RangeMapper::OneToOne);
                cgh.read_write(p, RangeMapper::OneToOne);
                cgh.parallel_for("update", range).work_per_item(2.0);
            })
            .expect("submit update");
        }
    }
}

fn rsim(steps: u64, width: u64, workaround: bool) -> impl Fn(&mut TaskManager) {
    move |tm| {
        let r = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
        let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
        if workaround {
            tm.submit_group(|cgh| {
                cgh.read_write(r, RangeMapper::Fixed(Region::full(Range::d2(steps, width))));
                cgh.parallel_for("touch", Range::d1(width)).work_per_item(1.0);
            })
            .expect("submit touch");
        }
        for t in 1..steps {
            let prev = Region::from(GridBox::d2((0, 0), (t, width)));
            tm.submit_group(|cgh| {
                cgh.read(r, RangeMapper::Fixed(prev));
                cgh.read(vis, RangeMapper::All);
                cgh.write(r, RangeMapper::RowSlice(t));
                // RSim's kernel scales well with GPU count (§5.2): heavy
                // per-item work growing with the history length.
                cgh.parallel_for("radiosity", Range::d1(width))
                    .work_per_item(t as f64 * 2000.0);
            })
            .expect("submit radiosity");
        }
    }
}

fn wavesim(rows: u64, cols: u64, steps: usize) -> impl Fn(&mut TaskManager) {
    move |tm| {
        let range = Range::d2(rows, cols);
        let bufs = [
            tm.create_buffer::<f32>("U0", range, true),
            tm.create_buffer::<f32>("U1", range, true),
            tm.create_buffer::<f32>("U2", range, true),
        ];
        for s in 0..steps {
            let prev = bufs[s % 3];
            let curr = bufs[(s + 1) % 3];
            let next = bufs[(s + 2) % 3];
            tm.submit_group(|cgh| {
                cgh.read(prev, RangeMapper::Neighborhood(Range::d2(1, 0)));
                cgh.read(curr, RangeMapper::Neighborhood(Range::d2(1, 0)));
                cgh.write(next, RangeMapper::OneToOne);
                cgh.parallel_for("wavesim", range).work_per_item(10.0);
            })
            .expect("submit wavesim");
        }
    }
}

fn row(app: &str, build: &dyn Fn(&mut TaskManager), variants: &[(&str, ExecModel, bool)]) {
    println!("\n== Fig 6: {app} strong scaling ==");
    print!("{:>6}", "GPUs");
    for (name, _, _) in variants {
        print!(" {:>16} {:>8}", format!("{name} t(s)"), "speedup");
    }
    println!();
    // Speedup is relative to each variant's own 4-GPU time (paper style).
    let mut base: Vec<f64> = Vec::new();
    for &gpus in GPUS {
        let nodes = gpus / DEVS_PER_NODE;
        print!("{gpus:>6}");
        for (vi, (_, exec, lookahead)) in variants.iter().enumerate() {
            let cfg = SimConfig {
                num_nodes: nodes,
                num_devices: DEVS_PER_NODE,
                exec: *exec,
                lookahead: *lookahead,
                ..Default::default()
            };
            let t = simulate(&cfg, build).makespan;
            if base.len() <= vi {
                base.push(t);
            }
            print!(" {:>16.6} {:>8.2}", t, base[vi] / t);
        }
        println!();
    }
}

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let small = std::env::var_os("FIG6_SMALL").is_some();
    // Paper: N = 2^20 bodies / 100 steps; scaled down so CDAG generation
    // for 32 nodes stays tractable on this machine (shape-preserving).
    let (nsteps, nbodies) = if small { (4, 1 << 12) } else { (10, 1 << 16) };
    let idag = ("idag", ExecModel::Idag, true);
    let baseline = ("baseline", ExecModel::Baseline, false);

    if filter.is_empty() || filter == "nbody" {
        row("N-body", &nbody(nbodies, nsteps), &[baseline, idag]);
    }
    if filter.is_empty() || filter == "rsim" {
        let steps = if small { 32 } else { 96 };
        row(
            "RSim (84k-triangle analogue)",
            &rsim(steps, 8192, false),
            &[baseline, idag],
        );
        row(
            "RSim + workaround",
            &rsim(steps, 8192, true),
            &[("baseline+wa", ExecModel::Baseline, false), idag],
        );
    }
    if filter.is_empty() || filter == "wavesim" {
        let steps = if small { 8 } else { 30 };
        row("WaveSim", &wavesim(4096, 512, steps), &[baseline, idag]);
    }
    println!("\n(speedup relative to each variant's own 4-GPU run; shape, not absolute numbers, is the claim)");
}
