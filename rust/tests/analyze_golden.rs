//! Golden workloads for `celerity analyze`: each seeded anti-pattern must
//! fire its lint exactly once, and the same workload compiled with the
//! corresponding scheduler feature enabled must come back clean.
//!
//! The compiled cases drive the real pipeline (TaskManager → scheduler →
//! instruction stream) with the one knob under test flipped off, so the
//! lints double as regression tests for the features themselves: a
//! lowering change that silently reintroduces resize churn, host staging
//! or p2p fan-out turns a green assertion red here before it ships as a
//! slowdown. The hand-built cases pin detector behavior on streams the
//! shipped scheduler (correctly) refuses to produce.

use celerity::analyze::{analyze_stream, lints, AnalyzeConfig, Finding, LintLevel, Report};
use celerity::buffer::BufferPool;
use celerity::dag::DepKind;
use celerity::grid::{GridBox, Range, Region};
use celerity::instruction::{AccessBinding, Instruction, InstructionKind, InstructionRef};
use celerity::scheduler::{Scheduler, SchedulerConfig};
use celerity::task::{AccessMode, RangeMapper, TaskDecl, TaskManager};
use celerity::util::{AllocationId, BufferId, DeviceId, InstructionId, MemoryId, NodeId, TaskId};
use std::sync::Arc;

type Streams = Vec<(NodeId, Vec<InstructionRef>)>;

/// Compile a program on every node of `base.num_nodes` (verifier on, so a
/// malformed golden workload fails loudly instead of skewing the lints).
fn compile(base: SchedulerConfig, f: impl Fn(&mut TaskManager)) -> (Streams, BufferPool) {
    let mut tm = TaskManager::new();
    f(&mut tm);
    tm.shutdown();
    let tasks = tm.take_new_tasks();
    let mut streams = Vec::new();
    for node in 0..base.num_nodes {
        let cfg = SchedulerConfig { node: NodeId(node), verify: true, ..base.clone() };
        let mut sched = Scheduler::new(cfg, tm.buffers().clone());
        let mut instructions = Vec::new();
        for t in &tasks {
            let (is, _) = sched.process(t);
            instructions.extend(is);
        }
        let (is, _) = sched.flush_now();
        instructions.extend(is);
        assert!(sched.take_errors().is_empty(), "node {node}: compile errors");
        let violations = sched.take_verify_errors();
        assert!(violations.is_empty(), "node {node}: {violations:?}");
        streams.push((NodeId(node), instructions));
    }
    (streams, tm.buffers().clone())
}

fn findings_of<'a>(r: &'a Report, lint: &str) -> Vec<&'a Finding> {
    r.findings.iter().filter(|f| f.lint == lint).collect()
}

/// RSim-like growing access pattern: step t writes row t of a (T × W)
/// buffer and reads rows [0, t) — the §4.3 resize-chain workload.
fn growing_rows(tm: &mut TaskManager) {
    let (steps, width) = (16u64, 64u64);
    let b = tm.create_buffer::<f64>("R", Range::d2(steps, width), false).id();
    for t in 0..steps {
        let row = Region::from(GridBox::d2((t, 0), (t + 1, width)));
        let prev = Region::from(GridBox::d2((0, 0), (t.max(1), width)));
        let mut decl =
            TaskDecl::device("radiosity", Range::d1(width)).write(b, RangeMapper::Fixed(row));
        if t > 0 {
            decl = decl.read(b, RangeMapper::Fixed(prev));
        }
        tm.submit(decl);
    }
}

/// One full all-gather: every node produces its slice, every node reads
/// the whole buffer.
fn all_gather(tm: &mut TaskManager) {
    let n = Range::d1(256);
    let b = tm.create_buffer::<f64>("B", n, true).id();
    tm.submit(TaskDecl::device("w", n).write(b, RangeMapper::OneToOne));
    tm.submit(TaskDecl::device("r", n).read(b, RangeMapper::All));
}

#[test]
fn alloc_churn_fires_without_lookahead_and_not_with() {
    let base = SchedulerConfig { num_devices: 1, lookahead: false, ..Default::default() };
    let (streams, buffers) = compile(base, growing_rows);
    let r = analyze_stream(streams[0].0, &buffers, &streams[0].1, &AnalyzeConfig::default());
    let churn = findings_of(&r, lints::ALLOC_CHURN);
    assert_eq!(churn.len(), 1, "exactly one aggregated finding: {:?}", r.findings);
    assert!(churn[0].instr.is_some(), "must anchor the first regrown allocation");
    assert!(churn[0].message.contains("lookahead"), "{}", churn[0].message);

    let base = SchedulerConfig { num_devices: 1, lookahead: true, ..Default::default() };
    let (streams, buffers) = compile(base, growing_rows);
    let r = analyze_stream(streams[0].0, &buffers, &streams[0].1, &AnalyzeConfig::default());
    assert_eq!(findings_of(&r, lints::ALLOC_CHURN).len(), 0, "{:?}", r.findings);
}

#[test]
fn staged_copy_fires_without_direct_comm_and_not_with() {
    // collectives off so the exchange lowers to p2p send/receive — the
    // shape §3.4 staging elision applies to. With 2 nodes each node sends
    // to exactly one peer, so missed-collective stays out of the picture.
    let base = SchedulerConfig {
        num_nodes: 2,
        num_devices: 1,
        collectives: false,
        direct_comm: false,
        ..Default::default()
    };
    let (streams, buffers) = compile(base, all_gather);
    for (node, instructions) in &streams {
        let r = analyze_stream(*node, &buffers, instructions, &AnalyzeConfig::default());
        let staged = findings_of(&r, lints::STAGED_COPY);
        assert_eq!(staged.len(), 1, "node {node}: one per-buffer finding: {:?}", r.findings);
        assert!(staged[0].message.contains("direct"), "{}", staged[0].message);
    }

    let base = SchedulerConfig {
        num_nodes: 2,
        num_devices: 1,
        collectives: false,
        direct_comm: true,
        ..Default::default()
    };
    let (streams, buffers) = compile(base, all_gather);
    for (node, instructions) in &streams {
        let r = analyze_stream(*node, &buffers, instructions, &AnalyzeConfig::default());
        assert_eq!(findings_of(&r, lints::STAGED_COPY).len(), 0, "node {node}: {:?}", r.findings);
    }
}

#[test]
fn missed_collective_fires_without_collectives_and_not_with() {
    let base = SchedulerConfig {
        num_nodes: 4,
        num_devices: 1,
        collectives: false,
        direct_comm: true,
        ..Default::default()
    };
    let (streams, buffers) = compile(base, all_gather);
    for (node, instructions) in &streams {
        let r = analyze_stream(*node, &buffers, instructions, &AnalyzeConfig::default());
        let missed = findings_of(&r, lints::MISSED_COLLECTIVE);
        assert_eq!(missed.len(), 1, "node {node}: one per-buffer finding: {:?}", r.findings);
        assert!(missed[0].message.contains("collective"), "{}", missed[0].message);
    }

    let base = SchedulerConfig {
        num_nodes: 4,
        num_devices: 1,
        collectives: true,
        direct_comm: true,
        ..Default::default()
    };
    let (streams, buffers) = compile(base, all_gather);
    for (node, instructions) in &streams {
        let r = analyze_stream(*node, &buffers, instructions, &AnalyzeConfig::default());
        assert_eq!(
            findings_of(&r, lints::MISSED_COLLECTIVE).len(),
            0,
            "node {node}: {:?}",
            r.findings
        );
    }
}

// ── Hand-built streams: patterns the shipped scheduler never emits ──────

fn instr(id: u64, kind: InstructionKind, deps: &[u64]) -> InstructionRef {
    Arc::new(Instruction {
        id: InstructionId(id),
        kind,
        deps: deps.iter().map(|&d| (InstructionId(d), DepKind::Dataflow)).collect(),
        task: None,
    })
}

fn alloc(id: u64, a: u64, buffer: Option<BufferId>, covers: GridBox) -> InstructionRef {
    instr(
        id,
        InstructionKind::Alloc {
            alloc: AllocationId(a),
            memory: MemoryId(2),
            buffer,
            covers,
            size_bytes: covers.area() * 8,
        },
        &[],
    )
}

fn kernel(id: u64, a: u64, mode: AccessMode, region: GridBox, deps: &[u64]) -> InstructionRef {
    instr(
        id,
        InstructionKind::DeviceKernel {
            device: DeviceId(0),
            chunk: region,
            bindings: vec![AccessBinding {
                buffer: BufferId(0),
                mode,
                region: Region::from(region),
                alloc: AllocationId(a),
                alloc_box: region,
                dtype: celerity::dtype::DType::F64,
                lanes: 1,
            }],
            work_per_item: 1.0,
            kernel: None,
        },
        deps,
    )
}

#[test]
fn false_serialization_fires_exactly_once_on_seeded_edge() {
    let bx = GridBox::d1(0, 64);
    // K4 writes allocation 8 but carries a gratuitous edge to K3 (which
    // only ever touches allocation 7) — pure serialization on the
    // critical path.
    let stream = vec![
        alloc(1, 7, None, bx),
        alloc(2, 8, None, bx),
        kernel(3, 7, AccessMode::DiscardWrite, bx, &[1]),
        kernel(4, 8, AccessMode::DiscardWrite, bx, &[2, 3]),
    ];
    let r = analyze_stream(NodeId(0), &BufferPool::new(), &stream, &AnalyzeConfig::default());
    let fs = findings_of(&r, lints::FALSE_SERIALIZATION);
    assert_eq!(fs.len(), 1, "{:?}", r.findings);
    assert_eq!(fs[0].instr, Some(4));
}

#[test]
fn oversized_allocation_fires_exactly_once_on_sparse_use() {
    let big = GridBox::d1(0, 2048);
    let stream = vec![
        alloc(1, 7, Some(BufferId(0)), big),
        kernel(2, 7, AccessMode::DiscardWrite, GridBox::d1(0, 64), &[1]),
    ];
    let r = analyze_stream(NodeId(0), &BufferPool::new(), &stream, &AnalyzeConfig::default());
    let over = findings_of(&r, lints::OVERSIZED_ALLOCATION);
    assert_eq!(over.len(), 1, "{:?}", r.findings);
    assert_eq!(over[0].instr, Some(1));
}

#[test]
fn receive_staged_through_host_fires_on_receiver_side() {
    // Hand-built receiver stream: network payload lands in pinned host
    // memory, then hops to the device — the receive-side half of the
    // staged-copy detector (the compiled test above covers the send side
    // through the real lowering).
    let bx = GridBox::d1(0, 64);
    let stream = vec![
        alloc(1, 7, None, bx),
        instr(
            2,
            InstructionKind::Alloc {
                alloc: AllocationId(8),
                memory: MemoryId::HOST,
                buffer: None,
                covers: bx,
                size_bytes: bx.area() * 8,
            },
            &[],
        ),
        instr(
            3,
            InstructionKind::Receive {
                buffer: BufferId(0),
                region: Region::from(bx),
                dst_memory: MemoryId::HOST,
                dst_alloc: AllocationId(8),
                dst_box: bx,
                transfer: TaskId(0),
            },
            &[2],
        ),
        instr(
            4,
            InstructionKind::Copy {
                buffer: BufferId(0),
                copy_box: bx,
                src_memory: MemoryId::HOST,
                dst_memory: MemoryId(2),
                src_alloc: AllocationId(8),
                src_box: bx,
                dst_alloc: AllocationId(7),
                dst_box: bx,
            },
            &[3, 1],
        ),
    ];
    let r = analyze_stream(NodeId(0), &BufferPool::new(), &stream, &AnalyzeConfig::default());
    let staged = findings_of(&r, lints::STAGED_COPY);
    assert_eq!(staged.len(), 1, "{:?}", r.findings);
    assert_eq!(staged[0].instr, Some(4));
}

// ── Shipped shapes stay deny-clean (what CI's analyze-smoke enforces) ───

#[test]
fn shipped_workload_shapes_are_deny_clean_under_default_knobs() {
    let nbody = |tm: &mut TaskManager| {
        let r = Range::d1(256);
        let p = tm.create_buffer::<[f64; 3]>("P", r, true).id();
        let v = tm.create_buffer::<[f64; 3]>("V", r, true).id();
        for _ in 0..3 {
            tm.submit(
                TaskDecl::device("timestep", r)
                    .read(p, RangeMapper::All)
                    .read_write(v, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("update", r)
                    .read(v, RangeMapper::OneToOne)
                    .read_write(p, RangeMapper::OneToOne),
            );
        }
    };
    let wavesim = |tm: &mut TaskManager| {
        let n = Range::d2(32, 32);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        let b = tm.create_buffer::<f64>("B", n, true).id();
        for _ in 0..4 {
            tm.submit(
                TaskDecl::device("s", n)
                    .read(a, RangeMapper::Neighborhood(Range::d2(1, 1)))
                    .write(b, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("s", n)
                    .read(b, RangeMapper::Neighborhood(Range::d2(1, 1)))
                    .write(a, RangeMapper::OneToOne),
            );
        }
    };
    let apps: [(&str, &dyn Fn(&mut TaskManager)); 2] = [("nbody", &nbody), ("wavesim", &wavesim)];
    for (name, app) in apps {
        for nodes in [1u64, 2] {
            let base = SchedulerConfig { num_nodes: nodes, num_devices: 2, ..Default::default() };
            let (streams, buffers) = compile(base, app);
            let mut acfg = AnalyzeConfig::default();
            acfg.lints.set("all", LintLevel::Deny).expect("all is valid");
            for (node, instructions) in &streams {
                let r = analyze_stream(*node, &buffers, instructions, &acfg);
                assert_eq!(
                    r.deny_count(),
                    0,
                    "{name} on {nodes} node(s), node {node}: {:?}",
                    r.findings
                );
                assert!(r.critical_path > 0.0, "{name}: empty critical path");
            }
        }
    }
}
