//! Trace-schema acceptance: a traced 4-node run must produce a valid,
//! monotonic event stream covering (nearly) every retired instruction, a
//! well-formed Chrome JSON export, a critical-path dot dump, and a
//! meaningful scheduler-lag summary.
//!
//! Single #[test] on purpose: the trace recorder is process-global, so one
//! traced run per test binary keeps the event stream attributable.

use celerity::apps::{self, wavesim};
use celerity::driver::{run_cluster, ClusterConfig};
use celerity::trace;

#[test]
fn traced_4_node_run_satisfies_the_schema() {
    trace::enable();
    let cfg = ClusterConfig {
        num_nodes: 4,
        num_devices: 2,
        registry: apps::reference_registry(),
        ..Default::default()
    };
    let reports = run_cluster(cfg, |q| {
        let out = wavesim::submit(q, 32, 16, 4).expect("submit wavesim");
        q.fence_bytes(out.id()).expect("fence");
    });
    let tr = trace::drain();
    for r in &reports {
        assert!(r.errors.is_empty(), "node {}: {:?}", r.node, r.errors);
    }

    // Structural validity: span extents, per-track monotonicity, and
    // issue-before-retire pairing.
    tr.validate().expect("trace must satisfy the schema");
    assert_eq!(tr.nodes().len(), 4, "every node must contribute events");

    // Coverage: ≥95% of retired instructions appear as retire events
    // (in practice 100% — the margin only tolerates TLS-teardown races).
    let retired: u64 = reports.iter().map(|r| r.executor.retired).sum();
    let retire_events =
        tr.count(|e| matches!(e.kind, trace::EventKind::Retire { .. })) as u64;
    assert!(
        retire_events * 100 >= retired * 95,
        "retire coverage: {retire_events} events for {retired} retired instructions"
    );
    // Scheduler-side events made it out of the scheduler threads too.
    assert!(tr.count(|e| matches!(e.kind, trace::EventKind::SchedBatch { .. })) > 0);
    assert!(tr.count(|e| matches!(e.kind, trace::EventKind::Compiled { .. })) > 0);
    assert!(tr.count(|e| matches!(e.kind, trace::EventKind::TaskSubmit { .. })) > 0);
    // A 4-node stencil must exchange halos: comm events prove the inbound
    // path is instrumented.
    assert!(tr.count(|e| matches!(e.kind, trace::EventKind::DataIn { .. })) > 0);

    // Chrome export: metadata rows, complete events, instants.
    let json = trace::chrome::to_chrome_json(&tr);
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.ends_with("}\n") || json.ends_with('}'));
    for needle in ["\"ph\":\"M\"", "\"ph\":\"X\"", "\"ph\":\"i\"", "process_name", "thread_name"] {
        assert!(json.contains(needle), "chrome JSON must contain {needle}");
    }
    // Balanced braces/brackets — cheap well-formedness proxy without a
    // JSON parser dependency (scripts/check_trace.py does the real parse
    // in CI).
    let balance = |open: char, close: char| {
        json.chars().filter(|&c| c == open).count() == json.chars().filter(|&c| c == close).count()
    };
    assert!(balance('{', '}') && balance('[', ']'), "unbalanced JSON");

    // Graphviz export with a critical path.
    let dot = trace::dot::to_dot(&tr);
    assert!(dot.starts_with("digraph"));
    assert!(dot.contains("color=red"), "critical path must be annotated");

    // The derived summary metric sees the pipeline.
    let lag = tr.scheduler_lag();
    assert!(lag.instructions > 0, "scheduler_lag must cover instructions");
    assert!(lag.wall_ns > 0);
    let line = lag.to_string();
    assert!(line.contains("scheduler_lag:"), "{line}");
}
