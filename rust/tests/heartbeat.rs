//! Worker-liveness integration tests: a dead peer must produce a clean,
//! attributed cluster error within the heartbeat timeout (never a hang),
//! and a slow-but-alive peer must never be declared dead.

use celerity::apps::{self, nbody};
use celerity::comm::{CommRef, TcpWorld, Transport};
use celerity::driver::{run_node, ClusterConfig, NodeReport};
use celerity::executor::Registry;
use celerity::grid::Range;
use celerity::task::TaskDecl;
use celerity::util::NodeId;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bring up an N-node TCP mesh, never start the last node (its endpoint is
/// dropped — the moral equivalent of `kill -9` before the first fence),
/// and run a communicating program on the survivors.
fn run_with_dead_peer(num_nodes: u64) -> Vec<NodeReport> {
    let cfg = ClusterConfig {
        num_nodes,
        num_devices: 2,
        registry: apps::reference_registry(),
        transport: Transport::Tcp,
        heartbeat_timeout_ms: Some(800),
        ..Default::default()
    };
    let mut comms = TcpWorld::bind_local(num_nodes).expect("bind mesh").communicators();
    // Shrink the connect-retry grace so data sends to the dead peer fail
    // fast; the detection bound under test is the heartbeat timeout.
    for c in &mut comms {
        c.set_connect_grace(Duration::from_millis(300));
    }
    let victim = comms.pop().expect("at least one node");
    drop(victim);
    let mut joins = Vec::new();
    for (i, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || {
            let comm: CommRef = Arc::new(comm);
            run_node(&cfg, NodeId(i as u64), comm, |q| {
                // nbody reads every peer's positions each step, so without
                // liveness detection this would wait forever on receives
                // from the dead node. Errors surface through the report.
                if let Ok((p, _v)) = nbody::submit(q, 128, 2) {
                    let _ = q.fence_bytes(p.id());
                }
            })
        }));
    }
    joins.into_iter().map(|j| j.join().expect("node thread")).collect()
}

fn assert_dead_peer_detected(num_nodes: u64) {
    let dead = num_nodes - 1;
    let t0 = Instant::now();
    let reports = run_with_dead_peer(num_nodes);
    let wall = t0.elapsed();
    assert!(
        wall < Duration::from_secs(30),
        "{num_nodes}-node cluster with a dead peer took {wall:?} — detection must be bounded"
    );
    assert_eq!(reports.len(), (num_nodes - 1) as usize);
    for r in &reports {
        let attributed = r
            .errors
            .iter()
            .any(|e| e.contains("heartbeat timeout") && e.contains(&format!("node {dead}")));
        assert!(
            attributed,
            "node {} must report an attributed heartbeat failure for node {dead}, got {:?}",
            r.node, r.errors
        );
    }
}

#[test]
fn dead_peer_detected_2_nodes_tcp() {
    assert_dead_peer_detected(2);
}

#[test]
fn dead_peer_detected_4_nodes_tcp() {
    assert_dead_peer_detected(4);
}

/// A worker whose lanes are busy far longer than the heartbeat timeout is
/// *alive*: its executor thread keeps beating while the host lane sleeps,
/// so the run must finish with no liveness errors (no false positives).
#[test]
fn slow_but_alive_worker_is_not_declared_dead() {
    let registry = Registry::new();
    registry.register_host_task(
        "nap",
        Arc::new(|_ctx| std::thread::sleep(Duration::from_millis(1200))),
    );
    let cfg = ClusterConfig {
        num_nodes: 2,
        registry,
        transport: Transport::Tcp,
        // Timeout far below the nap: only the executor thread's own
        // beacons keep the peer alive.
        heartbeat_timeout_ms: Some(400),
        ..Default::default()
    };
    let comms = TcpWorld::bind_local(2).expect("bind mesh").communicators();
    let mut joins = Vec::new();
    for (i, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || {
            let comm: CommRef = Arc::new(comm);
            run_node(&cfg, NodeId(i as u64), comm, |q| {
                q.submit_decl(TaskDecl::host("nap", Range::d1(2)));
                q.wait().expect("slow-but-alive cluster must complete cleanly");
            })
        }));
    }
    for j in joins {
        let r = j.join().expect("node thread");
        assert!(r.errors.is_empty(), "node {}: {:?}", r.node, r.errors);
    }
}
