//! p2p-fallback geometries: workloads whose all-reads the collective
//! detector must REJECT — multi-box owner slices (a partial rewrite
//! fragments ownership) and partial replication (a halo read leaves
//! boundary elements on two nodes). With `collectives` enabled the
//! detector keeps the precise p2p lowering for these, so fence results
//! must be byte-identical with collectives on and off, at 2 and 4 nodes,
//! and equal to the 1-node run. The CDAG-level rejection itself is pinned
//! by unit tests in `src/command/mod.rs`; this file proves the fallback
//! executes correctly end to end.

use celerity::comm::Transport;
use celerity::driver::{run_cluster, ClusterConfig};
use celerity::executor::{KernelCtx, Registry};
use celerity::grid::{Point, Range};
use celerity::task::RangeMapper;
use std::sync::{Arc, Mutex};

const N: u64 = 64;

/// Kernels for the geometry workload. Full-buffer sums run sequentially
/// over `0..N` so the float accumulation order is identical on every
/// split — byte equality is the right bar.
fn geometry_registry() -> Registry {
    let r = Registry::new();
    r.register_kernel(
        "geo_iota",
        Arc::new(|ctx: &KernelCtx| {
            let a = ctx.view(0);
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                a.write_f32(Point::d1(i), i as f32 + 1.0);
            }
        }),
    );
    r.register_kernel(
        "geo_rewrite",
        Arc::new(|ctx: &KernelCtx| {
            let a = ctx.view(0);
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                a.write_f32(Point::d1(i), 100.0 - i as f32);
            }
        }),
    );
    // out[i] = sum(src) + i — reads `src` with an All mapper.
    r.register_kernel(
        "geo_gather",
        Arc::new(|ctx: &KernelCtx| {
            let (src, out) = (ctx.view(0), ctx.view(1));
            let mut sum = 0.0f32;
            for i in 0..N {
                sum += src.read_f32(Point::d1(i));
            }
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                out.write_f32(Point::d1(i), sum * 0.001 + i as f32);
            }
        }),
    );
    // out[i] = src[i-1] + src[i] + src[i+1] (zero boundary) — the halo
    // read that partially replicates `src`.
    r.register_kernel(
        "geo_halo",
        Arc::new(|ctx: &KernelCtx| {
            let (src, out) = (ctx.view(0), ctx.view(1));
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                let left = if i == 0 { 0.0 } else { src.read_f32(Point::d1(i - 1)) };
                let right = if i + 1 >= N { 0.0 } else { src.read_f32(Point::d1(i + 1)) };
                out.write_f32(Point::d1(i), left + src.read_f32(Point::d1(i)) + right);
            }
        }),
    );
    // out[i] = sum(all_src) + 3·elem_src[i] — All read plus an element-wise
    // read of a second buffer.
    r.register_kernel(
        "geo_combine",
        Arc::new(|ctx: &KernelCtx| {
            let (all_src, elem_src, out) = (ctx.view(0), ctx.view(1), ctx.view(2));
            let mut sum = 0.0f32;
            for i in 0..N {
                sum += all_src.read_f32(Point::d1(i));
            }
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                out.write_f32(
                    Point::d1(i),
                    sum * 0.001 + 3.0 * elem_src.read_f32(Point::d1(i)),
                );
            }
        }),
    );
    r
}

/// Run the chained geometry workload and return every node's fence bytes
/// of the final buffer (which depends on every earlier stage).
fn geometry_fences(nodes: u64, collectives: bool) -> Vec<Vec<u8>> {
    let cfg = ClusterConfig {
        num_nodes: nodes,
        num_devices: 2,
        registry: geometry_registry(),
        transport: Transport::Channel,
        collectives,
        ..Default::default()
    };
    let results: Arc<Mutex<Vec<Vec<u8>>>> = Arc::new(Mutex::new(Vec::new()));
    let rc = results.clone();
    let reports = run_cluster(cfg, move |q| {
        let range = Range::d1(N);
        let a = q.create_buffer::<f32>("A", range);
        let c = q.create_buffer::<f32>("C", range);
        let h = q.create_buffer::<f32>("H", range);
        let d = q.create_buffer::<f32>("D", range);
        q.submit(|cgh| {
            cgh.write(a, RangeMapper::OneToOne);
            cgh.parallel_for("geo_iota", range);
        })
        .expect("iota");
        // Fragment A's ownership: the prefix [0, N/4) is redistributed, so
        // owner slices stop coalescing to single boxes.
        q.submit(|cgh| {
            cgh.write(a, RangeMapper::OneToOne);
            cgh.parallel_for("geo_rewrite", Range::d1(N / 4));
        })
        .expect("rewrite");
        // All-read of the fragmented buffer → detector must reject
        // (multi-box owner slices) and fall back to p2p.
        q.submit(|cgh| {
            cgh.read(a, RangeMapper::All);
            cgh.write(c, RangeMapper::OneToOne);
            cgh.parallel_for("geo_gather", range);
        })
        .expect("gather");
        // Halo read of C: boundary elements become replicated on two nodes.
        q.submit(|cgh| {
            cgh.read(c, RangeMapper::Neighborhood(Range::d1(1)));
            cgh.write(h, RangeMapper::OneToOne);
            cgh.parallel_for("geo_halo", range);
        })
        .expect("halo");
        // All-read of the partially replicated buffer → detector must
        // reject (non-exclusive replication) and fall back to p2p.
        q.submit(|cgh| {
            cgh.read(c, RangeMapper::All);
            cgh.read(h, RangeMapper::OneToOne);
            cgh.write(d, RangeMapper::OneToOne);
            cgh.parallel_for("geo_combine", range);
        })
        .expect("combine");
        let bytes = q.fence_bytes(d.id()).expect("fence D");
        rc.lock().unwrap().push(bytes);
    });
    for r in &reports {
        assert!(
            r.errors.is_empty(),
            "{nodes} nodes (collectives={collectives}): node {} errors: {:?}",
            r.node,
            r.errors
        );
    }
    let results = results.lock().unwrap().clone();
    assert_eq!(results.len(), nodes as usize);
    for (i, f) in results.iter().enumerate() {
        assert_eq!(f.len() as u64, N * 4, "node {i} fence size");
    }
    results
}

/// Acceptance: rejected geometries are a no-op for the collectives flag —
/// byte-identical fences with collectives on vs off at 2 and 4 nodes, all
/// equal to the 1-node run.
#[test]
fn fallback_geometries_byte_identical_with_collectives_on_or_off() {
    let reference = geometry_fences(1, true);
    for nodes in [2u64, 4] {
        let with = geometry_fences(nodes, true);
        let without = geometry_fences(nodes, false);
        for i in 0..nodes as usize {
            assert_eq!(
                with[i], without[i],
                "{nodes} nodes: node {i} differs between collectives on/off"
            );
            assert_eq!(with[i], with[0], "{nodes} nodes: node {i} disagrees with node 0");
        }
        assert_eq!(with[0], reference[0], "{nodes} nodes vs 1-node reference");
    }
}
