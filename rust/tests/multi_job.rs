//! Multi-tenant cluster tests: concurrent jobs sharing one cluster.
//!
//! Tenant isolation is a *correctness* property, not just a scheduling
//! one: two jobs running concurrently on a shared cluster must produce
//! fence results byte-identical to each job's solo run (same transports,
//! same node counts), because job namespacing puts every task, command,
//! instruction, buffer and comm tag in a disjoint id space — nothing about
//! a co-tenant may leak into the numerics. On top of that, error
//! attribution (§4.4 errors surface only on the job that caused them) and
//! the fair-share starvation guarantee (a light job's fence completes
//! while a heavy job streams) are asserted directly.

use celerity::apps::{self, nbody, wavesim};
use celerity::comm::Transport;
use celerity::driver::{run_cluster, run_cluster_jobs, ClusterConfig, JobProgram, Queue};
use celerity::grid::Range;
use celerity::task::RangeMapper;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

const NB_N: u64 = 64;
const NB_STEPS: usize = 2;
const WS_ROWS: u64 = 16;
const WS_COLS: u64 = 8;
const WS_STEPS: usize = 2;

fn cfg(transport: Transport, nodes: u64) -> ClusterConfig {
    ClusterConfig::builder()
        .num_nodes(nodes)
        .num_devices(2)
        .registry(apps::reference_registry())
        .transport(transport)
        .build()
}

fn nbody_bytes(q: &mut Queue) -> Vec<u8> {
    let (p, _v) = nbody::submit(q, NB_N, NB_STEPS).expect("submit nbody");
    q.fence_bytes(p.id()).expect("fence P")
}

fn wavesim_bytes(q: &mut Queue) -> Vec<u8> {
    let out = wavesim::submit(q, WS_ROWS, WS_COLS, WS_STEPS).expect("submit wavesim");
    q.fence_bytes(out.id()).expect("fence U")
}

/// Run one app solo (single-tenant cluster) and return the fence bytes;
/// asserts all nodes agree among themselves first.
fn solo(c: ClusterConfig, app: fn(&mut Queue) -> Vec<u8>) -> Vec<u8> {
    let out: Arc<Mutex<Vec<(u64, Vec<u8>)>>> = Arc::default();
    let oc = out.clone();
    let reports = run_cluster(c, move |q| {
        let b = app(q);
        oc.lock().unwrap().push((q.node.0, b));
    });
    for r in &reports {
        assert!(r.errors.is_empty(), "solo node {}: {:?}", r.node, r.errors);
    }
    let mut res = out.lock().unwrap().clone();
    res.sort_by_key(|(n, _)| *n);
    let first = res[0].1.clone();
    for (n, b) in &res {
        assert_eq!(b, &first, "solo node {n} fence differs from node 0");
    }
    first
}

/// Run the given apps concurrently as jobs of one shared cluster per node;
/// returns fence bytes keyed by (job, node) and asserts no job errored.
fn concurrent(
    c: ClusterConfig,
    apps: Vec<fn(&mut Queue) -> Vec<u8>>,
) -> HashMap<(u64, u64), Vec<u8>> {
    let out: Arc<Mutex<HashMap<(u64, u64), Vec<u8>>>> = Arc::default();
    let programs: Vec<JobProgram> = apps
        .into_iter()
        .map(|app| {
            let oc = out.clone();
            Arc::new(move |q: &mut Queue| {
                let b = app(q);
                oc.lock().unwrap().insert((q.job().0, q.node.0), b);
            }) as JobProgram
        })
        .collect();
    let reports = run_cluster_jobs(c, programs).expect("bring up cluster transport");
    for r in &reports {
        for jr in &r.jobs {
            assert!(jr.errors.is_empty(), "node {} job {}: {:?}", r.node, jr.job, jr.errors);
        }
    }
    let res = out.lock().unwrap().clone();
    res
}

/// The core isolation check: nbody (job 0) and wavesim (job 1) running
/// concurrently must reproduce their solo fence bytes exactly, on every
/// node.
fn check_concurrent_matches_solo(transport: Transport, nodes: u64, fair: bool, limit: usize) {
    let what = format!(
        "{} nodes over {} (fair_share={fair}, admission_limit={limit})",
        nodes,
        transport.name()
    );
    let solo_nb = solo(cfg(transport, nodes), nbody_bytes);
    let solo_ws = solo(cfg(transport, nodes), wavesim_bytes);
    let c = ClusterConfig::builder()
        .num_nodes(nodes)
        .num_devices(2)
        .registry(apps::reference_registry())
        .transport(transport)
        .fair_share(fair)
        .admission_limit(limit)
        .build();
    let got = concurrent(c, vec![nbody_bytes, wavesim_bytes]);
    assert_eq!(got.len(), 2 * nodes as usize, "{what}: missing fences");
    for ((job, node), bytes) in &got {
        let want = if *job == 0 { &solo_nb } else { &solo_ws };
        assert_eq!(
            bytes, want,
            "{what}: job {job} on node {node} diverged from its solo run"
        );
    }
}

#[test]
fn two_jobs_match_solo_channel() {
    for nodes in [1, 2, 4] {
        check_concurrent_matches_solo(Transport::Channel, nodes, true, 0);
    }
}

#[test]
fn two_jobs_match_solo_tcp() {
    for nodes in [2, 4] {
        check_concurrent_matches_solo(Transport::Tcp, nodes, true, 0);
    }
}

/// Digest identity must survive the dispatch-policy knobs too: admission
/// throttling, the FIFO ablation, and both combined only reorder execution
/// within the dependency structure — never change results.
#[test]
fn throttled_and_fifo_modes_keep_digests() {
    check_concurrent_matches_solo(Transport::Channel, 2, true, 2);
    check_concurrent_matches_solo(Transport::Channel, 2, false, 0);
    check_concurrent_matches_solo(Transport::Channel, 2, false, 2);
}

/// §4.4 error attribution: a job that launches an unregistered kernel gets
/// the error on ITS `wait()` and in ITS `JobReport`; the co-tenant job's
/// fence succeeds with clean results and a clean report.
#[test]
fn job_errors_are_attributed_to_their_job() {
    let solo_ws = solo(cfg(Transport::Channel, 1), wavesim_bytes);
    let bad: JobProgram = Arc::new(|q: &mut Queue| {
        let b = q.create_buffer::<f32>("B", Range::d1(16));
        q.submit(|cgh| {
            cgh.write(b, RangeMapper::OneToOne);
            cgh.parallel_for("no_such_kernel", Range::d1(16));
        })
        .expect("submission itself is well-formed");
        let err = q.wait().expect_err("missing kernel must fail THIS job's wait");
        assert!(
            format!("{err}").contains("no_such_kernel"),
            "error must name the unregistered kernel: {err}"
        );
    });
    let ws_out: Arc<Mutex<Vec<u8>>> = Arc::default();
    let oc = ws_out.clone();
    let good: JobProgram = Arc::new(move |q: &mut Queue| {
        // The co-tenant's failure must be invisible here.
        *oc.lock().unwrap() = wavesim_bytes(q);
    });
    let reports =
        run_cluster_jobs(cfg(Transport::Channel, 1), vec![bad, good]).expect("bring up cluster");
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.jobs.len(), 2, "one report per job: {:?}", r.jobs);
    assert!(
        r.jobs[0].errors.iter().any(|e| e.contains("no_such_kernel")),
        "job 0's report must carry its kernel error: {:?}",
        r.jobs[0].errors
    );
    assert!(
        r.jobs[1].errors.is_empty(),
        "job 1 must not inherit job 0's error: {:?}",
        r.jobs[1].errors
    );
    assert_eq!(*ws_out.lock().unwrap(), solo_ws, "good job's fence must match its solo run");
}

/// Fair-share starvation guarantee: a light job's single fence completes
/// while a heavy co-tenant is still streaming work — the weighted
/// round-robin ring reaches the light job every quantum, and the admission
/// limit keeps the heavy job from monopolizing the in-flight window.
#[test]
fn light_job_fence_is_not_starved_by_heavy_job() {
    let t0 = Instant::now();
    let done: Arc<Mutex<HashMap<&'static str, f64>>> = Arc::default();
    let dh = done.clone();
    let heavy: JobProgram = Arc::new(move |q: &mut Queue| {
        let (p, _v) = nbody::submit(q, 256, 16).expect("submit heavy nbody");
        q.fence_bytes(p.id()).expect("fence heavy");
        dh.lock().unwrap().insert("heavy", t0.elapsed().as_secs_f64());
    });
    let dl = done.clone();
    let light: JobProgram = Arc::new(move |q: &mut Queue| {
        let out = wavesim::submit(q, 8, 8, 1).expect("submit light wavesim");
        q.fence_bytes(out.id()).expect("fence light");
        dl.lock().unwrap().insert("light", t0.elapsed().as_secs_f64());
    });
    let c = ClusterConfig::builder()
        .num_devices(2)
        .registry(apps::reference_registry())
        .fair_share(true)
        .admission_limit(4)
        .build();
    let reports = run_cluster_jobs(c, vec![heavy, light]).expect("bring up cluster");
    for jr in &reports[0].jobs {
        assert!(jr.errors.is_empty(), "job {}: {:?}", jr.job, jr.errors);
    }
    let done = done.lock().unwrap();
    let (light_t, heavy_t) = (done["light"], done["heavy"]);
    assert!(
        light_t <= heavy_t,
        "light job's fence ({light_t:.3}s) must complete while the heavy job streams \
         (finished {heavy_t:.3}s) — fair-share dispatch failed to interleave it"
    );
}
