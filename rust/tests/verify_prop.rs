//! Property suite for the static instruction-graph verifier.
//!
//! Randomized workloads (random buffer sizes, producer/consumer geometry,
//! horizon placement) are compiled through the full TDAG → CDAG → IDAG
//! pipeline on every node of a randomized cluster, under every combination
//! of the scheduler's lowering knobs (collectives, direct-comm, lookahead,
//! d2d). The graphs the generators emit are correct *by construction*; the
//! verifier re-derives correctness *by analysis* — so any violation on any
//! seed is a real bug in one of the two. The suite requires zero
//! violations from both the in-core verifier (absorbing batch by batch,
//! exactly as `--verify` runs it) and the post-hoc cluster-level
//! send/receive/collective matching.

use celerity::grid::{GridBox, Point, Range, Region};
use celerity::instruction::InstructionKind;
use celerity::scheduler::{Scheduler, SchedulerConfig};
use celerity::task::{RangeMapper, TaskDecl, TaskManager};
use celerity::util::{JobId, NodeId, XorShift64};
use celerity::verify::{verify_cluster, verify_stream, NodeStream, Verifier};

/// Build a random program against one buffer. The only constraint imposed
/// on the randomness is *user-level* correctness: the buffer is either
/// host-initialized or fully written before anything reads it, because an
/// uninitialized read is a genuine violation the verifier must flag.
fn random_program(rng: &mut XorShift64, tm: &mut TaskManager) {
    let len = rng.next_range(2, 8) * 4; // splittable across 1/2/4 nodes
    let n = Range::d1(len);
    let host_init = rng.chance(0.5);
    let b = tm.create_buffer::<f64>("B", n, host_init).id();
    if !host_init {
        // First task must produce every byte a later consumer may read.
        tm.submit(TaskDecl::device("init", n).write(b, RangeMapper::OneToOne));
    }
    for _ in 0..rng.next_range(1, 4) {
        // Random producer: full read-modify-write or partial window write.
        if rng.chance(0.7) {
            tm.submit(TaskDecl::device("w", n).read_write(b, RangeMapper::OneToOne));
        } else {
            let sub = rng.next_range(1, len);
            tm.submit(
                TaskDecl::device("wp", Range::d1(sub))
                    .write(b, RangeMapper::Shift(Point::d1(rng.next_below(len - sub + 1)))),
            );
        }
        // Random consumer geometry (drives all-gather/broadcast/p2p/ring
        // lowerings depending on the knobs).
        let mapper = match rng.next_below(4) {
            0 => RangeMapper::All,
            1 => RangeMapper::OneToOne,
            2 => {
                let lo = rng.next_below(len);
                let hi = rng.next_range(lo + 1, len);
                RangeMapper::Fixed(Region::from(GridBox::d1(lo, hi)))
            }
            _ => RangeMapper::Neighborhood(Range::d1(rng.next_range(1, 3))),
        };
        tm.submit(TaskDecl::device("r", n).read(b, mapper));
        if rng.chance(0.25) {
            tm.barrier();
        }
    }
}

/// Compile the program on every node of `base.num_nodes` with the in-core
/// verifier enabled, then run the post-hoc per-node and cluster-level
/// passes. Panics (with `ctx`) on any violation.
fn compile_and_verify(ctx: &str, tm: &mut TaskManager, base: SchedulerConfig) {
    tm.shutdown();
    let tasks = tm.take_new_tasks();
    let mut streams = Vec::new();
    for node in 0..base.num_nodes {
        let cfg = SchedulerConfig { node: NodeId(node), verify: true, ..base.clone() };
        let mut sched = Scheduler::new(cfg, tm.buffers().clone());
        let mut instructions = Vec::new();
        let mut pilots = Vec::new();
        for t in &tasks {
            let (is, ps) = sched.process(t);
            instructions.extend(is);
            pilots.extend(ps);
        }
        let (is, ps) = sched.flush_now();
        instructions.extend(is);
        pilots.extend(ps);
        let cmd_errors = sched.take_errors();
        assert!(cmd_errors.is_empty(), "{ctx} node {node}: {cmd_errors:?}");
        let idag_errors = sched.take_idag_errors();
        assert!(idag_errors.is_empty(), "{ctx} node {node}: {idag_errors:?}");
        // In-core pass: ran batch-by-batch exactly as `--verify` does.
        let violations = sched.take_verify_errors();
        assert!(violations.is_empty(), "{ctx} node {node}: {violations:?}");
        assert_eq!(
            sched.instructions_verified() as usize,
            instructions.len(),
            "{ctx} node {node}: verifier must price every instruction"
        );
        // Post-hoc pass over the complete stream must agree.
        let post =
            verify_stream(JobId(0), NodeId(node), tm.buffers().clone(), &instructions, &pilots);
        assert!(post.is_empty(), "{ctx} node {node} (post-hoc): {post:?}");
        // Incremental re-verification (tracking state compacted at verified
        // boundaries) must reach exactly the same verdict as a from-scratch
        // pass over the identical stream — here: none at all.
        let mut inc = Verifier::incremental(JobId(0), NodeId(node), tm.buffers().clone());
        inc.absorb_batch(&instructions, &pilots);
        let inc_v: Vec<String> =
            inc.take_violations().iter().map(|v| v.to_string()).collect();
        let mut full = Verifier::new(JobId(0), NodeId(node), tm.buffers().clone());
        full.absorb_batch(&instructions, &pilots);
        let full_v: Vec<String> =
            full.take_violations().iter().map(|v| v.to_string()).collect();
        assert_eq!(
            inc_v, full_v,
            "{ctx} node {node}: incremental and from-scratch verdicts must match"
        );
        assert!(inc_v.is_empty(), "{ctx} node {node} (incremental): {inc_v:?}");
        // Streams with a boundary past the start must actually have
        // compacted — otherwise the incremental mode silently degraded to
        // the from-scratch cost profile.
        let boundary_past_start = instructions
            .iter()
            .enumerate()
            .any(|(i, ins)| {
                i > 0 && matches!(ins.kind, InstructionKind::Horizon | InstructionKind::Epoch(_))
            });
        if boundary_past_start {
            assert!(
                inc.compacted_below() > 0,
                "{ctx} node {node}: incremental verifier never compacted"
            );
        }
        streams.push(NodeStream { node: NodeId(node), instructions, pilots });
    }
    let cluster = verify_cluster(&streams);
    assert!(cluster.is_empty(), "{ctx} (cluster): {cluster:?}");
}

/// ≥100 random seeds × randomized cluster shape × randomized knobs.
#[test]
fn random_programs_verify_clean_under_all_knobs() {
    for seed in 1..=120u64 {
        let mut rng = XorShift64::new(seed);
        let base = SchedulerConfig {
            num_nodes: [1, 2, 4][rng.next_below(3) as usize],
            num_devices: rng.next_range(1, 2),
            collectives: rng.chance(0.5),
            direct_comm: rng.chance(0.5),
            lookahead: rng.chance(0.5),
            d2d: rng.chance(0.5),
            ..Default::default()
        };
        let ctx = format!(
            "seed {seed}: nodes={} devices={} collectives={} direct_comm={} lookahead={} d2d={}",
            base.num_nodes,
            base.num_devices,
            base.collectives,
            base.direct_comm,
            base.lookahead,
            base.d2d
        );
        let mut tm = TaskManager::new();
        random_program(&mut rng, &mut tm);
        compile_and_verify(&ctx, &mut tm, base);
    }
}

/// The knob matrix exhaustively, on a fixed representative program — so a
/// knob-specific regression cannot hide behind the random knob coin.
#[test]
fn knob_matrix_verifies_clean_on_fixed_program() {
    for nodes in [1u64, 2, 4] {
        for collectives in [false, true] {
            for direct_comm in [false, true] {
                for lookahead in [false, true] {
                    let mut tm = TaskManager::new();
                    let n = Range::d1(64);
                    let b = tm.create_buffer::<f64>("B", n, true).id();
                    for _ in 0..3 {
                        tm.submit(
                            TaskDecl::device("step", n).read_write(b, RangeMapper::OneToOne),
                        );
                        tm.submit(TaskDecl::device("gather", n).read(b, RangeMapper::All));
                    }
                    let base = SchedulerConfig {
                        num_nodes: nodes,
                        num_devices: 2,
                        collectives,
                        direct_comm,
                        lookahead,
                        ..Default::default()
                    };
                    let ctx = format!(
                        "fixed program: nodes={nodes} collectives={collectives} \
                         direct_comm={direct_comm} lookahead={lookahead}"
                    );
                    compile_and_verify(&ctx, &mut tm, base);
                }
            }
        }
    }
}

/// Horizon pruning must stay sound under verification: a long chain with an
/// aggressive horizon step exercises the boundary-domination check and the
/// verifier's ancestor-set collapse.
#[test]
fn long_chain_with_tight_horizons_verifies_clean() {
    for nodes in [1u64, 2] {
        let mut tm = TaskManager::with_horizon_step(2);
        let n = Range::d1(32);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        for _ in 0..24 {
            tm.submit(TaskDecl::device("step", n).read_write(b, RangeMapper::OneToOne));
        }
        let base = SchedulerConfig { num_nodes: nodes, num_devices: 2, ..Default::default() };
        compile_and_verify(&format!("horizon chain: nodes={nodes}"), &mut tm, base);
    }
}
