//! End-to-end tests of `celerity launch`: real worker processes over real
//! sockets, digest cross-checking, and killed-worker attribution.

use std::process::Command;
use std::time::{Duration, Instant};

const EXE: &str = env!("CARGO_BIN_EXE_celerity");

/// Parse every digest-marker line out of a stdout capture.
fn digest_markers(stdout: &str) -> Vec<(u64, u64)> {
    stdout
        .lines()
        .filter_map(|l| {
            // `launch` prefixes streamed worker lines with "[node i] ".
            let l = l.split("] ").last().unwrap_or(l);
            celerity::launch::parse_digest_marker(l)
        })
        .collect()
}

#[test]
fn launch_two_nodes_runs_to_matching_digests() {
    let out = Command::new(EXE)
        .args([
            "launch", "-n", "2", "--heartbeat-timeout", "8000", "--", "nbody", "--steps", "2",
        ])
        .output()
        .expect("spawn celerity launch");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "launch must exit 0\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    let digests = digest_markers(&stdout);
    assert_eq!(digests.len(), 2, "one marker per node\nstdout:\n{stdout}");
    assert_eq!(digests[0].1, digests[1].1, "fence digests must agree");
    assert!(stdout.contains("digests_agree=true"), "stdout:\n{stdout}");
}

/// Killing one worker mid-run must fail the whole launch with an error
/// attributing the dead node — within the heartbeat timeout, not after a
/// transport-level hang.
#[test]
fn launch_with_killed_worker_fails_attributed_and_bounded() {
    let t0 = Instant::now();
    let out = Command::new(EXE)
        .args([
            "launch",
            "-n",
            "2",
            "--heartbeat-timeout",
            "1500",
            "--",
            "nbody",
            "--steps",
            "2000",
            "--fault-node",
            "1",
            "--fault-exit-after",
            "800",
        ])
        .output()
        .expect("spawn celerity launch");
    let wall = t0.elapsed();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        !out.status.success(),
        "a killed worker must fail the launch\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        wall < Duration::from_secs(60),
        "launch with a dead worker took {wall:?} — must be bounded by the heartbeat timeout"
    );
    // The launcher attributes the dead node's exit...
    assert!(
        stderr.contains("node 1 exited with code 3"),
        "stderr must attribute the injected fault:\n{stderr}"
    );
    // ...and the survivor reports the death attributed to node 1 — via the
    // heartbeat detector or, when its own sends hit the dead peer first,
    // via the comm fabric's faster peer-lost escalation.
    assert!(
        (stderr.contains("heartbeat timeout") || stderr.contains("lost contact with node 1"))
            && stderr.contains("node 1"),
        "survivor must report an attributed peer death:\n{stderr}"
    );
}

/// A `kill=` fault-plan site hard-kills one worker mid-run. With the
/// survivors' heartbeat detectors deliberately configured far slower than
/// the fail-fast grace window, the *launcher* must bound the run: kill the
/// stragglers after the grace window, name the dead node first in the
/// error list, and exit nonzero.
#[test]
fn launch_with_kill_plan_fails_fast_and_bounded() {
    let t0 = Instant::now();
    let out = Command::new(EXE)
        .args([
            "launch",
            "-n",
            "2",
            // Sluggish heartbeats: fail-fast, not liveness detection, must
            // be what bounds this run.
            "--heartbeat-timeout",
            "120000",
            "--fail-fast-grace",
            "1500",
            "--fault-plan",
            "seed=1 kill=node1@frame1",
            "--",
            "nbody",
            "--steps",
            "2000",
        ])
        .output()
        .expect("spawn celerity launch");
    let wall = t0.elapsed();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        !out.status.success(),
        "a killed worker must fail the launch\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        wall < Duration::from_secs(60),
        "fail-fast must bound the run despite the 120 s heartbeat timeout (took {wall:?})"
    );
    // The kill watcher exits 3 when the plan site trips, and the launcher
    // reports that root cause as its FIRST error line — before whatever
    // happened to the survivor downstream.
    let first_error = stderr
        .lines()
        .find(|l| l.starts_with("[launch] "))
        .unwrap_or_else(|| panic!("launcher must report errors:\n{stderr}"));
    assert!(
        first_error.contains("node 1 exited with code 3"),
        "root-cause node must be reported first, got '{first_error}':\n{stderr}"
    );
    // The survivor must not outlive the cluster: either the launcher's
    // grace window expired and killed it, or the comm fabric escalated the
    // peer loss and the worker aborted attributed on its own (both are
    // legitimate — which wins is a timing race by design).
    assert!(
        stderr.contains("terminated by fail-fast")
            || stderr.contains("node 0 exited with code 1"),
        "survivor must be reaped by fail-fast or abort attributed:\n{stderr}"
    );
}

/// `--no-fail-fast` restores the old behavior: the launcher waits for the
/// survivors' own heartbeat detectors (configured fast here, so the run
/// stays bounded) instead of killing anything itself.
#[test]
fn launch_no_fail_fast_defers_to_heartbeats() {
    let out = Command::new(EXE)
        .args([
            "launch",
            "-n",
            "2",
            "--no-fail-fast",
            "--heartbeat-timeout",
            "1500",
            "--fault-plan",
            "seed=1 kill=node1@frame1",
            "--",
            "nbody",
            "--steps",
            "2000",
        ])
        .output()
        .expect("spawn celerity launch");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(
        !stderr.contains("terminated by fail-fast"),
        "--no-fail-fast must not kill survivors:\n{stderr}"
    );
    // The survivor winds down through its own (heartbeat or peer-lost)
    // detector, attributing node 1.
    assert!(
        stderr.contains("node 1"),
        "survivor must attribute the dead peer:\n{stderr}"
    );
}
