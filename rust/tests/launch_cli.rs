//! End-to-end tests of `celerity launch`: real worker processes over real
//! sockets, digest cross-checking, and killed-worker attribution.

use std::process::Command;
use std::time::{Duration, Instant};

const EXE: &str = env!("CARGO_BIN_EXE_celerity");

/// Parse every digest-marker line out of a stdout capture.
fn digest_markers(stdout: &str) -> Vec<(u64, u64)> {
    stdout
        .lines()
        .filter_map(|l| {
            // `launch` prefixes streamed worker lines with "[node i] ".
            let l = l.split("] ").last().unwrap_or(l);
            celerity::launch::parse_digest_marker(l)
        })
        .collect()
}

#[test]
fn launch_two_nodes_runs_to_matching_digests() {
    let out = Command::new(EXE)
        .args([
            "launch", "-n", "2", "--heartbeat-timeout", "8000", "--", "nbody", "--steps", "2",
        ])
        .output()
        .expect("spawn celerity launch");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "launch must exit 0\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    let digests = digest_markers(&stdout);
    assert_eq!(digests.len(), 2, "one marker per node\nstdout:\n{stdout}");
    assert_eq!(digests[0].1, digests[1].1, "fence digests must agree");
    assert!(stdout.contains("digests_agree=true"), "stdout:\n{stdout}");
}

/// Killing one worker mid-run must fail the whole launch with an error
/// attributing the dead node — within the heartbeat timeout, not after a
/// transport-level hang.
#[test]
fn launch_with_killed_worker_fails_attributed_and_bounded() {
    let t0 = Instant::now();
    let out = Command::new(EXE)
        .args([
            "launch",
            "-n",
            "2",
            "--heartbeat-timeout",
            "1500",
            "--",
            "nbody",
            "--steps",
            "2000",
            "--fault-node",
            "1",
            "--fault-exit-after",
            "800",
        ])
        .output()
        .expect("spawn celerity launch");
    let wall = t0.elapsed();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        !out.status.success(),
        "a killed worker must fail the launch\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        wall < Duration::from_secs(60),
        "launch with a dead worker took {wall:?} — must be bounded by the heartbeat timeout"
    );
    // The launcher attributes the dead node's exit...
    assert!(
        stderr.contains("node 1 exited with code 3"),
        "stderr must attribute the injected fault:\n{stderr}"
    );
    // ...and the survivor reports the heartbeat-detected death, also
    // naming node 1.
    assert!(
        stderr.contains("heartbeat timeout") && stderr.contains("node 1"),
        "survivor must report an attributed heartbeat failure:\n{stderr}"
    );
}
