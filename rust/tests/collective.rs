//! Collective-group lowering: cross-path byte-identity tests.
//!
//! The collective ring is an *optimization of the lowering*, not of the
//! numerics: an all-gather executed as n−1 ring rounds must hand every
//! kernel exactly the bytes the p2p push/await-push protocol would have —
//! so nbody's fence results are required to be bitwise identical between
//! the two lowerings, across node counts and across both transports.

use celerity::apps::{self, nbody};
use celerity::comm::Transport;
use celerity::driver::{run_cluster, ClusterConfig};
use std::sync::{Arc, Mutex};

const BODIES: u64 = 64;
const STEPS: usize = 3;

/// Run nbody on a live cluster; returns every node's fence bytes of P.
fn nbody_fences(transport: Transport, nodes: u64, collectives: bool) -> Vec<Vec<u8>> {
    let cfg = ClusterConfig {
        num_nodes: nodes,
        num_devices: 2,
        registry: apps::reference_registry(),
        transport,
        collectives,
        ..Default::default()
    };
    let results: Arc<Mutex<Vec<Vec<u8>>>> = Arc::new(Mutex::new(Vec::new()));
    let rc = results.clone();
    let reports = run_cluster(cfg, move |q| {
        let (p, _v) = nbody::submit(q, BODIES, STEPS).expect("submit nbody");
        let bytes = q.fence_bytes(p.id()).expect("fence P");
        rc.lock().unwrap().push(bytes);
    });
    for r in &reports {
        assert!(
            r.errors.is_empty(),
            "{nodes} nodes over {} (collectives={collectives}): node {} errors: {:?}",
            transport.name(),
            r.node,
            r.errors
        );
    }
    let results = results.lock().unwrap().clone();
    assert_eq!(results.len(), nodes as usize);
    for (i, f) in results.iter().enumerate() {
        assert_eq!(f.len() as u64, BODIES * 12, "node {i} fence size");
    }
    results
}

/// Acceptance criterion: fence digests byte-identical between the
/// collective and the p2p lowering, for nbody at 2 and 4 nodes, over both
/// transports.
#[test]
fn nbody_collective_byte_identical_to_p2p_both_transports() {
    let reference = nbody_fences(Transport::Channel, 1, true);
    for nodes in [2u64, 4] {
        for transport in [Transport::Channel, Transport::Tcp] {
            let p2p = nbody_fences(transport, nodes, false);
            let coll = nbody_fences(transport, nodes, true);
            for i in 0..nodes as usize {
                assert_eq!(
                    coll[i], p2p[i],
                    "{nodes} nodes over {}: node {i} collective fence differs from p2p",
                    transport.name()
                );
                assert_eq!(
                    coll[i], coll[0],
                    "{nodes} nodes over {}: node {i} disagrees with node 0",
                    transport.name()
                );
            }
            // And both match the single-node run (no comm at all).
            assert_eq!(
                coll[0],
                reference[0],
                "{nodes} nodes over {}: collective result differs from 1-node run",
                transport.name()
            );
        }
    }
}

/// The collective path must still match the sequential golden model (guards
/// against a bug identical in both lowerings).
#[test]
fn nbody_collective_matches_reference_model() {
    let got = nbody_fences(Transport::Tcp, 4, true);
    let want = nbody::reference(BODIES as usize, STEPS);
    let got_f32: Vec<f32> = got[0]
        .chunks_exact(4)
        .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    assert_eq!(got_f32.len(), want.len());
    for i in 0..want.len() {
        assert!(
            (got_f32[i] - want[i]).abs() < 1e-4,
            "element {i}: {} vs {}",
            got_f32[i],
            want[i]
        );
    }
}

/// wavesim (stencil halo) never matches the collective pattern: enabling
/// collectives must not change its lowering or results.
#[test]
fn wavesim_unaffected_by_collectives_flag() {
    use celerity::apps::wavesim;
    let run = |collectives: bool| {
        let cfg = ClusterConfig {
            num_nodes: 2,
            num_devices: 2,
            registry: apps::reference_registry(),
            transport: Transport::Channel,
            collectives,
            ..Default::default()
        };
        let out: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        let oc = out.clone();
        let reports = run_cluster(cfg, move |q| {
            let b = wavesim::submit(q, 32, 16, 4).expect("submit wavesim");
            let bytes = q.fence_bytes(b.id()).expect("fence");
            if q.node.0 == 0 {
                *oc.lock().unwrap() = bytes;
            }
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "{:?}", r.errors);
        }
        out.lock().unwrap().clone()
    };
    assert_eq!(run(true), run(false));
}
