//! Chaos property tests for the comm fabric (ISSUE 7 tentpole criterion):
//! under a deterministic seeded fault plan — drops, delays, duplicates and
//! corruption at the wire level, plus a mid-run stream break — a TCP
//! cluster must produce fence digests **byte-identical** to a fault-free
//! single-node run. The CRC/seq/ack-retransmit layer repairs every
//! injected fault transparently; anything less shows up here as a digest
//! mismatch or an unexpected runtime error.
//!
//! The seed sweep is split across several `#[test]` functions so the
//! harness runs the slices in parallel; together they cover 64 seeds
//! alternating app (wavesim/nbody) and cluster size (2/4 nodes), with a
//! `break=` site armed on every fourth seed.

use celerity::apps;
use celerity::comm::Transport;
use celerity::driver::{try_run_cluster, ClusterConfig, Queue};
use celerity::fault::FaultPlan;
use std::sync::{Arc, Mutex, OnceLock};

/// FNV-1a — same digest the `celerity run`/`worker` CLIs print, so a
/// failure here is directly comparable to a CLI reproduction.
fn digest(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Submit one of the two benchmark apps (sized down for test latency) and
/// fence its result buffer.
fn app_bytes(q: &mut Queue, app: &str) -> Vec<u8> {
    match app {
        "wavesim" => {
            let out = apps::wavesim::submit(q, 32, 32, 3).expect("submit wavesim");
            q.fence_bytes(out.id()).expect("fence wavesim")
        }
        "nbody" => {
            let (p, _v) = apps::nbody::submit(q, 128, 2).expect("submit nbody");
            q.fence_bytes(p.id()).expect("fence nbody")
        }
        other => panic!("unknown test app {other}"),
    }
}

/// Run `app` on `nodes` nodes and return every node's fence digest.
/// Panics on any runtime error — under an *active* plan the fabric must
/// repair faults without surfacing errors.
fn run_digests(app: &'static str, nodes: u64, plan: Option<FaultPlan>) -> Vec<u64> {
    let cfg = ClusterConfig {
        num_nodes: nodes,
        num_devices: 2,
        registry: apps::reference_registry(),
        transport: Transport::Tcp,
        // Tight beacons (500 ms interval) keep tail-loss nudge-retransmit
        // latency low; generous enough not to false-positive under load.
        heartbeat_timeout_ms: Some(2_000),
        fault_plan: plan,
        ..Default::default()
    };
    let digests: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let dc = digests.clone();
    let reports = try_run_cluster(cfg, move |q| {
        let bytes = app_bytes(q, app);
        dc.lock().unwrap().push(digest(&bytes));
    })
    .expect("bind loopback TCP mesh");
    for r in &reports {
        assert!(
            r.errors.is_empty(),
            "node {} reported errors under app={app} nodes={nodes}: {:?}",
            r.node,
            r.errors
        );
    }
    let got = digests.lock().unwrap().clone();
    assert_eq!(got.len(), nodes as usize, "every node must fence");
    got
}

/// Fault-free single-node reference digest per app, computed once.
fn reference(app: &'static str) -> u64 {
    static WAVESIM: OnceLock<u64> = OnceLock::new();
    static NBODY: OnceLock<u64> = OnceLock::new();
    let cell = match app {
        "wavesim" => &WAVESIM,
        "nbody" => &NBODY,
        other => panic!("unknown test app {other}"),
    };
    *cell.get_or_init(|| {
        let cfg = ClusterConfig {
            num_nodes: 1,
            num_devices: 2,
            registry: apps::reference_registry(),
            ..Default::default()
        };
        let out: Arc<Mutex<u64>> = Arc::new(Mutex::new(0));
        let oc = out.clone();
        try_run_cluster(cfg, move |q| {
            *oc.lock().unwrap() = digest(&app_bytes(q, app));
        })
        .expect("single-node reference run");
        let d = *out.lock().unwrap();
        d
    })
}

/// One seed of the sweep: app and node count alternate with the seed, a
/// stream-break site arms on every fourth seed, and every digest must
/// equal the fault-free reference.
fn check_seed(seed: u64) {
    let app = if seed % 2 == 0 { "wavesim" } else { "nbody" };
    let nodes = if seed % 4 < 2 { 2 } else { 4 };
    let mut spec = format!("seed={seed} drop=0.02 delay=0..1ms dup=0.01 corrupt=0.005");
    if seed % 4 == 3 {
        spec.push_str(" break=node1@frame7");
    }
    let plan = FaultPlan::parse(&spec).expect("valid plan spec");
    let want = reference(app);
    for (node, got) in run_digests(app, nodes, Some(plan)).into_iter().enumerate() {
        assert_eq!(
            got, want,
            "seed {seed}: node {node} digest {got:016x} != fault-free reference \
             {want:016x} (app={app} nodes={nodes} plan=\"{spec}\")"
        );
    }
}

fn check_seed_range(lo: u64, hi: u64) {
    for seed in lo..hi {
        check_seed(seed);
    }
}

#[test]
fn chaos_digests_match_reference_seeds_00_15() {
    check_seed_range(0, 16);
}

#[test]
fn chaos_digests_match_reference_seeds_16_31() {
    check_seed_range(16, 32);
}

#[test]
fn chaos_digests_match_reference_seeds_32_47() {
    check_seed_range(32, 48);
}

#[test]
fn chaos_digests_match_reference_seeds_48_63() {
    check_seed_range(48, 64);
}

/// Same plan, same program, run twice: the injector is a pure function of
/// (seed, node, peer, frame index), so both runs see identical faults and
/// both match the reference. A nondeterministic injector would make chaos
/// failures unreproducible.
#[test]
fn fault_injection_is_deterministic_across_runs() {
    let plan = FaultPlan::parse("seed=99 drop=0.05 dup=0.02 corrupt=0.01").expect("plan");
    let a = run_digests("wavesim", 2, Some(plan.clone()));
    let b = run_digests("wavesim", 2, Some(plan));
    assert_eq!(a, b, "same plan must reproduce the same outcome");
    assert!(a.iter().all(|d| *d == reference("wavesim")));
}

/// An inactive plan (all probabilities zero, no sites) must not disturb a
/// TCP run — the driver skips injector installation entirely.
#[test]
fn inactive_plan_is_transparent() {
    let plan = FaultPlan::parse("seed=5").expect("plan");
    let got = run_digests("nbody", 2, Some(plan));
    assert!(got.iter().all(|d| *d == reference("nbody")), "{got:?}");
}
