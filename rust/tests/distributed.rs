//! Cross-transport distributed-execution tests.
//!
//! The transport is a pluggable fabric underneath the exact same
//! pilot/receive-arbitration protocol, so the application-visible results
//! must be *byte-identical* across (a) transports and (b) cluster sizes:
//! wavesim is a float stencil whose per-element operation order is fixed
//! by the kernel, making bitwise equality the right bar (any divergence
//! means a fragment landed at the wrong offset or a transfer was dropped).

use celerity::apps::{self, nbody, wavesim};
use celerity::comm::{CommRef, TcpWorld, Transport};
use celerity::driver::{run_cluster, run_node, ClusterConfig, Queue};
use celerity::util::NodeId;
use std::sync::{Arc, Mutex};

const ROWS: u64 = 32;
const COLS: u64 = 16;
const STEPS: usize = 4;

/// Run `submit` on a live cluster under `cfg` and return every node's
/// fence bytes (all nodes fence the same buffer).
fn cluster_fences(
    cfg: ClusterConfig,
    expected_bytes: u64,
    submit: impl Fn(&mut Queue) -> Vec<u8> + Send + Sync + 'static,
) -> Vec<Vec<u8>> {
    let nodes = cfg.num_nodes;
    let what = format!(
        "{} nodes over {} (direct_comm={}, collectives={})",
        nodes,
        cfg.transport.name(),
        cfg.direct_comm,
        cfg.collectives
    );
    let results: Arc<Mutex<Vec<Vec<u8>>>> = Arc::new(Mutex::new(Vec::new()));
    let rc = results.clone();
    let reports = run_cluster(cfg, move |q| {
        let bytes = submit(q);
        rc.lock().unwrap().push(bytes);
    });
    for r in &reports {
        assert!(r.errors.is_empty(), "{what}: node {} errors: {:?}", r.node, r.errors);
    }
    let results = results.lock().unwrap().clone();
    assert_eq!(results.len(), nodes as usize);
    for (i, f) in results.iter().enumerate() {
        assert_eq!(f.len() as u64, expected_bytes, "{what}: node {i} fence size");
    }
    results
}

fn wavesim_cfg(transport: Transport, nodes: u64, devices: u64, direct: bool) -> ClusterConfig {
    ClusterConfig {
        num_nodes: nodes,
        num_devices: devices,
        registry: apps::reference_registry(),
        transport,
        direct_comm: direct,
        ..Default::default()
    }
}

/// Run wavesim on a live cluster and return every node's fence bytes.
fn wavesim_fences(transport: Transport, nodes: u64, devices: u64) -> Vec<Vec<u8>> {
    wavesim_fences_direct(transport, nodes, devices, true)
}

fn wavesim_fences_direct(
    transport: Transport,
    nodes: u64,
    devices: u64,
    direct: bool,
) -> Vec<Vec<u8>> {
    cluster_fences(wavesim_cfg(transport, nodes, devices, direct), ROWS * COLS * 4, |q| {
        let out = wavesim::submit(q, ROWS, COLS, STEPS).expect("submit wavesim");
        q.fence_bytes(out.id()).expect("fence")
    })
}

/// Run nbody over the p2p lowering (collectives off, so push/await-push —
/// the path direct device transfers specialize) and fence the positions.
fn nbody_fences_direct(transport: Transport, nodes: u64, direct: bool) -> Vec<Vec<u8>> {
    const N: u64 = 128;
    let cfg = ClusterConfig {
        num_nodes: nodes,
        num_devices: 2,
        registry: apps::reference_registry(),
        transport,
        collectives: false,
        direct_comm: direct,
        ..Default::default()
    };
    cluster_fences(cfg, N * 12, move |q| {
        let (p, _v) = nbody::submit(q, N, 2).expect("submit nbody");
        q.fence_bytes(p.id()).expect("fence P")
    })
}

/// All nodes of one run must agree among themselves (each node fences the
/// full field, assembled from every peer's fragments).
fn assert_all_equal(fences: &[Vec<u8>], what: &str) {
    for (i, f) in fences.iter().enumerate() {
        assert_eq!(
            f.as_slice(),
            fences[0].as_slice(),
            "{what}: node {i} fence differs from node 0"
        );
    }
}

#[test]
fn wavesim_2_nodes_identical_across_transports() {
    let chan = wavesim_fences(Transport::Channel, 2, 2);
    let tcp = wavesim_fences(Transport::Tcp, 2, 2);
    assert_all_equal(&chan, "channel 2-node");
    assert_all_equal(&tcp, "tcp 2-node");
    assert_eq!(
        chan[0], tcp[0],
        "ChannelWorld and TCP transports must produce identical fence results"
    );
}

/// Acceptance criterion: wavesim on 4 simulated nodes yields fence results
/// byte-identical to the 1-node run, over both transports.
#[test]
fn wavesim_4_nodes_byte_identical_to_single_node_both_transports() {
    let single = wavesim_fences(Transport::Channel, 1, 2);
    for transport in [Transport::Channel, Transport::Tcp] {
        let four = wavesim_fences(transport, 4, 2);
        assert_all_equal(&four, transport.name());
        assert_eq!(
            four[0],
            single[0],
            "4-node {} run must be byte-identical to the 1-node run",
            transport.name()
        );
    }
}

/// The per-process entry point (`run_node` + an explicitly-built TCP
/// communicator — what each `celerity worker` process executes) produces
/// the same bytes as the `run_cluster` convenience path.
#[test]
fn run_node_over_explicit_tcp_endpoints_matches_cluster() {
    let cfg = ClusterConfig {
        num_nodes: 2,
        num_devices: 2,
        registry: apps::reference_registry(),
        transport: Transport::Tcp,
        ..Default::default()
    };
    let comms = TcpWorld::bind_local(2).expect("bind mesh").communicators();
    let mut joins = Vec::new();
    for (i, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || {
            let comm: CommRef = Arc::new(comm);
            let fence: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
            let fc = fence.clone();
            let report = run_node(&cfg, NodeId(i as u64), comm, move |q| {
                let out = wavesim::submit(q, ROWS, COLS, STEPS).expect("submit wavesim");
                *fc.lock().unwrap() = q.fence_bytes(out.id()).expect("fence");
            });
            assert!(report.errors.is_empty(), "node {i}: {:?}", report.errors);
            let bytes = fence.lock().unwrap().clone();
            bytes
        }));
    }
    let fences: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().expect("node")).collect();
    assert_all_equal(&fences, "run_node tcp");
    let via_cluster = wavesim_fences(Transport::Channel, 1, 2);
    assert_eq!(fences[0], via_cluster[0], "run_node path must match run_cluster");
}

/// Acceptance: direct device transfers are a pure lowering change — fence
/// digests must be byte-identical with `--no-direct-comm` on/off at 2 and
/// 4 nodes over both transports, for the stencil workload (wavesim, p2p
/// push/await-push with consumer-split fallbacks)...
#[test]
fn wavesim_direct_vs_staged_byte_identical() {
    let reference = wavesim_fences_direct(Transport::Channel, 1, 2, true);
    for transport in [Transport::Channel, Transport::Tcp] {
        for nodes in [2u64, 4] {
            for direct in [true, false] {
                let fences = wavesim_fences_direct(transport, nodes, 2, direct);
                let what = format!(
                    "wavesim {} nodes over {} direct={direct}",
                    nodes,
                    transport.name()
                );
                assert_all_equal(&fences, &what);
                assert_eq!(fences[0], reference[0], "{what} vs 1-node reference");
            }
        }
    }
}

/// ...and for the all-gather workload (nbody over the p2p lowering, where
/// whole device-resident halves are pushed every timestep).
#[test]
fn nbody_p2p_direct_vs_staged_byte_identical() {
    let reference = nbody_fences_direct(Transport::Channel, 1, true);
    for transport in [Transport::Channel, Transport::Tcp] {
        for nodes in [2u64, 4] {
            for direct in [true, false] {
                let fences = nbody_fences_direct(transport, nodes, direct);
                let what = format!(
                    "nbody {} nodes over {} direct={direct}",
                    nodes,
                    transport.name()
                );
                assert_all_equal(&fences, &what);
                assert_eq!(fences[0], reference[0], "{what} vs 1-node reference");
            }
        }
    }
}

/// The golden model agrees too (guards against a bug identical on all
/// cluster shapes).
#[test]
fn wavesim_cluster_matches_reference_model() {
    let got = wavesim_fences(Transport::Tcp, 2, 2);
    let want = wavesim::reference(ROWS as usize, COLS as usize, STEPS);
    let got_f32: Vec<f32> = got[0]
        .chunks_exact(4)
        .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    for i in 0..want.len() {
        assert!(
            (got_f32[i] - want[i]).abs() < 1e-4,
            "element {i}: {} vs {}",
            got_f32[i],
            want[i]
        );
    }
}
