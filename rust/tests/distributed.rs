//! Cross-transport distributed-execution tests.
//!
//! The transport is a pluggable fabric underneath the exact same
//! pilot/receive-arbitration protocol, so the application-visible results
//! must be *byte-identical* across (a) transports and (b) cluster sizes:
//! wavesim is a float stencil whose per-element operation order is fixed
//! by the kernel, making bitwise equality the right bar (any divergence
//! means a fragment landed at the wrong offset or a transfer was dropped).

use celerity::apps::{self, wavesim};
use celerity::comm::{CommRef, TcpWorld, Transport};
use celerity::driver::{run_cluster, run_node, ClusterConfig};
use celerity::util::NodeId;
use std::sync::{Arc, Mutex};

const ROWS: u64 = 32;
const COLS: u64 = 16;
const STEPS: usize = 4;

/// Run wavesim on a live cluster and return every node's fence bytes.
fn wavesim_fences(transport: Transport, nodes: u64, devices: u64) -> Vec<Vec<u8>> {
    let cfg = ClusterConfig {
        num_nodes: nodes,
        num_devices: devices,
        registry: apps::reference_registry(),
        transport,
        ..Default::default()
    };
    let results: Arc<Mutex<Vec<Vec<u8>>>> = Arc::new(Mutex::new(Vec::new()));
    let rc = results.clone();
    let reports = run_cluster(cfg, move |q| {
        let out = wavesim::submit(q, ROWS, COLS, STEPS).expect("submit wavesim");
        let bytes = q.fence_bytes(out.id()).expect("fence");
        rc.lock().unwrap().push(bytes);
    });
    for r in &reports {
        assert!(
            r.errors.is_empty(),
            "{} nodes over {}: node {} errors: {:?}",
            nodes,
            transport.name(),
            r.node,
            r.errors
        );
    }
    let results = results.lock().unwrap().clone();
    assert_eq!(results.len(), nodes as usize);
    let bytes = ROWS * COLS * 4;
    for (i, f) in results.iter().enumerate() {
        assert_eq!(f.len() as u64, bytes, "node {i} fence size");
    }
    results
}

/// All nodes of one run must agree among themselves (each node fences the
/// full field, assembled from every peer's fragments).
fn assert_all_equal(fences: &[Vec<u8>], what: &str) {
    for (i, f) in fences.iter().enumerate() {
        assert_eq!(
            f.as_slice(),
            fences[0].as_slice(),
            "{what}: node {i} fence differs from node 0"
        );
    }
}

#[test]
fn wavesim_2_nodes_identical_across_transports() {
    let chan = wavesim_fences(Transport::Channel, 2, 2);
    let tcp = wavesim_fences(Transport::Tcp, 2, 2);
    assert_all_equal(&chan, "channel 2-node");
    assert_all_equal(&tcp, "tcp 2-node");
    assert_eq!(
        chan[0], tcp[0],
        "ChannelWorld and TCP transports must produce identical fence results"
    );
}

/// Acceptance criterion: wavesim on 4 simulated nodes yields fence results
/// byte-identical to the 1-node run, over both transports.
#[test]
fn wavesim_4_nodes_byte_identical_to_single_node_both_transports() {
    let single = wavesim_fences(Transport::Channel, 1, 2);
    for transport in [Transport::Channel, Transport::Tcp] {
        let four = wavesim_fences(transport, 4, 2);
        assert_all_equal(&four, transport.name());
        assert_eq!(
            four[0],
            single[0],
            "4-node {} run must be byte-identical to the 1-node run",
            transport.name()
        );
    }
}

/// The per-process entry point (`run_node` + an explicitly-built TCP
/// communicator — what each `celerity worker` process executes) produces
/// the same bytes as the `run_cluster` convenience path.
#[test]
fn run_node_over_explicit_tcp_endpoints_matches_cluster() {
    let cfg = ClusterConfig {
        num_nodes: 2,
        num_devices: 2,
        registry: apps::reference_registry(),
        transport: Transport::Tcp,
        ..Default::default()
    };
    let comms = TcpWorld::bind_local(2).expect("bind mesh").communicators();
    let mut joins = Vec::new();
    for (i, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || {
            let comm: CommRef = Arc::new(comm);
            let fence: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
            let fc = fence.clone();
            let report = run_node(&cfg, NodeId(i as u64), comm, move |q| {
                let out = wavesim::submit(q, ROWS, COLS, STEPS).expect("submit wavesim");
                *fc.lock().unwrap() = q.fence_bytes(out.id()).expect("fence");
            });
            assert!(report.errors.is_empty(), "node {i}: {:?}", report.errors);
            let bytes = fence.lock().unwrap().clone();
            bytes
        }));
    }
    let fences: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().expect("node")).collect();
    assert_all_equal(&fences, "run_node tcp");
    let via_cluster = wavesim_fences(Transport::Channel, 1, 2);
    assert_eq!(fences[0], via_cluster[0], "run_node path must match run_cluster");
}

/// The golden model agrees too (guards against a bug identical on all
/// cluster shapes).
#[test]
fn wavesim_cluster_matches_reference_model() {
    let got = wavesim_fences(Transport::Tcp, 2, 2);
    let want = wavesim::reference(ROWS as usize, COLS as usize, STEPS);
    let got_f32: Vec<f32> = got[0]
        .chunks_exact(4)
        .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    for i in 0..want.len() {
        assert!(
            (got_f32[i] - want[i]).abs() < 1e-4,
            "element {i}: {} vs {}",
            got_f32[i],
            want[i]
        );
    }
}
