//! Collective-group execution: ring schedules over the p2p primitives.
//!
//! A [`crate::instruction::InstructionKind::Collective`] gathers a buffer
//! region across all nodes in `n−1` ring rounds. Each node repeatedly
//! forwards one slice to its successor: round 0 sends its own contribution,
//! round *r* forwards the slice received from the predecessor in round
//! *r−1*. All transfers are ordinary pilot + [`Communicator::send_data`]
//! messages — the transports (`channel` and `tcp`) are untouched — and
//! inbound fragments land through the regular [`ReceiveArbiter`], which the
//! engine polls for per-round progress via `received_region`.
//!
//! The schedule is deadlock-free by induction: round 0 needs no inbound
//! data, and round *r*'s send only waits for round *r−1*'s receive, which
//! the predecessor's round *r−1* send satisfies.

use super::arbitration::ReceiveArbiter;
use super::arena::AllocBuf;
use crate::comm::CommRef;
use crate::grid::{GridBox, Region};
use crate::util::{InstructionId, MessageId, NodeId};
use std::collections::HashMap;
use std::sync::Arc;

/// One in-flight collective on this node.
struct CollectiveRun {
    rank: usize,
    /// Per-node contribution slices, indexed by node id.
    slices: Arc<Vec<GridBox>>,
    /// Message id per ring round (pre-allocated by the IDAG generator).
    msgs: Vec<MessageId>,
    succ: NodeId,
    /// The contiguous host backing holding the gathered region.
    dst: Arc<AllocBuf>,
    /// Current ring round; `slices.len() − 1` means the ring has finished.
    round: usize,
    /// Rounds whose outbound send has been performed.
    sent: usize,
}

impl CollectiveRun {
    fn n(&self) -> usize {
        self.slices.len()
    }

    /// Slice this node sends in `round`: (rank − round) mod n.
    fn send_slice(&self, round: usize) -> GridBox {
        self.slices[(self.rank + self.n() - round) % self.n()]
    }

    /// Slice this node receives in `round`: (rank − 1 − round) mod n.
    fn recv_slice(&self, round: usize) -> GridBox {
        self.slices[(self.rank + self.n() - 1 - round) % self.n()]
    }
}

/// Drives every active collective ring on this node. Owned by the executor,
/// pumped whenever inbound data arrived or a collective was dispatched.
#[derive(Default)]
pub struct CollectiveEngine {
    active: HashMap<InstructionId, CollectiveRun>,
}

impl CollectiveEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a dispatched collective instruction. The caller must have
    /// registered the inbound region with the arbiter first
    /// (`register_collective`), then pump the engine once.
    pub fn start(
        &mut self,
        id: InstructionId,
        rank: NodeId,
        slices: Arc<Vec<GridBox>>,
        msgs: Vec<MessageId>,
        dst: Arc<AllocBuf>,
    ) {
        let n = slices.len();
        debug_assert!(n >= 2, "collective needs at least 2 nodes");
        debug_assert_eq!(msgs.len(), n - 1, "one message id per ring round");
        let succ = NodeId((rank.0 + 1) % n as u64);
        self.active.insert(
            id,
            CollectiveRun {
                rank: rank.0 as usize,
                slices,
                msgs,
                succ,
                dst,
                round: 0,
                sent: 0,
            },
        );
    }

    /// Advance every active ring as far as received data allows: perform
    /// due sends, step rounds whose inbound slice has fully arrived, and
    /// return the ids of collectives whose ring completed (the caller
    /// retires them and drops their arbiter entry).
    pub fn pump(&mut self, arbiter: &ReceiveArbiter, comm: &CommRef) -> Vec<InstructionId> {
        let mut done = Vec::new();
        for (id, run) in self.active.iter_mut() {
            let rounds = run.n() - 1;
            let received = arbiter.received_region(*id);
            loop {
                if run.round >= rounds {
                    done.push(*id);
                    break;
                }
                // Send phase of the current round (exactly once). The bytes
                // come straight from the gathered-region backing: round 0's
                // slice was made coherent there by the IDAG, later rounds'
                // slices were landed there by the arbiter.
                if run.sent == run.round {
                    let s = run.send_slice(run.round);
                    if !s.is_empty() {
                        comm.send_data(run.succ, run.msgs[run.round], run.dst.read_box(&s));
                    }
                    run.sent += 1;
                }
                // Receive phase: the round is over once the predecessor's
                // slice for it has fully arrived (empty slices by geometry
                // count as arrived). The arbiter entry exists for the whole
                // ring lifetime — it is only removed by `finish_collective`
                // after we report completion — so a missing entry is a
                // sequencing bug, not "everything arrived": fail loudly in
                // debug, stall visibly (not corrupt silently) in release.
                let want = run.recv_slice(run.round);
                let arrived = want.is_empty()
                    || match &received {
                        Some(r) => r.contains(&Region::from(want)),
                        None => {
                            debug_assert!(
                                false,
                                "collective I{} pumped without an arbiter entry",
                                id.0
                            );
                            false
                        }
                    };
                if arrived {
                    run.round += 1;
                } else {
                    break;
                }
            }
        }
        for id in &done {
            self.active.remove(id);
        }
        done
    }

    /// Number of collectives still in flight.
    pub fn len(&self) -> usize {
        self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    /// Human-readable state dump (stall diagnostics).
    pub fn debug_state(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        for (id, run) in &self.active {
            let _ = writeln!(
                s,
                "  collective I{} rank {}/{} round {}/{} (sent {})",
                id.0,
                run.rank,
                run.n(),
                run.round,
                run.n() - 1,
                run.sent
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{ChannelWorld, Inbound};
    use crate::instruction::Pilot;
    use crate::util::{BufferId, TaskId};

    /// Drive a full n-node all-gather ring by hand: one arbiter + engine +
    /// destination buffer per node, messages carried by the channel fabric.
    /// Every node must end up with every slice, byte-exact, in n−1 rounds.
    fn run_ring(n: usize) {
        let total = 8 * n as u64;
        let bbox = GridBox::d1(0, total);
        let slices: Arc<Vec<GridBox>> = Arc::new(
            (0..n as u64).map(|i| GridBox::d1(i * 8, (i + 1) * 8)).collect(),
        );
        let comms: Vec<CommRef> = ChannelWorld::new(n as u64)
            .communicators()
            .into_iter()
            .map(|c| Arc::new(c) as CommRef)
            .collect();
        let transfer = TaskId(42);
        let buffer = BufferId(0);
        let id = InstructionId(7);

        let mut arbiters: Vec<ReceiveArbiter> = (0..n).map(|_| ReceiveArbiter::new()).collect();
        let mut engines: Vec<CollectiveEngine> =
            (0..n).map(|_| CollectiveEngine::new()).collect();
        let mut dsts: Vec<Arc<AllocBuf>> = Vec::new();

        for rank in 0..n {
            let dst = Arc::new(AllocBuf::new(bbox, 4));
            // Seed our own slice (what make_coherent would have staged).
            let own = slices[rank];
            let bytes: Vec<u8> = (own.min[0]..own.max[0])
                .flat_map(|i| (i as u32).to_ne_bytes())
                .collect();
            dst.write_box(&own, &bytes);
            let inbound = Region::from(bbox).difference(&Region::from(own));
            arbiters[rank].register_collective(id, buffer, transfer, inbound, dst.clone());
            // Pilots the IDAG would have emitted: one per non-empty round.
            let succ = NodeId(((rank + 1) % n) as u64);
            for r in 0..n - 1 {
                let send_box = slices[(rank + n - r) % n];
                if !send_box.is_empty() {
                    comms[rank].send_pilot(Pilot {
                        from: NodeId(rank as u64),
                        to: succ,
                        msg: MessageId(100 + r as u64),
                        buffer,
                        send_box,
                        transfer,
                    });
                }
            }
            engines[rank].start(
                id,
                NodeId(rank as u64),
                slices.clone(),
                (0..n - 1).map(|r| MessageId(100 + r as u64)).collect(),
                dst.clone(),
            );
            dsts.push(dst);
        }

        // Event loop: poll each node's fabric into its arbiter, pump rings.
        let mut finished = vec![false; n];
        let mut spins = 0;
        while finished.iter().any(|f| !f) {
            spins += 1;
            assert!(spins < 100_000, "ring did not converge");
            for rank in 0..n {
                while let Some(m) = comms[rank].poll() {
                    match m {
                        Inbound::Pilot(p) => arbiters[rank].on_pilot(p),
                        Inbound::Data { from, msg, bytes } => {
                            arbiters[rank].on_data(from, msg, bytes)
                        }
                        // No heartbeats, goodbyes or faults on this
                        // fault-free in-process fixture.
                        _ => {}
                    }
                }
                for done in engines[rank].pump(&arbiters[rank], &comms[rank]) {
                    assert_eq!(done, id);
                    arbiters[rank].finish_collective(done);
                    finished[rank] = true;
                }
            }
        }

        // Byte-exact gather everywhere.
        for (rank, dst) in dsts.iter().enumerate() {
            let want: Vec<u8> = (0..total).flat_map(|i| (i as u32).to_ne_bytes()).collect();
            assert_eq!(dst.read_box(&bbox), want, "node {rank} gathered bytes");
            assert!(engines[rank].is_empty());
        }
    }

    #[test]
    fn two_node_ring_gathers() {
        run_ring(2);
    }

    #[test]
    fn four_node_ring_gathers() {
        run_ring(4);
    }

    #[test]
    fn seven_node_ring_gathers() {
        run_ring(7);
    }

    /// Broadcast degenerates to a pipeline: only the root owns a slice.
    #[test]
    fn broadcast_pipeline_delivers_to_all() {
        let n = 4usize;
        let root = 2usize;
        let bbox = GridBox::d1(0, 16);
        let mut slices = vec![GridBox::EMPTY; n];
        slices[root] = bbox;
        let slices = Arc::new(slices);
        let comms: Vec<CommRef> = ChannelWorld::new(n as u64)
            .communicators()
            .into_iter()
            .map(|c| Arc::new(c) as CommRef)
            .collect();
        let (buffer, transfer, id) = (BufferId(1), TaskId(9), InstructionId(3));
        let payload: Vec<u8> = (0..16u32).flat_map(|i| (i * 3).to_ne_bytes()).collect();

        let mut arbiters: Vec<ReceiveArbiter> = (0..n).map(|_| ReceiveArbiter::new()).collect();
        let mut engines: Vec<CollectiveEngine> =
            (0..n).map(|_| CollectiveEngine::new()).collect();
        let mut dsts = Vec::new();
        for rank in 0..n {
            let dst = Arc::new(AllocBuf::new(bbox, 4));
            if rank == root {
                dst.write_box(&bbox, &payload);
            }
            let inbound = if rank == root {
                Region::empty()
            } else {
                Region::from(bbox)
            };
            arbiters[rank].register_collective(id, buffer, transfer, inbound, dst.clone());
            let succ = NodeId(((rank + 1) % n) as u64);
            for r in 0..n - 1 {
                let send_box = slices[(rank + n - r) % n];
                if !send_box.is_empty() {
                    comms[rank].send_pilot(Pilot {
                        from: NodeId(rank as u64),
                        to: succ,
                        msg: MessageId(200 + r as u64),
                        buffer,
                        send_box,
                        transfer,
                    });
                }
            }
            engines[rank].start(
                id,
                NodeId(rank as u64),
                slices.clone(),
                (0..n - 1).map(|r| MessageId(200 + r as u64)).collect(),
                dst.clone(),
            );
            dsts.push(dst);
        }
        let mut finished = vec![false; n];
        let mut spins = 0;
        while finished.iter().any(|f| !f) {
            spins += 1;
            assert!(spins < 100_000, "broadcast did not converge");
            for rank in 0..n {
                while let Some(m) = comms[rank].poll() {
                    match m {
                        Inbound::Pilot(p) => arbiters[rank].on_pilot(p),
                        Inbound::Data { from, msg, bytes } => {
                            arbiters[rank].on_data(from, msg, bytes)
                        }
                        _ => {}
                    }
                }
                for done in engines[rank].pump(&arbiters[rank], &comms[rank]) {
                    arbiters[rank].finish_collective(done);
                    finished[rank] = true;
                }
            }
        }
        for (rank, dst) in dsts.iter().enumerate() {
            assert_eq!(dst.read_box(&bbox), payload, "node {rank} broadcast bytes");
        }
    }
}
