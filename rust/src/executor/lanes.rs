//! Backend lanes: in-order worker threads (§4.1).
//!
//! Each [`Lane`](super::ooo::Lane) maps to one OS thread executing jobs in
//! FIFO order — the stand-in for SYCL in-order queues (device kernels,
//! device copies) and host threads. Completion events flow back to the
//! executor loop over a shared channel, which the executor polls — the
//! polling-based completion model the paper adopts from [18]/[4].

use super::ooo::Lane;
use crate::util::InstructionId;
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// A unit of work for a lane: the instruction id and its action.
pub struct Job {
    pub id: InstructionId,
    pub run: Box<dyn FnOnce() + Send>,
}

struct Worker {
    tx: mpsc::Sender<Job>,
    join: JoinHandle<()>,
}

/// Lazily-spawned pool of lane workers.
pub struct LanePool {
    workers: HashMap<Lane, Worker>,
    completion_tx: mpsc::Sender<InstructionId>,
    node_tag: u64,
}

impl LanePool {
    /// `completion_tx` receives the id of every finished job.
    pub fn new(completion_tx: mpsc::Sender<InstructionId>, node_tag: u64) -> LanePool {
        LanePool { workers: HashMap::new(), completion_tx, node_tag }
    }

    /// Enqueue a job on `lane`, spawning its worker on first use.
    pub fn submit(&mut self, lane: Lane, job: Job) {
        debug_assert!(!matches!(lane, Lane::Inline | Lane::Arbiter));
        let completion_tx = self.completion_tx.clone();
        let node_tag = self.node_tag;
        let worker = self.workers.entry(lane).or_insert_with(|| {
            let (tx, rx) = mpsc::channel::<Job>();
            let done = completion_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("celerity-n{node_tag}-{lane:?}"))
                .spawn(move || {
                    for job in rx {
                        (job.run)();
                        if done.send(job.id).is_err() {
                            break; // executor gone; drain and exit
                        }
                    }
                })
                .expect("spawn lane worker");
            Worker { tx, join }
        });
        worker
            .tx
            .send(job)
            .expect("lane worker alive while pool exists");
    }

    /// Number of spawned lanes.
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Close all lanes and wait for their queues to drain.
    pub fn shutdown(self) {
        for (_, w) in self.workers {
            drop(w.tx);
            let _ = w.join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::DeviceId;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn jobs_execute_in_fifo_order_per_lane() {
        let (tx, rx) = mpsc::channel();
        let mut pool = LanePool::new(tx, 0);
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        for i in 0..50u64 {
            let order = order.clone();
            pool.submit(
                Lane::DeviceKernel(DeviceId(0)),
                Job {
                    id: InstructionId(i),
                    run: Box::new(move || order.lock().unwrap().push(i)),
                },
            );
        }
        let mut completions = Vec::new();
        for _ in 0..50 {
            completions.push(rx.recv().unwrap().0);
        }
        pool.shutdown();
        assert_eq!(*order.lock().unwrap(), (0..50).collect::<Vec<_>>());
        assert_eq!(completions, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lanes_run_concurrently() {
        let (tx, rx) = mpsc::channel();
        let mut pool = LanePool::new(tx, 0);
        let counter = Arc::new(AtomicU64::new(0));
        // Two lanes, each job waits until both lanes have started — only
        // possible if they truly run in parallel.
        for d in 0..2 {
            let counter = counter.clone();
            pool.submit(
                Lane::DeviceKernel(DeviceId(d)),
                Job {
                    id: InstructionId(d),
                    run: Box::new(move || {
                        counter.fetch_add(1, Ordering::SeqCst);
                        while counter.load(Ordering::SeqCst) < 2 {
                            std::thread::yield_now();
                        }
                    }),
                },
            );
        }
        let a = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let b = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_ne!(a, b);
        pool.shutdown();
    }
}
