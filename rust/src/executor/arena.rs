//! The allocation arena: concrete bytes behind allocation ids.
//!
//! Every `alloc` instruction materializes an [`AllocBuf`] — a contiguous
//! byte buffer covering a buffer-space box (simulated device memory lives
//! in host RAM; the memory id only matters for scheduling). Copy-, kernel-,
//! send- and receive instructions operate on these buffers concurrently
//! from different lane threads.
//!
//! # Safety
//!
//! `AllocBuf` hands out raw interior mutability. Synchronization is the
//! IDAG's job: two instructions touching the same bytes always have a
//! dependency path between them (that is precisely what the instruction
//! graph guarantees, §3.3), so at runtime no two lanes ever race on a byte.
//! This mirrors how the real runtime relies on SYCL/MPI dependency ordering
//! rather than locks.

use crate::grid::{GridBox, Point};
use crate::util::AllocationId;
use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::sync::Arc;

/// One materialized allocation.
pub struct AllocBuf {
    /// Buffer-space box this allocation backs.
    pub covers: GridBox,
    pub elem_size: usize,
    data: UnsafeCell<Box<[u8]>>,
}

// SAFETY: an `AllocBuf` is plain memory; all aliasing discipline is
// delegated to the caller of the unsafe accessors below, which the IDAG
// dependency order provides (two instructions touching the same element are
// never in flight concurrently unless both only read).
unsafe impl Send for AllocBuf {}
// SAFETY: see above — interior mutability is only reachable through
// `unsafe fn`s whose contracts require element-exclusive access.
unsafe impl Sync for AllocBuf {}

impl AllocBuf {
    pub fn new(covers: GridBox, elem_size: usize) -> AllocBuf {
        let bytes = covers.area() as usize * elem_size;
        AllocBuf {
            covers,
            elem_size,
            data: UnsafeCell::new(vec![0u8; bytes].into_boxed_slice()),
        }
    }

    pub fn len_bytes(&self) -> usize {
        unsafe { (&*self.data.get()).len() }
    }

    /// Linear element index of buffer-space point `p` (row-major within the
    /// covered box).
    #[inline]
    pub fn index_of(&self, p: Point) -> usize {
        let r = self.covers.range();
        let rel = p - self.covers.min;
        ((rel[0] * r[1] + rel[1]) * r[2] + rel[2]) as usize
    }

    /// Read a typed element at buffer-space point `p`.
    ///
    /// # Safety
    /// Caller must guarantee no concurrent writer of this element (IDAG
    /// dependency ordering).
    #[inline]
    pub unsafe fn read<T: Copy>(&self, p: Point) -> T {
        debug_assert!(self.covers.contains_point(p), "{p} outside {}", self.covers);
        debug_assert_eq!(self.elem_size, std::mem::size_of::<T>());
        let idx = self.index_of(p);
        // SAFETY: `idx` is inside the allocation (debug-asserted above, and
        // the scheduler only binds in-bounds accessors); the caller contract
        // rules out a concurrent writer of this element.
        unsafe {
            let ptr = (*self.data.get()).as_ptr() as *const T;
            *ptr.add(idx)
        }
    }

    /// Write a typed element at buffer-space point `p`.
    ///
    /// # Safety
    /// Caller must guarantee exclusive access to this element.
    #[inline]
    pub unsafe fn write<T: Copy>(&self, p: Point, v: T) {
        debug_assert!(self.covers.contains_point(p), "{p} outside {}", self.covers);
        debug_assert_eq!(self.elem_size, std::mem::size_of::<T>());
        let idx = self.index_of(p);
        // SAFETY: `idx` is inside the allocation; the caller contract grants
        // exclusive access to this element, so the raw write cannot race.
        unsafe {
            let ptr = (*self.data.get()).as_mut_ptr() as *mut T;
            *ptr.add(idx) = v;
        }
    }

    /// Read one f32 lane of a multi-lane element (e.g. the y component of
    /// a 12-byte double3-style element).
    ///
    /// # Safety
    /// Caller must guarantee no concurrent writer (IDAG ordering) and
    /// `lane * 4 < elem_size`.
    #[inline]
    pub unsafe fn read_lane_f32(&self, p: Point, lane: usize) -> f32 {
        debug_assert!(self.covers.contains_point(p));
        debug_assert!(lane * 4 < self.elem_size);
        let off = self.index_of(p) * self.elem_size + lane * 4;
        // SAFETY: in-bounds by the lane/point contract; no concurrent writer
        // by the caller contract.
        let data = unsafe { &*self.data.get() };
        f32::from_ne_bytes(data[off..off + 4].try_into().expect("4-byte slice"))
    }

    /// Write one f32 lane of a multi-lane element.
    ///
    /// # Safety
    /// Caller must guarantee exclusive access and `lane * 4 < elem_size`.
    #[inline]
    pub unsafe fn write_lane_f32(&self, p: Point, lane: usize, v: f32) {
        debug_assert!(self.covers.contains_point(p));
        debug_assert!(lane * 4 < self.elem_size);
        let off = self.index_of(p) * self.elem_size + lane * 4;
        // SAFETY: in-bounds by the lane/point contract; exclusive access by
        // the caller contract.
        let data = unsafe { &mut *self.data.get() };
        data[off..off + 4].copy_from_slice(&v.to_ne_bytes());
    }

    /// Gather the bytes of `b` (must be inside `covers`) into a dense
    /// row-major payload — the wire format of `send` instructions.
    pub fn read_box(&self, b: &GridBox) -> Vec<u8> {
        assert!(self.covers.contains(b), "{b} outside {}", self.covers);
        let mut out = Vec::with_capacity(b.area() as usize * self.elem_size);
        self.for_each_run(b, |offset, len| {
            let data = unsafe { &*self.data.get() };
            out.extend_from_slice(&data[offset..offset + len]);
        });
        out
    }

    /// Scatter a dense row-major payload into box `b`.
    pub fn write_box(&self, b: &GridBox, bytes: &[u8]) {
        assert!(self.covers.contains(b), "{b} outside {}", self.covers);
        assert_eq!(bytes.len(), b.area() as usize * self.elem_size);
        let mut src = 0;
        self.for_each_run(b, |offset, len| {
            let data = unsafe { &mut *self.data.get() };
            data[offset..offset + len].copy_from_slice(&bytes[src..src + len]);
            src += len;
        });
    }

    /// Iterate the contiguous byte runs of box `b` within this allocation:
    /// one run per (x, y) row, spanning the z extent (fully contiguous
    /// boxes collapse into fewer, longer runs for 1D/2D buffers).
    fn for_each_run(&self, b: &GridBox, mut f: impl FnMut(usize, usize)) {
        let cr = self.covers.range();
        // Fast path: b spans the full y/z extent of the allocation → one run.
        if b.min[1] == self.covers.min[1]
            && b.max[1] == self.covers.max[1]
            && b.min[2] == self.covers.min[2]
            && b.max[2] == self.covers.max[2]
        {
            let start = self.index_of(b.min) * self.elem_size;
            let len = (b.area() * self.elem_size as u64) as usize;
            f(start, len);
            return;
        }
        let zrun = ((b.max[2] - b.min[2]) * self.elem_size as u64) as usize;
        // z spans full extent → merge y rows when b covers full z.
        let full_z = b.min[2] == self.covers.min[2] && b.max[2] == self.covers.max[2];
        for x in b.min[0]..b.max[0] {
            if full_z {
                let start = self.index_of(Point::d3(x, b.min[1], b.min[2])) * self.elem_size;
                let len = ((b.max[1] - b.min[1]) * cr[2]) as usize * self.elem_size;
                f(start, len);
            } else {
                for y in b.min[1]..b.max[1] {
                    let start = self.index_of(Point::d3(x, y, b.min[2])) * self.elem_size;
                    f(start, zrun);
                }
            }
        }
    }
}

/// Copy `copy_box` from `src` to `dst` (both must cover it).
pub fn copy_between(src: &AllocBuf, dst: &AllocBuf, copy_box: &GridBox) {
    debug_assert_eq!(src.elem_size, dst.elem_size);
    // Gather + scatter; for same-layout fast paths this is two memcpys.
    let bytes = src.read_box(copy_box);
    dst.write_box(copy_box, &bytes);
}

/// The arena: allocation id → live buffer. Owned by the executor thread;
/// lanes hold `Arc<AllocBuf>` clones of the allocations they operate on.
#[derive(Default)]
pub struct Arena {
    bufs: HashMap<AllocationId, Arc<AllocBuf>>,
    /// Peak concurrently-live bytes (the §4.3 out-of-memory concern).
    pub live_bytes: u64,
    pub peak_bytes: u64,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    pub fn alloc(&mut self, id: AllocationId, covers: GridBox, elem_size: usize) -> Arc<AllocBuf> {
        let buf = Arc::new(AllocBuf::new(covers, elem_size));
        self.live_bytes += buf.len_bytes() as u64;
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
        let prev = self.bufs.insert(id, buf.clone());
        debug_assert!(prev.is_none(), "allocation id {id} reused");
        buf
    }

    /// Materialize (or overwrite) a user-memory (M0) allocation holding
    /// host-initialized buffer contents.
    pub fn init_user(&mut self, id: AllocationId, covers: GridBox, elem_size: usize, bytes: &[u8]) {
        let buf = self.bufs.entry(id).or_insert_with(|| {
            Arc::new(AllocBuf::new(covers, elem_size))
        }).clone();
        if !bytes.is_empty() {
            assert_eq!(bytes.len(), buf.len_bytes(), "user init size mismatch");
            buf.write_box(&covers, bytes);
        }
    }

    pub fn free(&mut self, id: AllocationId) {
        if let Some(buf) = self.bufs.remove(&id) {
            self.live_bytes -= buf.len_bytes() as u64;
        }
    }

    pub fn get(&self, id: AllocationId) -> Arc<AllocBuf> {
        self.bufs
            .get(&id)
            .unwrap_or_else(|| panic!("allocation {id} not live"))
            .clone()
    }

    pub fn try_get(&self, id: AllocationId) -> Option<Arc<AllocBuf>> {
        self.bufs.get(&id).cloned()
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Range;

    #[test]
    fn typed_read_write_roundtrip() {
        let buf = AllocBuf::new(GridBox::d1(10, 20), 4);
        unsafe {
            buf.write::<f32>(Point::d1(15), 3.5);
            assert_eq!(buf.read::<f32>(Point::d1(15)), 3.5);
            assert_eq!(buf.read::<f32>(Point::d1(10)), 0.0);
        }
    }

    #[test]
    fn box_gather_scatter_1d() {
        let buf = AllocBuf::new(GridBox::d1(0, 8), 4);
        for i in 0..8 {
            unsafe { buf.write::<f32>(Point::d1(i), i as f32) };
        }
        let bytes = buf.read_box(&GridBox::d1(2, 5));
        assert_eq!(bytes.len(), 12);
        let other = AllocBuf::new(GridBox::d1(0, 8), 4);
        other.write_box(&GridBox::d1(2, 5), &bytes);
        unsafe {
            assert_eq!(other.read::<f32>(Point::d1(2)), 2.0);
            assert_eq!(other.read::<f32>(Point::d1(4)), 4.0);
            assert_eq!(other.read::<f32>(Point::d1(5)), 0.0);
        }
    }

    #[test]
    fn box_gather_scatter_2d_subbox() {
        // 2D allocation; copy an interior tile between differently-anchored
        // allocations.
        let a = AllocBuf::new(GridBox::d2((0, 0), (8, 8)), 8);
        for x in 0..8 {
            for y in 0..8 {
                unsafe { a.write::<f64>(Point::d2(x, y), (x * 8 + y) as f64) };
            }
        }
        let tile = GridBox::d2((2, 3), (5, 6));
        let b = AllocBuf::new(GridBox::d2((2, 2), (6, 7)), 8);
        copy_between(&a, &b, &tile);
        unsafe {
            assert_eq!(b.read::<f64>(Point::d2(2, 3)), (2 * 8 + 3) as f64);
            assert_eq!(b.read::<f64>(Point::d2(4, 5)), (4 * 8 + 5) as f64);
            // Outside the tile: untouched.
            assert_eq!(b.read::<f64>(Point::d2(2, 2)), 0.0);
        }
    }

    #[test]
    fn full_extent_fast_path_matches() {
        let a = AllocBuf::new(GridBox::full(Range::d2(4, 4)), 4);
        for x in 0..4 {
            for y in 0..4 {
                unsafe { a.write::<f32>(Point::d2(x, y), (x * 4 + y) as f32) };
            }
        }
        let all = a.read_box(&GridBox::full(Range::d2(4, 4)));
        assert_eq!(all.len(), 64);
        let b = AllocBuf::new(GridBox::full(Range::d2(4, 4)), 4);
        b.write_box(&GridBox::full(Range::d2(4, 4)), &all);
        unsafe { assert_eq!(b.read::<f32>(Point::d2(3, 3)), 15.0) };
    }

    #[test]
    fn arena_tracks_peak_bytes() {
        let mut arena = Arena::new();
        arena.alloc(AllocationId(1), GridBox::d1(0, 100), 8); // 800 B
        arena.alloc(AllocationId(2), GridBox::d1(0, 50), 8); // 400 B
        assert_eq!(arena.live_bytes, 1200);
        arena.free(AllocationId(1));
        assert_eq!(arena.live_bytes, 400);
        assert_eq!(arena.peak_bytes, 1200);
        arena.alloc(AllocationId(3), GridBox::d1(0, 10), 8);
        assert_eq!(arena.len(), 2);
    }

    #[test]
    fn three_d_runs() {
        let a = AllocBuf::new(GridBox::d3((0, 0, 0), (4, 4, 4)), 4);
        for x in 0..4 {
            for y in 0..4 {
                for z in 0..4 {
                    unsafe { a.write::<f32>(Point::d3(x, y, z), (x * 16 + y * 4 + z) as f32) };
                }
            }
        }
        let sub = GridBox::d3((1, 1, 1), (3, 3, 3));
        let b = AllocBuf::new(GridBox::d3((0, 0, 0), (4, 4, 4)), 4);
        copy_between(&a, &b, &sub);
        unsafe {
            assert_eq!(b.read::<f32>(Point::d3(2, 2, 2)), (2 * 16 + 2 * 4 + 2) as f32);
            assert_eq!(b.read::<f32>(Point::d3(0, 0, 0)), 0.0);
        }
    }
}
