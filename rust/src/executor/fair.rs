//! Fair-share dispatch arbitration across jobs (multi-tenant executor).
//!
//! When several jobs share one executor, the set of ready-to-issue
//! instructions is partitioned per job and drained by weighted round-robin:
//! each job gets a quantum of `weight` dispatches before the cursor moves
//! on, and an optional admission limit caps how many of a job's
//! instructions may be dispatched-but-not-retired at once. A job at its
//! admission limit is skipped, not waited on, so a heavy job can never
//! block a light one behind it (the starvation guarantee the multi-tenant
//! tests assert).
//!
//! With `fair_share` off (the ablation mode) the set degrades to a single
//! global FIFO in arrival order — admission limits still apply, but a
//! capped job at the head blocks everyone behind it, which is exactly the
//! head-of-line behaviour the ablation is meant to expose.

use super::ooo::Lane;
use crate::instruction::InstructionRef;
use crate::util::{InstructionId, JobId};
use std::collections::{HashMap, VecDeque};

type Entry = (InstructionRef, Lane);

enum Mode {
    /// Ablation: one global queue, arrival order.
    Fifo(VecDeque<Entry>),
    /// Weighted round-robin over per-job queues. `ring` holds jobs in
    /// first-seen order; `credit` is the remaining quantum of the job at
    /// `cursor`.
    Fair {
        ring: Vec<u64>,
        cursor: usize,
        credit: u32,
        queues: HashMap<u64, VecDeque<Entry>>,
    },
}

/// The pool of issuable instructions awaiting dispatch, with per-job
/// arbitration. Feed with [`ReadySet::push`], drain with [`ReadySet::next`],
/// and report retirements back via [`ReadySet::on_retire`] so admission
/// accounting stays balanced.
pub struct ReadySet {
    admission_limit: usize,
    weights: Vec<u32>,
    mode: Mode,
    /// Per-job dispatched-but-not-retired counts (admission accounting).
    in_flight: HashMap<u64, usize>,
    len: usize,
}

impl ReadySet {
    /// `admission_limit` of 0 means unlimited. `weights` is indexed by job
    /// id; missing entries (and zeros) default to weight 1.
    pub fn new(fair_share: bool, admission_limit: usize, weights: Vec<u32>) -> ReadySet {
        ReadySet {
            admission_limit,
            weights,
            mode: if fair_share {
                Mode::Fair {
                    ring: Vec::new(),
                    cursor: 0,
                    credit: 0,
                    queues: HashMap::new(),
                }
            } else {
                Mode::Fifo(VecDeque::new())
            },
            in_flight: HashMap::new(),
            len: 0,
        }
    }

    fn under_limit(limit: usize, in_flight: &HashMap<u64, usize>, job: u64) -> bool {
        limit == 0 || in_flight.get(&job).copied().unwrap_or(0) < limit
    }

    /// Add a ready instruction; the owning job is read off the id's high
    /// bits.
    pub fn push(&mut self, instr: InstructionRef, lane: Lane) {
        let job = JobId::of(instr.id.0).0;
        self.len += 1;
        match &mut self.mode {
            Mode::Fifo(q) => q.push_back((instr, lane)),
            Mode::Fair { ring, queues, .. } => {
                if !queues.contains_key(&job) {
                    ring.push(job);
                }
                queues.entry(job).or_default().push_back((instr, lane));
            }
        }
    }

    /// Pick the next instruction to dispatch, or `None` when every pending
    /// entry belongs to a job at its admission limit (or the set is empty).
    pub fn next(&mut self) -> Option<Entry> {
        match &mut self.mode {
            Mode::Fifo(q) => {
                let job = JobId::of(q.front()?.0.id.0).0;
                if !Self::under_limit(self.admission_limit, &self.in_flight, job) {
                    // Deliberate head-of-line blocking in the ablation mode.
                    return None;
                }
                let e = q.pop_front()?;
                *self.in_flight.entry(job).or_insert(0) += 1;
                self.len -= 1;
                Some(e)
            }
            Mode::Fair { ring, cursor, credit, queues } => {
                let n = ring.len();
                for _ in 0..n {
                    if *cursor >= ring.len() {
                        *cursor = 0;
                    }
                    let job = ring[*cursor];
                    if *credit == 0 {
                        *credit = self.weights.get(job as usize).copied().unwrap_or(1).max(1);
                    }
                    let has_work = queues.get(&job).is_some_and(|q| !q.is_empty());
                    if has_work && Self::under_limit(self.admission_limit, &self.in_flight, job) {
                        *credit -= 1;
                        let q = queues.get_mut(&job).expect("picked job has a queue");
        let e = q.pop_front().expect("picked queue is nonempty");
                        if *credit == 0 || queues[&job].is_empty() {
                            *cursor = (*cursor + 1) % ring.len();
                            *credit = 0;
                        }
                        *self.in_flight.entry(job).or_insert(0) += 1;
                        self.len -= 1;
                        return Some(e);
                    }
                    // Empty or admission-capped: skip without burning the
                    // wall-clock on it.
                    *cursor = (*cursor + 1) % ring.len();
                    *credit = 0;
                }
                None
            }
        }
    }

    /// An instruction retired: release its job's admission slot.
    pub fn on_retire(&mut self, id: InstructionId) {
        let job = JobId::of(id.0).0;
        if let Some(c) = self.in_flight.get_mut(&job) {
            *c = c.saturating_sub(1);
        }
    }

    /// Entries awaiting dispatch (admission-capped entries count).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instruction::{Instruction, InstructionKind};
    use std::sync::Arc;

    fn instr(id: u64) -> InstructionRef {
        Arc::new(Instruction {
            id: InstructionId(id),
            kind: InstructionKind::Horizon,
            deps: vec![],
            task: None,
        })
    }

    fn job_of(e: &Entry) -> u64 {
        JobId::of(e.0.id.0).0
    }

    #[test]
    fn weighted_round_robin_respects_weights() {
        // Job 0 weight 2, job 1 weight 1 → drain order 0,0,1,0,0,1,1,1.
        let mut r = ReadySet::new(true, 0, vec![2, 1]);
        let base = JobId(1).base();
        for k in 0..4 {
            r.push(instr(k), Lane::Inline);
            r.push(instr(base + k), Lane::Inline);
        }
        let order: Vec<u64> = std::iter::from_fn(|| r.next()).map(|e| job_of(&e)).collect();
        assert_eq!(order, vec![0, 0, 1, 0, 0, 1, 1, 1]);
        assert!(r.is_empty());
    }

    #[test]
    fn light_job_is_not_starved_by_heavy_backlog() {
        // 100 ready instructions for job 0, then one for job 1: the fair
        // ring must reach job 1 within one quantum of job 0.
        let mut r = ReadySet::new(true, 0, vec![]);
        for k in 0..100 {
            r.push(instr(k), Lane::Inline);
        }
        r.push(instr(JobId(1).base()), Lane::Inline);
        let first_two: Vec<u64> = (0..2).filter_map(|_| r.next()).map(|e| job_of(&e)).collect();
        assert!(first_two.contains(&1), "job 1 must dispatch within the first quantum: {first_two:?}");
    }

    #[test]
    fn admission_limit_caps_and_releases() {
        let mut r = ReadySet::new(true, 1, vec![]);
        for k in 0..3 {
            r.push(instr(k), Lane::Inline);
        }
        let first = r.next().expect("first dispatch fits the limit");
        assert!(r.next().is_none(), "job 0 is at its admission limit");
        assert_eq!(r.len(), 2, "capped entries still count as pending");
        r.on_retire(first.0.id);
        assert!(r.next().is_some(), "retirement frees an admission slot");
    }

    #[test]
    fn admission_limit_skips_capped_jobs_in_fair_mode() {
        let mut r = ReadySet::new(true, 1, vec![]);
        r.push(instr(0), Lane::Inline);
        r.push(instr(1), Lane::Inline);
        r.push(instr(JobId(1).base()), Lane::Inline);
        assert_eq!(job_of(&r.next().unwrap()), 0);
        // Job 0 capped → the ring skips to job 1 instead of stalling.
        assert_eq!(job_of(&r.next().unwrap()), 1);
        assert!(r.next().is_none());
    }

    #[test]
    fn fifo_mode_preserves_arrival_order_across_jobs() {
        let mut r = ReadySet::new(false, 0, vec![]);
        let base = JobId(1).base();
        r.push(instr(0), Lane::Inline);
        r.push(instr(base), Lane::Inline);
        r.push(instr(1), Lane::Inline);
        let order: Vec<u64> = std::iter::from_fn(|| r.next()).map(|e| e.0.id.0).collect();
        assert_eq!(order, vec![0, base, 1]);
    }

    #[test]
    fn fifo_mode_head_of_line_blocks_at_limit() {
        let mut r = ReadySet::new(false, 1, vec![]);
        r.push(instr(0), Lane::Inline);
        r.push(instr(1), Lane::Inline);
        r.push(instr(JobId(1).base()), Lane::Inline);
        let first = r.next().unwrap();
        // Job 0 capped at the head blocks job 1 behind it — the ablation's
        // whole point.
        assert!(r.next().is_none());
        r.on_retire(first.0.id);
        assert_eq!(r.next().unwrap().0.id.0, 1);
    }
}
