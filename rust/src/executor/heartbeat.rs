//! Worker liveness: heartbeats over the comm fabric.
//!
//! A multi-process cluster used to hang forever when one worker died — its
//! peers would wait indefinitely on receives that could never complete.
//! The monitor turns that into a clean, *attributed* error: each executor
//! thread ticks the monitor once per loop iteration, which (a) sends a
//! periodic beacon to every peer and (b) checks how long each peer has
//! been silent. *Any* inbound traffic (pilot, data, heartbeat, goodbye)
//! counts as proof of life, so a busy fabric never needs extra beacons and
//! a slow-but-alive worker (long kernel, long host task) never trips the
//! detector — its executor thread keeps beating regardless of lane work.
//!
//! A cleanly departing node broadcasts a goodbye first, excluding itself
//! from failure detection on the survivors (nodes finish at different
//! times; a finished peer is not a dead peer).

use crate::comm::CommRef;
use crate::util::NodeId;
use std::time::{Duration, Instant};

/// Monitor tuning. Derived from a single user-facing timeout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeartbeatConfig {
    /// How often to beacon each peer.
    pub interval: Duration,
    /// Silence longer than this declares the peer dead.
    pub timeout: Duration,
}

impl HeartbeatConfig {
    /// Beacon at a quarter of the timeout (min 10 ms) so several beacons
    /// must be lost before a false positive is possible.
    pub fn from_timeout_ms(timeout_ms: u64) -> HeartbeatConfig {
        let timeout_ms = timeout_ms.max(1);
        HeartbeatConfig {
            interval: Duration::from_millis((timeout_ms / 4).max(10)),
            timeout: Duration::from_millis(timeout_ms),
        }
    }
}

/// Per-node liveness state, owned by the executor thread.
pub struct HeartbeatMonitor {
    cfg: HeartbeatConfig,
    node: NodeId,
    last_send: Instant,
    /// Most recent proof of life per peer (own slot unused).
    last_seen: Vec<Instant>,
    /// Peers that announced clean shutdown.
    departed: Vec<bool>,
    failed: bool,
}

impl HeartbeatMonitor {
    pub fn new(cfg: HeartbeatConfig, node: NodeId, num_nodes: u64) -> HeartbeatMonitor {
        let now = Instant::now();
        HeartbeatMonitor {
            cfg,
            node,
            // Immediately due: announce ourselves on the first tick.
            last_send: now.checked_sub(cfg.interval).unwrap_or(now),
            last_seen: vec![now; num_nodes as usize],
            departed: vec![false; num_nodes as usize],
            failed: false,
        }
    }

    /// Record proof of life from `from` (any inbound message).
    pub fn mark_alive(&mut self, from: NodeId) {
        if let Some(slot) = self.last_seen.get_mut(from.0 as usize) {
            *slot = Instant::now();
        }
    }

    /// Record a clean-shutdown announcement from `from`.
    pub fn mark_departed(&mut self, from: NodeId) {
        if let Some(slot) = self.departed.get_mut(from.0 as usize) {
            *slot = true;
        }
    }

    /// Send due beacons and check peer silence. Returns the dead peer and
    /// an attributed error message on the first detected failure (once).
    pub fn tick(&mut self, comm: &CommRef) -> Option<(NodeId, String)> {
        if self.failed {
            return None;
        }
        let now = Instant::now();
        if now.duration_since(self.last_send) >= self.cfg.interval {
            self.last_send = now;
            for peer in self.live_peers() {
                comm.send_heartbeat(peer, false);
            }
        }
        for peer in self.live_peers() {
            let silent = now.duration_since(self.last_seen[peer.0 as usize]);
            if silent > self.cfg.timeout {
                self.failed = true;
                return Some((
                    peer,
                    format!(
                        "heartbeat timeout on node {}: no sign of life from node {} for {} ms \
                         (limit {} ms) — peer process dead or wedged; aborting this node \
                         instead of hanging",
                        self.node.0,
                        peer.0,
                        silent.as_millis(),
                        self.cfg.timeout.as_millis(),
                    ),
                ));
            }
        }
        None
    }

    /// Declare `peer` dead immediately (e.g. the comm fabric escalated an
    /// unrecoverable stream). Returns `None` if a failure was already
    /// reported or the peer departed cleanly.
    pub fn declare_dead(&mut self, peer: NodeId, why: &str) -> Option<(NodeId, String)> {
        if self.failed || self.departed.get(peer.0 as usize).copied().unwrap_or(true) {
            return None;
        }
        self.failed = true;
        Some((
            peer,
            format!(
                "node {} lost contact with node {}: {why}; aborting this node instead of hanging",
                self.node.0, peer.0,
            ),
        ))
    }

    /// Broadcast a clean-shutdown goodbye to all still-live peers.
    pub fn say_goodbye(&self, comm: &CommRef) {
        for peer in self.live_peers() {
            comm.send_heartbeat(peer, true);
        }
    }

    /// Whether this monitor already reported a failure.
    pub fn failed(&self) -> bool {
        self.failed
    }

    fn live_peers(&self) -> impl Iterator<Item = NodeId> + '_ {
        let me = self.node.0;
        self.departed
            .iter()
            .enumerate()
            .filter(move |(i, departed)| *i as u64 != me && !**departed)
            .map(|(i, _)| NodeId(i as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{ChannelWorld, CommRef, Inbound};
    use std::sync::Arc;

    fn pair() -> (CommRef, CommRef) {
        let mut world = ChannelWorld::new(2);
        let c0: CommRef = Arc::new(world.communicator(NodeId(0)));
        let c1: CommRef = Arc::new(world.communicator(NodeId(1)));
        (c0, c1)
    }

    #[test]
    fn config_derives_interval_from_timeout() {
        let cfg = HeartbeatConfig::from_timeout_ms(1000);
        assert_eq!(cfg.interval, Duration::from_millis(250));
        assert_eq!(cfg.timeout, Duration::from_millis(1000));
        // Tiny timeouts clamp the interval to something sendable.
        assert_eq!(HeartbeatConfig::from_timeout_ms(20).interval, Duration::from_millis(10));
    }

    #[test]
    fn first_tick_beacons_all_peers() {
        let (c0, c1) = pair();
        let mut m = HeartbeatMonitor::new(HeartbeatConfig::from_timeout_ms(10_000), NodeId(0), 2);
        assert!(m.tick(&c0).is_none());
        assert!(matches!(c1.poll(), Some(Inbound::Heartbeat { from }) if from == NodeId(0)));
    }

    #[test]
    fn silence_past_timeout_is_an_attributed_failure() {
        let (c0, _c1) = pair();
        let mut m = HeartbeatMonitor::new(HeartbeatConfig::from_timeout_ms(30), NodeId(0), 2);
        std::thread::sleep(Duration::from_millis(60));
        let (who, err) = m.tick(&c0).expect("peer must be declared dead");
        assert_eq!(who, NodeId(1));
        assert!(err.contains("node 1"), "{err}");
        assert!(err.contains("heartbeat timeout"), "{err}");
        assert!(m.failed());
        // Reported exactly once.
        assert!(m.tick(&c0).is_none());
    }

    #[test]
    fn declare_dead_reports_once_and_respects_departures() {
        let mut m = HeartbeatMonitor::new(HeartbeatConfig::from_timeout_ms(10_000), NodeId(0), 3);
        m.mark_departed(NodeId(2));
        assert!(m.declare_dead(NodeId(2), "stream broke").is_none(), "departed peers are exempt");
        let (who, err) = m.declare_dead(NodeId(1), "stream unrecoverable").unwrap();
        assert_eq!(who, NodeId(1));
        assert!(err.contains("stream unrecoverable"), "{err}");
        assert!(m.failed());
        assert!(m.declare_dead(NodeId(1), "again").is_none(), "reported exactly once");
    }

    #[test]
    fn inbound_traffic_resets_the_clock() {
        let (c0, _c1) = pair();
        let mut m = HeartbeatMonitor::new(HeartbeatConfig::from_timeout_ms(80), NodeId(0), 2);
        for _ in 0..6 {
            std::thread::sleep(Duration::from_millis(25));
            m.mark_alive(NodeId(1));
            assert!(m.tick(&c0).is_none(), "refreshed peer must stay alive");
        }
    }

    #[test]
    fn departed_peer_is_exempt_from_detection() {
        let (c0, c1) = pair();
        let mut m = HeartbeatMonitor::new(HeartbeatConfig::from_timeout_ms(30), NodeId(0), 2);
        m.mark_departed(NodeId(1));
        std::thread::sleep(Duration::from_millis(60));
        assert!(m.tick(&c0).is_none(), "goodbye exempts the peer");
        // And goodbyes skip departed peers too.
        m.say_goodbye(&c0);
        assert!(c1.poll().is_none());
    }
}
