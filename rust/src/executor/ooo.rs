//! The out-of-order engine (§4.1).
//!
//! "We propose the *out-of-order engine* state machine to handle both
//! instruction selection and retirement. It is fed the stream of incoming
//! instructions as well as completion events, and will select the next
//! instruction to be issued to a backend queue. An instruction can either
//! be assigned *directly* when all its dependencies are satisfied; or
//! *eagerly* when all its incomplete dependencies are currently pending on
//! the same single in-order queue or host thread."

use crate::instruction::{InstructionKind, InstructionRef};
use crate::util::{DeviceId, InstructionId, JobId, MemoryId};
use std::collections::{HashMap, HashSet};

/// The backend queue an instruction is issued to. Device queues and host
/// threads are *in-order* (FIFO), which the eager-assignment path exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Per-device kernel queue.
    DeviceKernel(DeviceId),
    /// Per-device copy queue (one per direction to allow duplex overlap).
    DeviceCopy(DeviceId, Direction),
    /// One of the host worker threads.
    Host(usize),
    /// The communicator lane (sends; FIFO).
    Comm,
    /// Receive-arbitration: completion is event-driven, *not* FIFO — never
    /// eligible for eager assignment.
    Arbiter,
    /// Executed inline on the executor thread (alloc/free/horizon/epoch);
    /// retires immediately.
    Inline,
}

/// Copy direction relative to the device (duplex DMA engines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Direction {
    In,
    Out,
}

impl Lane {
    /// Whether completion order equals issue order on this lane.
    fn is_fifo(self) -> bool {
        !matches!(self, Lane::Arbiter | Lane::Inline)
    }
}

/// Classify an instruction to its backend lane. `host_lanes` is the number
/// of host worker threads (round-robin by instruction id).
pub fn target_lane(kind: &InstructionKind, host_lanes: usize, id: InstructionId) -> Lane {
    match kind {
        InstructionKind::Alloc { .. }
        | InstructionKind::Free { .. }
        | InstructionKind::Horizon
        | InstructionKind::Epoch(_) => Lane::Inline,
        InstructionKind::Copy { src_memory, dst_memory, .. } => {
            match (dst_memory.to_device(), src_memory.to_device()) {
                // Into a device: that device's inbound DMA engine.
                (Some(d), _) => Lane::DeviceCopy(d, Direction::In),
                // Out of a device to host: outbound engine.
                (None, Some(d)) => Lane::DeviceCopy(d, Direction::Out),
                // Host-to-host (resize of a host backing): host thread.
                (None, None) => Lane::Host(id.0 as usize % host_lanes.max(1)),
            }
        }
        InstructionKind::DeviceKernel { device, .. } => Lane::DeviceKernel(*device),
        InstructionKind::HostTask { .. } => Lane::Host(id.0 as usize % host_lanes.max(1)),
        InstructionKind::Send { .. } => Lane::Comm,
        InstructionKind::Receive { .. }
        | InstructionKind::SplitReceive { .. }
        | InstructionKind::AwaitReceive { .. }
        // Collective completion is event-driven (ring rounds), like the
        // receive family: never eligible for eager assignment.
        | InstructionKind::Collective { .. } => Lane::Arbiter,
    }
}

struct Waiting {
    instr: InstructionRef,
    lane: Lane,
    missing: HashSet<u64>,
}

/// The state machine: feed instructions with [`OooEngine::admit`] and
/// completion events with [`OooEngine::retire`]; both return instructions
/// that became issuable (with their lane).
pub struct OooEngine {
    host_lanes: usize,
    waiting: HashMap<u64, Waiting>,
    /// dep id → ids of waiting instructions blocked on it.
    waiters: HashMap<u64, Vec<u64>>,
    /// Completed instruction ids ≥ their job's watermark; within a job's
    /// id namespace everything below that job's watermark is complete
    /// (horizon compaction). Watermarks are per job: instruction ids carry
    /// the job tag in their high bits, and a horizon only fences the
    /// execution front of the job that emitted it — a single global
    /// watermark would falsely complete every lower-numbered job's ids.
    completed: HashSet<u64>,
    watermarks: HashMap<u64, u64>,
    /// Lane an instruction is currently issued-but-not-retired on (the
    /// eager-assignment lookup).
    in_flight: HashMap<u64, Lane>,
    /// Statistics.
    pub issued_direct: u64,
    pub issued_eager: u64,
    pub retired: u64,
    pub peak_waiting: usize,
    /// Spurious completions (duplicate or never-issued ids) tolerated and
    /// reported instead of corrupting engine state; drained by the
    /// executor into its `ExecEvent::Error` stream (§4.4).
    errors: Vec<String>,
}

impl OooEngine {
    pub fn new(host_lanes: usize) -> OooEngine {
        OooEngine {
            host_lanes,
            waiting: HashMap::new(),
            waiters: HashMap::new(),
            completed: HashSet::new(),
            watermarks: HashMap::new(),
            in_flight: HashMap::new(),
            issued_direct: 0,
            issued_eager: 0,
            retired: 0,
            peak_waiting: 0,
            errors: Vec::new(),
        }
    }

    /// Drain spurious-completion reports (§4.4 error stream).
    pub fn take_errors(&mut self) -> Vec<String> {
        std::mem::take(&mut self.errors)
    }

    fn is_complete(&self, id: u64) -> bool {
        let watermark = self.watermarks.get(&JobId::of(id).0).copied().unwrap_or(0);
        id < watermark || self.completed.contains(&id)
    }

    /// Feed a new instruction; returns it (with lane) if issuable now.
    pub fn admit(&mut self, instr: InstructionRef) -> Option<(InstructionRef, Lane)> {
        let lane = target_lane(&instr.kind, self.host_lanes, instr.id);
        let missing: HashSet<u64> = instr
            .deps
            .iter()
            .map(|(d, _)| d.0)
            .filter(|d| !self.is_complete(*d))
            .collect();
        if missing.is_empty() {
            // Direct assignment.
            self.issued_direct += 1;
            self.in_flight.insert(instr.id.0, lane);
            return Some((instr, lane));
        }
        // Eager assignment: all incomplete deps pending on the same FIFO
        // lane we target → the backend's in-order semantics guarantee
        // correct ordering (§4.1).
        if lane.is_fifo()
            && missing
                .iter()
                .all(|d| self.in_flight.get(d) == Some(&lane))
        {
            self.issued_eager += 1;
            self.in_flight.insert(instr.id.0, lane);
            return Some((instr, lane));
        }
        let id = instr.id.0;
        for d in &missing {
            self.waiters.entry(*d).or_default().push(id);
        }
        self.waiting.insert(id, Waiting { instr, lane, missing });
        self.peak_waiting = self.peak_waiting.max(self.waiting.len());
        None
    }

    /// Record a completion; returns instructions that became issuable.
    ///
    /// A duplicate completion (id already retired) or an unknown one (id
    /// never issued — e.g. a confused backend lane or arbitration bug) is
    /// tolerated: the engine's state is left untouched and the event is
    /// reported through [`OooEngine::take_errors`] instead of panicking
    /// the executor thread or double-releasing dependents.
    pub fn retire(&mut self, id: InstructionId) -> Vec<(InstructionRef, Lane)> {
        let id = id.0;
        if self.is_complete(id) {
            self.errors
                .push(format!("duplicate completion of I{id} ignored (already retired)"));
            return Vec::new();
        }
        if !self.in_flight.contains_key(&id) {
            self.errors
                .push(format!("completion of I{id} ignored: instruction was never issued"));
            return Vec::new();
        }
        self.completed.insert(id);
        self.in_flight.remove(&id);
        self.retired += 1;
        let mut out = Vec::new();
        if let Some(blocked) = self.waiters.remove(&id) {
            for bid in blocked {
                let ready = {
                    let Some(w) = self.waiting.get_mut(&bid) else { continue };
                    w.missing.remove(&id);
                    w.missing.is_empty()
                        || (w.lane.is_fifo()
                            && w.missing
                                .iter()
                                .all(|d| self.in_flight.get(d) == Some(&w.lane)))
                };
                if ready {
                    let w = self.waiting.remove(&bid).expect("retiring instruction was waiting");
                    if w.missing.is_empty() {
                        self.issued_direct += 1;
                    } else {
                        self.issued_eager += 1;
                    }
                    self.in_flight.insert(bid, w.lane);
                    out.push((w.instr, w.lane));
                }
            }
        }
        out
    }

    /// Horizon-based compaction: when a horizon instruction retires, every
    /// id below it *in the same job's namespace* is transitively complete
    /// (a horizon depends on that job's whole execution front). Other jobs'
    /// completion sets are untouched.
    pub fn compact_below(&mut self, horizon: InstructionId) {
        let job = JobId::of(horizon.0);
        let wm = self.watermarks.entry(job.0).or_insert(0);
        *wm = (*wm).max(horizon.0);
        let wm = *wm;
        self.completed.retain(|id| JobId::of(*id) != job || *id >= wm);
    }

    /// Number of instructions admitted but not yet issuable.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Number of instructions issued but not yet retired.
    pub fn in_flight_len(&self) -> usize {
        self.in_flight.len()
    }

    /// True when nothing is pending anywhere.
    pub fn is_drained(&self) -> bool {
        self.waiting.is_empty() && self.in_flight.is_empty()
    }

    /// Human-readable dump of pending state (stall diagnostics).
    pub fn debug_pending(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let mut waiting: Vec<_> = self.waiting.values().collect();
        waiting.sort_by_key(|w| w.instr.id);
        for w in waiting.iter().take(20) {
            let _ = writeln!(
                s,
                "  waiting {} on {:?} (lane {:?})",
                w.instr.label(),
                w.missing.iter().collect::<Vec<_>>(),
                w.lane
            );
        }
        let mut inflight: Vec<_> = self.in_flight.iter().collect();
        inflight.sort_by_key(|(id, _)| **id);
        for (id, lane) in inflight.iter().take(20) {
            let _ = writeln!(s, "  in-flight I{id} on {lane:?}");
        }
        s
    }
}

/// Memory id of the lane's device, if any (diagnostics).
pub fn lane_memory(lane: Lane) -> Option<MemoryId> {
    match lane {
        Lane::DeviceKernel(d) | Lane::DeviceCopy(d, _) => Some(MemoryId::device_native(d)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DepKind;
    use crate::instruction::Instruction;
    use std::sync::Arc;

    fn kernel(id: u64, dev: u64, deps: &[u64]) -> InstructionRef {
        Arc::new(Instruction {
            id: InstructionId(id),
            kind: InstructionKind::DeviceKernel {
                device: DeviceId(dev),
                chunk: crate::grid::GridBox::d1(0, 1),
                bindings: vec![],
                work_per_item: 1.0,
                kernel: None,
            },
            deps: deps.iter().map(|d| (InstructionId(*d), DepKind::Dataflow)).collect(),
            task: None,
        })
    }

    fn horizon(id: u64, deps: &[u64]) -> InstructionRef {
        Arc::new(Instruction {
            id: InstructionId(id),
            kind: InstructionKind::Horizon,
            deps: deps.iter().map(|d| (InstructionId(*d), DepKind::Sync)).collect(),
            task: None,
        })
    }

    #[test]
    fn direct_assignment_when_deps_met() {
        let mut e = OooEngine::new(2);
        let a = e.admit(kernel(0, 0, &[]));
        assert!(a.is_some());
        assert_eq!(a.unwrap().1, Lane::DeviceKernel(DeviceId(0)));
        assert_eq!(e.issued_direct, 1);
    }

    #[test]
    fn blocked_until_retire() {
        let mut e = OooEngine::new(2);
        e.admit(kernel(0, 0, &[])).unwrap();
        // Different device → not eager-eligible.
        assert!(e.admit(kernel(1, 1, &[0])).is_none());
        assert_eq!(e.waiting_len(), 1);
        let ready = e.retire(InstructionId(0));
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].0.id, InstructionId(1));
    }

    #[test]
    fn eager_assignment_same_lane() {
        // Dep pending on device 0's kernel queue; successor targets the
        // same queue → issued immediately (FIFO guarantees order).
        let mut e = OooEngine::new(2);
        e.admit(kernel(0, 0, &[])).unwrap();
        let eager = e.admit(kernel(1, 0, &[0]));
        assert!(eager.is_some(), "same-lane successor must issue eagerly");
        assert_eq!(e.issued_eager, 1);
        // Retiring in FIFO order works fine.
        assert!(e.retire(InstructionId(0)).is_empty());
        assert!(e.retire(InstructionId(1)).is_empty());
        assert!(e.is_drained());
    }

    #[test]
    fn eager_chains_extend() {
        let mut e = OooEngine::new(2);
        e.admit(kernel(0, 0, &[])).unwrap();
        assert!(e.admit(kernel(1, 0, &[0])).is_some());
        assert!(e.admit(kernel(2, 0, &[1])).is_some());
        assert!(e.admit(kernel(3, 0, &[0, 1, 2])).is_some());
        assert_eq!(e.issued_eager, 3);
    }

    #[test]
    fn no_eager_across_lanes() {
        let mut e = OooEngine::new(2);
        e.admit(kernel(0, 0, &[])).unwrap();
        e.admit(kernel(1, 1, &[])).unwrap();
        // Deps on two different lanes → must wait.
        assert!(e.admit(kernel(2, 0, &[0, 1])).is_none());
        assert!(e.retire(InstructionId(0)).is_empty());
        // Now the only incomplete dep (1) is on lane D1 but target is D0 →
        // still waiting.
        assert_eq!(e.waiting_len(), 1);
        let ready = e.retire(InstructionId(1));
        assert_eq!(ready.len(), 1);
    }

    #[test]
    fn eager_becomes_possible_after_partial_retire() {
        let mut e = OooEngine::new(2);
        e.admit(kernel(0, 1, &[])).unwrap(); // lane D1
        e.admit(kernel(1, 0, &[])).unwrap(); // lane D0
        assert!(e.admit(kernel(2, 0, &[0, 1])).is_none());
        // Retire the D1 dep: remaining incomplete dep (1) is on D0 = target
        // lane → eager issue.
        let ready = e.retire(InstructionId(0));
        assert_eq!(ready.len(), 1);
        assert_eq!(e.issued_eager, 1);
    }

    #[test]
    fn arbiter_lane_never_eager() {
        let mut e = OooEngine::new(2);
        let recv = Arc::new(Instruction {
            id: InstructionId(0),
            kind: InstructionKind::Receive {
                buffer: crate::util::BufferId(0),
                region: crate::grid::Region::empty(),
                dst_memory: MemoryId::HOST,
                dst_alloc: crate::util::AllocationId(1),
                dst_box: crate::grid::GridBox::d1(0, 1),
                transfer: crate::util::TaskId(0),
            },
            deps: vec![],
            task: None,
        });
        e.admit(recv).unwrap();
        let recv2 = Arc::new(Instruction {
            id: InstructionId(1),
            kind: InstructionKind::Receive {
                buffer: crate::util::BufferId(0),
                region: crate::grid::Region::empty(),
                dst_memory: MemoryId::HOST,
                dst_alloc: crate::util::AllocationId(1),
                dst_box: crate::grid::GridBox::d1(0, 1),
                transfer: crate::util::TaskId(0),
            },
            deps: vec![(InstructionId(0), DepKind::Anti)],
            task: None,
        });
        assert!(e.admit(recv2).is_none(), "arbiter completions are not FIFO");
    }

    #[test]
    fn compaction_below_horizon() {
        let mut e = OooEngine::new(2);
        for i in 0..10 {
            e.admit(kernel(i, 0, &[])).unwrap();
            e.retire(InstructionId(i));
        }
        e.admit(horizon(10, &[9])).unwrap();
        e.retire(InstructionId(10));
        e.compact_below(InstructionId(10));
        // Later instructions with deps below the watermark admit directly.
        assert!(e.admit(kernel(11, 0, &[3, 7])).is_some());
        assert!(e.completed.len() <= 2);
    }

    /// A horizon from one job must not mark another job's in-flight or
    /// future instructions complete: watermarks are per job-namespace.
    #[test]
    fn compaction_is_job_scoped() {
        let mut e = OooEngine::new(2);
        let base = JobId(1).base();
        // Job 0 runs a few instructions and a horizon well above job-0 ids
        // would sit *below* job-1's namespace start.
        for i in 0..4 {
            e.admit(kernel(i, 0, &[])).unwrap();
            e.retire(InstructionId(i));
        }
        e.admit(horizon(4, &[3])).unwrap();
        e.retire(InstructionId(4));
        e.compact_below(InstructionId(4));
        // Job 1's first instruction has an unmet dep inside job 1: it must
        // NOT admit directly even though its dep id is far above job 0's
        // watermark — and conversely job-1 compaction must not complete it.
        assert!(e.admit(kernel(base + 1, 1, &[base])).is_none());
        // Job 1 retires its dep, runs a horizon, compacts.
        e.admit(kernel(base, 0, &[])).unwrap();
        let ready = e.retire(InstructionId(base));
        assert_eq!(ready.len(), 1);
        e.retire(InstructionId(base + 1));
        e.admit(horizon(base + 2, &[base + 1])).unwrap();
        e.retire(InstructionId(base + 2));
        e.compact_below(InstructionId(base + 2));
        // Job 1 ids below its watermark are complete; job 0's namespace is
        // untouched (id 5 was never run → still incomplete).
        assert!(e.admit(kernel(base + 3, 0, &[base, base + 1])).is_some());
        assert!(e.admit(kernel(6, 1, &[5])).is_none());
        assert!(e.take_errors().is_empty());
    }

    /// Satellite regression: a double completion used to trip a debug
    /// assert / corrupt release-mode state (`waiting.remove(..).unwrap()`
    /// family); it must now be tolerated and reported, leaving the engine
    /// fully functional.
    #[test]
    fn duplicate_completion_is_reported_not_fatal() {
        let mut e = OooEngine::new(2);
        e.admit(kernel(0, 0, &[])).unwrap();
        assert!(e.admit(kernel(1, 1, &[0])).is_none());
        assert_eq!(e.retire(InstructionId(0)).len(), 1);
        assert!(e.take_errors().is_empty());
        // Inject the double completion.
        let newly = e.retire(InstructionId(0));
        assert!(newly.is_empty(), "duplicate must not re-release dependents");
        let errors = e.take_errors();
        assert_eq!(errors.len(), 1, "{errors:?}");
        assert!(errors[0].contains("duplicate completion of I0"), "{errors:?}");
        assert_eq!(e.retired, 1, "stats must not double-count");
        // Engine still drains normally afterwards.
        assert!(e.retire(InstructionId(1)).is_empty());
        assert!(e.is_drained());
        assert!(e.take_errors().is_empty());
    }

    /// A completion for an id that was never issued (confused lane /
    /// arbitration bug) is reported and ignored.
    #[test]
    fn unknown_completion_is_reported_not_fatal() {
        let mut e = OooEngine::new(2);
        e.admit(kernel(0, 0, &[])).unwrap();
        assert!(e.retire(InstructionId(77)).is_empty());
        let errors = e.take_errors();
        assert_eq!(errors.len(), 1);
        assert!(errors[0].contains("never issued"), "{errors:?}");
        // The legitimate completion still works.
        assert!(e.retire(InstructionId(0)).is_empty());
        assert!(e.is_drained());
    }

    /// Duplicate completion below the horizon watermark (after compaction)
    /// is classified as a duplicate too.
    #[test]
    fn duplicate_completion_below_watermark_reported() {
        let mut e = OooEngine::new(2);
        for i in 0..4 {
            e.admit(kernel(i, 0, &[])).unwrap();
            e.retire(InstructionId(i));
        }
        e.admit(horizon(4, &[3])).unwrap();
        e.retire(InstructionId(4));
        e.compact_below(InstructionId(4));
        assert!(e.retire(InstructionId(2)).is_empty());
        let errors = e.take_errors();
        assert!(errors[0].contains("duplicate"), "{errors:?}");
    }

    #[test]
    fn lane_classification() {
        use crate::instruction::InstructionKind as K;
        let host_lanes = 4;
        assert_eq!(
            target_lane(&K::Horizon, host_lanes, InstructionId(0)),
            Lane::Inline
        );
        let copy_in = K::Copy {
            buffer: crate::util::BufferId(0),
            copy_box: crate::grid::GridBox::d1(0, 1),
            src_memory: MemoryId(1),
            dst_memory: MemoryId(3),
            src_alloc: crate::util::AllocationId(1),
            src_box: crate::grid::GridBox::d1(0, 1),
            dst_alloc: crate::util::AllocationId(2),
            dst_box: crate::grid::GridBox::d1(0, 1),
        };
        assert_eq!(
            target_lane(&copy_in, host_lanes, InstructionId(0)),
            Lane::DeviceCopy(DeviceId(1), Direction::In)
        );
    }
}
