//! Per-job demultiplexing of the executor's event stream.
//!
//! A multi-tenant cluster runs one executor thread but hands out one
//! [`crate::driver::Queue`] per job, and each queue's `wait()`/`fence()`
//! must observe *its own* job's epochs and §4.4 errors — one job's
//! out-of-bounds kernel must never fail another job's fence. The executor
//! therefore tags every event with an [`EventRoute`] at the emission site
//! (where attribution is still known), and the [`EventHub`] sorts the
//! single mpsc stream into per-job queues on the consumer side.
//!
//! Cluster-routed events (peer death, unattributable engine anomalies) are
//! broadcast: every registered job sees a clone, because every job's
//! pending work is affected.

use super::ExecEvent;
use crate::task::EpochAction;
use crate::util::JobId;
use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Mutex};

/// Where an executor event is delivered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventRoute {
    /// Attributed to one job: delivered only to that job's consumers.
    Job(JobId),
    /// Cluster-wide condition: broadcast to every registered job.
    Cluster,
}

struct Slots {
    queues: HashMap<u64, VecDeque<ExecEvent>>,
    /// The executor thread exited and dropped its sender.
    closed: bool,
}

struct HubInner {
    rx: Mutex<mpsc::Receiver<(EventRoute, ExecEvent)>>,
    slots: Mutex<Slots>,
}

/// Clonable consumer side of the executor event stream; each clone shares
/// the underlying per-job queues.
#[derive(Clone)]
pub struct EventHub {
    inner: Arc<HubInner>,
}

impl EventHub {
    /// Wrap the executor's event receiver. Job 0 (the single-tenant
    /// default) is pre-registered so cluster broadcasts always have at
    /// least one destination.
    pub fn new(rx: mpsc::Receiver<(EventRoute, ExecEvent)>) -> EventHub {
        let hub = EventHub {
            inner: Arc::new(HubInner {
                rx: Mutex::new(rx),
                slots: Mutex::new(Slots { queues: HashMap::new(), closed: false }),
            }),
        };
        hub.register(JobId(0));
        hub
    }

    /// Register a job as a broadcast destination. Must happen before the
    /// job submits work, or a cluster-wide event raced in between would
    /// miss it.
    pub fn register(&self, job: JobId) {
        self.inner.slots.lock().expect("event hub lock poisoned").queues.entry(job.0).or_default();
    }

    /// Drain whatever is currently in the shared receiver into the per-job
    /// queues. Contention-tolerant: if another consumer holds the receiver
    /// it is already pumping on our behalf.
    fn pump(&self) {
        let Ok(rx) = self.inner.rx.try_lock() else { return };
        let mut slots = self.inner.slots.lock().expect("event hub lock poisoned");
        loop {
            match rx.try_recv() {
                Ok((EventRoute::Job(job), ev)) => {
                    slots.queues.entry(job.0).or_default().push_back(ev);
                }
                Ok((EventRoute::Cluster, ev)) => {
                    for q in slots.queues.values_mut() {
                        q.push_back(ev.clone());
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    slots.closed = true;
                    break;
                }
            }
        }
    }

    /// Non-blocking receive of the next event routed to `job`.
    pub fn try_recv(&self, job: JobId) -> Option<ExecEvent> {
        self.pump();
        self.inner
            .slots
            .lock()
            .expect("event hub lock poisoned")
            .queues
            .entry(job.0)
            .or_default()
            .pop_front()
    }

    /// Blocking receive; `None` once the executor has exited and `job`'s
    /// queue is fully drained.
    pub fn recv(&self, job: JobId) -> Option<ExecEvent> {
        loop {
            if let Some(ev) = self.try_recv(job) {
                return Some(ev);
            }
            if self.inner.slots.lock().expect("event hub lock poisoned").closed {
                // Re-check after observing closed: pump() may have landed a
                // final event between our pop and the flag read.
                return self.try_recv(job);
            }
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
    }

    /// Block until `job` reports an epoch of `action`; returns the side
    /// events (errors, faults) seen on the way, which is also the
    /// exhaustive list if the executor dies before the epoch arrives.
    pub fn wait_epoch(&self, job: JobId, action: EpochAction) -> Vec<ExecEvent> {
        let mut side = Vec::new();
        loop {
            match self.recv(job) {
                Some(ExecEvent::Epoch(a, _)) if a == action => return side,
                Some(ev) => side.push(ev),
                None => return side,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::InstructionId;

    #[test]
    fn job_events_are_isolated() {
        let (tx, rx) = mpsc::channel();
        let hub = EventHub::new(rx);
        hub.register(JobId(1));
        tx.send((EventRoute::Job(JobId(1)), ExecEvent::Error("job1 oob".into()))).unwrap();
        tx.send((EventRoute::Job(JobId(0)), ExecEvent::Error("job0 oob".into()))).unwrap();
        match hub.try_recv(JobId(0)) {
            Some(ExecEvent::Error(m)) => assert_eq!(m, "job0 oob"),
            other => panic!("{other:?}"),
        }
        match hub.try_recv(JobId(1)) {
            Some(ExecEvent::Error(m)) => assert_eq!(m, "job1 oob"),
            other => panic!("{other:?}"),
        }
        assert!(hub.try_recv(JobId(0)).is_none());
        assert!(hub.try_recv(JobId(1)).is_none());
    }

    #[test]
    fn cluster_events_broadcast_to_all_registered_jobs() {
        let (tx, rx) = mpsc::channel();
        let hub = EventHub::new(rx);
        hub.register(JobId(1));
        tx.send((EventRoute::Cluster, ExecEvent::Error("peer died".into()))).unwrap();
        for job in [JobId(0), JobId(1)] {
            match hub.try_recv(job) {
                Some(ExecEvent::Error(m)) => assert!(m.contains("peer died")),
                other => panic!("{job:?}: {other:?}"),
            }
        }
    }

    #[test]
    fn wait_epoch_skips_other_jobs_and_collects_side_events() {
        let (tx, rx) = mpsc::channel();
        let hub = EventHub::new(rx);
        hub.register(JobId(1));
        let base = JobId(1).base();
        tx.send((EventRoute::Job(JobId(1)), ExecEvent::Fault("retransmit".into()))).unwrap();
        tx.send((
            EventRoute::Job(JobId(0)),
            ExecEvent::Epoch(EpochAction::Barrier, InstructionId(7)),
        ))
        .unwrap();
        tx.send((
            EventRoute::Job(JobId(1)),
            ExecEvent::Epoch(EpochAction::Barrier, InstructionId(base + 7)),
        ))
        .unwrap();
        let side = hub.wait_epoch(JobId(1), EpochAction::Barrier);
        assert_eq!(side.len(), 1, "{side:?}");
        assert!(matches!(&side[0], ExecEvent::Fault(_)));
        // Job 0's own epoch is still waiting in its queue, untouched.
        assert!(matches!(
            hub.try_recv(JobId(0)),
            Some(ExecEvent::Epoch(EpochAction::Barrier, _))
        ));
    }

    #[test]
    fn recv_returns_none_after_close_and_drain() {
        let (tx, rx) = mpsc::channel();
        let hub = EventHub::new(rx);
        tx.send((EventRoute::Job(JobId(0)), ExecEvent::Error("last".into()))).unwrap();
        drop(tx);
        assert!(matches!(hub.recv(JobId(0)), Some(ExecEvent::Error(_))));
        assert!(hub.recv(JobId(0)).is_none());
    }
}
