//! The executor: out-of-order instruction dispatch (§4.1–4.2).
//!
//! A dedicated executor thread consumes the instruction stream from the
//! scheduler, keeps multiple instructions in flight across per-device
//! in-order queues / host threads / the communicator, and polls for
//! completions. Instruction selection and retirement run through the
//! [`OooEngine`]; inbound transfers through the [`ReceiveArbiter`].

pub mod arbitration;
pub mod arena;
pub mod collective;
pub mod events;
pub mod fair;
pub mod heartbeat;
pub mod lanes;
pub mod ooo;

pub use arbitration::ReceiveArbiter;
pub use arena::{copy_between, AllocBuf, Arena};
pub use collective::CollectiveEngine;
pub use events::{EventHub, EventRoute};
pub use fair::ReadySet;
pub use heartbeat::{HeartbeatConfig, HeartbeatMonitor};
pub use ooo::{Lane, OooEngine};

use crate::comm::{CommRef, Inbound};
use crate::dtype::DType;
use crate::grid::{GridBox, Point, Region};
use crate::instruction::{AccessBinding, InstructionKind, InstructionRef};
use crate::scheduler::SchedulerOut;
use crate::task::EpochAction;
use crate::trace;
use crate::util::{spsc, InstructionId, JobId, NodeId};
use lanes::{Job, LanePool};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

// ──────────────────────────────────────────────────────────────────────────
// Kernel interface
// ──────────────────────────────────────────────────────────────────────────

/// Accessor view handed to kernel/host-task functors: typed element access
/// with §4.4 bounds checking against the range-mapper-declared region.
pub struct BindingView {
    pub binding: AccessBinding,
    buf: Arc<AllocBuf>,
    /// Bounding box of out-of-bounds accesses, if any (§4.4: "will report
    /// their bounding box in a runtime error message after the kernel").
    oob: std::cell::Cell<Option<(Point, Point)>>,
}

macro_rules! typed_access {
    ($read:ident, $write:ident, $t:ty) => {
        /// Read one element; out-of-region reads are recorded and return 0.
        #[inline]
        pub fn $read(&self, p: Point) -> $t {
            if !self.in_region(p) {
                self.record_oob(p);
                return <$t>::default();
            }
            unsafe { self.buf.read::<$t>(p) }
        }

        /// Write one element; out-of-region writes are recorded and dropped.
        #[inline]
        pub fn $write(&self, p: Point, v: $t) {
            if !self.in_region(p) {
                self.record_oob(p);
                return;
            }
            unsafe { self.buf.write::<$t>(p, v) }
        }
    };
}

impl BindingView {
    #[inline]
    fn in_region(&self, p: Point) -> bool {
        self.binding.region.boxes().iter().any(|b| b.contains_point(p))
    }

    fn record_oob(&self, p: Point) {
        let next = match self.oob.get() {
            None => (p, p),
            Some((lo, hi)) => (lo.min(p), hi.max(p)),
        };
        self.oob.set(Some(next));
    }

    typed_access!(read_f32, write_f32, f32);
    typed_access!(read_f64, write_f64, f64);
    typed_access!(read_i32, write_i32, i32);
    typed_access!(read_u32, write_u32, u32);

    /// Scalar element type of the accessed buffer (shared [`DType`],
    /// carried through the instruction layer from the buffer registry).
    pub fn dtype(&self) -> DType {
        self.binding.dtype
    }

    /// Scalar lanes per element (3 for the "double3"-style N-body state).
    pub fn lanes(&self) -> usize {
        self.binding.lanes
    }

    /// Read a 12-byte "double3"-style element as three f32 lanes.
    #[inline]
    pub fn read_elem3(&self, p: Point) -> [f32; 3] {
        if !self.in_region(p) {
            self.record_oob(p);
            return [0.0; 3];
        }
        unsafe {
            [
                self.buf.read_lane_f32(p, 0),
                self.buf.read_lane_f32(p, 1),
                self.buf.read_lane_f32(p, 2),
            ]
        }
    }

    /// Write a 12-byte "double3"-style element as three f32 lanes.
    #[inline]
    pub fn write_elem3(&self, p: Point, v: [f32; 3]) {
        if !self.in_region(p) {
            self.record_oob(p);
            return;
        }
        unsafe {
            self.buf.write_lane_f32(p, 0, v[0]);
            self.buf.write_lane_f32(p, 1, v[1]);
            self.buf.write_lane_f32(p, 2, v[2]);
        }
    }

    /// Raw dense bytes of the accessed region's bounding box (PJRT input
    /// marshalling).
    pub fn read_region_bytes(&self) -> Vec<u8> {
        self.buf.read_box(&self.binding.region.bounding_box())
    }

    /// Scatter dense bytes back over the region's bounding box (PJRT output
    /// marshalling).
    pub fn write_region_bytes(&self, bytes: &[u8]) {
        self.buf.write_box(&self.binding.region.bounding_box(), bytes);
    }
}

/// Execution context for one kernel chunk or host-task chunk.
pub struct KernelCtx {
    /// The index-space chunk this launch covers.
    pub chunk: GridBox,
    /// Accessor views, in declaration order.
    pub views: Vec<BindingView>,
}

impl KernelCtx {
    pub fn view(&self, i: usize) -> &BindingView {
        &self.views[i]
    }
}

/// A registered kernel/host-task implementation.
pub type KernelFn = Arc<dyn Fn(&KernelCtx) + Send + Sync>;

#[derive(Default)]
struct RegistryTables {
    kernels: HashMap<String, KernelFn>,
    host_tasks: HashMap<String, KernelFn>,
}

/// Name → implementation registry. Device kernels resolve by their AOT
/// artifact name (or task name as fallback); host tasks by task name.
/// Cloning shares the underlying tables, so registrations made after the
/// executor thread spawned (e.g. fence host-tasks) are visible to it.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<std::sync::RwLock<RegistryTables>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register_kernel(&self, name: impl Into<String>, f: KernelFn) -> &Self {
        self.inner.write().expect("registry lock poisoned").kernels.insert(name.into(), f);
        self
    }

    pub fn register_host_task(&self, name: impl Into<String>, f: KernelFn) -> &Self {
        self.inner.write().expect("registry lock poisoned").host_tasks.insert(name.into(), f);
        self
    }

    fn lookup(&self, name: &str, host: bool) -> Option<KernelFn> {
        let t = self.inner.read().expect("registry lock poisoned");
        if host { t.host_tasks.get(name).cloned() } else { t.kernels.get(name).cloned() }
    }
}

// ──────────────────────────────────────────────────────────────────────────
// Executor
// ──────────────────────────────────────────────────────────────────────────

/// Executor configuration.
#[derive(Clone)]
pub struct ExecutorConfig {
    pub node: NodeId,
    /// Host worker threads for host tasks and host-side copies.
    pub host_lanes: usize,
    pub registry: Registry,
    /// Peer liveness monitoring (multi-process clusters). `None` disables
    /// it — the right default in-process, where a "dead peer" is a panic
    /// the driver already surfaces.
    pub heartbeat: Option<HeartbeatConfig>,
    /// Weighted round-robin dispatch across jobs (multi-tenant clusters).
    /// `false` degrades to a single global FIFO — the fairness ablation.
    pub fair_share: bool,
    /// Per-job cap on dispatched-but-not-retired instructions; 0 means
    /// unlimited.
    pub admission_limit: usize,
    /// Per-job round-robin weights, indexed by job id; missing entries
    /// default to 1.
    pub job_weights: Vec<u32>,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            node: NodeId(0),
            host_lanes: 4,
            registry: Registry::new(),
            heartbeat: None,
            fair_share: true,
            admission_limit: 0,
            job_weights: Vec::new(),
        }
    }
}

/// Events surfaced to the main thread. Every event is emitted with an
/// [`EventRoute`] naming the job it belongs to (or the whole cluster), and
/// the [`EventHub`] delivers it only to that job's consumers — one job's
/// error must never fail another job's fence.
#[derive(Debug, Clone)]
pub enum ExecEvent {
    /// An epoch instruction retired (barrier/shutdown reached).
    Epoch(EpochAction, InstructionId),
    /// A runtime correctness error (§4.4), e.g. accessor out-of-bounds.
    Error(String),
    /// A non-fatal comm-fabric fault notice (corrupt frame rejected,
    /// reconnect, retransmission). The fabric already repaired or contained
    /// the damage; these are surfaced for observability, not failure.
    Fault(String),
}

/// Final statistics returned by [`ExecutorHandle::join`].
#[derive(Debug, Clone, Default)]
pub struct ExecutorStats {
    pub issued_direct: u64,
    pub issued_eager: u64,
    pub retired: u64,
    pub peak_arena_bytes: u64,
    pub peak_waiting: usize,
    pub lanes_spawned: usize,
}

/// The executor state machine. Normally driven by its own thread via
/// [`ExecutorHandle::spawn`]; `run_to_shutdown` exposes the loop for tests.
pub struct Executor {
    cfg: ExecutorConfig,
    comm: CommRef,
    ooo: OooEngine,
    arbiter: ReceiveArbiter,
    collectives: CollectiveEngine,
    arena: Arena,
    lanes: LanePool,
    lane_completions: mpsc::Receiver<InstructionId>,
    events: mpsc::Sender<(EventRoute, ExecEvent)>,
    ready: ReadySet,
    monitor: Option<HeartbeatMonitor>,
}

impl Executor {
    pub fn new(
        cfg: ExecutorConfig,
        comm: CommRef,
        events: mpsc::Sender<(EventRoute, ExecEvent)>,
    ) -> Executor {
        let (ctx, crx) = mpsc::channel();
        let node = cfg.node.0;
        // Liveness monitoring only makes sense with actual peers.
        let monitor = cfg
            .heartbeat
            .filter(|_| comm.num_nodes() > 1)
            .map(|hc| HeartbeatMonitor::new(hc, cfg.node, comm.num_nodes()));
        Executor {
            ooo: OooEngine::new(cfg.host_lanes),
            arbiter: ReceiveArbiter::new(),
            collectives: CollectiveEngine::new(),
            arena: Arena::new(),
            lanes: LanePool::new(ctx, node),
            lane_completions: crx,
            ready: ReadySet::new(cfg.fair_share, cfg.admission_limit, cfg.job_weights.clone()),
            cfg,
            comm,
            events,
            monitor,
        }
    }

    fn emit(&self, route: EventRoute, ev: ExecEvent) {
        let _ = self.events.send((route, ev));
    }

    /// Main loop: poll inputs, retire completions, dispatch ready
    /// instructions; returns when the scheduler has hung up and all work is
    /// drained. A job's shutdown epoch does *not* stop the loop — other
    /// jobs sharing this executor may still be running; the scheduler
    /// thread closing the inbox is the cluster-wide shutdown signal.
    pub fn run_to_shutdown(mut self, inbox: spsc::Receiver<SchedulerOut>) -> ExecutorStats {
        let mut idle_spins = 0u32;
        let mut inbox_open = true;
        let mut last_progress = std::time::Instant::now();
        let mut stall_reported = false;
        let mut heartbeat_failed = false;
        loop {
            let mut progressed = false;

            // 0. Liveness: beacon peers and check their silence. Runs every
            // iteration — even a saturated executor must keep beating, or
            // *it* would look dead to its peers.
            if let Some(m) = &mut self.monitor {
                if let Some((peer, err)) = m.tick(&self.comm) {
                    // Abort the node: pending receives from the dead peer
                    // can never complete, so draining would hang forever.
                    self.abort_unreachable(peer, err);
                    heartbeat_failed = true;
                    break;
                }
            }

            // 1. New instructions + outbound pilots from the scheduler.
            if inbox_open {
                loop {
                    match inbox.try_recv() {
                        Ok(batch) => {
                            progressed = true;
                            // §4.4 scheduler errors (e.g. overlapping
                            // writes) surface through the same event stream
                            // as executor errors, attributed to the job
                            // whose compilation raised them.
                            for e in batch.errors {
                                self.emit(EventRoute::Job(batch.job), ExecEvent::Error(e));
                            }
                            for init in batch.user_inits {
                                self.arena.init_user(
                                    init.alloc,
                                    init.covers,
                                    init.elem_size,
                                    &init.bytes,
                                );
                            }
                            for p in batch.pilots {
                                self.comm.send_pilot(p);
                            }
                            for i in batch.instructions {
                                if let Some((instr, lane)) = self.ooo.admit(i) {
                                    self.ready.push(instr, lane);
                                }
                            }
                        }
                        Err(None) => break,
                        Err(Some(_)) => {
                            inbox_open = false;
                            break;
                        }
                    }
                }
            }

            // 2. Inbound communication → receive arbitration. Any inbound
            // message is proof of life for its sender — except fault
            // notices, which the fabric generates locally *about* a peer
            // and must not refresh that peer's liveness clock.
            let mut inbound_data = false;
            let mut fatal_fault: Option<(NodeId, String)> = None;
            let node = self.cfg.node.0;
            while let Some(m) = self.comm.poll() {
                progressed = true;
                if !matches!(m, Inbound::Fault { .. }) {
                    if let Some(mon) = &mut self.monitor {
                        mon.mark_alive(m.from());
                    }
                }
                match m {
                    Inbound::Pilot(p) => {
                        trace::instant(
                            node,
                            trace::Track::CommIn,
                            trace::EventKind::PilotIn { from: p.from.0 },
                        );
                        self.arbiter.on_pilot(p)
                    }
                    Inbound::Data { from, msg, bytes } => {
                        inbound_data = true;
                        trace::instant(
                            node,
                            trace::Track::CommIn,
                            trace::EventKind::DataIn { from: from.0, bytes: bytes.len() as u64 },
                        );
                        self.arbiter.on_data(from, msg, bytes)
                    }
                    Inbound::Heartbeat { from } => {
                        trace::instant(
                            node,
                            trace::Track::CommIn,
                            trace::EventKind::HeartbeatIn { from: from.0 },
                        );
                    }
                    Inbound::Goodbye { from } => {
                        if let Some(mon) = &mut self.monitor {
                            mon.mark_departed(from);
                        }
                    }
                    Inbound::Fault { from, kind, detail, fatal } => {
                        use crate::comm::FaultKind;
                        // Fault trace events are emitted here, on the
                        // executor thread, so per-(node, track) timestamp
                        // monotonicity holds (reader threads race).
                        trace::instant(
                            node,
                            trace::Track::CommIn,
                            match kind {
                                FaultKind::Reconnect => {
                                    trace::EventKind::Reconnect { peer: from.0 }
                                }
                                FaultKind::Retransmit => {
                                    trace::EventKind::Retransmit { peer: from.0 }
                                }
                                k => trace::EventKind::CommFault {
                                    from: from.0,
                                    what: k.name(),
                                    fatal,
                                },
                            },
                        );
                        if fatal {
                            fatal_fault = Some((from, detail));
                            break;
                        }
                        // Non-fatal: the fabric repaired or contained it
                        // (CRC reject + retransmit, reconnect, dedup).
                        // Report for observability without failing the run.
                        // Link-level, so no single job owns it: broadcast.
                        self.emit(
                            EventRoute::Cluster,
                            ExecEvent::Fault(format!("[{}] {detail}", kind.name())),
                        );
                    }
                }
            }
            // Unrecoverable peer loss escalated by the comm fabric: abort
            // like a heartbeat timeout — pending receives from that peer
            // can never complete.
            if let Some((peer, detail)) = fatal_fault {
                let attributed = match &mut self.monitor {
                    Some(mon) => mon.declare_dead(peer, &detail),
                    None => Some((peer, format!("lost contact with node {}: {detail}", peer.0))),
                };
                if let Some((peer, err)) = attributed {
                    self.abort_unreachable(peer, err);
                    heartbeat_failed = true;
                    break;
                }
            }
            // New data may unblock collective ring rounds (sends and/or
            // completions); pumping on other iterations is pointless since
            // rounds only advance on arrivals.
            if inbound_data {
                self.pump_collectives();
            }
            for id in self.arbiter.take_completions() {
                progressed = true;
                self.finish(id);
            }

            // 3. Lane completions.
            while let Ok(id) = self.lane_completions.try_recv() {
                progressed = true;
                self.finish(id);
            }

            // 4. Dispatch everything issuable, arbitrated per job
            // (weighted round-robin + admission limits); entries held back
            // by an admission cap stay queued and re-arm when their job's
            // in-flight instructions retire.
            while let Some((instr, lane)) = self.ready.next() {
                progressed = true;
                self.dispatch(instr, lane);
            }

            // Spurious completions / protocol anomalies tolerated by the
            // OoO engine and receive arbiter surface as §4.4 errors rather
            // than killing the executor thread.
            self.drain_engine_errors();

            if !inbox_open && self.ooo.is_drained() && self.ready.is_empty() {
                // Scheduler hung up and nothing pending: every job drained.
                break;
            }

            if progressed {
                idle_spins = 0;
                last_progress = std::time::Instant::now();
                stall_reported = false;
            } else {
                // Stall detector: a runtime with pending work but no
                // progress for seconds indicates a dependency or
                // arbitration bug — report once, loudly.
                if !stall_reported
                    && !self.ooo.is_drained()
                    && last_progress.elapsed() > std::time::Duration::from_secs(5)
                {
                    stall_reported = true;
                    let msg = format!(
                        "executor stalled on node {}: {} waiting, {} in flight, arbiter idle={}, {} collectives in flight",
                        self.cfg.node,
                        self.ooo.waiting_len(),
                        self.ooo.in_flight_len(),
                        self.arbiter.is_idle(),
                        self.collectives.len(),
                    );
                    eprintln!(
                        "{msg}\n{}{}{}",
                        self.ooo.debug_pending(),
                        self.arbiter.debug_state(),
                        self.collectives.debug_state()
                    );
                    self.emit(EventRoute::Cluster, ExecEvent::Error(msg));
                }
                // Polling loop etiquette: spin briefly, then yield, then
                // sleep — idle executors must not starve worker lanes on
                // small machines.
                idle_spins += 1;
                if idle_spins < 64 {
                    std::hint::spin_loop();
                } else if idle_spins < 192 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                }
            }
        }
        self.drain_engine_errors();
        // Tell surviving peers this node's silence from here on is a clean
        // departure, not a death (skipped after a heartbeat failure: peers
        // of a dying cluster should fail attributably too).
        if !heartbeat_failed {
            if let Some(m) = &self.monitor {
                m.say_goodbye(&self.comm);
            }
        }
        let stats = ExecutorStats {
            issued_direct: self.ooo.issued_direct,
            issued_eager: self.ooo.issued_eager,
            retired: self.ooo.retired,
            peak_arena_bytes: self.arena.peak_bytes,
            peak_waiting: self.ooo.peak_waiting,
            lanes_spawned: self.lanes.len(),
        };
        self.lanes.shutdown();
        trace::flush_thread();
        stats
    }

    /// Retire `id` and queue newly-ready dependents. The single retirement
    /// point: every completion path (inline, lane, arbiter, collective)
    /// funnels through here so the trace sees each retire exactly once and
    /// admission accounting stays balanced (spurious completions, which the
    /// engine rejects, must not release admission slots).
    fn finish(&mut self, id: InstructionId) {
        trace::instant(
            self.cfg.node.0,
            trace::Track::Executor,
            trace::EventKind::Retire { instr: id.0 },
        );
        let retired_before = self.ooo.retired;
        let newly = self.ooo.retire(id);
        if self.ooo.retired > retired_before {
            self.ready.on_retire(id);
        }
        for (instr, lane) in newly {
            self.ready.push(instr, lane);
        }
    }

    /// Unrecoverable peer loss (heartbeat timeout or escalated comm
    /// fault): report the attributed error, then fail every pending
    /// receive/await with an attributed error of its own so fences and
    /// waits observe failures instead of hanging forever (graceful
    /// degradation, §ISSUE: "drain lanes and fail pending receives").
    fn abort_unreachable(&mut self, peer: NodeId, err: String) {
        // A dead peer dooms every job's pending receives: broadcast.
        self.emit(EventRoute::Cluster, ExecEvent::Error(err));
        self.arbiter
            .fail_all(&format!("node {} is unreachable", peer.0));
        self.drain_engine_errors();
    }

    /// Forward tolerated engine anomalies (OoO spurious completions,
    /// arbiter payloads for retired receives) to the event stream. These
    /// indicate executor-level protocol confusion rather than one job's
    /// misbehaviour, so they are broadcast cluster-wide.
    fn drain_engine_errors(&mut self) {
        for e in self.ooo.take_errors() {
            self.emit(EventRoute::Cluster, ExecEvent::Error(e));
        }
        for e in self.arbiter.take_errors() {
            self.emit(EventRoute::Cluster, ExecEvent::Error(e));
        }
    }

    fn make_views(&self, bindings: &[AccessBinding]) -> Vec<BindingView> {
        bindings
            .iter()
            .map(|b| BindingView {
                buf: self.arena.get(b.alloc),
                binding: b.clone(),
                oob: std::cell::Cell::new(None),
            })
            .collect()
    }

    fn dispatch(&mut self, instr: InstructionRef, lane: Lane) {
        let id = instr.id;
        trace::instant(
            self.cfg.node.0,
            trace::Track::Executor,
            trace::EventKind::Issue { instr: id.0 },
        );
        match &instr.kind {
            // ── inline instructions ─────────────────────────────────────
            InstructionKind::Alloc { alloc, covers, size_bytes, .. } => {
                let elem = if covers.area() > 0 {
                    (*size_bytes / covers.area()) as usize
                } else {
                    1
                };
                self.arena.alloc(*alloc, *covers, elem.max(1));
                trace::instant(
                    self.cfg.node.0,
                    trace::Track::Executor,
                    trace::EventKind::Alloc { bytes: *size_bytes },
                );
                self.finish(id);
            }
            InstructionKind::Free { alloc, .. } => {
                self.arena.free(*alloc);
                self.finish(id);
            }
            InstructionKind::Horizon => {
                self.finish(id);
                self.ooo.compact_below(id);
            }
            InstructionKind::Epoch(action) => {
                // Routed to the owning job: a shutdown epoch ends *that
                // job*, not the executor — the loop exits when the
                // scheduler closes the inbox and all jobs are drained.
                self.emit(EventRoute::Job(JobId::of(id.0)), ExecEvent::Epoch(*action, id));
                self.finish(id);
            }

            // ── arbitration-completed instructions ──────────────────────
            InstructionKind::Receive { buffer, region, dst_alloc, transfer, .. } => {
                let dst = self.arena.get(*dst_alloc);
                self.arbiter
                    .register_receive(id, *buffer, *transfer, region.clone(), dst, false);
                self.drain_arbiter();
            }
            InstructionKind::SplitReceive { buffer, region, dst_alloc, transfer, .. } => {
                let dst = self.arena.get(*dst_alloc);
                self.arbiter
                    .register_receive(id, *buffer, *transfer, region.clone(), dst, true);
                self.drain_arbiter();
            }
            InstructionKind::AwaitReceive { region, split, .. } => {
                self.arbiter.register_await(id, *split, region.clone());
                self.drain_arbiter();
            }
            InstructionKind::Collective {
                region, slices, dst_alloc, transfer, msgs, buffer, ..
            } => {
                let dst = self.arena.get(*dst_alloc);
                let own = Region::from(slices[self.cfg.node.0 as usize]);
                let inbound = region.difference(&own);
                // Inbound slices land through the ordinary arbiter; the
                // ring engine owns round scheduling and completion.
                self.arbiter
                    .register_collective(id, *buffer, *transfer, inbound, dst.clone());
                self.collectives
                    .start(id, self.cfg.node, slices.clone(), msgs.clone(), dst);
                // Round 0 sends immediately; data already queued locally
                // may even finish the ring on the spot.
                self.pump_collectives();
                self.drain_arbiter();
            }

            // ── lane-executed instructions ──────────────────────────────
            InstructionKind::Copy { copy_box, src_alloc, dst_alloc, .. } => {
                let src = self.arena.get(*src_alloc);
                let dst = self.arena.get(*dst_alloc);
                let copy_box = *copy_box;
                let job = traced_job(
                    self.cfg.node.0,
                    lane,
                    instr.kind.mnemonic(),
                    id,
                    Box::new(move || copy_between(&src, &dst, &copy_box)),
                );
                self.lanes.submit(lane, job);
            }
            InstructionKind::Send { send_box, target, msg, src_alloc, .. } => {
                let src = self.arena.get(*src_alloc);
                let comm = self.comm.clone();
                let (send_box, target, msg) = (*send_box, *target, *msg);
                let job = traced_job(
                    self.cfg.node.0,
                    lane,
                    instr.kind.mnemonic(),
                    id,
                    Box::new(move || {
                        let bytes = src.read_box(&send_box);
                        comm.send_data(target, msg, bytes);
                    }),
                );
                self.lanes.submit(lane, job);
            }
            InstructionKind::DeviceKernel { chunk, bindings, kernel, .. } => {
                let name = kernel
                    .clone()
                    .or_else(|| instr.task.as_ref().map(|t| t.name.clone()))
                    .unwrap_or_default();
                self.submit_functor(lane, id, *chunk, bindings, &name, false);
            }
            InstructionKind::HostTask { chunk, bindings, .. } => {
                let name = instr
                    .task
                    .as_ref()
                    .map(|t| t.name.clone())
                    .unwrap_or_default();
                self.submit_functor(lane, id, *chunk, bindings, &name, true);
            }
        }
    }

    fn submit_functor(
        &mut self,
        lane: Lane,
        id: InstructionId,
        chunk: GridBox,
        bindings: &[AccessBinding],
        name: &str,
        host: bool,
    ) {
        let mnemonic = if host { "host task" } else { "device kernel" };
        let job = JobId::of(id.0);
        let Some(f) = self.cfg.registry.lookup(name, host) else {
            self.emit(
                EventRoute::Job(job),
                ExecEvent::Error(format!(
                    "no {} registered under '{name}'; treating as no-op",
                    if host { "host task" } else { "kernel" }
                )),
            );
            // Still execute as a no-op through the lane to preserve ordering.
            let job = traced_job(self.cfg.node.0, lane, mnemonic, id, Box::new(|| {}));
            self.lanes.submit(lane, job);
            return;
        };
        let views = self.make_views(bindings);
        let events = self.events.clone();
        let label = name.to_string();
        let job = traced_job(
            self.cfg.node.0,
            lane,
            mnemonic,
            id,
            Box::new(move || {
                let ctx = KernelCtx { chunk, views };
                f(&ctx);
                // §4.4 accessor bounds checking: report after the kernel
                // exits, attributed to the job the instruction belongs to.
                for v in &ctx.views {
                    if let Some((lo, hi)) = v.oob.get() {
                        let _ = events.send((
                            EventRoute::Job(job),
                            ExecEvent::Error(format!(
                                "kernel '{label}': out-of-bounds access on buffer {} within [{lo} - {hi}], permitted region {}",
                                v.binding.buffer, v.binding.region
                            )),
                        ));
                    }
                }
            }),
        );
        self.lanes.submit(lane, job);
    }

    fn drain_arbiter(&mut self) {
        for cid in self.arbiter.take_completions() {
            self.finish(cid);
        }
    }

    /// Advance collective rings and retire completed ones.
    fn pump_collectives(&mut self) {
        for cid in self.collectives.pump(&self.arbiter, &self.comm) {
            self.arbiter.finish_collective(cid);
            self.finish(cid);
        }
    }
}

/// The trace track a lane's work is recorded on.
fn lane_track(lane: Lane) -> trace::Track {
    match lane {
        Lane::DeviceKernel(d) => trace::Track::DeviceKernel(d.0),
        Lane::DeviceCopy(d, ooo::Direction::In) => trace::Track::DeviceCopyIn(d.0),
        Lane::DeviceCopy(d, ooo::Direction::Out) => trace::Track::DeviceCopyOut(d.0),
        Lane::Host(i) => trace::Track::Host(i as u64),
        Lane::Comm => trace::Track::Comm,
        Lane::Arbiter | Lane::Inline => trace::Track::Executor,
    }
}

/// Wrap a lane job in an `Exec` trace span when tracing is on.
/// The timing closure runs on the lane thread, so the span lands in that
/// thread's local buffer; with tracing off the job is passed through
/// untouched and the hot path pays only this one branch.
fn traced_job(
    node: u64,
    lane: Lane,
    mnemonic: &'static str,
    id: InstructionId,
    run: Box<dyn FnOnce() + Send>,
) -> Job {
    if !trace::enabled() {
        return Job { id, run };
    }
    let track = lane_track(lane);
    Job {
        id,
        run: Box::new(move || {
            let t0 = trace::now_ns();
            run();
            trace::span(node, track, t0, trace::EventKind::Exec { instr: id.0, mnemonic });
        }),
    }
}

/// Handle to a running executor thread.
pub struct ExecutorHandle {
    join: std::thread::JoinHandle<ExecutorStats>,
    /// Demultiplexed event stream (epochs, errors): clone per job consumer.
    pub events: EventHub,
}

impl ExecutorHandle {
    pub fn spawn(
        cfg: ExecutorConfig,
        comm: CommRef,
        inbox: spsc::Receiver<SchedulerOut>,
    ) -> ExecutorHandle {
        let (etx, erx) = mpsc::channel();
        let node = cfg.node.0;
        let join = std::thread::Builder::new()
            .name(format!("celerity-exec-{node}"))
            .spawn(move || Executor::new(cfg, comm, etx).run_to_shutdown(inbox))
            .expect("spawn executor thread");
        ExecutorHandle { join, events: EventHub::new(erx) }
    }

    /// Block until job 0 (the single-tenant default) reports an epoch of
    /// `action`. Multi-job consumers use [`EventHub::wait_epoch`] directly.
    pub fn wait_epoch(&self, action: EpochAction) -> Vec<ExecEvent> {
        self.events.wait_epoch(JobId(0), action)
    }

    pub fn join(self) -> ExecutorStats {
        match self.join.join() {
            Ok(stats) => stats,
            Err(payload) => {
                // A panicked executor must not take the driver thread down
                // with it: report what we can and return empty stats so the
                // caller's error stream (which already carries the real
                // failure) decides the exit code.
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                eprintln!("[celerity] executor thread panicked: {msg}");
                ExecutorStats::default()
            }
        }
    }
}

/// Utility: extract the bytes of `region` of a buffer from a `BindingView`
/// (used by fence host tasks).
pub fn region_to_vec(view: &BindingView, region: &Region) -> Vec<u8> {
    let mut out = Vec::new();
    for b in region.boxes() {
        out.extend(view.buf.read_box(b));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NullCommunicator;
    use crate::grid::Range;
    use crate::scheduler::{Scheduler, SchedulerConfig};
    use crate::task::{RangeMapper, TaskDecl, TaskManager};

    /// End-to-end single-node correctness: TDAG → CDAG → IDAG → executor
    /// with real bytes, 2 devices, fence via host task.
    #[test]
    fn executes_pipeline_with_correct_numerics() {
        let mut tm = TaskManager::new();
        let n = Range::d1(256);
        let a = tm.create_buffer::<f32>("A", n, false).id();
        // iota kernel writes A[i] = i; double kernel A[i] *= 2; host task
        // sums into a shared sink.
        tm.submit(
            TaskDecl::device("iota", n)
                .discard_write(a, RangeMapper::OneToOne)
                .kernel("iota"),
        );
        tm.submit(
            TaskDecl::device("double", n)
                .read_write(a, RangeMapper::OneToOne)
                .kernel("double"),
        );
        tm.submit(TaskDecl::host("sum", n).read(a, RangeMapper::All));
        tm.shutdown();
        let tasks = tm.take_new_tasks();

        let mut sched = Scheduler::new(
            SchedulerConfig { num_devices: 2, ..Default::default() },
            tm.buffers().clone(),
        );

        let sum = Arc::new(std::sync::Mutex::new(0f64));
        let sum_c = sum.clone();
        let mut registry = Registry::new();
        registry.register_kernel(
            "iota",
            Arc::new(|ctx: &KernelCtx| {
                let v = ctx.view(0);
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    v.write_f32(Point::d1(i), i as f32);
                }
            }),
        );
        registry.register_kernel(
            "double",
            Arc::new(|ctx: &KernelCtx| {
                let v = ctx.view(0);
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    v.write_f32(Point::d1(i), v.read_f32(Point::d1(i)) * 2.0);
                }
            }),
        );
        registry.register_host_task(
            "sum",
            Arc::new(move |ctx: &KernelCtx| {
                let v = ctx.view(0);
                let mut s = 0f64;
                for i in 0..256 {
                    s += v.read_f32(Point::d1(i)) as f64;
                }
                *sum_c.lock().unwrap() = s;
            }),
        );

        let (tx, rx) = spsc::channel(4096);
        let exec = ExecutorHandle::spawn(
            ExecutorConfig { registry, ..Default::default() },
            Arc::new(NullCommunicator(NodeId(0))),
            rx,
        );
        for t in &tasks {
            let (instructions, pilots) = sched.process(t);
            tx.send(SchedulerOut::batch(JobId(0), instructions, pilots)).unwrap();
        }
        let (instructions, pilots) = sched.flush_now();
        tx.send(SchedulerOut::batch(JobId(0), instructions, pilots)).unwrap();
        drop(tx);

        let side = exec.wait_epoch(EpochAction::Shutdown);
        let errors: Vec<_> = side
            .iter()
            .filter(|e| matches!(e, ExecEvent::Error(_)))
            .collect();
        assert!(errors.is_empty(), "{errors:?}");
        let stats = exec.join();
        // sum(2*i for i in 0..256) = 2 * 255*256/2 = 65280
        assert_eq!(*sum.lock().unwrap(), 65280.0);
        assert!(stats.retired > 5);
        assert_eq!(stats.peak_arena_bytes > 0, true);
    }

    /// §4.4: an out-of-bounds access is reported with its bounding box.
    #[test]
    fn oob_access_reported() {
        let mut tm = TaskManager::new();
        let n = Range::d1(64);
        let a = tm.create_buffer::<f32>("A", n, false).id();
        tm.submit(
            TaskDecl::device("bad", n)
                .discard_write(a, RangeMapper::OneToOne)
                .kernel("bad"),
        );
        tm.shutdown();
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(SchedulerConfig::default(), tm.buffers().clone());

        let mut registry = Registry::new();
        registry.register_kernel(
            "bad",
            Arc::new(|ctx: &KernelCtx| {
                let v = ctx.view(0);
                // Write one element past the permitted region.
                v.write_f32(Point::d1(ctx.chunk.max[0] + 5), 1.0);
            }),
        );

        let (tx, rx) = spsc::channel(1024);
        let exec = ExecutorHandle::spawn(
            ExecutorConfig { registry, ..Default::default() },
            Arc::new(NullCommunicator(NodeId(0))),
            rx,
        );
        for t in &tasks {
            let (instructions, pilots) = sched.process(t);
            tx.send(SchedulerOut::batch(JobId(0), instructions, pilots)).unwrap();
        }
        let (instructions, pilots) = sched.flush_now();
        tx.send(SchedulerOut::batch(JobId(0), instructions, pilots)).unwrap();
        drop(tx);
        let side = exec.wait_epoch(EpochAction::Shutdown);
        exec.join();
        assert!(
            side.iter().any(|e| matches!(e, ExecEvent::Error(msg) if msg.contains("out-of-bounds"))),
            "{side:?}"
        );
    }
}
