//! Receive arbitration (§4.2).
//!
//! `receive` and `split receive` instructions only know the *union* of
//! buffer regions that will arrive; which peer contributes which subregion
//! becomes known at execution time through *pilot messages*. This state
//! machine matches receive instructions against pilots, ingests payloads
//! into the destination allocation, and recognizes an `await receive` "as
//! completed as soon as its subregion or a superset thereof has been
//! received, regardless of the geometry of inbound transfers that satisfied
//! the request" (§3.4).

use super::arena::AllocBuf;
use crate::grid::Region;
use crate::instruction::Pilot;
use crate::util::{BufferId, InstructionId, MessageId, NodeId};
use std::collections::HashMap;
use std::sync::Arc;

/// How completion of an active receive is signalled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RecvMode {
    /// Plain `receive`: completes when `remaining` drains.
    Plain,
    /// `split receive`: completes at registration; its `await receive`s
    /// carry the data dependency.
    Split,
    /// Collective ring member: data lands here like any receive, but
    /// completion (and garbage collection) is driven externally by the
    /// executor's [`super::collective::CollectiveEngine`], which polls
    /// [`ReceiveArbiter::received_region`] to advance ring rounds.
    Collective,
}

struct ActiveReceive {
    buffer: BufferId,
    /// Transfer id (consuming task): pilots match on (buffer, transfer).
    transfer: crate::util::TaskId,
    /// What is still outstanding.
    remaining: Region,
    /// What has arrived so far (for await-receive checks).
    received: Region,
    dst: Arc<AllocBuf>,
    mode: RecvMode,
    done: bool,
}

struct PendingAwait {
    split: InstructionId,
    region: Region,
}

/// The receive-arbitration state machine.
#[derive(Default)]
pub struct ReceiveArbiter {
    /// Pilots not yet matched to a receive instruction.
    unmatched_pilots: Vec<Pilot>,
    /// Payloads that arrived before their pilot/receive was known. Message
    /// ids are only unique per *sender*, so all keys are (sender, msg).
    early_data: HashMap<(NodeId, MessageId), Vec<u8>>,
    /// (sender, msg) → receive instruction expecting it (with the pilot box).
    expected: HashMap<(NodeId, MessageId), (InstructionId, crate::grid::GridBox)>,
    active: HashMap<InstructionId, ActiveReceive>,
    awaits: HashMap<InstructionId, PendingAwait>,
    completions: Vec<InstructionId>,
    /// Protocol anomalies tolerated instead of panicking (e.g. a payload
    /// arriving for an already-retired receive); drained by the executor
    /// into its `ExecEvent::Error` stream (§4.4).
    errors: Vec<String>,
    /// Statistics: how many MPI_Irecv-equivalents were posted before the
    /// data arrived (the §4.2 double-buffering-elimination effect).
    pub irecvs_posted_early: u64,
    pub irecvs_posted_late: u64,
}

impl ReceiveArbiter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a `receive` (is_split = false) or `split receive`
    /// (is_split = true) instruction. Split receives complete immediately.
    pub fn register_receive(
        &mut self,
        id: InstructionId,
        buffer: BufferId,
        transfer: crate::util::TaskId,
        region: Region,
        dst: Arc<AllocBuf>,
        is_split: bool,
    ) {
        let mode = if is_split { RecvMode::Split } else { RecvMode::Plain };
        self.register(id, buffer, transfer, region, dst, mode);
    }

    /// Register the inbound side of a collective ring member: fragments
    /// land in `dst` like any receive, but no completion is ever pushed —
    /// the collective engine owns completion and calls
    /// [`ReceiveArbiter::finish_collective`] when the ring has run its
    /// rounds.
    pub fn register_collective(
        &mut self,
        id: InstructionId,
        buffer: BufferId,
        transfer: crate::util::TaskId,
        region: Region,
        dst: Arc<AllocBuf>,
    ) {
        self.register(id, buffer, transfer, region, dst, RecvMode::Collective);
    }

    fn register(
        &mut self,
        id: InstructionId,
        buffer: BufferId,
        transfer: crate::util::TaskId,
        region: Region,
        dst: Arc<AllocBuf>,
        mode: RecvMode,
    ) {
        let mut ar = ActiveReceive {
            buffer,
            transfer,
            remaining: region,
            received: Region::empty(),
            dst,
            mode,
            done: false,
        };
        match mode {
            RecvMode::Split => {
                self.completions.push(id);
                ar.done = true; // instruction-level completion; data still tracked
            }
            RecvMode::Collective => {
                ar.done = true; // completion owned by the collective engine
            }
            RecvMode::Plain => {}
        }
        self.active.insert(id, ar);
        // Match any pilots that arrived before the instruction (receives
        // are issued "long before the sender side begins transmitting" in
        // the ideal case, but the opposite order must also work).
        let pilots = std::mem::take(&mut self.unmatched_pilots);
        for p in pilots {
            self.on_pilot(p);
        }
    }

    /// What has arrived so far for an active receive (collective ring
    /// progress poll). `None` once the entry has been garbage collected.
    pub fn received_region(&self, id: InstructionId) -> Option<Region> {
        self.active.get(&id).map(|ar| ar.received.clone())
    }

    /// Drop a collective entry once its engine declared the ring complete.
    pub fn finish_collective(&mut self, id: InstructionId) {
        self.active.remove(&id);
    }

    /// Register an `await receive` for a subregion of `split`. Must be
    /// called after `register_receive(split, ..)` — the IDAG guarantees
    /// this ordering (every `await receive` depends on its `split
    /// receive`).
    pub fn register_await(&mut self, id: InstructionId, split: InstructionId, region: Region) {
        match self.active.get(&split) {
            // Maybe already satisfied.
            Some(ar) => {
                if ar.received.contains(&region) {
                    self.completions.push(id);
                    return;
                }
            }
            // The split receive's entire region drained and its state was
            // garbage collected (payloads can race arbitrarily far ahead
            // of the awaiting instructions): any subregion is complete.
            None => {
                self.completions.push(id);
                return;
            }
        }
        self.awaits.insert(id, PendingAwait { split, region });
    }

    /// Ingest a pilot message.
    pub fn on_pilot(&mut self, pilot: Pilot) {
        // Find the active receive this pilot belongs to.
        let target = self.active.iter().find_map(|(id, ar)| {
            (ar.buffer == pilot.buffer
                && ar.transfer == pilot.transfer
                && ar.remaining.intersects(&Region::from(pilot.send_box)))
            .then_some(*id)
        });
        match target {
            Some(id) => {
                // "Calls to MPI_Irecv can typically be issued long before
                // the sender side begins transmitting" — posting the Irecv
                // corresponds to recording the expectation here.
                if let Some(bytes) = self.early_data.remove(&(pilot.from, pilot.msg)) {
                    self.irecvs_posted_late += 1;
                    self.ingest(id, &pilot.send_box, &bytes);
                } else {
                    self.irecvs_posted_early += 1;
                    self.expected
                        .insert((pilot.from, pilot.msg), (id, pilot.send_box));
                }
            }
            None => self.unmatched_pilots.push(pilot),
        }
    }

    /// Ingest a data payload.
    pub fn on_data(&mut self, from: NodeId, msg: MessageId, bytes: Vec<u8>) {
        match self.expected.remove(&(from, msg)) {
            Some((id, send_box)) => self.ingest(id, &send_box, &bytes),
            None => {
                // Data raced ahead of its pilot (or of the receive
                // instruction): park it.
                self.early_data.insert((from, msg), bytes);
            }
        }
    }

    fn ingest(&mut self, id: InstructionId, send_box: &crate::grid::GridBox, bytes: &[u8]) {
        // Defensive: the expectation table should only ever name live
        // receives, but a protocol bug (e.g. overlapping sends draining an
        // entry early) must drop the payload with a reported §4.4 error,
        // not panic the executor thread mid-run.
        let Some(ar) = self.active.get_mut(&id) else {
            self.errors.push(format!(
                "receive arbitration: payload for retired receive I{} ({send_box}) dropped",
                id.0
            ));
            return;
        };
        ar.dst.write_box(send_box, bytes);
        let got = Region::from(*send_box);
        ar.remaining = ar.remaining.difference(&got);
        ar.received = ar.received.union(&got);
        if ar.mode == RecvMode::Plain && !ar.done && ar.remaining.is_empty() {
            ar.done = true;
            self.completions.push(id);
        }
        // Await-receives: complete every await whose subregion is covered.
        let received = ar.received.clone();
        let finished: Vec<InstructionId> = self
            .awaits
            .iter()
            .filter(|(_, aw)| aw.split == id && received.contains(&aw.region))
            .map(|(k, _)| *k)
            .collect();
        for k in finished {
            self.awaits.remove(&k);
            self.completions.push(k);
        }
        // Fully drained plain receive or split receive with no outstanding
        // awaits can be garbage collected. Collective entries stay until
        // their engine calls `finish_collective` — the ring may still need
        // to read `received_region` to schedule its remaining sends.
        let ar = self.active.get(&id).expect("arbiter tracks every active receive");
        if ar.remaining.is_empty()
            && ar.done
            && ar.mode != RecvMode::Collective
            && !self.awaits.values().any(|aw| aw.split == id)
        {
            self.active.remove(&id);
        }
    }

    /// Drain instruction completions produced by recent events.
    pub fn take_completions(&mut self) -> Vec<InstructionId> {
        std::mem::take(&mut self.completions)
    }

    /// Drain tolerated protocol anomalies (§4.4 error stream).
    pub fn take_errors(&mut self) -> Vec<String> {
        std::mem::take(&mut self.errors)
    }

    /// Anything still outstanding? (Shutdown sanity check.)
    pub fn is_idle(&self) -> bool {
        self.active.iter().all(|(_, a)| a.remaining.is_empty()) && self.awaits.is_empty()
    }

    /// Graceful degradation on unrecoverable peer loss: fail every pending
    /// receive and await with an attributed error instead of letting them
    /// (and the fences behind them) hang forever. Returns the instructions
    /// that were abandoned; their errors are queued on the §4.4 stream.
    pub fn fail_all(&mut self, reason: &str) -> Vec<InstructionId> {
        let mut failed: Vec<InstructionId> = Vec::new();
        for (id, ar) in &self.active {
            if !ar.remaining.is_empty() || !ar.done {
                failed.push(*id);
                self.errors.push(format!(
                    "receive I{} (buffer {}, transfer T{}, remaining {}) abandoned: {reason}",
                    id.0, ar.buffer, ar.transfer.0, ar.remaining
                ));
            }
        }
        for (id, aw) in &self.awaits {
            failed.push(*id);
            self.errors.push(format!(
                "await receive I{} (split I{}, region {}) abandoned: {reason}",
                id.0,
                aw.split.0,
                aw.region.bounding_box()
            ));
        }
        failed.sort();
        self.active.clear();
        self.awaits.clear();
        self.expected.clear();
        self.early_data.clear();
        self.unmatched_pilots.clear();
        failed
    }

    /// Human-readable state dump (stall diagnostics).
    pub fn debug_state(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        for (id, ar) in &self.active {
            let _ = writeln!(
                s,
                "  active recv I{} buffer {} transfer T{} remaining {}",
                id.0, ar.buffer, ar.transfer.0, ar.remaining
            );
        }
        for p in &self.unmatched_pilots {
            let _ = writeln!(
                s,
                "  unmatched pilot {}→{} {} {} transfer T{}",
                p.from, p.to, p.msg, p.send_box, p.transfer.0
            );
        }
        for ((from, msg), _) in &self.early_data {
            let _ = writeln!(s, "  early data from {} {}", from, msg);
        }
        for ((from, msg), (id, _)) in &self.expected {
            let _ = writeln!(s, "  expecting {} {} for I{}", from, msg, id.0);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridBox;

    fn pilot(msg: u64, b: GridBox) -> Pilot {
        Pilot {
            from: NodeId(1),
            to: NodeId(0),
            msg: MessageId(msg),
            buffer: BufferId(0),
            send_box: b,
            transfer: crate::util::TaskId(1),
        }
    }

    fn payload(b: &GridBox, val: f32) -> Vec<u8> {
        let n = b.area() as usize;
        let mut out = Vec::with_capacity(n * 4);
        for _ in 0..n {
            out.extend_from_slice(&val.to_ne_bytes());
        }
        out
    }

    fn dst() -> Arc<AllocBuf> {
        Arc::new(AllocBuf::new(GridBox::d1(0, 100), 4))
    }

    #[test]
    fn single_receive_single_sender() {
        // §3.4 case b: one sender satisfies the entire region.
        let mut a = ReceiveArbiter::new();
        let buf = dst();
        a.register_receive(InstructionId(5), BufferId(0), crate::util::TaskId(1), Region::from(GridBox::d1(0, 100)), buf.clone(), false);
        a.on_pilot(pilot(1, GridBox::d1(0, 100)));
        assert!(a.take_completions().is_empty());
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 100), 2.5));
        assert_eq!(a.take_completions(), vec![InstructionId(5)]);
        unsafe {
            assert_eq!(buf.read::<f32>(crate::grid::Point::d1(50)), 2.5);
        }
        assert!(a.is_idle());
        assert_eq!(a.irecvs_posted_early, 1);
    }

    #[test]
    fn receive_completes_from_multiple_senders() {
        // §3.4 case a: multiple senders in exact consumer geometry.
        let mut a = ReceiveArbiter::new();
        a.register_receive(InstructionId(7), BufferId(0), crate::util::TaskId(1), Region::from(GridBox::d1(0, 100)), dst(), false);
        a.on_pilot(pilot(1, GridBox::d1(0, 50)));
        a.on_pilot(pilot(2, GridBox::d1(50, 100)));
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 50), 1.0));
        assert!(a.take_completions().is_empty(), "half received ≠ done");
        a.on_data(NodeId(1), MessageId(2), payload(&GridBox::d1(50, 100), 2.0));
        assert_eq!(a.take_completions(), vec![InstructionId(7)]);
    }

    #[test]
    fn data_before_pilot_before_receive() {
        // Worst-case ordering: payload first, then pilot, then instruction.
        let mut a = ReceiveArbiter::new();
        a.on_data(NodeId(1), MessageId(9), payload(&GridBox::d1(10, 20), 3.0));
        a.on_pilot(pilot(9, GridBox::d1(10, 20)));
        assert!(a.take_completions().is_empty());
        let buf = dst();
        a.register_receive(InstructionId(3), BufferId(0), crate::util::TaskId(1), Region::from(GridBox::d1(10, 20)), buf.clone(), false);
        assert_eq!(a.take_completions(), vec![InstructionId(3)]);
        unsafe { assert_eq!(buf.read::<f32>(crate::grid::Point::d1(15)), 3.0) };
        assert_eq!(a.irecvs_posted_late, 1);
    }

    #[test]
    fn split_receive_await_subregions() {
        // §3.4 case a with consumer split: two awaits complete
        // independently as their halves arrive.
        let mut a = ReceiveArbiter::new();
        a.register_receive(InstructionId(10), BufferId(0), crate::util::TaskId(1), Region::from(GridBox::d1(0, 100)), dst(), true);
        // Split receive completes immediately.
        assert_eq!(a.take_completions(), vec![InstructionId(10)]);
        a.register_await(InstructionId(11), InstructionId(10), Region::from(GridBox::d1(0, 50)));
        a.register_await(InstructionId(12), InstructionId(10), Region::from(GridBox::d1(50, 100)));
        a.on_pilot(pilot(1, GridBox::d1(0, 50)));
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 50), 1.0));
        assert_eq!(a.take_completions(), vec![InstructionId(11)]);
        a.on_pilot(pilot(2, GridBox::d1(50, 100)));
        a.on_data(NodeId(1), MessageId(2), payload(&GridBox::d1(50, 100), 2.0));
        assert_eq!(a.take_completions(), vec![InstructionId(12)]);
        assert!(a.is_idle());
    }

    #[test]
    fn split_receive_degrades_to_single_sender() {
        // §3.4 case b under consumer split: one sender covers everything →
        // both awaits complete at once.
        let mut a = ReceiveArbiter::new();
        a.register_receive(InstructionId(10), BufferId(0), crate::util::TaskId(1), Region::from(GridBox::d1(0, 100)), dst(), true);
        a.take_completions();
        a.register_await(InstructionId(11), InstructionId(10), Region::from(GridBox::d1(0, 50)));
        a.register_await(InstructionId(12), InstructionId(10), Region::from(GridBox::d1(50, 100)));
        a.on_pilot(pilot(1, GridBox::d1(0, 100)));
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 100), 4.0));
        let mut done = a.take_completions();
        done.sort();
        assert_eq!(done, vec![InstructionId(11), InstructionId(12)]);
    }

    #[test]
    fn orthogonal_geometry_partial_await() {
        // §3.4 case c: sender split orthogonal to consumer split — an await
        // completes only once a superset of its subregion arrived.
        let mut a = ReceiveArbiter::new();
        a.register_receive(InstructionId(10), BufferId(0), crate::util::TaskId(1), Region::from(GridBox::d1(0, 90)), dst(), true);
        a.take_completions();
        a.register_await(InstructionId(11), InstructionId(10), Region::from(GridBox::d1(0, 30)));
        a.register_await(InstructionId(12), InstructionId(10), Region::from(GridBox::d1(30, 90)));
        // Senders split at 45.
        a.on_pilot(pilot(1, GridBox::d1(0, 45)));
        a.on_pilot(pilot(2, GridBox::d1(45, 90)));
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 45), 1.0));
        // [0,45) ⊇ [0,30): first await done, second not.
        assert_eq!(a.take_completions(), vec![InstructionId(11)]);
        a.on_data(NodeId(1), MessageId(2), payload(&GridBox::d1(45, 90), 1.0));
        assert_eq!(a.take_completions(), vec![InstructionId(12)]);
    }

    /// Collective mode: data lands and `received_region` tracks progress,
    /// but the arbiter never pushes a completion — the ring engine owns it.
    #[test]
    fn collective_mode_tracks_progress_without_completing() {
        let mut a = ReceiveArbiter::new();
        let buf = dst();
        a.register_collective(
            InstructionId(20),
            BufferId(0),
            crate::util::TaskId(1),
            Region::from(GridBox::d1(0, 100)),
            buf.clone(),
        );
        assert!(a.take_completions().is_empty(), "no completion at registration");
        assert_eq!(a.received_region(InstructionId(20)), Some(Region::empty()));
        a.on_pilot(pilot(1, GridBox::d1(0, 50)));
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 50), 1.5));
        assert!(a.take_completions().is_empty(), "collectives never self-complete");
        assert_eq!(
            a.received_region(InstructionId(20)),
            Some(Region::from(GridBox::d1(0, 50)))
        );
        a.on_pilot(pilot(2, GridBox::d1(50, 100)));
        a.on_data(NodeId(1), MessageId(2), payload(&GridBox::d1(50, 100), 2.5));
        assert!(a.take_completions().is_empty());
        // Fully received, still queryable until the engine finishes it.
        assert_eq!(
            a.received_region(InstructionId(20)),
            Some(Region::from(GridBox::d1(0, 100)))
        );
        unsafe {
            assert_eq!(buf.read::<f32>(crate::grid::Point::d1(25)), 1.5);
            assert_eq!(buf.read::<f32>(crate::grid::Point::d1(75)), 2.5);
        }
        a.finish_collective(InstructionId(20));
        assert_eq!(a.received_region(InstructionId(20)), None);
        assert!(a.is_idle());
    }

    /// Panic-hardening: a payload whose receive entry is already gone must
    /// be dropped with a reported §4.4 error, not a panic — and the report
    /// flows through `take_errors` (→ `ExecEvent::Error`), not just stderr.
    #[test]
    fn payload_for_retired_receive_reports_error_not_panic() {
        let mut a = ReceiveArbiter::new();
        let buf = dst();
        a.register_receive(
            InstructionId(1),
            BufferId(0),
            crate::util::TaskId(1),
            Region::from(GridBox::d1(0, 10)),
            buf,
            false,
        );
        a.on_pilot(pilot(1, GridBox::d1(0, 10)));
        // Second pilot for the same bytes (overlapping-send protocol bug):
        // the entry drains on the first payload and is garbage collected.
        a.on_pilot(pilot(2, GridBox::d1(0, 10)));
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 10), 1.0));
        assert_eq!(a.take_completions(), vec![InstructionId(1)]);
        assert!(a.take_errors().is_empty());
        // The late duplicate payload hits the retired entry.
        a.on_data(NodeId(1), MessageId(2), payload(&GridBox::d1(0, 10), 2.0));
        let errors = a.take_errors();
        assert_eq!(errors.len(), 1, "{errors:?}");
        assert!(errors[0].contains("retired receive"), "{errors:?}");
        assert!(a.take_completions().is_empty());
        assert!(a.take_errors().is_empty(), "drained");
    }

    /// Graceful degradation: `fail_all` abandons every pending receive and
    /// await with an attributed error and leaves the arbiter idle — a lost
    /// peer must fail fences, not hang them.
    #[test]
    fn fail_all_abandons_pending_work_with_attributed_errors() {
        let mut a = ReceiveArbiter::new();
        a.register_receive(
            InstructionId(1),
            BufferId(0),
            crate::util::TaskId(1),
            Region::from(GridBox::d1(0, 10)),
            dst(),
            false,
        );
        a.register_receive(
            InstructionId(2),
            BufferId(0),
            crate::util::TaskId(2),
            Region::from(GridBox::d1(0, 20)),
            dst(),
            true,
        );
        a.take_completions(); // the split receive completes immediately
        a.register_await(InstructionId(3), InstructionId(2), Region::from(GridBox::d1(0, 10)));
        let failed = a.fail_all("node 1 lost (transport gave up)");
        assert_eq!(failed, vec![InstructionId(1), InstructionId(2), InstructionId(3)]);
        let errors = a.take_errors();
        assert_eq!(errors.len(), 3, "{errors:?}");
        assert!(errors.iter().all(|e| e.contains("node 1 lost")), "{errors:?}");
        assert!(errors.iter().any(|e| e.contains("receive I1")), "{errors:?}");
        assert!(errors.iter().any(|e| e.contains("await receive I3")), "{errors:?}");
        assert!(a.is_idle(), "failed state must not linger");
        assert!(a.take_completions().is_empty(), "abandoned ≠ completed");
    }

    #[test]
    fn pilots_for_later_receives_are_parked() {
        let mut a = ReceiveArbiter::new();
        a.on_pilot(pilot(1, GridBox::d1(0, 10)));
        let buf = dst();
        a.register_receive(InstructionId(1), BufferId(0), crate::util::TaskId(1), Region::from(GridBox::d1(0, 10)), buf, false);
        a.on_data(NodeId(1), MessageId(1), payload(&GridBox::d1(0, 10), 1.0));
        assert_eq!(a.take_completions(), vec![InstructionId(1)]);
    }

    // ── property test: fully out-of-order delivery ──────────────────────
    //
    // Randomized region splits delivered in adversarial order — payloads
    // racing ahead of their pilots, fragments arriving before the receive
    // is even posted, consumer splits orthogonal to sender splits — must
    // always reassemble byte-exactly and complete every instruction.

    use crate::grid::Point;
    use crate::util::XorShift64;

    /// Deterministic per-point byte pattern (distinguishes every element,
    /// so any misplaced fragment shows up as a byte mismatch).
    fn pattern(p: Point, seed: u64) -> u32 {
        (p[0].wrapping_mul(1_000_003)
            ^ p[1].wrapping_mul(10_007)
            ^ p[2].wrapping_mul(101)
            ^ seed) as u32
    }

    /// Dense row-major payload of `b` under the pattern (matches the
    /// iteration order of `AllocBuf::{read_box,write_box}`).
    fn pattern_payload(b: &GridBox, seed: u64) -> Vec<u8> {
        let mut out = Vec::with_capacity(b.area() as usize * 4);
        for x in b.min[0]..b.max[0] {
            for y in b.min[1]..b.max[1] {
                for z in b.min[2]..b.max[2] {
                    out.extend_from_slice(&pattern(Point::d3(x, y, z), seed).to_ne_bytes());
                }
            }
        }
        out
    }

    /// Random partition of `b` into disjoint boxes (recursive splits).
    fn random_partition(rng: &mut XorShift64, b: GridBox, depth: u32) -> Vec<GridBox> {
        let splittable: Vec<usize> =
            (0..3).filter(|&d| b.max[d] - b.min[d] > 1).collect();
        if depth == 0 || splittable.is_empty() || rng.chance(0.3) {
            return vec![b];
        }
        let d = *rng.pick(&splittable);
        let cut = rng.next_range(b.min[d] + 1, b.max[d] - 1);
        let (mut lo_max, mut hi_min) = (b.max, b.min);
        lo_max[d] = cut;
        hi_min[d] = cut;
        let mut out = random_partition(rng, GridBox { min: b.min, max: lo_max }, depth - 1);
        out.extend(random_partition(rng, GridBox { min: hi_min, max: b.max }, depth - 1));
        out
    }

    enum Ev {
        Recv,
        Await(usize),
        Pilot(usize),
        Data(usize),
    }

    fn run_out_of_order_case(seed: u64, forced_worst_case: bool) {
        let mut rng = XorShift64::new(seed);
        // Random ≤3D box, non-degenerate in the used dims.
        let dims = 1 + rng.next_below(3) as usize;
        let mut min = [0u64; 3];
        let mut max = [1u64; 3];
        for d in 0..dims {
            min[d] = rng.next_below(6);
            max[d] = min[d] + rng.next_range(1, 10);
        }
        let bbox = GridBox { min: Point(min), max: Point(max) };
        let region = Region::from(bbox);

        // Sender split: fragments with unique (sender, msg) and pilots.
        let frags = random_partition(&mut rng, bbox, 4);
        // Consumer split (split-receive mode only): an independent
        // partition — random cases include geometry orthogonal to the
        // sender split (§3.4 case c).
        let is_split = rng.chance(0.5);
        let awaits: Vec<GridBox> = if is_split {
            random_partition(&mut rng, bbox, 2)
        } else {
            Vec::new()
        };

        let recv_id = InstructionId(1000);
        let await_ids: Vec<InstructionId> =
            (0..awaits.len() as u64).map(|i| InstructionId(2000 + i)).collect();
        let transfer = crate::util::TaskId(7);
        let dst = Arc::new(AllocBuf::new(bbox, 4));

        // Event list. The receive always precedes its awaits (the IDAG
        // dependency the executor enforces); everything else is free.
        let mut events: Vec<Ev> = Vec::new();
        for i in 0..frags.len() {
            events.push(Ev::Pilot(i));
            events.push(Ev::Data(i));
        }
        if forced_worst_case {
            // All payloads first, then all pilots, then the receive, then
            // the awaits: data-before-pilot AND fragment-before-receive.
            events.clear();
            for i in 0..frags.len() {
                events.push(Ev::Data(i));
            }
            for i in 0..frags.len() {
                events.push(Ev::Pilot(i));
            }
            events.push(Ev::Recv);
            for i in 0..awaits.len() {
                events.push(Ev::Await(i));
            }
        } else {
            // Fisher–Yates over pilots+data, then insert the receive at a
            // random position and the awaits at random positions after it.
            for i in (1..events.len()).rev() {
                events.swap(i, rng.next_below(i as u64 + 1) as usize);
            }
            let rpos = rng.next_below(events.len() as u64 + 1) as usize;
            events.insert(rpos, Ev::Recv);
            for i in 0..awaits.len() {
                let pos = rng.next_range(rpos as u64 + 1, events.len() as u64) as usize;
                events.insert(pos, Ev::Await(i));
            }
        }

        let mut a = ReceiveArbiter::new();
        let mut done: Vec<InstructionId> = Vec::new();
        for ev in events {
            match ev {
                Ev::Recv => a.register_receive(
                    recv_id,
                    BufferId(0),
                    transfer,
                    region.clone(),
                    dst.clone(),
                    is_split,
                ),
                Ev::Await(i) => a.register_await(await_ids[i], recv_id, Region::from(awaits[i])),
                Ev::Pilot(i) => a.on_pilot(Pilot {
                    from: NodeId(1 + (i as u64 % 3)),
                    to: NodeId(0),
                    msg: MessageId(100 + i as u64),
                    buffer: BufferId(0),
                    send_box: frags[i],
                    transfer,
                }),
                Ev::Data(i) => a.on_data(
                    NodeId(1 + (i as u64 % 3)),
                    MessageId(100 + i as u64),
                    pattern_payload(&frags[i], seed),
                ),
            }
            done.extend(a.take_completions());
        }

        // Every instruction completed, exactly once.
        let mut expect: Vec<InstructionId> = vec![recv_id];
        expect.extend(await_ids.iter().copied());
        let mut got = done.clone();
        got.sort();
        got.dedup();
        expect.sort();
        assert_eq!(got, expect, "seed {seed}: completions");
        assert_eq!(done.len(), expect.len(), "seed {seed}: duplicate completions");
        assert!(a.is_idle(), "seed {seed}: arbiter not idle");

        // Byte-exact reassembly: every fragment landed at its offset.
        for f in &frags {
            assert_eq!(
                dst.read_box(f),
                pattern_payload(f, seed),
                "seed {seed}: bytes of fragment {f}"
            );
        }
    }

    #[test]
    fn property_out_of_order_reassembly() {
        for seed in 1..=60 {
            run_out_of_order_case(seed, false);
        }
    }

    #[test]
    fn property_worst_case_order_data_pilots_receive_awaits() {
        for seed in 1..=30 {
            run_out_of_order_case(seed, true);
        }
    }
}
