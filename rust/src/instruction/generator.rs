//! IDAG generation from the command stream (§3).
//!
//! The generator maintains, per buffer:
//!
//! - the set of **backing allocations** per memory (§3.2) — multiple
//!   non-overlapping allocations may coexist; accessors require a single
//!   contiguous backing, which may force *resize* chains of
//!   `alloc`/`copy`/`free` instructions (Fig 3);
//! - **coherence** tracking (§3.3): which memories hold the newest version
//!   of every buffer element, and per memory the *local original producer*
//!   instruction of those bytes — the source of producer-split copies;
//! - reader sets per memory for anti-dependencies.
//!
//! Memory ids follow §3.2: `M0` user host memory (host-initialized buffer
//! contents live here), `M1` DMA-capable pinned host memory (staging,
//! send/receive targets, host tasks), `M2..` device-native memories.

use super::memory::{Backing, BackingSet, MemMask};
use super::{AccessBinding, Instruction, InstructionKind, InstructionRef};
use crate::buffer::BufferPool;
use crate::command::{split_box, Command, CommandKind, SplitHint};
use crate::dag::{Dag, Dep, DepKind};
use crate::grid::{GridBox, Region, RegionMap};
use crate::task::{EpochAction, TaskKind, TaskRef};
use crate::util::{
    AllocationId, BufferId, DeviceId, InstructionId, JobId, MemoryId, MessageId, NodeId, TaskId,
};
use std::collections::HashMap;

/// Static configuration of one node's IDAG generator.
#[derive(Debug, Clone)]
pub struct IdagConfig {
    pub node: NodeId,
    pub num_nodes: u64,
    pub num_devices: u64,
    /// Node-level split of task index spaces (must match CDAG generation).
    pub node_hint: SplitHint,
    /// Device-level split of command chunks (§3.1, second application).
    pub device_hint: SplitHint,
    /// Whether the devices support direct device-to-device copies; when
    /// false, inter-device coherence stages through pinned host memory
    /// (§3.3, consumer-GPU case).
    pub d2d: bool,
    /// Direct device transfers (§3.3–3.4 specialization): when true,
    /// device-resident pushed regions are sent straight from the device
    /// backing (no d2h coherence hop into M1), and inbound transfers whose
    /// consumer geometry is a single known device land directly in that
    /// device's allocation (no pinned intermediate + h2d hop). The M1
    /// detour remains the automatic fallback (unknown/host/multi-consumer
    /// geometry, consumer splits) and the forced path when false
    /// (`--no-direct-comm` ablation).
    pub direct_comm: bool,
}

impl Default for IdagConfig {
    fn default() -> Self {
        IdagConfig {
            node: NodeId(0),
            num_nodes: 1,
            num_devices: 1,
            node_hint: SplitHint::D1,
            device_hint: SplitHint::D1,
            d2d: true,
            direct_comm: true,
        }
    }
}

/// Deterministic allocation id of the user-memory (M0) backing of a
/// host-initialized buffer. Reserved id space disjoint from sequentially
/// assigned runtime allocations.
pub fn user_alloc_id(buffer: BufferId) -> AllocationId {
    AllocationId((1u64 << 62) | buffer.0)
}

/// A pilot message (§3.4): announces to the receiver which buffer box an
/// upcoming `send` with `msg` id will carry. Transmitted eagerly, ingested
/// by the peer's receive-arbitration state machine (§4.2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pilot {
    pub from: NodeId,
    pub to: NodeId,
    pub msg: MessageId,
    pub buffer: BufferId,
    pub send_box: GridBox,
    /// The task whose data dependency this transfer satisfies. Disambiguates
    /// transfers of the same buffer region across iterations during receive
    /// arbitration (Celerity's transfer id).
    pub transfer: TaskId,
}

/// Per-(buffer, memory) tracking state.
struct MemState {
    /// Local original producer of each element's bytes *in this memory*.
    last_writer: RegionMap<Option<InstructionId>>,
    /// Instructions reading each element since its last local write.
    readers_since: RegionMap<Vec<InstructionId>>,
    /// Backing allocations.
    backings: BackingSet,
}

/// Per-buffer tracking state.
struct BufState {
    range: crate::grid::Range,
    elem_size: usize,
    name: String,
    /// Which memories hold the newest version of each element.
    coherent: RegionMap<MemMask>,
    per_mem: Vec<MemState>,
}

/// Generates the instruction graph from this node's command stream.
pub struct IdagGenerator {
    cfg: IdagConfig,
    buffers: BufferPool,
    states: HashMap<BufferId, BufState>,
    dag: Dag<InstructionRef>,
    outbox: Vec<InstructionRef>,
    pilots: Vec<Pilot>,
    /// Every instruction that has touched an allocation (dependencies of the
    /// eventual `free`); bounded by horizon substitution.
    alloc_users: HashMap<AllocationId, Vec<InstructionId>>,
    /// Lookahead-announced future requirements per (buffer, memory):
    /// bounding box of everything observed in the scheduler queue (§4.3).
    announced: HashMap<(BufferId, MemoryId), GridBox>,
    next_alloc: u64,
    next_msg: u64,
    current_horizon: Option<InstructionId>,
    last_epoch: Option<InstructionId>,
    /// §4.4 correctness errors detected during instruction generation
    /// (e.g. a push/consume of a region no task has ever written). Drained
    /// by the scheduler into `SchedulerOut.errors`, surfacing as
    /// `QueueError::Runtime` instead of a scheduler-thread panic.
    errors: Vec<String>,
    /// Statistics: total alloc instructions emitted (resize metric, §4.3).
    pub allocs_emitted: u64,
    /// Statistics: total bytes requested by alloc instructions.
    pub bytes_allocated: u64,
    /// Statistics: resize chains emitted (alloc that replaced live backings).
    pub resizes_emitted: u64,
}

impl IdagGenerator {
    pub fn new(cfg: IdagConfig, buffers: BufferPool) -> Self {
        Self::with_job(JobId(0), cfg, buffers)
    }

    /// Generator whose instruction, allocation and message ids live in
    /// `job`'s namespace. Message-id tagging is what keeps p2p/collective
    /// traffic of concurrent jobs from cross-matching during receive
    /// arbitration: pilot frames carry the full tagged u64, so two jobs'
    /// transfers of the same buffer region can never be confused.
    pub fn with_job(job: JobId, cfg: IdagConfig, buffers: BufferPool) -> Self {
        // 2 host memories + devices must fit the 64-bit coherence MemMask.
        assert!(cfg.num_devices >= 1 && cfg.num_devices <= 62);
        let base = job.base();
        IdagGenerator {
            cfg,
            buffers,
            states: HashMap::new(),
            dag: Dag::with_base(base),
            outbox: Vec::new(),
            pilots: Vec::new(),
            alloc_users: HashMap::new(),
            announced: HashMap::new(),
            next_alloc: base + 1,
            next_msg: base + 1,
            current_horizon: None,
            last_epoch: None,
            errors: Vec::new(),
            allocs_emitted: 0,
            bytes_allocated: 0,
            resizes_emitted: 0,
        }
    }

    pub fn config(&self) -> &IdagConfig {
        &self.cfg
    }

    /// Update the buffer-pool snapshot (streaming buffer creation).
    pub fn notify_buffers(&mut self, pool: BufferPool) {
        self.buffers = pool;
    }

    /// Drain instructions generated since the last call.
    pub fn take_new_instructions(&mut self) -> Vec<InstructionRef> {
        std::mem::take(&mut self.outbox)
    }

    /// Drain pilot messages generated since the last call.
    pub fn take_pilots(&mut self) -> Vec<Pilot> {
        std::mem::take(&mut self.pilots)
    }

    /// Drain §4.4 errors detected during instruction generation.
    pub fn take_errors(&mut self) -> Vec<String> {
        std::mem::take(&mut self.errors)
    }

    pub fn dag(&self) -> &Dag<InstructionRef> {
        &self.dag
    }

    /// Render the IDAG as Graphviz dot.
    pub fn to_dot(&self) -> String {
        self.dag.to_dot(&format!("idag_{}", self.cfg.node), |i| i.label())
    }

    // ──────────────────────────────────────────────────────────────────────
    // Lookahead support (§4.3)
    // ──────────────────────────────────────────────────────────────────────

    /// The (buffer, memory, contiguous box) requirements compiling `cmd`
    /// would impose. Used by the scheduler to detect allocating commands
    /// and to announce merged requirements; "recognizing this condition is
    /// inexpensive compared to generation of the actual instruction graph".
    pub fn requirements(&self, cmd: &Command) -> Vec<(BufferId, MemoryId, GridBox)> {
        let mut out = Vec::new();
        match &cmd.kind {
            CommandKind::Execute { chunk } => {
                let Some(range) = cmd.task.kind.execution_range() else {
                    return out;
                };
                let on_host = matches!(cmd.task.kind, TaskKind::HostTask { .. });
                let chunks = if on_host {
                    vec![(MemoryId::HOST, *chunk)]
                } else {
                    split_box(chunk, self.cfg.num_devices, self.cfg.device_hint)
                        .into_iter()
                        .enumerate()
                        .map(|(d, c)| (MemoryId::device_native(DeviceId(d as u64)), c))
                        .collect()
                };
                for a in cmd.task.kind.accesses() {
                    let Some(info) = self.buffers.try_get(a.buffer) else { continue };
                    for (mem, c) in &chunks {
                        let bbox = a.mapper.apply(c, range, info.range).bounding_box();
                        if !bbox.is_empty() {
                            out.push((a.buffer, *mem, bbox));
                        }
                    }
                }
            }
            CommandKind::Push { buffer, region, .. } => {
                // With direct transfers, fragments coherent in *any* memory
                // are sent from where they live (device, M1 or M0) and need
                // no pinned staging backing; only never-written fragments
                // fall back to M1 (and are reported as §4.4 errors when
                // compiled). Without elision — or before the buffer has any
                // tracking state — the whole region stages through M1.
                match self.states.get(buffer) {
                    Some(st) if self.cfg.direct_comm => {
                        let mut fallback: Vec<GridBox> = Vec::new();
                        st.coherent.for_each_in_region(region, |b, mask| {
                            if mask.is_empty() {
                                fallback.push(b);
                            }
                        });
                        for b in fallback {
                            out.push((*buffer, MemoryId::HOST, b));
                        }
                    }
                    _ => {
                        for b in region.boxes() {
                            out.push((*buffer, MemoryId::HOST, *b));
                        }
                    }
                }
            }
            CommandKind::AwaitPush { buffer, region } => {
                // Direct landing targets the consuming device's memory, so
                // the lookahead merges this requirement with the consuming
                // kernel's own allocation instead of a pinned intermediate.
                let mem = self.receive_memory(&cmd.task, *buffer, region);
                out.push((*buffer, mem, region.bounding_box()));
            }
            CommandKind::Collective { buffer, region, .. } => {
                // One contiguous host backing for the whole gathered region
                // (send staging + receive target in one), so the lookahead
                // window sees collectives exactly like other allocating
                // commands (§4.3).
                out.push((*buffer, MemoryId::HOST, region.bounding_box()));
            }
            _ => {}
        }
        out
    }

    /// Whether compiling `cmd` right now would emit any `alloc` instruction
    /// (the *allocating command* predicate driving lookahead, §4.3).
    pub fn would_allocate(&self, cmd: &Command) -> bool {
        self.would_allocate_reqs(&self.requirements(cmd))
    }

    /// [`Self::would_allocate`] over precomputed requirements, so the
    /// scheduler's lookahead window computes each command's requirement set
    /// once instead of re-walking the task split per predicate (§4.3).
    pub fn would_allocate_reqs(&self, reqs: &[(BufferId, MemoryId, GridBox)]) -> bool {
        reqs.iter().any(|(buffer, mem, bbox)| match self.states.get(buffer) {
            Some(st) => st.per_mem[mem.0 as usize].backings.needs_alloc(bbox),
            None => true,
        })
    }

    /// Total `(allocation, user instruction)` tracking entries currently
    /// held for eventual `free` dependencies. Horizon application must keep
    /// this bounded (§3.5) — diagnostics for the regression test.
    pub fn alloc_user_entries(&self) -> usize {
        self.alloc_users.values().map(|v| v.len()).sum()
    }

    /// Merge future requirements observed in the scheduler queue; the next
    /// `alloc` for each (buffer, memory) is extended to cover them (§4.3).
    pub fn announce(&mut self, reqs: &[(BufferId, MemoryId, GridBox)]) {
        for (buffer, mem, bbox) in reqs {
            let e = self
                .announced
                .entry((*buffer, *mem))
                .or_insert(GridBox::EMPTY);
            *e = e.bounding_union(bbox);
        }
    }

    // ──────────────────────────────────────────────────────────────────────
    // Command compilation
    // ──────────────────────────────────────────────────────────────────────

    /// Compile one command into instructions (appended to the outbox).
    pub fn compile(&mut self, cmd: &Command) {
        match cmd.kind.clone() {
            CommandKind::Execute { chunk } => self.compile_execute(cmd, chunk),
            CommandKind::Push { buffer, region, target } => {
                self.compile_push(cmd, buffer, region, target)
            }
            CommandKind::AwaitPush { buffer, region } => {
                self.compile_await_push(cmd, buffer, region)
            }
            CommandKind::Collective { buffer, region, kind, slices } => {
                self.compile_collective(cmd, buffer, region, kind, slices)
            }
            CommandKind::Horizon => {
                let id = self.push_front_instruction(InstructionKind::Horizon, Some(&cmd.task));
                if let Some(prev) = self.current_horizon.take() {
                    self.apply_boundary(prev);
                }
                self.current_horizon = Some(id);
            }
            CommandKind::Epoch(action) => {
                if action == EpochAction::Shutdown {
                    self.free_all_backings();
                }
                let id =
                    self.push_front_instruction(InstructionKind::Epoch(action), Some(&cmd.task));
                self.apply_boundary(id);
                self.current_horizon = None;
                self.last_epoch = Some(id);
            }
        }
    }

    fn compile_execute(&mut self, cmd: &Command, chunk: GridBox) {
        let task = cmd.task.clone();
        let Some(range) = task.kind.execution_range() else { return };
        let (on_host, accesses, work_per_item, kernel) = match &task.kind {
            TaskKind::DeviceCompute { accesses, work_per_item, kernel, .. } => {
                (false, accesses.clone(), *work_per_item, kernel.clone())
            }
            TaskKind::HostTask { accesses, work_per_item, .. } => {
                (true, accesses.clone(), *work_per_item, None)
            }
            _ => return,
        };

        // Hierarchical work assignment (§3.1): second split across devices.
        let dchunks: Vec<(MemoryId, GridBox)> = if on_host {
            vec![(MemoryId::HOST, chunk)]
        } else {
            split_box(&chunk, self.cfg.num_devices, self.cfg.device_hint)
                .into_iter()
                .enumerate()
                .map(|(d, c)| (MemoryId::device_native(DeviceId(d as u64)), c))
                .collect()
        };

        for (mem, dchunk) in dchunks {
            if dchunk.is_empty() {
                continue;
            }
            // 1. Materialize backing allocations + coherence copies (Fig 3).
            let mut bindings = Vec::new();
            for a in &accesses {
                let info = self.buffers.get(a.buffer).clone();
                self.ensure_state(a.buffer);
                let region = a.mapper.apply(&dchunk, range, info.range);
                if region.is_empty() {
                    continue;
                }
                let bbox = region.bounding_box();
                let backing = self.ensure_backing(a.buffer, mem, bbox, Some(&task));
                if a.mode.is_consumer() {
                    self.make_coherent(a.buffer, mem, &region, Some(&task));
                }
                bindings.push(AccessBinding {
                    buffer: a.buffer,
                    mode: a.mode,
                    region,
                    alloc: backing.alloc,
                    alloc_box: backing.covers,
                    dtype: info.dtype,
                    lanes: info.lanes,
                });
            }

            // 2. Dependencies (borrowing visitors: no fragment clones).
            let mut deps: Vec<(InstructionId, DepKind)> = Vec::new();
            for b in &bindings {
                let st = &self.states[&b.buffer];
                let ms = &st.per_mem[mem.0 as usize];
                if b.mode.is_consumer() {
                    ms.last_writer.for_each_in_region(&b.region, |_, w| {
                        if let Some(w) = w {
                            push_dep(&mut deps, *w, DepKind::Dataflow);
                        }
                    });
                }
                if b.mode.is_producer() {
                    ms.readers_since.for_each_in_region(&b.region, |_, readers| {
                        for r in readers {
                            push_dep(&mut deps, *r, DepKind::Anti);
                        }
                    });
                    ms.last_writer.for_each_in_region(&b.region, |_, w| {
                        if let Some(w) = w {
                            push_dep(&mut deps, *w, DepKind::Output);
                        }
                    });
                }
                // First use of a fresh allocation must wait for the alloc.
                if let Some(bk) = st.per_mem[mem.0 as usize].backings.containing(&b.region.bounding_box()) {
                    push_dep(&mut deps, bk.alloc_instr, DepKind::Dataflow);
                }
            }
            if deps.is_empty() {
                if let Some(e) = self.last_epoch {
                    push_dep(&mut deps, e, DepKind::Sync);
                }
            }

            // 3. Emit.
            let kind = if on_host {
                InstructionKind::HostTask { chunk: dchunk, bindings: bindings.clone(), work_per_item }
            } else {
                InstructionKind::DeviceKernel {
                    device: mem.to_device().expect("kernels launch only on device memories"),
                    chunk: dchunk,
                    bindings: bindings.clone(),
                    work_per_item,
                    kernel: kernel.clone(),
                }
            };
            let id = self.push_instruction(kind, deps, Some(&task));

            // 4. Tracking updates.
            for b in &bindings {
                self.alloc_users.entry(b.alloc).or_default().push(id);
                let st = self.states.get_mut(&b.buffer).expect("buffer tracked since creation");
                if b.mode.is_producer() {
                    // Written region: this memory holds the only coherent
                    // copy; this kernel is the local original producer.
                    st.coherent.update_region(&b.region, MemMask::single(mem));
                    let ms = &mut st.per_mem[mem.0 as usize];
                    ms.last_writer.update_region(&b.region, Some(id));
                    ms.readers_since.update_region(&b.region, Vec::new());
                } else {
                    let ms = &mut st.per_mem[mem.0 as usize];
                    ms.readers_since.apply_to_region(&b.region, |rs| {
                        let mut rs = rs.clone();
                        rs.push(id);
                        rs
                    });
                }
            }
        }
    }

    /// Outbound transfer (§3.4). With direct transfers enabled, every
    /// fragment of the pushed region is sent straight from the memory it is
    /// coherent in — pinned host if already staged, the device backing for
    /// device-resident data (eliding the d2h coherence hop), or user memory
    /// for never-touched host-initialized bytes. Without elision (or for
    /// never-written fragments) the classic path applies: coherence-copy to
    /// pinned host memory, then send from M1. In every mode the sends are
    /// producer-split: one `send` per (rectangle × original producer).
    fn compile_push(&mut self, cmd: &Command, buffer: BufferId, region: Region, target: NodeId) {
        self.ensure_state(buffer);

        // Partition the pushed region by send-source memory (one coherence
        // scan also collects never-written fragments for the §4.4 report).
        let mut uninit: Vec<GridBox> = Vec::new();
        let mut plan: Vec<(MemoryId, Region)> = Vec::new();
        fn add(plan: &mut Vec<(MemoryId, Region)>, mem: MemoryId, b: GridBox) {
            match plan.iter_mut().find(|(m, _)| *m == mem) {
                Some((_, r)) => *r = r.union(&Region::from(b)),
                None => plan.push((mem, Region::from(b))),
            }
        }
        if self.cfg.direct_comm {
            let st = &self.states[&buffer];
            st.coherent.for_each_in_region(&region, |b, mask| {
                let src = if mask.contains(MemoryId::HOST) {
                    MemoryId::HOST // already staged — free
                } else if let Some(d) = mask.first_device() {
                    d // device-resident: send directly, no d2h hop
                } else if mask.contains(MemoryId::USER) {
                    MemoryId::USER // host-initialized, never copied: send from M0
                } else {
                    uninit.push(b); // never written (§4.4 below): M1 fallback
                    MemoryId::HOST
                };
                add(&mut plan, src, b);
            });
            // Fallback fragments need a backing to read zeroes out of; the
            // host-coherent ones already have one (coherence implies a
            // backing), making these calls no-ops for them.
            let host_part: Option<Region> = plan
                .iter()
                .find(|(m, _)| *m == MemoryId::HOST)
                .map(|(_, r)| r.clone());
            if let Some(r) = host_part {
                for b in r.boxes() {
                    self.ensure_backing(buffer, MemoryId::HOST, *b, Some(&cmd.task));
                }
            }
        } else {
            // Staged lowering: host backing + coherence for the whole
            // pushed region, one d2h copy per device-resident producer
            // fragment, sends read M1. (make_coherent skips empty-mask
            // fragments, so the uninit scan here is the only report.)
            self.states[&buffer].coherent.for_each_in_region(&region, |b, mask| {
                if mask.is_empty() {
                    uninit.push(b);
                }
            });
            for b in region.boxes() {
                self.ensure_backing(buffer, MemoryId::HOST, *b, Some(&cmd.task));
            }
            self.make_coherent(buffer, MemoryId::HOST, &region, Some(&cmd.task));
            plan.push((MemoryId::HOST, region.clone()));
        }

        // §4.4: a push of bytes no task has ever produced means the peer
        // will consume garbage. Report it (the scheduler forwards this into
        // the executor's event stream), but still transmit from an M1
        // backing so the peer's await-push cannot hang.
        if !uninit.is_empty() {
            self.errors.push(format!(
                "push of buffer '{}' to {target}: region {} was never written by any \
                 task or init (§4.4); transmitting uninitialized bytes",
                self.states[&buffer].name,
                Region::from_boxes(uninit),
            ));
        }

        // Producer split per source memory: one send per original-producer
        // fragment × backing overlap.
        for (src_mem, sub) in plan {
            let st = &self.states[&buffer];
            let ms = &st.per_mem[src_mem.0 as usize];
            let mut sends: Vec<(GridBox, Option<InstructionId>, Backing)> = Vec::new();
            ms.last_writer.for_each_in_region(&sub, |pbox, producer| {
                for bk in ms.backings.intersecting(&pbox) {
                    let frag = pbox.intersection(&bk.covers);
                    if !frag.is_empty() {
                        sends.push((frag, *producer, bk.clone()));
                    }
                }
            });
            for (send_box, producer, backing) in sends {
                let msg = MessageId(self.next_msg);
                self.next_msg += 1;
                let mut deps: Vec<(InstructionId, DepKind)> = Vec::new();
                if let Some(p) = producer {
                    push_dep(&mut deps, p, DepKind::Dataflow);
                }
                push_dep(&mut deps, backing.alloc_instr, DepKind::Dataflow);
                let id = self.push_instruction(
                    InstructionKind::Send {
                        buffer,
                        send_box,
                        target,
                        msg,
                        src_memory: src_mem,
                        src_alloc: backing.alloc,
                        src_box: backing.covers,
                    },
                    deps,
                    Some(&cmd.task),
                );
                self.alloc_users.entry(backing.alloc).or_default().push(id);
                // The send reads the source memory: later writers of these
                // bytes (in *that* memory) must wait for it.
                let st = self.states.get_mut(&buffer).expect("buffer tracked since creation");
                st.per_mem[src_mem.0 as usize]
                    .readers_since
                    .apply_to_region(&Region::from(send_box), |rs| {
                        let mut rs = rs.clone();
                        rs.push(id);
                        rs
                    });
                // Pilot message announced to the peer immediately (§3.4).
                self.pilots.push(Pilot {
                    from: self.cfg.node,
                    to: target,
                    msg,
                    buffer,
                    send_box,
                    transfer: cmd.task.id,
                });
            }
        }
    }

    /// Inbound transfer (§3.4): contiguous backing for the whole awaited
    /// region (case b), then either a single `receive` or a `split receive`
    /// + consumer-split `await receive`s (cases a/c).
    ///
    /// When direct transfers are enabled and the consumer geometry is a
    /// single known device consuming the entire region, fragments land
    /// straight in that device's allocation (h2d from the wire buffer) —
    /// no pinned intermediate, no staging copy. Everything else (host
    /// consumers, consumer splits, partial overlap) keeps the M1 detour.
    fn compile_await_push(&mut self, cmd: &Command, buffer: BufferId, region: Region) {
        self.ensure_state(buffer);
        let bbox = region.bounding_box();

        // Consumer split: which local device chunks of the owning task
        // consume which subregions of the awaited region?
        let by_mem = self.consumer_subregions_by_mem(&cmd.task, buffer, &region);
        let dst_mem = self.landing_memory(&by_mem, &region);
        let consumers: Vec<Region> = {
            let mut out: Vec<Region> = Vec::new();
            for (_, r) in &by_mem {
                if !out.iter().any(|o| o == r) {
                    out.push(r.clone());
                }
            }
            out
        };

        let backing = self.ensure_backing(buffer, dst_mem, bbox, Some(&cmd.task));

        // Anti-dependencies: incoming data overwrites local bytes in the
        // landing memory.
        let mut deps: Vec<(InstructionId, DepKind)> = Vec::new();
        {
            let st = &self.states[&buffer];
            let dm = &st.per_mem[dst_mem.0 as usize];
            dm.readers_since.for_each_in_region(&region, |_, readers| {
                for r in readers {
                    push_dep(&mut deps, *r, DepKind::Anti);
                }
            });
            dm.last_writer.for_each_in_region(&region, |_, w| {
                if let Some(w) = w {
                    push_dep(&mut deps, *w, DepKind::Anti);
                }
            });
        }
        push_dep(&mut deps, backing.alloc_instr, DepKind::Dataflow);

        // A direct device landing implies some consumer covers the whole
        // region, so it always takes the single-receive path.
        let single = consumers.len() <= 1 || consumers.iter().any(|c| *c == region);
        debug_assert!(single || dst_mem == MemoryId::HOST);
        if single {
            let id = self.push_instruction(
                InstructionKind::Receive {
                    buffer,
                    region: region.clone(),
                    dst_memory: dst_mem,
                    dst_alloc: backing.alloc,
                    dst_box: backing.covers,
                    transfer: cmd.task.id,
                },
                deps,
                Some(&cmd.task),
            );
            self.alloc_users.entry(backing.alloc).or_default().push(id);
            let st = self.states.get_mut(&buffer).expect("buffer tracked since creation");
            st.coherent.update_region(&region, MemMask::single(dst_mem));
            let dm = &mut st.per_mem[dst_mem.0 as usize];
            dm.last_writer.update_region(&region, Some(id));
            dm.readers_since.update_region(&region, Vec::new());
        } else {
            let split_id = self.push_instruction(
                InstructionKind::SplitReceive {
                    buffer,
                    region: region.clone(),
                    dst_memory: MemoryId::HOST,
                    dst_alloc: backing.alloc,
                    dst_box: backing.covers,
                    transfer: cmd.task.id,
                },
                deps,
                Some(&cmd.task),
            );
            self.alloc_users.entry(backing.alloc).or_default().push(split_id);
            // Cover any remainder not claimed by a consumer so the whole
            // awaited region ends up tracked.
            let mut claimed = Region::empty();
            for c in &consumers {
                claimed = claimed.union(c);
            }
            let mut parts = consumers;
            let rest = region.difference(&claimed);
            if !rest.is_empty() {
                parts.push(rest);
            }
            for sub in parts {
                let id = self.push_instruction(
                    InstructionKind::AwaitReceive {
                        buffer,
                        region: sub.clone(),
                        split: split_id,
                    },
                    vec![(split_id, DepKind::Dataflow)],
                    Some(&cmd.task),
                );
                let st = self.states.get_mut(&buffer).expect("buffer tracked since creation");
                st.coherent.update_region(&sub, MemMask::single(MemoryId::HOST));
                let hs = &mut st.per_mem[MemoryId::HOST.0 as usize];
                hs.last_writer.update_region(&sub, Some(id));
                hs.readers_since.update_region(&sub, Vec::new());
            }
        }
    }

    /// Collective group operation (all-gather / broadcast): one contiguous
    /// pinned-host backing for the whole gathered region doubles as send
    /// staging and receive target; our contribution slice is made coherent
    /// there, and the executor then runs `n−1` ring rounds over the
    /// ordinary pilot/send primitives. Pilots for every round we will send
    /// travel eagerly at generation time (§3.4), exactly like p2p sends.
    fn compile_collective(
        &mut self,
        cmd: &Command,
        buffer: BufferId,
        region: Region,
        kind: crate::command::CollectiveKind,
        slices: std::sync::Arc<Vec<GridBox>>,
    ) {
        self.ensure_state(buffer);
        let me = self.cfg.node;
        let n = slices.len();
        debug_assert!(n as u64 == self.cfg.num_nodes && n >= 2);
        let own = Region::from(slices[me.0 as usize]);
        let inbound = region.difference(&own);
        let bbox = region.bounding_box();
        let backing = self.ensure_backing(buffer, MemoryId::HOST, bbox, Some(&cmd.task));
        if !own.is_empty() {
            self.make_coherent(buffer, MemoryId::HOST, &own, Some(&cmd.task));
        }

        // Dependencies: dataflow on the producers of our contribution in
        // host memory (send role), anti-dependencies against anything still
        // touching the bytes the inbound slices overwrite (receive role).
        let mut deps: Vec<(InstructionId, DepKind)> = Vec::new();
        {
            let st = &self.states[&buffer];
            let hs = &st.per_mem[MemoryId::HOST.0 as usize];
            hs.last_writer.for_each_in_region(&own, |_, w| {
                if let Some(w) = w {
                    push_dep(&mut deps, *w, DepKind::Dataflow);
                }
            });
            hs.readers_since.for_each_in_region(&inbound, |_, readers| {
                for r in readers {
                    push_dep(&mut deps, *r, DepKind::Anti);
                }
            });
            hs.last_writer.for_each_in_region(&inbound, |_, w| {
                if let Some(w) = w {
                    push_dep(&mut deps, *w, DepKind::Anti);
                }
            });
        }
        push_dep(&mut deps, backing.alloc_instr, DepKind::Dataflow);

        // One message id per ring round; round r forwards slice
        // (me − r) mod n to the successor. Pilots only for non-empty
        // rounds — the peer's round check skips empty slices by geometry.
        let succ = NodeId((me.0 + 1) % n as u64);
        let mut msgs = Vec::with_capacity(n - 1);
        for r in 0..n - 1 {
            let msg = MessageId(self.next_msg);
            self.next_msg += 1;
            msgs.push(msg);
            let send_box = slices[(me.0 as usize + n - r) % n];
            if !send_box.is_empty() {
                self.pilots.push(Pilot {
                    from: me,
                    to: succ,
                    msg,
                    buffer,
                    send_box,
                    transfer: cmd.task.id,
                });
            }
        }

        let id = self.push_instruction(
            InstructionKind::Collective {
                buffer,
                region: region.clone(),
                kind,
                slices,
                dst_alloc: backing.alloc,
                dst_box: backing.covers,
                transfer: cmd.task.id,
                msgs,
            },
            deps,
            Some(&cmd.task),
        );
        self.alloc_users.entry(backing.alloc).or_default().push(id);

        // Tracking: the collective is the local original producer of the
        // inbound bytes (they exist only on the host after it), and a
        // reader of our own contribution.
        let st = self.states.get_mut(&buffer).expect("buffer tracked since creation");
        if !inbound.is_empty() {
            st.coherent.update_region(&inbound, MemMask::single(MemoryId::HOST));
            let hs = &mut st.per_mem[MemoryId::HOST.0 as usize];
            hs.last_writer.update_region(&inbound, Some(id));
            hs.readers_since.update_region(&inbound, Vec::new());
        }
        if !own.is_empty() {
            let hs = &mut st.per_mem[MemoryId::HOST.0 as usize];
            hs.readers_since.apply_to_region(&own, |rs| {
                let mut rs = rs.clone();
                rs.push(id);
                rs
            });
        }
    }

    /// The per-device-chunk consumed subregions of an awaited region
    /// (consumer split, §3.4), tagged with the memory each chunk executes
    /// against. Recomputes the hierarchical split of the task
    /// deterministically; deduplicated by (memory, region).
    fn consumer_subregions_by_mem(
        &self,
        task: &TaskRef,
        buffer: BufferId,
        region: &Region,
    ) -> Vec<(MemoryId, Region)> {
        let Some(range) = task.kind.execution_range() else {
            return vec![];
        };
        let mut node_chunks =
            crate::command::split_range(range, self.cfg.num_nodes, self.cfg.node_hint);
        node_chunks.resize(self.cfg.num_nodes as usize, GridBox::EMPTY);
        let my_chunk = node_chunks[self.cfg.node.0 as usize];
        if my_chunk.is_empty() {
            return vec![];
        }
        let on_host = matches!(task.kind, TaskKind::HostTask { .. });
        let dchunks: Vec<(MemoryId, GridBox)> = if on_host {
            vec![(MemoryId::HOST, my_chunk)]
        } else {
            split_box(&my_chunk, self.cfg.num_devices, self.cfg.device_hint)
                .into_iter()
                .enumerate()
                .map(|(d, c)| (MemoryId::device_native(DeviceId(d as u64)), c))
                .collect()
        };
        let info = self.buffers.get(buffer);
        let mut out: Vec<(MemoryId, Region)> = Vec::new();
        for (mem, c) in dchunks {
            let mut consumed = Region::empty();
            for a in task.kind.accesses() {
                if a.buffer == buffer && a.mode.is_consumer() {
                    consumed = consumed.union(&a.mapper.apply(&c, range, info.range));
                }
            }
            let consumed = consumed.intersection(region);
            if !consumed.is_empty() && !out.iter().any(|(m, r)| *m == mem && *r == consumed) {
                out.push((mem, consumed));
            }
        }
        out
    }

    /// Where inbound fragments of an awaited region should land: the
    /// consuming device's native memory when direct transfers are on and a
    /// single known device consumes the *entire* region (and every other
    /// consumer can be made coherent from it — trivially true with d2d
    /// copies, or when it is the only consumer); pinned host memory (M1)
    /// otherwise.
    fn landing_memory(&self, by_mem: &[(MemoryId, Region)], region: &Region) -> MemoryId {
        if !self.cfg.direct_comm {
            return MemoryId::HOST;
        }
        by_mem
            .iter()
            .find(|(m, r)| {
                m.is_device()
                    && r == region
                    && (self.cfg.d2d || by_mem.iter().all(|(m2, _)| m2 == m))
            })
            .map(|(m, _)| *m)
            .unwrap_or(MemoryId::HOST)
    }

    /// [`Self::landing_memory`] from a command's task (lookahead support:
    /// `requirements` must announce the same memory `compile_await_push`
    /// will allocate in).
    fn receive_memory(&self, task: &TaskRef, buffer: BufferId, region: &Region) -> MemoryId {
        if !self.cfg.direct_comm || self.buffers.try_get(buffer).is_none() {
            return MemoryId::HOST;
        }
        let by_mem = self.consumer_subregions_by_mem(task, buffer, region);
        self.landing_memory(&by_mem, region)
    }

    // ──────────────────────────────────────────────────────────────────────
    // Allocation management (§3.2, Fig 3)
    // ──────────────────────────────────────────────────────────────────────

    fn ensure_state(&mut self, buffer: BufferId) {
        if self.states.contains_key(&buffer) {
            return;
        }
        let info = self.buffers.get(buffer).clone();
        let n_mem = 2 + self.cfg.num_devices as usize;
        let mut per_mem: Vec<MemState> = (0..n_mem)
            .map(|_| MemState {
                last_writer: RegionMap::new(info.range, None),
                readers_since: RegionMap::new(info.range, Vec::new()),
                backings: BackingSet::default(),
            })
            .collect();
        let mut coherent = RegionMap::new(info.range, MemMask::EMPTY);
        if !info.host_initialized.is_empty() {
            // User data lives in M0: a pre-existing, user-owned "backing"
            // covering the full range; the init epoch is its producer. The
            // allocation id is deterministic so the executor can
            // materialize the user bytes before instructions reference it.
            let alloc = user_alloc_id(buffer);
            per_mem[MemoryId::USER.0 as usize].backings.insert(Backing {
                alloc,
                covers: GridBox::full(info.range),
                alloc_instr: self.last_epoch.unwrap_or(InstructionId(0)),
            });
            per_mem[MemoryId::USER.0 as usize]
                .last_writer
                .update_region(&info.host_initialized, self.last_epoch.or(Some(InstructionId(0))));
            coherent.update_region(&info.host_initialized, MemMask::single(MemoryId::USER));
        }
        self.states.insert(
            buffer,
            BufState {
                range: info.range,
                elem_size: info.elem_size,
                name: info.name.clone(),
                coherent,
                per_mem,
            },
        );
    }

    /// Guarantee a single contiguous backing allocation covering `need` on
    /// `(buffer, mem)`, emitting the `alloc`/`copy`/`free` resize chain of
    /// Fig 3 if necessary. Never downsizes (§3.2).
    fn ensure_backing(
        &mut self,
        buffer: BufferId,
        mem: MemoryId,
        need: GridBox,
        task: Option<&TaskRef>,
    ) -> Backing {
        self.ensure_state(buffer);
        let elem_size = self.states[&buffer].elem_size as u64;
        if let Some(bk) = self.states[&buffer].per_mem[mem.0 as usize]
            .backings
            .containing(&need)
        {
            return bk.clone();
        }

        // Extend the goal box over announced future requirements (§4.3
        // resize elision) and over every existing backing it touches.
        let mut goal = need;
        if let Some(a) = self.announced.get(&(buffer, mem)) {
            goal = goal.bounding_union(a);
        }
        // Clamp to the virtual buffer range.
        goal = goal.intersection(&GridBox::full(self.states[&buffer].range));
        let mut old: Vec<Backing>;
        loop {
            old = self.states[&buffer].per_mem[mem.0 as usize]
                .backings
                .intersecting(&goal);
            let grown = old
                .iter()
                .fold(goal, |g, bk| g.bounding_union(&bk.covers));
            if grown == goal {
                break;
            }
            goal = grown;
        }

        // 1. The new allocation.
        let alloc = AllocationId(self.next_alloc);
        self.next_alloc += 1;
        let size_bytes = goal.area() * elem_size;
        let alloc_deps: Vec<(InstructionId, DepKind)> = self
            .last_epoch
            .iter()
            .map(|e| (*e, DepKind::Sync))
            .collect();
        let alloc_instr = self.push_instruction(
            InstructionKind::Alloc { alloc, memory: mem, buffer: Some(buffer), covers: goal, size_bytes },
            alloc_deps,
            task,
        );
        self.allocs_emitted += 1;
        self.bytes_allocated += size_bytes;
        if !old.is_empty() {
            self.resizes_emitted += 1;
        }
        self.alloc_users.insert(alloc, vec![alloc_instr]);

        // 2. Resize copies old → new, preserving current bytes.
        for bk in &old {
            let copy_box = bk.covers; // goal ⊇ covers after extension
            let mut deps: Vec<(InstructionId, DepKind)> = vec![(alloc_instr, DepKind::Dataflow)];
            {
                let ms = &self.states[&buffer].per_mem[mem.0 as usize];
                ms.last_writer.for_each_intersecting(&copy_box, |_, w| {
                    if let Some(w) = w {
                        push_dep(&mut deps, *w, DepKind::Dataflow);
                    }
                });
                ms.readers_since.for_each_intersecting(&copy_box, |_, readers| {
                    for r in readers {
                        push_dep(&mut deps, *r, DepKind::Dataflow);
                    }
                });
            }
            push_dep(&mut deps, bk.alloc_instr, DepKind::Dataflow);
            let copy_id = self.push_instruction(
                InstructionKind::Copy {
                    buffer,
                    copy_box,
                    src_memory: mem,
                    dst_memory: mem,
                    src_alloc: bk.alloc,
                    src_box: bk.covers,
                    dst_alloc: alloc,
                    dst_box: goal,
                },
                deps,
                task,
            );
            self.alloc_users.entry(bk.alloc).or_default().push(copy_id);
            self.alloc_users.entry(alloc).or_default().push(copy_id);
            // The resize copy is now the producer of those bytes in this
            // memory (they moved allocations).
            let st = self.states.get_mut(&buffer).expect("buffer tracked since creation");
            let ms = &mut st.per_mem[mem.0 as usize];
            ms.last_writer
                .update_region(&Region::from(copy_box), Some(copy_id));
            ms.readers_since.update_region(&Region::from(copy_box), Vec::new());
        }

        // 3. Free the replaced allocations.
        for bk in &old {
            let users = self.alloc_users.remove(&bk.alloc).unwrap_or_default();
            let deps: Vec<(InstructionId, DepKind)> =
                users.into_iter().map(|u| (u, DepKind::Anti)).collect();
            let covered = bk.covers.area() * elem_size;
            self.push_instruction(
                InstructionKind::Free { alloc: bk.alloc, memory: mem, size_bytes: covered },
                deps,
                task,
            );
            self.states
                .get_mut(&buffer)
                .expect("buffer tracked since creation")
                .per_mem[mem.0 as usize]
                .backings
                .remove(bk.alloc);
        }

        let backing = Backing { alloc, covers: goal, alloc_instr };
        self.states
            .get_mut(&buffer)
            .expect("buffer tracked since creation")
            .per_mem[mem.0 as usize]
            .backings
            .insert(backing.clone());
        backing
    }

    // ──────────────────────────────────────────────────────────────────────
    // Coherence (§3.3)
    // ──────────────────────────────────────────────────────────────────────

    /// Make `region` of `buffer` coherent in `dst` memory, emitting copy
    /// instructions subject to producer- and consumer split. Assumes a
    /// backing covering `region` already exists on `dst`.
    fn make_coherent(
        &mut self,
        buffer: BufferId,
        dst: MemoryId,
        region: &Region,
        task: Option<&TaskRef>,
    ) {
        // Fragments not yet coherent in dst, keyed by source-memory set.
        let mut missing: Vec<(GridBox, MemMask)> = Vec::new();
        self.states[&buffer].coherent.for_each_in_region(region, |b, mask| {
            if !mask.contains(dst) && !mask.is_empty() {
                missing.push((b, *mask));
            }
        });
        for (mbox, mask) in missing {
            match self.pick_source(dst, mask) {
                Some(CopyPath::Direct(src_mem)) => {
                    self.emit_copies(buffer, src_mem, dst, &mbox, task);
                }
                Some(CopyPath::Staged(src_mem)) => {
                    // Device→host, then host→device (§3.3 consumer-GPU path).
                    self.ensure_backing(buffer, MemoryId::HOST, mbox, task);
                    self.emit_copies(buffer, src_mem, MemoryId::HOST, &mbox, task);
                    self.emit_copies(buffer, MemoryId::HOST, dst, &mbox, task);
                }
                // No usable copy source (§4.4): report through the
                // scheduler's error stream instead of panicking the
                // scheduler thread; the consumer reads the (uninitialized)
                // destination backing.
                None => {
                    self.errors.push(format!(
                        "cannot make {} of buffer '{}' coherent on {dst}: no readable \
                         copy source in coherence mask {:#x} (§4.4)",
                        mbox, self.states[&buffer].name, mask.0
                    ));
                }
            }
        }
    }

    /// One copy instruction per (original-producer fragment × backing
    /// overlap) — the producer split of §3.3: "one copy for each pairing of
    /// original-producer and consumer instruction" so that "subregions
    /// available early can be copied to the target memory right away".
    fn emit_copies(
        &mut self,
        buffer: BufferId,
        src: MemoryId,
        dst: MemoryId,
        mbox: &GridBox,
        task: Option<&TaskRef>,
    ) {
        let frags: Vec<(GridBox, Option<InstructionId>, Backing, Backing)> = {
            let st = &self.states[&buffer];
            let sm = &st.per_mem[src.0 as usize];
            let dm = &st.per_mem[dst.0 as usize];
            let mut v = Vec::new();
            sm.last_writer.for_each_intersecting(mbox, |pbox, producer| {
                for sbk in sm.backings.intersecting(&pbox) {
                    let frag = pbox.intersection(&sbk.covers);
                    if frag.is_empty() {
                        continue;
                    }
                    let dbk = dm
                        .backings
                        .containing(&frag)
                        .cloned()
                        .unwrap_or_else(|| panic!(
                            "no dst backing for {} of buffer {} on {dst}",
                            frag, st.name
                        ));
                    v.push((frag, *producer, sbk.clone(), dbk));
                }
            });
            v
        };
        // One copy per fragment; the fragments partition `mbox ∩ producers`,
        // so their tracking updates are independent and can be applied as
        // one batch after the loop (a single partition pass per map instead
        // of one rebuild per copy).
        let mut copied_boxes: Vec<GridBox> = Vec::new();
        let mut writer_updates: Vec<(GridBox, Option<InstructionId>)> = Vec::new();
        let mut reader_resets: Vec<(GridBox, Vec<InstructionId>)> = Vec::new();
        let mut src_reader_adds: Vec<(GridBox, InstructionId)> = Vec::new();
        for (frag, producer, sbk, dbk) in frags {
            let mut deps: Vec<(InstructionId, DepKind)> = Vec::new();
            if let Some(p) = producer {
                push_dep(&mut deps, p, DepKind::Dataflow);
            }
            push_dep(&mut deps, sbk.alloc_instr, DepKind::Dataflow);
            push_dep(&mut deps, dbk.alloc_instr, DepKind::Dataflow);
            {
                let st = &self.states[&buffer];
                let dm = &st.per_mem[dst.0 as usize];
                dm.readers_since.for_each_intersecting(&frag, |_, readers| {
                    for r in readers {
                        push_dep(&mut deps, *r, DepKind::Anti);
                    }
                });
                dm.last_writer.for_each_intersecting(&frag, |_, w| {
                    if let Some(w) = w {
                        push_dep(&mut deps, *w, DepKind::Output);
                    }
                });
            }
            let id = self.push_instruction(
                InstructionKind::Copy {
                    buffer,
                    copy_box: frag,
                    src_memory: src,
                    dst_memory: dst,
                    src_alloc: sbk.alloc,
                    src_box: sbk.covers,
                    dst_alloc: dbk.alloc,
                    dst_box: dbk.covers,
                },
                deps,
                task,
            );
            self.alloc_users.entry(sbk.alloc).or_default().push(id);
            self.alloc_users.entry(dbk.alloc).or_default().push(id);
            copied_boxes.push(frag);
            writer_updates.push((frag, Some(id)));
            reader_resets.push((frag, Vec::new()));
            src_reader_adds.push((frag, id));
        }
        if !copied_boxes.is_empty() {
            let st = self.states.get_mut(&buffer).expect("buffer tracked since creation");
            st.coherent.apply_to_region(
                &Region::from_boxes(copied_boxes.iter().copied()),
                |m| m.insert(dst),
            );
            let dm = &mut st.per_mem[dst.0 as usize];
            dm.last_writer.update_boxes(writer_updates);
            dm.readers_since.update_boxes(reader_resets);
            let sm = &mut st.per_mem[src.0 as usize];
            for (frag, id) in src_reader_adds {
                sm.readers_since.apply_to_region(&Region::from(frag), |rs| {
                    let mut rs = rs.clone();
                    rs.push(id);
                    rs
                });
            }
        }
    }

    /// Choose the copy source for data currently coherent in `mask`, or
    /// `None` when the mask names no readable memory (never-written bytes
    /// or corrupted tracking state — a §4.4 error for the caller to report,
    /// not a reason to kill the scheduler thread).
    fn pick_source(&self, dst: MemoryId, mask: MemMask) -> Option<CopyPath> {
        // Host sources (pinned first, then user memory) are always direct.
        if mask.contains(MemoryId::HOST) {
            return Some(CopyPath::Direct(MemoryId::HOST));
        }
        if mask.contains(MemoryId::USER) {
            return Some(CopyPath::Direct(MemoryId::USER));
        }
        // Device source.
        let src_dev = mask.first_device()?;
        Some(if !dst.is_device() || self.cfg.d2d {
            CopyPath::Direct(src_dev)
        } else {
            CopyPath::Staged(src_dev)
        })
    }

    // ──────────────────────────────────────────────────────────────────────
    // Synchronization & pruning
    // ──────────────────────────────────────────────────────────────────────

    /// Free every live runtime allocation (shutdown; user M0 memory is not
    /// ours to free).
    fn free_all_backings(&mut self) {
        let targets: Vec<(BufferId, MemoryId, Backing, u64)> = self
            .states
            .iter()
            .flat_map(|(buf, st)| {
                st.per_mem
                    .iter()
                    .enumerate()
                    .filter(|(m, _)| *m != MemoryId::USER.0 as usize)
                    .flat_map(move |(m, ms)| {
                        ms.backings.backings.iter().map(move |bk| {
                            (*buf, MemoryId(m as u64), bk.clone(), st.elem_size as u64)
                        })
                    })
            })
            .collect();
        for (buffer, mem, bk, elem_size) in targets {
            let users = self.alloc_users.remove(&bk.alloc).unwrap_or_default();
            let deps: Vec<(InstructionId, DepKind)> =
                users.into_iter().map(|u| (u, DepKind::Anti)).collect();
            self.push_instruction(
                InstructionKind::Free {
                    alloc: bk.alloc,
                    memory: mem,
                    size_bytes: bk.covers.area() * elem_size,
                },
                deps,
                None,
            );
            self.states
                .get_mut(&buffer)
                .expect("buffer tracked since creation")
                .per_mem[mem.0 as usize]
                .backings
                .remove(bk.alloc);
        }
    }

    fn push_front_instruction(
        &mut self,
        kind: InstructionKind,
        task: Option<&TaskRef>,
    ) -> InstructionId {
        let deps: Vec<(InstructionId, DepKind)> = self
            .dag
            .front()
            .into_iter()
            .map(|id| (InstructionId(id), DepKind::Sync))
            .collect();
        self.push_instruction(kind, deps, task)
    }

    /// Substitute `boundary` for all older producers/readers/users, then
    /// prune the DAG (§3.5).
    fn apply_boundary(&mut self, boundary: InstructionId) {
        for st in self.states.values_mut() {
            for ms in &mut st.per_mem {
                let full = Region::full(ms.last_writer.extent().range());
                ms.last_writer.apply_to_region(&full, |w| match w {
                    Some(w) if w.0 < boundary.0 => Some(boundary),
                    other => *other,
                });
                ms.readers_since.apply_to_region(&full, |rs| {
                    let newer: Vec<InstructionId> =
                        rs.iter().copied().filter(|r| r.0 >= boundary.0).collect();
                    if rs.is_empty() {
                        Vec::new()
                    } else if newer.len() == rs.len() {
                        rs.clone()
                    } else {
                        let mut v = vec![boundary];
                        v.extend(newer);
                        v
                    }
                });
            }
        }
        for users in self.alloc_users.values_mut() {
            let had_old = users.iter().any(|u| u.0 < boundary.0);
            users.retain(|u| u.0 >= boundary.0);
            if had_old {
                users.insert(0, boundary);
            }
        }
        self.dag.prune_before(boundary.0);
    }

    fn push_instruction(
        &mut self,
        kind: InstructionKind,
        deps: Vec<(InstructionId, DepKind)>,
        task: Option<&TaskRef>,
    ) -> InstructionId {
        let id = InstructionId(self.dag.total_created());
        let instr = std::sync::Arc::new(Instruction {
            id,
            kind,
            deps: deps.clone(),
            task: task.cloned(),
        });
        self.dag.push(
            instr.clone(),
            deps.iter().map(|(d, k)| Dep { from: d.0, kind: *k }),
        );
        self.outbox.push(instr);
        id
    }
}

enum CopyPath {
    Direct(MemoryId),
    Staged(MemoryId),
}

fn push_dep(deps: &mut Vec<(InstructionId, DepKind)>, id: InstructionId, kind: DepKind) {
    if !deps.iter().any(|(d, _)| *d == id) {
        deps.push((id, kind));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::command::CdagGenerator;
    use crate::grid::Range;
    use crate::task::{RangeMapper, TaskDecl, TaskManager};

    /// Full pipeline helper: submit tasks, compile CDAG on node 0 of
    /// `nodes`, compile IDAG with `devices`, return all instructions.
    /// Collective lowering is disabled — these tests pin the paper's p2p
    /// instruction shapes; the collective path has its own tests below.
    /// Direct device transfers are on (the default); `build_with` exposes
    /// the `--no-direct-comm` staged lowering.
    fn build(
        nodes: u64,
        devices: u64,
        d2d: bool,
        f: impl FnOnce(&mut TaskManager),
    ) -> (Vec<InstructionRef>, Vec<Pilot>, IdagGenerator) {
        build_with(nodes, devices, d2d, true, f)
    }

    fn build_with(
        nodes: u64,
        devices: u64,
        d2d: bool,
        direct_comm: bool,
        f: impl FnOnce(&mut TaskManager),
    ) -> (Vec<InstructionRef>, Vec<Pilot>, IdagGenerator) {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        f(&mut tm);
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), nodes, SplitHint::D1, tm.buffers().clone());
        cg.set_collectives(false);
        for t in &tasks {
            cg.compile(t);
        }
        let cmds = cg.take_new_commands();
        let cfg = IdagConfig {
            node: NodeId(0),
            num_nodes: nodes,
            num_devices: devices,
            node_hint: SplitHint::D1,
            device_hint: SplitHint::D1,
            d2d,
            direct_comm,
        };
        let mut ig = IdagGenerator::new(cfg, tm.buffers().clone());
        for c in &cmds {
            ig.compile(c);
        }
        assert!(ig.dag().check_acyclic());
        let instrs = ig.take_new_instructions();
        let pilots = ig.take_pilots();
        (instrs, pilots, ig)
    }

    fn count(instrs: &[InstructionRef], mnemonic: &str) -> usize {
        instrs.iter().filter(|i| i.kind.mnemonic() == mnemonic).count()
    }

    fn nbody(tm: &mut TaskManager, steps: usize, n: u64) {
        let r = Range::d1(n);
        let p = tm.create_buffer::<[f64; 3]>("P", r, true).id();
        let v = tm.create_buffer::<[f64; 3]>("V", r, true).id();
        for _ in 0..steps {
            tm.submit(
                TaskDecl::device("timestep", r)
                    .read(p, RangeMapper::All)
                    .read_write(v, RangeMapper::OneToOne)
                    .kernel("nbody_timestep"),
            );
            tm.submit(
                TaskDecl::device("update", r)
                    .read(v, RangeMapper::OneToOne)
                    .read_write(p, RangeMapper::OneToOne)
                    .kernel("nbody_update"),
            );
        }
    }

    #[test]
    fn fig4_nbody_two_devices_single_node() {
        // §3.6 / Fig 4 on one node: allocs for P (full range, both devices)
        // and V (quarter each... here: half each since 1 node), kernels per
        // device, d2d copies on the second timestep.
        let (instrs, pilots, _) = build(1, 2, true, |tm| nbody(tm, 2, 4096));
        assert!(pilots.is_empty());

        // P full-range on M2 and M3; V half on each; plus M0 user backings
        // don't emit allocs. First timestep: 2 P allocs + 2 V allocs.
        let allocs: Vec<_> = instrs
            .iter()
            .filter_map(|i| match &i.kind {
                InstructionKind::Alloc { memory, covers, .. } => Some((*memory, *covers)),
                _ => None,
            })
            .collect();
        assert!(allocs.contains(&(MemoryId(2), GridBox::d1(0, 4096))), "{allocs:?}");
        assert!(allocs.contains(&(MemoryId(3), GridBox::d1(0, 4096))));
        assert!(allocs.contains(&(MemoryId(2), GridBox::d1(0, 2048))));
        assert!(allocs.contains(&(MemoryId(3), GridBox::d1(2048, 4096))));

        // 2 kernels per task × 4 tasks.
        assert_eq!(count(&instrs, "device kernel"), 8);

        // Second timestep needs P coherent everywhere: the halves produced
        // by "update" on each device cross over → at least 2 d2d copies.
        let d2d: Vec<_> = instrs
            .iter()
            .filter_map(|i| match &i.kind {
                InstructionKind::Copy { src_memory, dst_memory, copy_box, .. }
                    if src_memory.is_device() && dst_memory.is_device() =>
                {
                    Some((*src_memory, *dst_memory, *copy_box))
                }
                _ => None,
            })
            .collect();
        assert!(d2d.contains(&(MemoryId(3), MemoryId(2), GridBox::d1(2048, 4096))), "{d2d:?}");
        assert!(d2d.contains(&(MemoryId(2), MemoryId(3), GridBox::d1(0, 2048))));
        assert_eq!(count(&instrs, "receive") + count(&instrs, "send"), 0);
    }

    #[test]
    fn staging_when_d2d_unsupported() {
        // Same workload with d2d disabled: inter-device coherence goes
        // through pinned host memory (§3.3).
        let (instrs, _, _) = build(1, 2, false, |tm| nbody(tm, 2, 4096));
        let direct_d2d = instrs
            .iter()
            .filter(|i| match &i.kind {
                InstructionKind::Copy { src_memory, dst_memory, .. } => {
                    src_memory.is_device() && dst_memory.is_device()
                }
                _ => false,
            })
            .count();
        assert_eq!(direct_d2d, 0);
        // Both d2h and h2d staging hops must exist.
        let d2h = instrs
            .iter()
            .filter(|i| matches!(&i.kind,
                InstructionKind::Copy { src_memory, dst_memory, .. }
                    if src_memory.is_device() && *dst_memory == MemoryId::HOST))
            .count();
        let h2d = instrs
            .iter()
            .filter(|i| matches!(&i.kind,
                InstructionKind::Copy { src_memory, dst_memory, .. }
                    if *src_memory == MemoryId::HOST && dst_memory.is_device()))
            .count();
        assert!(d2h >= 2 && h2d >= 2, "d2h={d2h} h2d={h2d}");
    }

    #[test]
    fn fig4_two_nodes_emits_sends_and_receive() {
        // Node 0 of 2, 2 devices (Fig 4 exactly, staged lowering — direct
        // transfers off): the push command becomes producer-split sends
        // (one per device producing half of our half), with pilots; the
        // await-push becomes a receive.
        let (instrs, pilots, _) = build_with(2, 2, true, false, |tm| nbody(tm, 2, 4096));
        let sends = count(&instrs, "send");
        // Our half of P (0..2048) is produced by update-kernels on D0
        // (0..1024) and D1 (1024..2048) → 2 producer-split sends (I10/I11).
        assert_eq!(sends, 2);
        assert_eq!(pilots.len(), 2);
        assert!(pilots.iter().all(|p| p.to == NodeId(1)));
        let boxes: Vec<GridBox> = pilots.iter().map(|p| p.send_box).collect();
        assert!(boxes.contains(&GridBox::d1(0, 1024)), "{boxes:?}");
        assert!(boxes.contains(&GridBox::d1(1024, 2048)));

        // Await-push of the peer half: both local devices consume the
        // *same* region (All mapper) → single receive (§3.6: "the
        // consumer-split logic does not apply").
        assert_eq!(count(&instrs, "receive"), 1);
        assert_eq!(count(&instrs, "split receive"), 0);

        // Sends are preceded by d2h coherence copies.
        let d2h = instrs
            .iter()
            .filter(|i| matches!(&i.kind,
                InstructionKind::Copy { src_memory, dst_memory, .. }
                    if src_memory.is_device() && *dst_memory == MemoryId::HOST))
            .count();
        assert!(d2h >= 2);
    }

    #[test]
    fn consumer_split_receive_for_disjoint_consumers() {
        // Stencil-like: each device consumes a *disjoint* part of the
        // awaited region → split receive + await receives (§3.4 case a/c).
        let (instrs, _, _) = build(2, 2, true, |tm| {
            let r = Range::d1(4096);
            let a = tm.create_buffer::<f64>("A", r, true).id();
            let b = tm.create_buffer::<f64>("B", r, false).id();
            // Step 1: everyone writes their part of A.
            tm.submit(TaskDecl::device("w", r).read_write(a, RangeMapper::OneToOne));
            // Step 2: shifted read: each element i reads a[i + 2048] where
            // available — node 0 needs exactly node 1's half, split across
            // its devices.
            tm.submit(
                TaskDecl::device("shift", r)
                    .read(a, RangeMapper::Shift(crate::grid::Point::d1(2048)))
                    .write(b, RangeMapper::OneToOne),
            );
        });
        assert_eq!(count(&instrs, "split receive"), 1, "{:#?}",
            instrs.iter().map(|i| i.label()).collect::<Vec<_>>());
        assert_eq!(count(&instrs, "await receive"), 2);
        assert_eq!(count(&instrs, "receive"), 0);
    }

    #[test]
    fn listing2_growing_access_triggers_resize_chain() {
        // Listing 2: one-to-one write, then neighborhood read → the second
        // task's backing must grow → alloc/copy/free resize chain (Fig 3).
        let (instrs, _, ig) = build(1, 1, true, |tm| {
            let r = Range::d1(1024);
            let a = tm.create_buffer::<f64>("A", r, false).id();
            let b = tm.create_buffer::<f64>("B", r, false).id();
            // Task writes only the middle of A.
            tm.submit(TaskDecl::device("w", Range::d1(512)).write(
                a,
                RangeMapper::Shift(crate::grid::Point::d1(256)),
            ));
            // Then a full-range read of A (grown requirement) + write B.
            tm.submit(
                TaskDecl::device("r", r)
                    .read(a, RangeMapper::Neighborhood(Range::d1(1)))
                    .write(b, RangeMapper::OneToOne),
            );
        });
        assert!(ig.resizes_emitted >= 1, "expected a resize");
        // The resize chain: second alloc for A, one same-memory copy
        // preserving the middle, one free of the small backing.
        let same_mem_copies = instrs
            .iter()
            .filter(|i| matches!(&i.kind,
                InstructionKind::Copy { src_memory, dst_memory, copy_box, .. }
                    if src_memory == dst_memory && *copy_box == GridBox::d1(256, 768)))
            .count();
        assert_eq!(same_mem_copies, 1);
        assert!(count(&instrs, "free") >= 1);
    }

    #[test]
    fn announce_elides_resize() {
        // Same workload, but with the second task's requirement announced
        // ahead of time (what the scheduler lookahead does): the first
        // alloc covers everything, no resize.
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let r = Range::d1(1024);
        let a = tm.create_buffer::<f64>("A", r, false).id();
        let b = tm.create_buffer::<f64>("B", r, false).id();
        tm.submit(TaskDecl::device("w", Range::d1(512)).write(
            a,
            RangeMapper::Shift(crate::grid::Point::d1(256)),
        ));
        tm.submit(
            TaskDecl::device("r", r)
                .read(a, RangeMapper::Neighborhood(Range::d1(1)))
                .write(b, RangeMapper::OneToOne),
        );
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), 1, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            cg.compile(t);
        }
        let cmds = cg.take_new_commands();
        let mut ig = IdagGenerator::new(
            IdagConfig { num_devices: 1, ..Default::default() },
            tm.buffers().clone(),
        );
        // Announce all requirements up-front (the flush step of §4.3).
        let all_reqs: Vec<_> = cmds.iter().flat_map(|c| ig.requirements(c)).collect();
        ig.announce(&all_reqs);
        for c in &cmds {
            ig.compile(c);
        }
        assert_eq!(ig.resizes_emitted, 0);
        // A gets exactly one alloc on the device, covering the full range.
        let instrs = ig.take_new_instructions();
        let a_allocs: Vec<_> = instrs
            .iter()
            .filter_map(|i| match &i.kind {
                InstructionKind::Alloc { buffer, covers, memory, .. }
                    if *buffer == Some(a) && memory.is_device() =>
                {
                    Some(*covers)
                }
                _ => None,
            })
            .collect();
        assert_eq!(a_allocs, vec![GridBox::d1(0, 1024)]);
    }

    #[test]
    fn would_allocate_predicate() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let r = Range::d1(256);
        let a = tm.create_buffer::<f64>("A", r, true).id();
        tm.submit(TaskDecl::device("w1", r).read_write(a, RangeMapper::OneToOne));
        tm.submit(TaskDecl::device("w2", r).read_write(a, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), 1, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            cg.compile(t);
        }
        let cmds = cg.take_new_commands();
        let mut ig = IdagGenerator::new(
            IdagConfig { num_devices: 1, ..Default::default() },
            tm.buffers().clone(),
        );
        let execs: Vec<_> = cmds.iter().filter(|c| c.is_execution()).collect();
        // Before compiling anything: first exec would allocate.
        assert!(ig.would_allocate(execs[0]));
        for c in &cmds[..2] {
            ig.compile(c); // epoch + first exec
        }
        // Identical access pattern: second exec no longer allocates.
        assert!(!ig.would_allocate(execs[1]));
    }

    #[test]
    fn host_init_data_copied_from_user_memory() {
        // First consumer of a host-initialized buffer pulls from M0.
        let (instrs, _, _) = build(1, 1, true, |tm| {
            let r = Range::d1(64);
            let a = tm.create_buffer::<f64>("A", r, true).id();
            let b = tm.create_buffer::<f64>("B", r, false).id();
            tm.submit(
                TaskDecl::device("r", r)
                    .read(a, RangeMapper::OneToOne)
                    .write(b, RangeMapper::OneToOne),
            );
        });
        let from_user = instrs
            .iter()
            .filter(|i| matches!(&i.kind,
                InstructionKind::Copy { src_memory, .. } if *src_memory == MemoryId::USER))
            .count();
        assert_eq!(from_user, 1);
    }

    #[test]
    fn shutdown_frees_every_runtime_allocation() {
        let (instrs, _, _) = build(1, 2, true, |tm| {
            nbody(tm, 3, 1024);
            tm.shutdown();
        });
        let allocs = count(&instrs, "alloc");
        let frees = count(&instrs, "free");
        assert_eq!(allocs, frees, "every alloc must eventually be freed");
        assert!(allocs > 0);
        // The shutdown epoch is last and depends on the frees.
        let last = instrs.last().unwrap();
        assert_eq!(last.kind.mnemonic(), "epoch");
    }

    #[test]
    fn horizons_bound_idag_size() {
        let mut tm = TaskManager::with_horizon_step(2);
        let r = Range::d1(512);
        let a = tm.create_buffer::<f64>("A", r, true).id();
        for _ in 0..30 {
            tm.submit(TaskDecl::device("w", r).read_write(a, RangeMapper::OneToOne));
        }
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), 1, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            cg.compile(t);
        }
        let cmds = cg.take_new_commands();
        let mut ig = IdagGenerator::new(
            IdagConfig { num_devices: 2, ..Default::default() },
            tm.buffers().clone(),
        );
        for c in &cmds {
            ig.compile(c);
        }
        assert!(ig.dag().check_acyclic());
        assert!(
            (ig.dag().len() as u64) < ig.dag().total_created() / 2,
            "pruning must keep the live IDAG small: live={} total={}",
            ig.dag().len(),
            ig.dag().total_created()
        );
    }

    #[test]
    fn horizon_application_bounds_alloc_user_tracking() {
        // Satellite regression: applying horizons must substitute the
        // boundary for old alloc users, keeping `alloc_users` bounded
        // instead of growing with every kernel ever emitted.
        let run = |horizon_step: u64| {
            let mut tm = TaskManager::with_horizon_step(horizon_step);
            let r = Range::d1(512);
            let a = tm.create_buffer::<f64>("A", r, true).id();
            let b = tm.create_buffer::<f64>("B", r, true).id();
            for _ in 0..60 {
                tm.submit(
                    TaskDecl::device("w", r)
                        .read(a, RangeMapper::All)
                        .read_write(b, RangeMapper::OneToOne),
                );
            }
            let tasks = tm.take_new_tasks();
            let mut cg = CdagGenerator::new(NodeId(0), 1, SplitHint::D1, tm.buffers().clone());
            for t in &tasks {
                cg.compile(t);
            }
            let cmds = cg.take_new_commands();
            let mut ig = IdagGenerator::new(
                IdagConfig { num_devices: 2, ..Default::default() },
                tm.buffers().clone(),
            );
            for c in &cmds {
                ig.compile(c);
            }
            assert!(ig.dag().check_acyclic());
            ig.alloc_user_entries()
        };
        let bounded = run(2);
        let unbounded = run(u64::MAX);
        assert!(
            bounded * 3 < unbounded,
            "horizons must prune alloc-user tracking: bounded={bounded} unbounded={unbounded}"
        );
    }

    #[test]
    fn kernel_bindings_cover_access_regions() {
        let (instrs, _, _) = build(1, 2, true, |tm| nbody(tm, 1, 2048));
        for i in &instrs {
            if let InstructionKind::DeviceKernel { bindings, chunk, .. } = &i.kind {
                assert!(!bindings.is_empty());
                for b in bindings {
                    assert!(
                        b.alloc_box.contains(&b.region.bounding_box()),
                        "binding backing must cover the accessed region"
                    );
                }
                assert!(!chunk.is_empty());
            }
        }
    }

    /// Collective lowering helper: like [`build`] but with collectives on
    /// and a configurable node id.
    fn build_collective(
        node: u64,
        nodes: u64,
        devices: u64,
        f: impl FnOnce(&mut TaskManager),
    ) -> (Vec<InstructionRef>, Vec<Pilot>, IdagGenerator) {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        f(&mut tm);
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(node), nodes, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            cg.compile(t);
        }
        let cmds = cg.take_new_commands();
        let cfg = IdagConfig {
            node: NodeId(node),
            num_nodes: nodes,
            num_devices: devices,
            node_hint: SplitHint::D1,
            device_hint: SplitHint::D1,
            d2d: true,
            direct_comm: true,
        };
        let mut ig = IdagGenerator::new(cfg, tm.buffers().clone());
        for c in &cmds {
            ig.compile(c);
        }
        assert!(ig.dag().check_acyclic());
        let instrs = ig.take_new_instructions();
        let pilots = ig.take_pilots();
        (instrs, pilots, ig)
    }

    /// The all-gather command compiles into one collective instruction per
    /// exchange: pilots go to the ring successor only (one per round), the
    /// gathered region gets a single contiguous host backing, and no
    /// p2p send/receive instructions remain for that buffer.
    #[test]
    fn collective_lowering_ring_pilots_and_backing() {
        let nodes = 4u64;
        for node in 0..nodes {
            let (instrs, pilots, _) =
                build_collective(node, nodes, 2, |tm| nbody(tm, 2, 4096));
            let colls: Vec<_> = instrs
                .iter()
                .filter_map(|i| match &i.kind {
                    InstructionKind::Collective { region, slices, msgs, dst_box, .. } => {
                        Some((region.clone(), slices.clone(), msgs.clone(), *dst_box))
                    }
                    _ => None,
                })
                .collect();
            assert_eq!(colls.len(), 1, "node {node}: one exchange in 2 steps");
            let (region, slices, msgs, dst_box) = &colls[0];
            assert_eq!(*region, Region::from(GridBox::d1(0, 4096)));
            assert_eq!(slices.len(), nodes as usize);
            assert_eq!(msgs.len(), nodes as usize - 1, "one message per ring round");
            assert!(dst_box.contains(&region.bounding_box()), "contiguous backing");
            // No p2p left for the gathered buffer.
            assert_eq!(count(&instrs, "send"), 0);
            assert_eq!(count(&instrs, "receive"), 0);
            assert_eq!(count(&instrs, "split receive"), 0);
            // All pilots target the ring successor, one per round, and
            // announce the statically-known forwarded slices.
            let succ = NodeId((node + 1) % nodes);
            assert_eq!(pilots.len(), nodes as usize - 1);
            for (r, p) in pilots.iter().enumerate() {
                assert_eq!(p.to, succ, "node {node} round {r}");
                assert_eq!(p.from, NodeId(node));
                assert_eq!(
                    p.send_box,
                    slices[((node as usize) + nodes as usize - r) % nodes as usize],
                    "node {node} round {r} forwards the right slice"
                );
            }
            // The collective depends on the d2h staging of our own slice.
            let coll = instrs
                .iter()
                .find(|i| matches!(i.kind, InstructionKind::Collective { .. }))
                .unwrap();
            assert!(!coll.deps.is_empty());
        }
    }

    /// Lookahead integration: a collective command reports its host-memory
    /// requirement, so `would_allocate` treats it like other allocating
    /// commands (§4.3) and the first host alloc covers the gathered region.
    #[test]
    fn collective_requirements_drive_would_allocate() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        nbody(&mut tm, 2, 1024);
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), 2, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            cg.compile(t);
        }
        let cmds = cg.take_new_commands();
        let coll_cmd = cmds
            .iter()
            .find(|c| matches!(c.kind, crate::command::CommandKind::Collective { .. }))
            .expect("nbody all-gather fires");
        let ig = IdagGenerator::new(
            IdagConfig { num_nodes: 2, num_devices: 2, ..Default::default() },
            tm.buffers().clone(),
        );
        let reqs = ig.requirements(coll_cmd);
        assert_eq!(reqs.len(), 1);
        let (_, mem, bbox) = reqs[0];
        assert_eq!(mem, MemoryId::HOST);
        assert_eq!(bbox, GridBox::d1(0, 1024));
        assert!(ig.would_allocate(coll_cmd), "fresh generator must allocate");
    }

    #[test]
    fn sends_depend_on_their_producers_only() {
        // Producer split (§3.3): each send depends on the specific kernel
        // that produced its fragment, not on both. Holds on the direct path
        // (sends depend on the producing kernels themselves) exactly as on
        // the staged path (where they depend on per-producer d2h copies).
        let (instrs, _, _) = build(2, 2, true, |tm| nbody(tm, 2, 4096));
        let sends: Vec<_> = instrs
            .iter()
            .filter(|i| matches!(i.kind, InstructionKind::Send { .. }))
            .collect();
        assert_eq!(sends.len(), 2);
        assert_ne!(
            sends[0].deps.iter().map(|(d, _)| *d).collect::<Vec<_>>(),
            sends[1].deps.iter().map(|(d, _)| *d).collect::<Vec<_>>()
        );
    }

    // ── direct device transfers (d2h/h2d staging elision) ───────────────

    /// Count instructions that touch pinned host memory (M1) for `buffer`
    /// in any role: backings, copies in or out, send sources, receive
    /// destinations.
    fn m1_touches(instrs: &[InstructionRef], buffer: BufferId) -> usize {
        instrs
            .iter()
            .filter(|i| match &i.kind {
                InstructionKind::Alloc { memory, buffer: b, .. } => {
                    *memory == MemoryId::HOST && *b == Some(buffer)
                }
                InstructionKind::Copy { buffer: b, src_memory, dst_memory, .. } => {
                    *b == buffer
                        && (*src_memory == MemoryId::HOST || *dst_memory == MemoryId::HOST)
                }
                InstructionKind::Send { buffer: b, src_memory, .. } => {
                    *b == buffer && *src_memory == MemoryId::HOST
                }
                InstructionKind::Receive { buffer: b, dst_memory, .. }
                | InstructionKind::SplitReceive { buffer: b, dst_memory, .. } => {
                    *b == buffer && *dst_memory == MemoryId::HOST
                }
                _ => false,
            })
            .count()
    }

    /// Acceptance: a device-resident push with elision on emits *zero* M1
    /// staging instructions for that buffer — the send reads the device
    /// backing directly and the receive lands straight in the consuming
    /// device's allocation. The staged lowering of the same program pays
    /// both hops.
    #[test]
    fn device_resident_push_elides_all_host_staging() {
        let find_p = |instrs: &[InstructionRef]| {
            // nbody buffer P is pushed (peers read it with the All mapper).
            instrs
                .iter()
                .find_map(|i| match &i.kind {
                    InstructionKind::Send { buffer, .. } => Some(*buffer),
                    _ => None,
                })
                .expect("nbody must push P")
        };

        // Direct: single device per node — the awaited region's only
        // consumer is that device, so both ends elide M1 entirely.
        let (direct, pilots, _) = build(2, 1, true, |tm| nbody(tm, 2, 4096));
        let p = find_p(&direct);
        assert_eq!(m1_touches(&direct, p), 0, "elision must leave no M1 staging");
        for i in &direct {
            match &i.kind {
                InstructionKind::Send { src_memory, .. } => {
                    assert!(src_memory.is_device(), "send must read the device backing");
                }
                InstructionKind::Receive { dst_memory, .. } => {
                    assert!(dst_memory.is_device(), "receive must land in the device");
                }
                _ => {}
            }
        }
        assert!(!pilots.is_empty(), "pilot protocol is unchanged");

        // Staged lowering of the identical program: d2h before the send,
        // M1 landing + h2d after the receive.
        let (staged, _, _) = build_with(2, 1, true, false, |tm| nbody(tm, 2, 4096));
        assert!(m1_touches(&staged, p) > 0, "staged path must use M1");
        let d2h = staged
            .iter()
            .filter(|i| matches!(&i.kind,
                InstructionKind::Copy { src_memory, dst_memory, .. }
                    if src_memory.is_device() && *dst_memory == MemoryId::HOST))
            .count();
        assert!(d2h >= 1, "staged sends are preceded by d2h copies");

        // Same sends/receives/pilots shape either way — only the memory
        // path differs.
        assert_eq!(count(&direct, "send"), count(&staged, "send"));
        assert_eq!(count(&direct, "receive"), count(&staged, "receive"));
    }

    /// Multi-device node: the producer split keeps one direct send per
    /// producing device (src M2 and M3), and the full-region consumer
    /// geometry lands the inbound transfer in one device from which the
    /// other is made coherent by a d2d copy — no M1 hop anywhere.
    #[test]
    fn direct_sends_split_across_producing_devices() {
        let (instrs, _, _) = build(2, 2, true, |tm| nbody(tm, 2, 4096));
        let send_srcs: Vec<MemoryId> = instrs
            .iter()
            .filter_map(|i| match &i.kind {
                InstructionKind::Send { src_memory, .. } => Some(*src_memory),
                _ => None,
            })
            .collect();
        assert_eq!(send_srcs.len(), 2);
        assert!(send_srcs.contains(&MemoryId(2)) && send_srcs.contains(&MemoryId(3)),
            "{send_srcs:?}");
        // No d2h staging copies for the pushed buffer.
        let d2h = instrs
            .iter()
            .filter(|i| matches!(&i.kind,
                InstructionKind::Copy { src_memory, dst_memory, .. }
                    if src_memory.is_device() && *dst_memory == MemoryId::HOST))
            .count();
        assert_eq!(d2h, 0, "direct sends must not stage through M1");
        // The receive lands on the first consuming device.
        let recv_dst: Vec<MemoryId> = instrs
            .iter()
            .filter_map(|i| match &i.kind {
                InstructionKind::Receive { dst_memory, .. } => Some(*dst_memory),
                _ => None,
            })
            .collect();
        assert_eq!(recv_dst, vec![MemoryId(2)]);
    }

    /// The consumer-split fallback: disjoint per-device consumers keep the
    /// pinned-host detour (split receive into M1) even with elision on.
    #[test]
    fn consumer_split_falls_back_to_host_staging() {
        let (instrs, _, _) = build(2, 2, true, |tm| {
            let r = Range::d1(4096);
            let a = tm.create_buffer::<f64>("A", r, true).id();
            let b = tm.create_buffer::<f64>("B", r, false).id();
            tm.submit(TaskDecl::device("w", r).read_write(a, RangeMapper::OneToOne));
            tm.submit(
                TaskDecl::device("shift", r)
                    .read(a, RangeMapper::Shift(crate::grid::Point::d1(2048)))
                    .write(b, RangeMapper::OneToOne),
            );
        });
        let split_dst: Vec<MemoryId> = instrs
            .iter()
            .filter_map(|i| match &i.kind {
                InstructionKind::SplitReceive { dst_memory, .. } => Some(*dst_memory),
                _ => None,
            })
            .collect();
        assert_eq!(split_dst, vec![MemoryId::HOST]);
    }

    /// Satellite regression: a push of a region no task has ever written
    /// must not panic the generator (it used to die in `pick_source` /
    /// leave the peer hanging); it reports a §4.4 error, still emits the
    /// send (uninitialized bytes from an M1 backing) so the peer's
    /// await-push completes, and stays usable afterwards.
    #[test]
    fn push_of_never_written_region_reports_error_not_panic() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let r = Range::d1(256);
        let a = tm.create_buffer::<f64>("A", r, false).id();
        // A task only so the hand-built command has a TaskRef.
        tm.submit(TaskDecl::device("w", r).write(a, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let task = tasks
            .iter()
            .find(|t| matches!(t.kind, TaskKind::DeviceCompute { .. }))
            .unwrap()
            .clone();

        let mut ig = IdagGenerator::new(
            IdagConfig { num_nodes: 2, ..Default::default() },
            tm.buffers().clone(),
        );
        let push = crate::command::Command {
            id: crate::util::CommandId(99),
            task,
            kind: crate::command::CommandKind::Push {
                buffer: a,
                region: Region::from(GridBox::d1(0, 256)),
                target: NodeId(1),
            },
            deps: vec![],
        };
        ig.compile(&push); // must not panic
        let errors = ig.take_errors();
        assert_eq!(errors.len(), 1, "{errors:?}");
        assert!(errors[0].contains("never written"), "{errors:?}");
        let instrs = ig.take_new_instructions();
        assert_eq!(
            instrs.iter().filter(|i| i.kind.mnemonic() == "send").count(),
            1,
            "liveness: the peer's await still gets bytes"
        );
        assert_eq!(ig.take_pilots().len(), 1);
        assert!(ig.dag().check_acyclic());
        // The generator keeps working after the error.
        ig.compile(&push);
        assert!(!ig.take_errors().is_empty());
    }

    /// Lookahead integration: with direct transfers the await-push reports
    /// the consuming *device* memory as its requirement, so the first
    /// device alloc covers the received region and the kernel's own
    /// accesses in one backing.
    #[test]
    fn await_push_requirements_target_consuming_device() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        nbody(&mut tm, 2, 1024);
        let tasks = tm.take_new_tasks();
        let mut cg = CdagGenerator::new(NodeId(0), 2, SplitHint::D1, tm.buffers().clone());
        cg.set_collectives(false);
        for t in &tasks {
            cg.compile(t);
        }
        let cmds = cg.take_new_commands();
        let await_cmd = cmds
            .iter()
            .find(|c| matches!(c.kind, crate::command::CommandKind::AwaitPush { .. }))
            .expect("nbody p2p lowering awaits the peer half");
        let direct = IdagGenerator::new(
            IdagConfig { num_nodes: 2, num_devices: 1, ..Default::default() },
            tm.buffers().clone(),
        );
        let reqs = direct.requirements(await_cmd);
        assert_eq!(reqs.len(), 1);
        assert!(reqs[0].1.is_device(), "direct landing requirement: {reqs:?}");
        let staged = IdagGenerator::new(
            IdagConfig {
                num_nodes: 2,
                num_devices: 1,
                direct_comm: false,
                ..Default::default()
            },
            tm.buffers().clone(),
        );
        let reqs = staged.requirements(await_cmd);
        assert_eq!(reqs[0].1, MemoryId::HOST, "staged landing requirement");
    }
}
