//! The instruction layer: the paper's core contribution (§3).
//!
//! Instructions are the "local micro-operations" a node performs: memory
//! management, coherence copies, MPI peer-to-peer transfers, kernel
//! launches and synchronization primitives. Table 1 of the paper enumerates
//! the instruction types; [`InstructionKind`] mirrors it exactly.
//!
//! The IDAG "preserves full concurrency between memory management, data
//! transfers, MPI peer-to-peer communication and kernel invocation" — its
//! generation ([`IdagGenerator`]) happens on the scheduler thread,
//! concurrently with the execution of earlier instructions.

mod generator;
mod memory;

pub use generator::{user_alloc_id, IdagConfig, IdagGenerator, Pilot};
pub use memory::MemMask;

use crate::grid::{GridBox, Region};
use crate::task::{EpochAction, TaskRef};
use crate::util::{AllocationId, BufferId, DeviceId, InstructionId, MemoryId, MessageId, NodeId};
use std::sync::Arc;

/// Binding of one declared accessor to a concrete backing allocation,
/// interpolated into the kernel before launch (§3.2: "allocation pointers
/// are interpolated into accessors").
#[derive(Debug, Clone)]
pub struct AccessBinding {
    pub buffer: BufferId,
    pub mode: crate::task::AccessMode,
    /// The exact buffer region this chunk may touch.
    pub region: Region,
    /// Backing allocation (contiguous, covers `region`'s bounding box).
    pub alloc: AllocationId,
    /// The buffer-space box the allocation covers (for pointer math).
    pub alloc_box: GridBox,
    /// Scalar element type of the buffer (shared [`crate::dtype::DType`]);
    /// exposed to kernels through `BindingView::dtype`.
    pub dtype: crate::dtype::DType,
    /// Scalar lanes per element.
    pub lanes: usize,
}

/// All instruction types of Table 1, grouped as in the paper: memory
/// management, peer-to-peer communication, compute, synchronization.
#[derive(Debug, Clone)]
pub enum InstructionKind {
    // ── memory management ────────────────────────────────────────────────
    /// Allocate host or device memory. Buffer-backing allocations carry the
    /// covered buffer box; scratch allocations (e.g. staging) do not.
    Alloc {
        alloc: AllocationId,
        memory: MemoryId,
        buffer: Option<BufferId>,
        /// Buffer-space box this allocation backs.
        covers: GridBox,
        size_bytes: u64,
    },
    /// 1/2/3D copy between allocations (device-to-device, device-to-host,
    /// host-to-device or host-to-host).
    Copy {
        buffer: BufferId,
        /// The copied buffer-space box.
        copy_box: GridBox,
        src_memory: MemoryId,
        dst_memory: MemoryId,
        src_alloc: AllocationId,
        src_box: GridBox,
        dst_alloc: AllocationId,
        dst_box: GridBox,
    },
    /// Free host or device memory.
    Free { alloc: AllocationId, memory: MemoryId, size_bytes: u64 },

    // ── peer-to-peer communication ───────────────────────────────────────
    /// Perform an `MPI_Isend` of one rectangular box to `target`. The
    /// matching pilot message travels eagerly (§3.4). `src_memory` records
    /// which memory the payload is read from: pinned host memory (M1) on
    /// the staged path, or a device-native / user memory directly when the
    /// d2h staging hop has been elided (direct device transfers).
    Send {
        buffer: BufferId,
        send_box: GridBox,
        target: NodeId,
        msg: MessageId,
        src_memory: MemoryId,
        src_alloc: AllocationId,
        src_box: GridBox,
    },
    /// Perform one or more `MPI_Irecv`s covering `region` into a contiguous
    /// allocation; sender geometry resolved by receive arbitration.
    /// `dst_memory` is pinned host memory (M1) on the staged path, or the
    /// consuming device's native memory when fragments land directly in the
    /// device allocation (receive-side staging elision).
    Receive {
        buffer: BufferId,
        region: Region,
        dst_memory: MemoryId,
        dst_alloc: AllocationId,
        dst_box: GridBox,
        /// Transfer id: the consuming task (matches the pilots' `transfer`).
        transfer: crate::util::TaskId,
    },
    /// Initiate a receive whose completion is consumed piecewise by
    /// `AwaitReceive` instructions (consumer split, §3.4 case a/c). Always
    /// lands in pinned host memory (the consumer split means no single
    /// device owns the whole region — the M1 detour is the fallback).
    SplitReceive {
        buffer: BufferId,
        region: Region,
        dst_memory: MemoryId,
        dst_alloc: AllocationId,
        dst_box: GridBox,
        /// Transfer id: the consuming task (matches the pilots' `transfer`).
        transfer: crate::util::TaskId,
    },
    /// Await a subregion of a `SplitReceive` being fully received.
    AwaitReceive {
        buffer: BufferId,
        region: Region,
        split: InstructionId,
    },
    /// Execute one node's side of a collective group operation (all-gather
    /// or broadcast) as a ring schedule: `slices.len() − 1` rounds, each
    /// forwarding one slice to the ring successor over the ordinary
    /// pilot/send primitives while the receive arbiter lands the
    /// predecessor's slices in `dst_alloc`. Completion is event-driven
    /// (after the last round's slice arrived), like the receive family.
    Collective {
        buffer: BufferId,
        /// The full gathered region; every participant holds it afterwards.
        region: Region,
        kind: crate::command::CollectiveKind,
        /// Per-node contribution, indexed by node id (`EMPTY` = non-owner).
        slices: Arc<Vec<GridBox>>,
        dst_alloc: AllocationId,
        dst_box: GridBox,
        /// Transfer id (the consuming task) matched by inbound pilots.
        transfer: crate::util::TaskId,
        /// Pre-allocated message ids, one per ring round.
        msgs: Vec<MessageId>,
    },

    // ── compute ──────────────────────────────────────────────────────────
    /// Launch a SYCL kernel chunk on one device.
    DeviceKernel {
        device: DeviceId,
        chunk: GridBox,
        bindings: Vec<AccessBinding>,
        /// Abstract work units per item (cost model input).
        work_per_item: f64,
        /// AOT artifact name, if executing for real.
        kernel: Option<String>,
    },
    /// Launch a host-task functor in a host thread.
    HostTask {
        chunk: GridBox,
        bindings: Vec<AccessBinding>,
        work_per_item: f64,
    },

    // ── synchronization ──────────────────────────────────────────────────
    /// Prune graphs in the scheduler; forward-progress marker (§3.5).
    Horizon,
    /// Synchronize with the main thread.
    Epoch(EpochAction),
}

impl InstructionKind {
    /// Table-1 group of this instruction (used by trace output and tests).
    pub fn group(&self) -> &'static str {
        match self {
            InstructionKind::Alloc { .. }
            | InstructionKind::Copy { .. }
            | InstructionKind::Free { .. } => "memory",
            InstructionKind::Send { .. }
            | InstructionKind::Receive { .. }
            | InstructionKind::SplitReceive { .. }
            | InstructionKind::AwaitReceive { .. }
            | InstructionKind::Collective { .. } => "p2p",
            InstructionKind::DeviceKernel { .. } | InstructionKind::HostTask { .. } => "compute",
            InstructionKind::Horizon | InstructionKind::Epoch(_) => "sync",
        }
    }

    /// Short mnemonic matching Table 1's rows.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            InstructionKind::Alloc { .. } => "alloc",
            InstructionKind::Copy { .. } => "copy",
            InstructionKind::Free { .. } => "free",
            InstructionKind::Send { .. } => "send",
            InstructionKind::Receive { .. } => "receive",
            InstructionKind::SplitReceive { .. } => "split receive",
            InstructionKind::AwaitReceive { .. } => "await receive",
            InstructionKind::Collective { .. } => "collective",
            InstructionKind::DeviceKernel { .. } => "device kernel",
            InstructionKind::HostTask { .. } => "host task",
            InstructionKind::Horizon => "horizon",
            InstructionKind::Epoch(_) => "epoch",
        }
    }
}

/// One node of the instruction graph.
#[derive(Debug, Clone)]
pub struct Instruction {
    pub id: InstructionId,
    pub kind: InstructionKind,
    pub deps: Vec<(InstructionId, crate::dag::DepKind)>,
    /// The originating task (for traces/debug); synchronization and free
    /// instructions may not have one.
    pub task: Option<TaskRef>,
}

impl Instruction {
    /// Display label ("I16 copy B0 [..] M2→M3" style).
    pub fn label(&self) -> String {
        match &self.kind {
            InstructionKind::Alloc { alloc, memory, covers, size_bytes, .. } => {
                format!("{} alloc {alloc} on {memory} {covers} ({size_bytes}B)", self.id)
            }
            InstructionKind::Copy { buffer, copy_box, src_memory, dst_memory, .. } => {
                format!("{} copy {buffer} {copy_box} {src_memory}→{dst_memory}", self.id)
            }
            InstructionKind::Free { alloc, memory, .. } => {
                format!("{} free {alloc} on {memory}", self.id)
            }
            InstructionKind::Send { buffer, send_box, target, msg, src_memory, .. } => {
                format!("{} send {buffer} {send_box} from {src_memory} →{target} {msg}", self.id)
            }
            InstructionKind::Receive { buffer, region, dst_memory, .. } => {
                format!("{} receive {buffer} {region} into {dst_memory}", self.id)
            }
            InstructionKind::SplitReceive { buffer, region, dst_memory, .. } => {
                format!("{} split-receive {buffer} {region} into {dst_memory}", self.id)
            }
            InstructionKind::AwaitReceive { buffer, region, split } => {
                format!("{} await-receive {buffer} {region} of {split}", self.id)
            }
            InstructionKind::Collective { buffer, region, kind, slices, .. } => {
                format!(
                    "{} {} {buffer} {region} ({} nodes)",
                    self.id,
                    kind.name(),
                    slices.len()
                )
            }
            InstructionKind::DeviceKernel { device, chunk, .. } => {
                let name = self.task.as_ref().map(|t| t.name.as_str()).unwrap_or("?");
                format!("{} kernel '{name}' {chunk} on {device}", self.id)
            }
            InstructionKind::HostTask { chunk, .. } => {
                let name = self.task.as_ref().map(|t| t.name.as_str()).unwrap_or("?");
                format!("{} host-task '{name}' {chunk}", self.id)
            }
            InstructionKind::Horizon => format!("{} horizon", self.id),
            InstructionKind::Epoch(a) => format!("{} epoch {a:?}", self.id),
        }
    }
}

pub type InstructionRef = Arc<Instruction>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mnemonics_and_groups() {
        // Exhaustive over Table 1: every row is represented and grouped as
        // in the paper.
        let rows: Vec<(InstructionKind, &str, &str)> = vec![
            (
                InstructionKind::Alloc {
                    alloc: AllocationId(0),
                    memory: MemoryId(2),
                    buffer: None,
                    covers: GridBox::EMPTY,
                    size_bytes: 0,
                },
                "alloc",
                "memory",
            ),
            (
                InstructionKind::Free { alloc: AllocationId(0), memory: MemoryId(2), size_bytes: 0 },
                "free",
                "memory",
            ),
            (
                InstructionKind::Receive {
                    buffer: BufferId(0),
                    region: Region::empty(),
                    dst_memory: MemoryId::HOST,
                    dst_alloc: AllocationId(0),
                    dst_box: GridBox::EMPTY,
                    transfer: crate::util::TaskId(0),
                },
                "receive",
                "p2p",
            ),
            (
                InstructionKind::AwaitReceive {
                    buffer: BufferId(0),
                    region: Region::empty(),
                    split: InstructionId(0),
                },
                "await receive",
                "p2p",
            ),
            (InstructionKind::Horizon, "horizon", "sync"),
            (InstructionKind::Epoch(EpochAction::Init), "epoch", "sync"),
        ];
        for (k, mnemonic, group) in rows {
            assert_eq!(k.mnemonic(), mnemonic);
            assert_eq!(k.group(), group);
        }
    }
}
