//! Memory-id sets and allocation bookkeeping for the IDAG generator.

use crate::grid::GridBox;
use crate::util::{AllocationId, InstructionId, MemoryId};

/// A set of memory ids as a bitmask (bit *i* = memory M*i*). Used by the
/// coherence tracker: which memories hold the newest version of a buffer
/// fragment (§3.3).
///
/// The mask is 64 bits wide: M0 (user) + M1 (pinned host) + up to 62
/// device-native memories. Memory ids ≥ 64 are rejected with a clear panic
/// instead of the silent shift overflow a narrower mask would produce
/// (`1 << m` wraps in release builds — a correctness bug, not a crash).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct MemMask(pub u64);

/// Number of distinct memory ids a [`MemMask`] can track.
pub const MEM_MASK_BITS: u64 = 64;

#[inline]
fn mask_bit(m: MemoryId) -> u64 {
    assert!(
        m.0 < MEM_MASK_BITS,
        "memory id {m} out of range for MemMask ({MEM_MASK_BITS} memories max; \
         2 host memories + {} devices)",
        MEM_MASK_BITS - 2
    );
    1u64 << m.0
}

impl MemMask {
    pub const EMPTY: MemMask = MemMask(0);

    pub fn single(m: MemoryId) -> MemMask {
        MemMask(mask_bit(m))
    }

    pub fn contains(self, m: MemoryId) -> bool {
        self.0 & mask_bit(m) != 0
    }

    pub fn insert(self, m: MemoryId) -> MemMask {
        MemMask(self.0 | mask_bit(m))
    }

    pub fn iter(self) -> impl Iterator<Item = MemoryId> {
        (0..MEM_MASK_BITS)
            .filter(move |i| self.0 & (1u64 << i) != 0)
            .map(MemoryId)
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// The lowest-numbered device-native memory in the set, if any.
    /// Deterministic (lowest id wins), so every replica of the generator
    /// picks the same direct-send source for a multi-device-coherent
    /// fragment.
    pub fn first_device(self) -> Option<MemoryId> {
        self.iter().find(|m| m.is_device())
    }
}

/// One buffer-backing allocation on a specific memory (§3.2): covers a
/// contiguous buffer-space box. Multiple non-overlapping backings may
/// coexist per (buffer, memory).
#[derive(Debug, Clone)]
pub struct Backing {
    pub alloc: AllocationId,
    /// Buffer-space box this allocation holds.
    pub covers: GridBox,
    /// The `alloc` instruction that created it (dependency for first use).
    pub alloc_instr: InstructionId,
}

/// The set of backing allocations of one (buffer, memory) pair.
#[derive(Debug, Clone, Default)]
pub struct BackingSet {
    pub backings: Vec<Backing>,
}

impl BackingSet {
    /// The backing that fully contains `b`, if any.
    pub fn containing(&self, b: &GridBox) -> Option<&Backing> {
        self.backings.iter().find(|bk| bk.covers.contains(b))
    }

    /// All backings intersecting `b`.
    pub fn intersecting(&self, b: &GridBox) -> Vec<Backing> {
        self.backings
            .iter()
            .filter(|bk| bk.covers.intersects(b))
            .cloned()
            .collect()
    }

    /// Whether satisfying `b` requires a new allocation (used by the
    /// scheduler-lookahead "allocating command" check, §4.3 — this must be
    /// cheap compared to full IDAG generation).
    pub fn needs_alloc(&self, b: &GridBox) -> bool {
        !b.is_empty() && self.containing(b).is_none()
    }

    pub fn remove(&mut self, alloc: AllocationId) {
        self.backings.retain(|bk| bk.alloc != alloc);
    }

    pub fn insert(&mut self, backing: Backing) {
        debug_assert!(
            self.backings.iter().all(|bk| !bk.covers.intersects(&backing.covers)),
            "buffer backing allocations must remain non-overlapping (§3.2)"
        );
        self.backings.push(backing);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memmask_ops() {
        let m = MemMask::single(MemoryId(2)).insert(MemoryId(3));
        assert!(m.contains(MemoryId(2)) && m.contains(MemoryId(3)));
        assert!(!m.contains(MemoryId(1)));
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![MemoryId(2), MemoryId(3)]);
        assert!(MemMask::EMPTY.is_empty());
    }

    #[test]
    fn first_device_skips_host_memories() {
        assert_eq!(MemMask::EMPTY.first_device(), None);
        assert_eq!(MemMask::single(MemoryId::USER).first_device(), None);
        assert_eq!(
            MemMask::single(MemoryId::HOST).insert(MemoryId(1)).first_device(),
            None
        );
        let m = MemMask::single(MemoryId::USER)
            .insert(MemoryId(3))
            .insert(MemoryId(5));
        assert_eq!(m.first_device(), Some(MemoryId(3)), "lowest device id wins");
    }

    /// Regression: `MemMask` was a `u32` whose `1 << m` overflowed at the
    /// 32-memory boundary (debug panic, silent wrap in release) and whose
    /// `iter()` hardcoded `0..32`. Ids 31, 32 and 63 must all round-trip.
    #[test]
    fn memmask_boundary_ids_round_trip() {
        for id in [31u64, 32, 63] {
            let m = MemMask::single(MemoryId(id));
            assert!(m.contains(MemoryId(id)), "id {id} lost by the mask");
            assert!(!m.contains(MemoryId(id - 1)));
            assert_eq!(m.iter().collect::<Vec<_>>(), vec![MemoryId(id)], "iter missed id {id}");
        }
        // All three coexist in one mask.
        let m = MemMask::single(MemoryId(31))
            .insert(MemoryId(32))
            .insert(MemoryId(63));
        assert_eq!(
            m.iter().collect::<Vec<_>>(),
            vec![MemoryId(31), MemoryId(32), MemoryId(63)]
        );
    }

    #[test]
    #[should_panic(expected = "out of range for MemMask")]
    fn memmask_rejects_out_of_range_id() {
        let _ = MemMask::single(MemoryId(64));
    }

    #[test]
    #[should_panic(expected = "out of range for MemMask")]
    fn memmask_contains_rejects_out_of_range_id() {
        let _ = MemMask::EMPTY.contains(MemoryId(64));
    }

    #[test]
    fn backing_set_lookup() {
        let mut set = BackingSet::default();
        set.insert(Backing {
            alloc: AllocationId(1),
            covers: GridBox::d1(0, 50),
            alloc_instr: InstructionId(0),
        });
        set.insert(Backing {
            alloc: AllocationId(2),
            covers: GridBox::d1(50, 100),
            alloc_instr: InstructionId(1),
        });
        assert_eq!(set.containing(&GridBox::d1(10, 20)).unwrap().alloc, AllocationId(1));
        // Spanning box: no single backing contains it → resize needed.
        assert!(set.containing(&GridBox::d1(40, 60)).is_none());
        assert!(set.needs_alloc(&GridBox::d1(40, 60)));
        assert!(!set.needs_alloc(&GridBox::d1(50, 99)));
        assert!(!set.needs_alloc(&GridBox::EMPTY));
        assert_eq!(set.intersecting(&GridBox::d1(40, 60)).len(), 2);
        set.remove(AllocationId(1));
        assert_eq!(set.backings.len(), 1);
    }
}
