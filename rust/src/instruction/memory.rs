//! Memory-id sets and allocation bookkeeping for the IDAG generator.

use crate::grid::GridBox;
use crate::util::{AllocationId, InstructionId, MemoryId};

/// A set of memory ids as a bitmask (bit *i* = memory M*i*). Used by the
/// coherence tracker: which memories hold the newest version of a buffer
/// fragment (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct MemMask(pub u32);

impl MemMask {
    pub const EMPTY: MemMask = MemMask(0);

    pub fn single(m: MemoryId) -> MemMask {
        MemMask(1 << m.0)
    }

    pub fn contains(self, m: MemoryId) -> bool {
        self.0 & (1 << m.0) != 0
    }

    pub fn insert(self, m: MemoryId) -> MemMask {
        MemMask(self.0 | (1 << m.0))
    }

    pub fn iter(self) -> impl Iterator<Item = MemoryId> {
        (0..32).filter(move |i| self.0 & (1 << i) != 0).map(|i| MemoryId(i as u64))
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }
}

/// One buffer-backing allocation on a specific memory (§3.2): covers a
/// contiguous buffer-space box. Multiple non-overlapping backings may
/// coexist per (buffer, memory).
#[derive(Debug, Clone)]
pub struct Backing {
    pub alloc: AllocationId,
    /// Buffer-space box this allocation holds.
    pub covers: GridBox,
    /// The `alloc` instruction that created it (dependency for first use).
    pub alloc_instr: InstructionId,
}

/// The set of backing allocations of one (buffer, memory) pair.
#[derive(Debug, Clone, Default)]
pub struct BackingSet {
    pub backings: Vec<Backing>,
}

impl BackingSet {
    /// The backing that fully contains `b`, if any.
    pub fn containing(&self, b: &GridBox) -> Option<&Backing> {
        self.backings.iter().find(|bk| bk.covers.contains(b))
    }

    /// All backings intersecting `b`.
    pub fn intersecting(&self, b: &GridBox) -> Vec<Backing> {
        self.backings
            .iter()
            .filter(|bk| bk.covers.intersects(b))
            .cloned()
            .collect()
    }

    /// Whether satisfying `b` requires a new allocation (used by the
    /// scheduler-lookahead "allocating command" check, §4.3 — this must be
    /// cheap compared to full IDAG generation).
    pub fn needs_alloc(&self, b: &GridBox) -> bool {
        !b.is_empty() && self.containing(b).is_none()
    }

    pub fn remove(&mut self, alloc: AllocationId) {
        self.backings.retain(|bk| bk.alloc != alloc);
    }

    pub fn insert(&mut self, backing: Backing) {
        debug_assert!(
            self.backings.iter().all(|bk| !bk.covers.intersects(&backing.covers)),
            "buffer backing allocations must remain non-overlapping (§3.2)"
        );
        self.backings.push(backing);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memmask_ops() {
        let m = MemMask::single(MemoryId(2)).insert(MemoryId(3));
        assert!(m.contains(MemoryId(2)) && m.contains(MemoryId(3)));
        assert!(!m.contains(MemoryId(1)));
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![MemoryId(2), MemoryId(3)]);
        assert!(MemMask::EMPTY.is_empty());
    }

    #[test]
    fn backing_set_lookup() {
        let mut set = BackingSet::default();
        set.insert(Backing {
            alloc: AllocationId(1),
            covers: GridBox::d1(0, 50),
            alloc_instr: InstructionId(0),
        });
        set.insert(Backing {
            alloc: AllocationId(2),
            covers: GridBox::d1(50, 100),
            alloc_instr: InstructionId(1),
        });
        assert_eq!(set.containing(&GridBox::d1(10, 20)).unwrap().alloc, AllocationId(1));
        // Spanning box: no single backing contains it → resize needed.
        assert!(set.containing(&GridBox::d1(40, 60)).is_none());
        assert!(set.needs_alloc(&GridBox::d1(40, 60)));
        assert!(!set.needs_alloc(&GridBox::d1(50, 99)));
        assert!(!set.needs_alloc(&GridBox::EMPTY));
        assert_eq!(set.intersecting(&GridBox::d1(40, 60)).len(), 2);
        set.remove(AllocationId(1));
        assert_eq!(set.backings.len(), 1);
    }
}
