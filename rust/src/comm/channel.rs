//! In-process channel transport: the fastest fabric for simulated clusters
//! whose nodes run as threads of one process.
//!
//! [`ChannelWorld`] wires `n` [`ChannelCommunicator`]s together over
//! `std::sync::mpsc` channels — delivery is immediate and lossless, which
//! makes it the reference transport the TCP fabric is validated against
//! (see `rust/tests/distributed.rs`).
//!
//! There is no wire format here, so chaos testing injects at the message
//! level instead: wrap an endpoint in
//! [`crate::fault::FaultyCommunicator`] to apply a seeded drop/delay/dup
//! plan (corruption needs a CRC to be detectable and is a wire-level,
//! TCP-only fault).

use super::{Communicator, Inbound};
use crate::instruction::Pilot;
use crate::util::{MessageId, NodeId};
use std::sync::mpsc;
use std::sync::Mutex;

/// In-process fabric connecting `n` [`ChannelCommunicator`]s.
pub struct ChannelWorld {
    senders: Vec<mpsc::Sender<Inbound>>,
    receivers: Vec<Option<mpsc::Receiver<Inbound>>>,
}

impl ChannelWorld {
    pub fn new(num_nodes: u64) -> ChannelWorld {
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..num_nodes {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        ChannelWorld { senders, receivers }
    }

    /// Extract the communicator endpoint for `node`. Each may be taken once.
    pub fn communicator(&mut self, node: NodeId) -> ChannelCommunicator {
        ChannelCommunicator {
            node,
            peers: self.senders.clone(),
            inbox: Mutex::new(
                self.receivers[node.0 as usize]
                    .take()
                    .expect("communicator already taken"),
            ),
        }
    }

    /// All communicators at once (for spawning node threads).
    pub fn communicators(mut self) -> Vec<ChannelCommunicator> {
        (0..self.senders.len())
            .map(|i| self.communicator(NodeId(i as u64)))
            .collect()
    }
}

/// Channel-backed [`Communicator`].
pub struct ChannelCommunicator {
    node: NodeId,
    peers: Vec<mpsc::Sender<Inbound>>,
    inbox: Mutex<mpsc::Receiver<Inbound>>,
}

impl Communicator for ChannelCommunicator {
    fn node(&self) -> NodeId {
        self.node
    }

    fn num_nodes(&self) -> u64 {
        self.peers.len() as u64
    }

    fn send_pilot(&self, pilot: Pilot) {
        let to = pilot.to.0 as usize;
        if super::comm_trace() {
            eprintln!("[comm] {} pilot {} {} t{} -> {}", self.node, pilot.msg, pilot.send_box, pilot.transfer.0, pilot.to);
        }
        // Out-of-range node ids (stale config) are reported and dropped —
        // same as the TCP fabric — instead of panicking the sender.
        let Some(peer) = self.peers.get(to) else {
            eprintln!(
                "[comm] {} pilot to {} dropped: node id out of range for this {}-node cluster",
                self.node,
                pilot.to,
                self.peers.len()
            );
            return;
        };
        // A dropped peer means that node already shut down; losing the
        // pilot is then inconsequential.
        let _ = peer.send(Inbound::Pilot(pilot));
    }

    fn send_data(&self, to: NodeId, msg: MessageId, bytes: Vec<u8>) {
        if super::comm_trace() {
            eprintln!("[comm] {} data {} ({}B) -> {}", self.node, msg, bytes.len(), to);
        }
        let Some(peer) = self.peers.get(to.0 as usize) else {
            eprintln!(
                "[comm] {} data to {} dropped: node id out of range for this {}-node cluster",
                self.node,
                to,
                self.peers.len()
            );
            return;
        };
        let _ = peer.send(Inbound::Data { from: self.node, msg, bytes });
    }

    fn send_heartbeat(&self, to: NodeId, departing: bool) {
        // Out-of-range / dropped peers lose the beacon silently — liveness
        // signals are best-effort by contract.
        let Some(peer) = self.peers.get(to.0 as usize) else { return };
        let msg = if departing {
            Inbound::Goodbye { from: self.node }
        } else {
            Inbound::Heartbeat { from: self.node }
        };
        let _ = peer.send(msg);
    }

    fn poll(&self) -> Option<Inbound> {
        self.inbox.lock().expect("channel inbox lock poisoned").try_recv().ok()
    }
}

/// A no-op communicator for single-node runs.
#[derive(Debug)]
pub struct NullCommunicator(pub NodeId);

impl Communicator for NullCommunicator {
    fn node(&self) -> NodeId {
        self.0
    }
    fn num_nodes(&self) -> u64 {
        1
    }
    fn send_pilot(&self, p: Pilot) {
        // A single-node graph should never lower to sends; if one slips
        // through, report it loudly but keep the executor thread alive —
        // the dropped pilot will surface as a stalled receive on the
        // (nonexistent) peer, not as a process abort.
        eprintln!("[celerity] BUG: single-node run tried to send pilot {:?}; dropped", p.msg);
    }
    fn send_data(&self, to: NodeId, msg: MessageId, _: Vec<u8>) {
        eprintln!("[celerity] BUG: single-node run tried to send {msg} to node {}; dropped", to.0);
    }
    fn poll(&self) -> Option<Inbound> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridBox;
    use crate::util::BufferId;

    fn pilot(from: u64, to: u64, msg: u64) -> Pilot {
        Pilot {
            from: NodeId(from),
            to: NodeId(to),
            msg: MessageId(msg),
            buffer: BufferId(0),
            send_box: GridBox::d1(0, 4),
            transfer: crate::util::TaskId(0),
        }
    }

    #[test]
    fn pilots_and_data_are_routed() {
        let mut world = ChannelWorld::new(2);
        let c0 = world.communicator(NodeId(0));
        let c1 = world.communicator(NodeId(1));
        c0.send_pilot(pilot(0, 1, 7));
        c0.send_data(NodeId(1), MessageId(7), vec![1, 2, 3]);
        match c1.poll().unwrap() {
            Inbound::Pilot(p) => assert_eq!(p.msg, MessageId(7)),
            other => panic!("{other:?}"),
        }
        match c1.poll().unwrap() {
            Inbound::Data { from, msg, bytes } => {
                assert_eq!(from, NodeId(0));
                assert_eq!(msg, MessageId(7));
                assert_eq!(bytes, vec![1, 2, 3]);
            }
            other => panic!("{other:?}"),
        }
        assert!(c1.poll().is_none());
        assert!(c0.poll().is_none());
    }

    #[test]
    fn cross_thread_messaging() {
        let mut world = ChannelWorld::new(2);
        let c0 = world.communicator(NodeId(0));
        let c1 = world.communicator(NodeId(1));
        let t = std::thread::spawn(move || {
            for i in 0..100u64 {
                c1.send_data(NodeId(0), MessageId(i), vec![i as u8]);
            }
        });
        let mut got = 0;
        while got < 100 {
            if let Some(Inbound::Data { msg, bytes, .. }) = c0.poll() {
                assert_eq!(bytes, vec![msg.0 as u8]);
                got += 1;
            } else {
                std::thread::yield_now();
            }
        }
        t.join().unwrap();
    }

    #[test]
    fn heartbeats_and_goodbyes_are_routed() {
        let mut world = ChannelWorld::new(2);
        let c0 = world.communicator(NodeId(0));
        let c1 = world.communicator(NodeId(1));
        c0.send_heartbeat(NodeId(1), false);
        c0.send_heartbeat(NodeId(1), true);
        c0.send_heartbeat(NodeId(9), false); // out of range: dropped
        assert!(matches!(c1.poll(), Some(Inbound::Heartbeat { from }) if from == NodeId(0)));
        assert!(matches!(c1.poll(), Some(Inbound::Goodbye { from }) if from == NodeId(0)));
        assert!(c1.poll().is_none());
    }

    #[test]
    #[should_panic(expected = "single-node")]
    fn null_communicator_rejects_sends() {
        NullCommunicator(NodeId(0)).send_data(NodeId(0), MessageId(0), vec![]);
    }

    /// The channel fabric composes with the message-level chaos wrapper:
    /// a `dup=1` plan duplicates every message, a `drop=1` plan loses every
    /// message, and heartbeats are exempt either way.
    #[test]
    fn faulty_wrapper_injects_on_the_channel_fabric() {
        use crate::fault::{FaultPlan, FaultyCommunicator};

        // dup=1: every data-plane message is delivered twice.
        let mut world = ChannelWorld::new(2);
        let c0 = world.communicator(NodeId(0));
        let c1 = world.communicator(NodeId(1));
        let dup =
            FaultyCommunicator::wrap(Box::new(c0), FaultPlan::parse("seed=3 dup=1").unwrap());
        dup.send_data(NodeId(1), MessageId(4), vec![1]);
        for _ in 0..2 {
            assert!(matches!(
                c1.poll(),
                Some(Inbound::Data { msg, .. }) if msg == MessageId(4)
            ));
        }
        assert!(c1.poll().is_none());
        assert_eq!(dup.injector().frames_sent(), 1);

        // drop=1: every data-plane message is lost; heartbeats are exempt.
        let mut world = ChannelWorld::new(2);
        let c0 = world.communicator(NodeId(0));
        let c1 = world.communicator(NodeId(1));
        let lossy =
            FaultyCommunicator::wrap(Box::new(c0), FaultPlan::parse("seed=3 drop=1").unwrap());
        lossy.send_pilot(pilot(0, 1, 9));
        lossy.send_heartbeat(NodeId(1), false);
        assert!(
            matches!(c1.poll(), Some(Inbound::Heartbeat { .. })),
            "control plane is exempt from injection"
        );
        assert!(c1.poll().is_none(), "pilot was dropped");
        assert_eq!(lossy.injector().frames_sent(), 1, "heartbeats are not stamped");
    }

    /// Out-of-range node ids are dropped with a report, not a panic
    /// (mirrors the TCP fabric's stale-config behavior).
    #[test]
    fn send_to_out_of_range_node_is_dropped() {
        let mut world = ChannelWorld::new(2);
        let c0 = world.communicator(NodeId(0));
        let c1 = world.communicator(NodeId(1));
        c0.send_data(NodeId(9), MessageId(0), vec![1]);
        c0.send_pilot(pilot(0, 9, 1));
        c0.send_data(NodeId(1), MessageId(2), vec![7]);
        match c1.poll().unwrap() {
            Inbound::Data { msg, bytes, .. } => {
                assert_eq!(msg, MessageId(2));
                assert_eq!(bytes, vec![7]);
            }
            other => panic!("{other:?}"),
        }
    }
}
