//! TCP transport: a [`Communicator`] whose peers talk over real sockets.
//!
//! Unlike the [`ChannelWorld`](super::ChannelWorld) thread fabric, this
//! transport crosses process boundaries: every node owns one listening
//! socket and lazily opens one outbound stream per peer, so a simulated
//! cluster can run as `n` threads of one process ([`TcpWorld::bind_local`])
//! *or* as `n` genuinely separate OS processes ([`TcpCommunicator::bind`]
//! with a shared address list — see the `celerity worker` CLI subcommand).
//!
//! Semantics match the channel transport exactly: non-blocking sends,
//! polled receipt, pilots racing ahead of (or behind) their payloads, and
//! sends to an already-departed peer silently dropped (that node has
//! shut down, so nobody is waiting for the bytes). Frames use the
//! length-prefixed format of [`super::wire`]; `TCP_NODELAY` is set on
//! every stream because the executor's latency — not bandwidth — is what
//! the paper's WaveSim workload stresses.

use super::{wire, Communicator, Inbound};
use crate::instruction::Pilot;
use crate::util::{MessageId, NodeId};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default startup grace: how long outbound connects retry before giving
/// up. Separate worker processes start in arbitrary order; the first sender
/// may race a peer that has not bound its listener yet. Once the grace
/// window (measured from communicator creation) has passed, a refused
/// connection means the peer has departed and the send is dropped.
const CONNECT_GRACE: Duration = Duration::from_secs(10);
const CONNECT_BACKOFF: Duration = Duration::from_millis(20);
/// Accept-loop poll interval (the listener is non-blocking so the thread
/// can observe shutdown).
const ACCEPT_POLL: Duration = Duration::from_micros(500);
/// Single-shot connect timeout for heartbeat frames. Liveness beacons must
/// never park the executor in the startup-grace retry loop a dead peer
/// causes — one bounded attempt, then drop (the next tick retries anyway).
const HEARTBEAT_CONNECT_TIMEOUT: Duration = Duration::from_millis(250);

/// Bookkeeping shared between the communicator, its accept loop and its
/// reader threads, so teardown can *join* everything it spawned (readers
/// used to be detached and leak past cluster shutdown).
struct ReaderSet {
    /// One entry per accepted connection: a clone of the stream (to force
    /// a blocked read to return via `TcpStream::shutdown`) and the reader's
    /// join handle.
    conns: Mutex<Vec<(TcpStream, Option<JoinHandle<()>>)>>,
    /// Live reader-thread count; drops to zero once teardown has joined
    /// them all (asserted by the teardown regression test).
    active: AtomicUsize,
}

/// Decrements the live-reader gauge when a reader thread exits, however it
/// exits (EOF, error, or forced socket shutdown).
struct ReaderGuard(Arc<ReaderSet>);

impl Drop for ReaderGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::Release);
    }
}

/// In-process convenience: bind `n` loopback listeners on ephemeral ports
/// and wire the full mesh. The TCP analogue of [`super::ChannelWorld`].
pub struct TcpWorld {
    comms: Vec<TcpCommunicator>,
}

impl TcpWorld {
    pub fn bind_local(num_nodes: u64) -> std::io::Result<TcpWorld> {
        let mut listeners = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..num_nodes {
            let l = TcpListener::bind(("127.0.0.1", 0))?;
            addrs.push(l.local_addr()?);
            listeners.push(l);
        }
        let comms = listeners
            .into_iter()
            .enumerate()
            .map(|(i, l)| TcpCommunicator::from_listener(NodeId(i as u64), l, addrs.clone()))
            .collect::<std::io::Result<Vec<_>>>()?;
        Ok(TcpWorld { comms })
    }

    /// The listen addresses, indexed by node id.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.comms[0].peers.clone()
    }

    /// All communicators at once (for spawning node threads).
    pub fn communicators(self) -> Vec<TcpCommunicator> {
        self.comms
    }
}

/// Socket-backed [`Communicator`]: one listener, `n` lazily-connected
/// outbound streams, a reader thread per accepted connection decoding
/// frames into the poll queue.
pub struct TcpCommunicator {
    node: NodeId,
    /// Listen addresses of the whole cluster, indexed by node id.
    peers: Vec<SocketAddr>,
    /// Outbound streams, lazily connected; one mutex per peer so sends to
    /// different peers never serialize against each other.
    outbound: Vec<Mutex<Option<TcpStream>>>,
    inbox: Mutex<mpsc::Receiver<Inbound>>,
    shutdown: Arc<AtomicBool>,
    /// Connect retries stop at this instant (creation + startup grace).
    connect_deadline: Instant,
    accept_join: Option<JoinHandle<()>>,
    readers: Arc<ReaderSet>,
}

impl TcpCommunicator {
    /// Bind the listener for `node` at `peers[node]` and become that node's
    /// endpoint of the mesh. Every process of a multi-process cluster calls
    /// this with the *same* address list and its own node id.
    pub fn bind(node: NodeId, peers: Vec<SocketAddr>) -> std::io::Result<TcpCommunicator> {
        let listener = TcpListener::bind(peers[node.0 as usize])?;
        Self::from_listener(node, listener, peers)
    }

    fn from_listener(
        node: NodeId,
        listener: TcpListener,
        peers: Vec<SocketAddr>,
    ) -> std::io::Result<TcpCommunicator> {
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel::<Inbound>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let readers = Arc::new(ReaderSet {
            conns: Mutex::new(Vec::new()),
            active: AtomicUsize::new(0),
        });
        let reader_set = readers.clone();
        // Thread-spawn failure (resource exhaustion) propagates as an
        // io::Error through bind/bind_local → driver::run_node, so the
        // `celerity worker` CLI can print a friendly message and exit 2
        // instead of aborting on a raw panic.
        let accept_join = std::thread::Builder::new()
            .name(format!("celerity-tcp-accept-{}", node.0))
            .spawn(move || accept_loop(listener, tx, flag, reader_set))?;
        let outbound = peers.iter().map(|_| Mutex::new(None)).collect();
        Ok(TcpCommunicator {
            node,
            peers,
            outbound,
            inbox: Mutex::new(rx),
            shutdown,
            connect_deadline: Instant::now() + CONNECT_GRACE,
            accept_join: Some(accept_join),
            readers,
        })
    }

    /// Shrink the startup grace window: after it lapses, a refused connect
    /// means the peer is gone and the frame is dropped instead of retried.
    /// Tests exercising dead peers use this to keep detection fast.
    pub fn set_connect_grace(&mut self, grace: Duration) {
        self.connect_deadline = Instant::now() + grace;
    }

    /// Live reader-thread count (teardown regression test hook).
    #[cfg(test)]
    fn reader_gauge(&self) -> Arc<ReaderSet> {
        self.readers.clone()
    }

    /// Write one frame to `to`, connecting on first use. Failures are
    /// swallowed like the channel transport's dropped-peer sends: a peer
    /// that cannot be reached anymore has already shut down.
    fn send_frame(&self, to: NodeId, frame: &[u8]) {
        self.send_frame_opts(to, frame, true);
    }

    fn send_frame_opts(&self, to: NodeId, frame: &[u8], retry_connect: bool) {
        // A node id beyond the peer list (stale config, wrong --peers
        // order) must not panic a reader/executor thread: report and drop
        // the frame like any other unreachable-peer send.
        if to.0 as usize >= self.outbound.len() {
            eprintln!(
                "[comm] {} send to {} dropped: node id out of range for this {}-node cluster (stale config?)",
                self.node,
                to,
                self.peers.len()
            );
            return;
        }
        let mut slot = self.outbound[to.0 as usize].lock().unwrap();
        if slot.is_none() {
            let addr = self.peers[to.0 as usize];
            *slot = if retry_connect {
                connect_with_retry(addr, self.connect_deadline)
            } else {
                connect_once(addr)
            };
        }
        let failed = match slot.as_mut() {
            Some(stream) => wire::write_frame(stream, frame).is_err(),
            None => true,
        };
        if failed {
            // Drop the stream so a later send re-attempts the connection
            // rather than writing into a known-broken pipe.
            *slot = None;
            if super::comm_trace() {
                eprintln!("[comm] {} tcp send to {} failed (peer gone)", self.node, to);
            }
        }
    }
}

impl Communicator for TcpCommunicator {
    fn node(&self) -> NodeId {
        self.node
    }

    fn num_nodes(&self) -> u64 {
        self.peers.len() as u64
    }

    fn send_pilot(&self, pilot: Pilot) {
        if super::comm_trace() {
            eprintln!("[comm] {} pilot {} {} t{} -> {} (tcp)", self.node, pilot.msg, pilot.send_box, pilot.transfer.0, pilot.to);
        }
        let to = pilot.to;
        self.send_frame(to, &wire::encode_pilot(&pilot));
    }

    fn send_data(&self, to: NodeId, msg: MessageId, bytes: Vec<u8>) {
        if super::comm_trace() {
            eprintln!("[comm] {} data {} ({}B) -> {} (tcp)", self.node, msg, bytes.len(), to);
        }
        self.send_frame(to, &wire::encode_data(self.node, msg, &bytes));
    }

    fn send_heartbeat(&self, to: NodeId, departing: bool) {
        // No connect-retry loop: a heartbeat to a not-yet (or no-longer)
        // reachable peer is dropped after one bounded attempt.
        self.send_frame_opts(to, &wire::encode_heartbeat(self.node, departing), false);
    }

    fn poll(&self) -> Option<Inbound> {
        self.inbox.lock().unwrap().try_recv().ok()
    }
}

impl Drop for TcpCommunicator {
    fn drop(&mut self) {
        // Satellite fix: teardown used to just set the flag and leave the
        // accept/reader threads detached, leaking them (and their output)
        // past cluster shutdown. Join everything: stop the accept loop,
        // close our outbound streams so peers see EOF promptly, then force
        // each reader's blocking read to return by shutting its socket
        // down — bounded even against a wedged peer — and join it.
        self.shutdown.store(true, Ordering::Relaxed);
        for slot in &self.outbound {
            if let Some(stream) = slot.lock().unwrap().take() {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        }
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        let mut conns = self.readers.conns.lock().unwrap();
        for (stream, join) in conns.drain(..) {
            let _ = stream.shutdown(std::net::Shutdown::Both);
            if let Some(j) = join {
                let _ = j.join();
            }
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    tx: mpsc::Sender<Inbound>,
    shutdown: Arc<AtomicBool>,
    readers: Arc<ReaderSet>,
) {
    let mut count = 0u64;
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_nonblocking(false);
                // Teardown needs a second handle to the socket to force a
                // blocked read to return; without one (fd exhaustion) the
                // connection cannot be supervised — refuse it and let the
                // peer's send-retry path reconnect.
                let Ok(handle) = stream.try_clone() else { continue };
                let tx = tx.clone();
                count += 1;
                readers.active.fetch_add(1, Ordering::Acquire);
                let guard = ReaderGuard(readers.clone());
                let join = std::thread::Builder::new()
                    .name(format!("celerity-tcp-read-{count}"))
                    .spawn(move || reader_loop(stream, tx, guard))
                    .ok();
                // A failed spawn dropped the closure (and its guard), so
                // the gauge is already balanced; join is None then.
                readers.conns.lock().unwrap().push((handle, join));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
}

fn reader_loop(stream: TcpStream, tx: mpsc::Sender<Inbound>, _guard: ReaderGuard) {
    let mut r = BufReader::new(stream);
    loop {
        match wire::read_frame(&mut r) {
            // Receiver side dropped: the local node is shutting down.
            Ok(Some(m)) => {
                if tx.send(m).is_err() {
                    break;
                }
            }
            // Clean EOF: the sending peer closed its outbound stream.
            Ok(None) => break,
            Err(e) => {
                // Connection reset during peer teardown is normal; anything
                // else indicates stream corruption and is worth a trace.
                if super::comm_trace() {
                    eprintln!("[comm] tcp reader: {e}");
                }
                break;
            }
        }
    }
}

/// One bounded connect attempt (heartbeat frames — never retry-loop).
fn connect_once(addr: SocketAddr) -> Option<TcpStream> {
    match TcpStream::connect_timeout(&addr, HEARTBEAT_CONNECT_TIMEOUT) {
        Ok(stream) => {
            let _ = stream.set_nodelay(true);
            Some(stream)
        }
        Err(_) => None,
    }
}

fn connect_with_retry(addr: SocketAddr, deadline: Instant) -> Option<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                return Some(stream);
            }
            Err(_) if Instant::now() < deadline => std::thread::sleep(CONNECT_BACKOFF),
            Err(e) => {
                if super::comm_trace() {
                    eprintln!("[comm] tcp connect {addr} failed: {e}");
                }
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridBox;
    use crate::util::{BufferId, TaskId};
    use std::time::Duration;

    fn pilot(from: u64, to: u64, msg: u64) -> Pilot {
        Pilot {
            from: NodeId(from),
            to: NodeId(to),
            msg: MessageId(msg),
            buffer: BufferId(3),
            send_box: GridBox::d2((2, 0), (4, 8)),
            transfer: TaskId(9),
        }
    }

    /// Spin-poll with a deadline: TCP delivery is asynchronous.
    fn poll_one(c: &TcpCommunicator) -> Inbound {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(m) = c.poll() {
                return m;
            }
            assert!(Instant::now() < deadline, "no message within deadline");
            std::thread::yield_now();
        }
    }

    #[test]
    fn pilots_and_data_are_routed() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        c0.send_pilot(pilot(0, 1, 7));
        c0.send_data(NodeId(1), MessageId(7), vec![1, 2, 3]);
        // One stream carries both frames: order within a peer pair holds.
        match poll_one(&c1) {
            Inbound::Pilot(p) => assert_eq!(p, pilot(0, 1, 7)),
            other => panic!("{other:?}"),
        }
        match poll_one(&c1) {
            Inbound::Data { from, msg, bytes } => {
                assert_eq!(from, NodeId(0));
                assert_eq!(msg, MessageId(7));
                assert_eq!(bytes, vec![1, 2, 3]);
            }
            other => panic!("{other:?}"),
        }
        assert!(c1.poll().is_none());
        assert!(c0.poll().is_none());
    }

    #[test]
    fn cross_thread_messaging_many() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t = std::thread::spawn(move || {
            for i in 0..200u64 {
                c1.send_data(NodeId(0), MessageId(i), vec![i as u8]);
            }
            c1 // keep alive until the receiver drained everything
        });
        let mut got = 0;
        while got < 200 {
            if let Inbound::Data { msg, bytes, .. } = poll_one(&c0) {
                assert_eq!(bytes, vec![msg.0 as u8]);
                got += 1;
            }
        }
        drop(t.join().unwrap());
    }

    #[test]
    fn large_payload_round_trips() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let big: Vec<u8> = (0..1_000_000u32).map(|i| (i % 251) as u8).collect();
        c0.send_data(NodeId(1), MessageId(1), big.clone());
        match poll_one(&c1) {
            Inbound::Data { bytes, .. } => assert_eq!(bytes, big),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn full_mesh_all_pairs() {
        let world = TcpWorld::bind_local(3).unwrap();
        let comms = world.communicators();
        for (i, c) in comms.iter().enumerate() {
            for j in 0..3u64 {
                if j != i as u64 {
                    c.send_data(NodeId(j), MessageId(i as u64), vec![i as u8, j as u8]);
                }
            }
        }
        for (j, c) in comms.iter().enumerate() {
            let mut seen = Vec::new();
            for _ in 0..2 {
                match poll_one(c) {
                    Inbound::Data { from, bytes, .. } => {
                        assert_eq!(bytes, vec![from.0 as u8, j as u8]);
                        seen.push(from.0);
                    }
                    other => panic!("{other:?}"),
                }
            }
            seen.sort();
            let want: Vec<u64> = (0..3).filter(|k| *k != j as u64).collect();
            assert_eq!(seen, want);
        }
    }

    /// Regression: an out-of-range `NodeId` (stale cluster config) used to
    /// index `outbound` unchecked and panic the sending thread; it must be
    /// reported and dropped through the unreachable-peer path instead.
    #[test]
    fn send_to_out_of_range_node_is_dropped_not_fatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let comms = world.communicators();
        comms[0].send_data(NodeId(5), MessageId(1), vec![1, 2, 3]);
        comms[0].send_pilot(pilot(0, 7, 2));
        // The in-range peer still works afterwards.
        comms[0].send_data(NodeId(1), MessageId(3), vec![9]);
        match poll_one(&comms[1]) {
            Inbound::Data { msg, bytes, .. } => {
                assert_eq!(msg, MessageId(3));
                assert_eq!(bytes, vec![9]);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Satellite regression: a bind conflict (port already taken) must
    /// come back as an `io::Result::Err` for the caller (`driver::run_node`
    /// / `celerity worker` print it and exit 2), never a panic.
    #[test]
    fn bind_conflict_is_an_error_not_a_panic() {
        let world = TcpWorld::bind_local(2).unwrap();
        let addrs = world.addrs();
        // Both listeners are alive: re-binding node 0's address must fail
        // gracefully.
        let err = TcpCommunicator::bind(NodeId(0), addrs);
        assert!(err.is_err(), "duplicate bind must surface as io::Error");
    }

    /// Satellite regression: reader/accept threads used to be detached and
    /// leak past cluster teardown. Drop must join them all — observed by
    /// the live-reader gauge hitting zero *immediately* after drop returns.
    #[test]
    fn teardown_joins_reader_and_accept_threads() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        // Establish streams in both directions so both nodes spawn readers.
        c0.send_data(NodeId(1), MessageId(1), vec![1]);
        c1.send_data(NodeId(0), MessageId(2), vec![2]);
        assert!(matches!(poll_one(&c1), Inbound::Data { .. }));
        assert!(matches!(poll_one(&c0), Inbound::Data { .. }));
        let g0 = c0.reader_gauge();
        let g1 = c1.reader_gauge();
        assert!(g0.active.load(Ordering::Acquire) >= 1, "node 0 spawned a reader");
        assert!(g1.active.load(Ordering::Acquire) >= 1, "node 1 spawned a reader");
        drop(c0);
        drop(c1);
        // Joined means *done*, synchronously — not "will exit eventually".
        assert_eq!(g0.active.load(Ordering::Acquire), 0, "node 0 readers leaked");
        assert_eq!(g1.active.load(Ordering::Acquire), 0, "node 1 readers leaked");
    }

    #[test]
    fn heartbeats_and_goodbyes_round_trip() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        c0.send_heartbeat(NodeId(1), false);
        match poll_one(&c1) {
            Inbound::Heartbeat { from } => assert_eq!(from, NodeId(0)),
            other => panic!("{other:?}"),
        }
        c0.send_heartbeat(NodeId(1), true);
        match poll_one(&c1) {
            Inbound::Goodbye { from } => assert_eq!(from, NodeId(0)),
            other => panic!("{other:?}"),
        }
    }

    /// A heartbeat to a dead peer must return promptly (single bounded
    /// connect attempt — no startup-grace retry loop) and not panic.
    #[test]
    fn heartbeat_to_dead_peer_is_fast_and_nonfatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        drop(c1);
        let t0 = Instant::now();
        c0.send_heartbeat(NodeId(1), false);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "heartbeat send must not sit in the connect-retry loop"
        );
    }

    #[test]
    fn send_to_departed_peer_is_dropped_not_fatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.set_connect_grace(Duration::from_millis(50));
        drop(c1);
        // Listener gone: connect may still succeed against the dead socket's
        // backlog or fail outright — either way the send must not panic and
        // must return promptly once the grace window lapses.
        std::thread::sleep(Duration::from_millis(60));
        let t0 = Instant::now();
        c0.send_data(NodeId(1), MessageId(0), vec![1]);
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
