//! TCP transport: a [`Communicator`] whose peers talk over real sockets.
//!
//! Unlike the [`ChannelWorld`](super::ChannelWorld) thread fabric, this
//! transport crosses process boundaries: every node owns one listening
//! socket and lazily opens one outbound stream per peer, so a simulated
//! cluster can run as `n` threads of one process ([`TcpWorld::bind_local`])
//! *or* as `n` genuinely separate OS processes ([`TcpCommunicator::bind`]
//! with a shared address list — see the `celerity worker` CLI subcommand).
//!
//! Semantics match the channel transport exactly: non-blocking sends,
//! polled receipt, pilots racing ahead of (or behind) their payloads, and
//! sends to an already-departed peer silently dropped (that node has shut
//! down, so nobody is waiting for the bytes). `TCP_NODELAY` is set on every
//! stream because the executor's latency — not bandwidth — is what the
//! paper's WaveSim workload stresses.
//!
//! # Reliability layer
//!
//! On top of the CRC32-checked, sequence-numbered frames of [`super::wire`]
//! this transport survives *transient* stream faults transparently:
//!
//! * Every data-plane frame (pilot, data) gets a per-(sender → receiver)
//!   sequence number and is retained in a bounded per-peer ring until the
//!   receiver's cumulative ack covers it.
//! * The receiver delivers sequenced frames exactly once and in order:
//!   already-seen seqs are dropped (and re-acked, healing lost acks), a
//!   sequence gap or an undecodable frame severs the connection and is
//!   reported as a non-fatal [`Inbound::Fault`].
//! * A failed write to an established stream triggers reconnect with
//!   capped exponential backoff and retransmission of every unacked frame;
//!   an ack stall with unacked frames outstanding triggers a retransmit
//!   nudge from the next heartbeat tick (covering tail loss, where the
//!   receiver never learns a final frame went missing).
//! * Exhausted reconnect attempts (or an overflowing ring) *escalate*: the
//!   peer is marked lost and a fatal [`Inbound::Fault`] with
//!   [`FaultKind::PeerLost`] is surfaced so the executor can fail pending
//!   work with an attributed error instead of hanging.
//!
//! Control frames (heartbeat, goodbye, ack) are unsequenced and losable by
//! design. Deterministic fault injection ([`crate::fault::FaultPlan`], via
//! [`TcpCommunicator::set_fault_plan`]) mutates frames *below* this layer,
//! so an injected drop/dup/corrupt/break is repaired by the machinery above
//! and application results stay byte-identical to a fault-free run.

use super::{wire, Communicator, FaultKind, Inbound};
use crate::fault::{Fate, FaultInjector, FaultPlan};
use crate::instruction::Pilot;
use crate::util::{MessageId, NodeId, XorShift64};
use std::collections::VecDeque;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default startup grace: how long outbound connects retry before giving
/// up. Separate worker processes start in arbitrary order; the first sender
/// may race a peer that has not bound its listener yet. Once the grace
/// window (measured from communicator creation) has passed, a refused
/// connection means the peer has departed and the send is dropped.
const CONNECT_GRACE: Duration = Duration::from_secs(10);
const CONNECT_BACKOFF: Duration = Duration::from_millis(20);
/// Accept-loop poll interval (the listener is non-blocking so the thread
/// can observe shutdown).
const ACCEPT_POLL: Duration = Duration::from_micros(500);
/// Single-shot connect timeout for heartbeat/ack frames. Control frames
/// must never park a thread in the startup-grace retry loop a dead peer
/// causes — one bounded attempt, then drop (the next tick retries anyway).
const CTRL_CONNECT_TIMEOUT: Duration = Duration::from_millis(250);

/// Reconnect policy for an *established* stream that broke mid-run:
/// bounded attempts with exponential backoff, then escalation.
const RECONNECT_ATTEMPTS: u32 = 5;
const RECONNECT_TIMEOUT: Duration = Duration::from_millis(250);
const RECONNECT_BACKOFF: Duration = Duration::from_millis(20);
const RECONNECT_BACKOFF_CAP: Duration = Duration::from_millis(500);

/// Cumulative-ack cadence: the receiver acks every N delivered frames (and
/// on every inbound heartbeat, and once more at teardown).
const ACK_EVERY: u64 = 16;
/// Consecutive out-of-sequence strikes on one link before the fault report
/// turns fatal (a persistently desynchronized peer is as good as lost).
const STRIKE_MAX: u32 = 8;
/// Bounds of the per-peer retransmission ring. Overflow means the peer has
/// been unreachable (or unacking) for far longer than transient-fault
/// recovery is meant to bridge — escalate rather than grow without bound.
const RING_MAX_FRAMES: usize = 4096;
const RING_MAX_BYTES: usize = 64 << 20;

/// Bookkeeping shared between the communicator, its accept loop and its
/// reader threads, so teardown can *join* everything it spawned (readers
/// used to be detached and leak past cluster shutdown).
struct ReaderSet {
    /// One entry per accepted connection: a clone of the stream (to force
    /// a blocked read to return via `TcpStream::shutdown`) and the reader's
    /// join handle.
    conns: Mutex<Vec<(TcpStream, Option<JoinHandle<()>>)>>,
    /// Live reader-thread count; drops to zero once teardown has joined
    /// them all (asserted by the teardown regression test).
    active: AtomicUsize,
}

/// Decrements the live-reader gauge when a reader thread exits, however it
/// exits (EOF, error, or forced socket shutdown).
struct ReaderGuard(Arc<ReaderSet>);

impl Drop for ReaderGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::Release);
    }
}

/// Send-side state of one peer link.
struct PeerOut {
    stream: Option<TcpStream>,
    /// Whether a connection to this peer ever succeeded. Distinguishes
    /// "peer never showed up" (startup-grace semantics: drop the send)
    /// from "stream broke mid-run" (recover: reconnect + retransmit).
    established: bool,
    /// Peer announced clean shutdown; further sends are dropped.
    departed: bool,
    /// Recovery was exhausted; further sends are dropped.
    lost: bool,
    /// Next sequence number to assign on this link.
    next_seq: u64,
    /// All seqs below this are acked (ring trimmed up to here).
    acked: u64,
    /// `acked` as of the previous heartbeat tick — no progress between two
    /// ticks with frames outstanding triggers a retransmit nudge.
    nudge_acked: u64,
    /// Unacked frames, oldest first: (seq, encoded frame).
    ring: VecDeque<(u64, Vec<u8>)>,
    ring_bytes: usize,
    /// Deterministic fault stream for this link (None = no injection).
    rng: Option<XorShift64>,
}

impl PeerOut {
    fn new() -> PeerOut {
        PeerOut {
            stream: None,
            established: false,
            departed: false,
            lost: false,
            next_seq: 0,
            acked: 0,
            nudge_acked: 0,
            ring: VecDeque::new(),
            ring_bytes: 0,
            rng: None,
        }
    }
}

/// Receive-side state of one peer link.
struct RecvPeer {
    /// Next sequence number to deliver (everything below was delivered).
    expected: u64,
    /// Highest cumulative ack sent back to the peer.
    acked_upto: u64,
    /// Consecutive sequence-gap strikes (reset on in-order delivery).
    strikes: u32,
}

impl RecvPeer {
    fn new() -> RecvPeer {
        RecvPeer { expected: 0, acked_upto: 0, strikes: 0 }
    }
}

/// State shared by the communicator handle, the accept loop and every
/// reader thread: the mesh addresses, per-peer send/receive state, the
/// inbox sender and the shutdown flag.
struct Fabric {
    node: NodeId,
    /// Listen addresses of the whole cluster, indexed by node id.
    peers: Vec<SocketAddr>,
    /// Outbound link state, one mutex per peer so sends to different peers
    /// never serialize against each other.
    outbound: Vec<Mutex<PeerOut>>,
    /// Inbound sequencing state, one mutex per peer.
    recv: Vec<Mutex<RecvPeer>>,
    tx: mpsc::Sender<Inbound>,
    shutdown: AtomicBool,
    /// Connect retries stop at this instant (creation + startup grace).
    connect_deadline: Mutex<Instant>,
    injector: OnceLock<Arc<FaultInjector>>,
}

/// In-process convenience: bind `n` loopback listeners on ephemeral ports
/// and wire the full mesh. The TCP analogue of [`super::ChannelWorld`].
pub struct TcpWorld {
    comms: Vec<TcpCommunicator>,
}

impl TcpWorld {
    pub fn bind_local(num_nodes: u64) -> std::io::Result<TcpWorld> {
        let mut listeners = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..num_nodes {
            let l = TcpListener::bind(("127.0.0.1", 0))?;
            addrs.push(l.local_addr()?);
            listeners.push(l);
        }
        let comms = listeners
            .into_iter()
            .enumerate()
            .map(|(i, l)| TcpCommunicator::from_listener(NodeId(i as u64), l, addrs.clone()))
            .collect::<std::io::Result<Vec<_>>>()?;
        Ok(TcpWorld { comms })
    }

    /// The listen addresses, indexed by node id.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.comms[0].fabric.peers.clone()
    }

    /// All communicators at once (for spawning node threads).
    pub fn communicators(self) -> Vec<TcpCommunicator> {
        self.comms
    }
}

/// Socket-backed [`Communicator`]: one listener, `n` lazily-connected
/// outbound streams with ack/retransmit recovery, a reader thread per
/// accepted connection decoding and sequencing frames into the poll queue.
pub struct TcpCommunicator {
    fabric: Arc<Fabric>,
    inbox: Mutex<mpsc::Receiver<Inbound>>,
    accept_join: Option<JoinHandle<()>>,
    readers: Arc<ReaderSet>,
}

impl TcpCommunicator {
    /// Bind the listener for `node` at `peers[node]` and become that node's
    /// endpoint of the mesh. Every process of a multi-process cluster calls
    /// this with the *same* address list and its own node id.
    pub fn bind(node: NodeId, peers: Vec<SocketAddr>) -> std::io::Result<TcpCommunicator> {
        let listener = TcpListener::bind(peers[node.0 as usize])?;
        Self::from_listener(node, listener, peers)
    }

    fn from_listener(
        node: NodeId,
        listener: TcpListener,
        peers: Vec<SocketAddr>,
    ) -> std::io::Result<TcpCommunicator> {
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel::<Inbound>();
        let fabric = Arc::new(Fabric {
            node,
            outbound: peers.iter().map(|_| Mutex::new(PeerOut::new())).collect(),
            recv: peers.iter().map(|_| Mutex::new(RecvPeer::new())).collect(),
            peers,
            tx,
            shutdown: AtomicBool::new(false),
            connect_deadline: Mutex::new(Instant::now() + CONNECT_GRACE),
            injector: OnceLock::new(),
        });
        let readers = Arc::new(ReaderSet {
            conns: Mutex::new(Vec::new()),
            active: AtomicUsize::new(0),
        });
        let reader_set = readers.clone();
        let fab = fabric.clone();
        // Thread-spawn failure (resource exhaustion) propagates as an
        // io::Error through bind/bind_local → driver::run_node, so the
        // `celerity worker` CLI can print a friendly message and exit 2
        // instead of aborting on a raw panic.
        let accept_join = std::thread::Builder::new()
            .name(format!("celerity-tcp-accept-{}", node.0))
            .spawn(move || accept_loop(listener, fab, reader_set))?;
        Ok(TcpCommunicator {
            fabric,
            inbox: Mutex::new(rx),
            accept_join: Some(accept_join),
            readers,
        })
    }

    /// Shrink the startup grace window: after it lapses, a refused connect
    /// means the peer is gone and the frame is dropped instead of retried.
    /// Tests exercising dead peers use this to keep detection fast.
    pub fn set_connect_grace(&mut self, grace: Duration) {
        let mut deadline = self.fabric.connect_deadline.lock().expect("deadline lock poisoned");
            *deadline = Instant::now() + grace;
    }

    /// Arm deterministic fault injection on every outbound link of this
    /// node. Injection happens *below* the ack/retransmit layer (see the
    /// module docs), so an active plan perturbs the wire without changing
    /// what the executor observes. Call before the first send.
    pub fn set_fault_plan(&mut self, plan: &FaultPlan) {
        if !plan.is_active() {
            return;
        }
        let injector = Arc::new(FaultInjector::new(plan.clone(), self.fabric.node));
        for (i, slot) in self.fabric.outbound.iter().enumerate() {
            let rng = injector.peer_rng(NodeId(i as u64));
                slot.lock().expect("fault rng lock poisoned").rng = Some(rng);
        }
        let _ = self.fabric.injector.set(injector);
    }

    /// The armed injector, if [`set_fault_plan`](Self::set_fault_plan) was
    /// called with an active plan (`celerity worker` polls its `kill=`
    /// latch).
    pub fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        self.fabric.injector.get().cloned()
    }

    /// Live reader-thread count (teardown regression test hook).
    #[cfg(test)]
    fn reader_gauge(&self) -> Arc<ReaderSet> {
        self.readers.clone()
    }
}

impl Fabric {
    /// A node id beyond the peer list (stale config, wrong --peers order)
    /// must not panic a reader/executor thread: report and drop the frame
    /// like any other unreachable-peer send.
    fn check_range(&self, to: NodeId) -> bool {
        if to.0 as usize >= self.outbound.len() {
            eprintln!(
                "[comm] {} send to {} dropped: node id out of range for this {}-node cluster (stale config?)",
                self.node,
                to,
                self.peers.len()
            );
            return false;
        }
        true
    }

    /// Surface a transport fault to the executor via the inbox (suppressed
    /// during shutdown — teardown races are not faults).
    fn notice(&self, from: NodeId, kind: FaultKind, detail: String, fatal: bool) {
        if self.shutdown.load(Ordering::Relaxed) {
            return;
        }
        if super::comm_trace() {
            eprintln!("[comm] {} fault [{}] from {}: {detail}", self.node, kind.name(), from);
        }
        let _ = self.tx.send(Inbound::Fault { from, kind, detail, fatal });
    }

    /// Sequence, ring and transmit one data-plane frame to `to`. `build`
    /// receives the assigned sequence number and returns the encoded frame.
    fn send_seq(&self, to: NodeId, build: impl FnOnce(u64) -> Vec<u8>) {
        if !self.check_range(to) {
            return;
        }
        let mut slot = self.outbound[to.0 as usize].lock().unwrap();
        if slot.departed || slot.lost {
            // That node is gone (cleanly or terminally); nobody waits for
            // the bytes — same contract as the channel transport.
            return;
        }
        let seq = slot.next_seq;
        slot.next_seq += 1;
        let frame = build(seq);

        // Deterministic chaos, sampled before any I/O so the fault stream
        // position depends only on (plan, link, frame index).
        let faults = match (self.injector.get(), slot.rng.as_mut()) {
            (Some(inj), Some(rng)) => Some(inj.on_frame(rng)),
            _ => None,
        };
        if let Some(f) = &faults {
            if let Some(d) = f.delay {
                std::thread::sleep(d);
            }
            if f.break_now {
                // One-shot `break=` trip point: sever the live stream so
                // the very next write exercises reconnect + retransmit.
                if let Some(s) = slot.stream.take() {
                    let _ = s.shutdown(std::net::Shutdown::Both);
                }
            }
        }

        slot.ring_bytes += frame.len();
        slot.ring.push_back((seq, frame));
        if slot.ring.len() > RING_MAX_FRAMES || slot.ring_bytes > RING_MAX_BYTES {
            let detail = format!(
                "retransmission ring overflow toward {to} ({} frames / {} bytes unacked)",
                slot.ring.len(),
                slot.ring_bytes
            );
            self.escalate(to, &mut slot, detail);
            return;
        }

        if slot.stream.is_none() {
            self.open_and_flush(to, &mut slot);
            return;
        }

        // Healthy stream: write the frame, applying its injected fate. The
        // ring keeps the pristine copy, so a dropped or corrupted write is
        // exactly what retransmission later repairs.
        let fate = faults.map(|f| f.fate).unwrap_or(Fate::Deliver);
        let ok = {
            let PeerOut { stream, ring, rng, .. } = &mut *slot;
            let stream = stream.as_mut().unwrap();
            let bytes = &ring.back().unwrap().1;
            match fate {
                Fate::Drop => true, // "lost on the wire": skip the write
                Fate::Corrupt => {
                    // Flip one bit past the tag byte (a flipped tag could
                    // change the frame's *shape* and stall the reader; a
                    // flipped seq/crc/body byte is a clean CRC rejection).
                    let mut bad = bytes.clone();
                    let rng = rng.as_mut().unwrap();
                    let idx = 1 + rng.next_below(bad.len() as u64 - 1) as usize;
                    bad[idx] ^= 1 << rng.next_below(8);
                    wire::write_frame(stream, &bad).is_ok()
                }
                Fate::Duplicate => {
                    wire::write_frame(stream, bytes).is_ok()
                        && wire::write_frame(stream, bytes).is_ok()
                }
                Fate::Deliver => wire::write_frame(stream, bytes).is_ok(),
            }
        };
        if !ok {
            slot.stream = None;
            self.recover(to, &mut slot);
        }
    }

    /// No stream yet (first send, or a previous failure cleared it): open
    /// one and flush the ring. First-contact connect failures keep the old
    /// startup-grace semantics — the peer is gone, drop the frame; mid-run
    /// breakage goes through bounded-backoff recovery instead.
    fn open_and_flush(&self, to: NodeId, slot: &mut PeerOut) {
        if !slot.established {
            let deadline = *self.connect_deadline.lock().unwrap();
            match connect_with_retry(self.peers[to.0 as usize], deadline) {
                Some(stream) => {
                    slot.stream = Some(stream);
                    slot.established = true;
                    self.flush_ring(to, slot);
                }
                None => {
                    // Peer never showed up within the grace window.
                    if let Some((_, f)) = slot.ring.pop_back() {
                        slot.ring_bytes -= f.len();
                    }
                    if super::comm_trace() {
                        eprintln!("[comm] {} tcp send to {to} failed (peer gone)", self.node);
                    }
                }
            }
        } else {
            self.recover(to, slot);
        }
    }

    /// Write every ringed frame in order. Returns false (clearing the
    /// stream) on the first failed write.
    fn flush_ring(&self, _to: NodeId, slot: &mut PeerOut) -> bool {
        let ok = {
            let PeerOut { stream, ring, .. } = &mut *slot;
            match stream.as_mut() {
                Some(stream) => ring
                    .iter()
                    .all(|(_, frame)| wire::write_frame(stream, frame).is_ok()),
                None => false,
            }
        };
        if !ok {
            slot.stream = None;
        }
        ok
    }

    /// An established stream broke: reconnect with capped exponential
    /// backoff and retransmit everything unacked; escalate when attempts
    /// are exhausted. Called with the peer's outbound lock held.
    fn recover(&self, to: NodeId, slot: &mut PeerOut) {
        let mut backoff = RECONNECT_BACKOFF;
        for attempt in 1..=RECONNECT_ATTEMPTS {
            if self.shutdown.load(Ordering::Relaxed) {
                return;
            }
            if let Some(stream) = connect_once(self.peers[to.0 as usize]) {
                slot.stream = Some(stream);
                self.notice(
                    to,
                    FaultKind::Reconnect,
                    format!("stream to {to} re-established (attempt {attempt})"),
                    false,
                );
                let frames = slot.ring.len() as u64;
                if self.flush_ring(to, slot) {
                    if frames > 0 {
                        self.notice(
                            to,
                            FaultKind::Retransmit,
                            format!("retransmitted {frames} unacked frames to {to}"),
                            false,
                        );
                    }
                    return;
                }
                // Reconnected but the flush died: keep trying.
            }
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(RECONNECT_BACKOFF_CAP);
        }
        let detail = format!(
            "stream to {to} unrecoverable after {RECONNECT_ATTEMPTS} reconnect attempts \
             ({} frames unacked)",
            slot.ring.len()
        );
        self.escalate(to, slot, detail);
    }

    /// Recovery exhausted: mark the peer lost, drop its ring, and surface
    /// a fatal attributed fault on the executor error stream.
    fn escalate(&self, to: NodeId, slot: &mut PeerOut, detail: String) {
        slot.lost = true;
        slot.stream = None;
        slot.ring.clear();
        slot.ring_bytes = 0;
        self.notice(to, FaultKind::PeerLost, detail, true);
    }

    /// Heartbeat tick duties for one link: nudge-retransmit on ack stall,
    /// then the beacon itself (single bounded connect — liveness probing
    /// must never park in a retry loop).
    fn send_beacon(&self, to: NodeId, departing: bool) {
        if !self.check_range(to) {
            return;
        }
        let mut slot = self.outbound[to.0 as usize].lock().unwrap();
        if slot.departed || slot.lost {
            return;
        }
        if slot.established && !slot.ring.is_empty() {
            if slot.stream.is_none() {
                // Only beacons flow right now and the last one died with
                // frames outstanding: recover from here, there is no data
                // send coming to do it.
                self.recover(to, &mut slot);
                if slot.lost {
                    return;
                }
            } else if slot.acked == slot.nudge_acked {
                // No ack progress across a whole heartbeat interval with
                // unacked frames outstanding: the tail of the stream was
                // lost (receiver saw no gap — nothing arrived after it).
                // Re-send the ring; the receiver dedups by seq.
                let frames = slot.ring.len() as u64;
                if self.flush_ring(to, &mut slot) {
                    self.notice(
                        to,
                        FaultKind::Retransmit,
                        format!("ack stall: re-sent {frames} unacked frames to {to}"),
                        false,
                    );
                }
            }
        }
        slot.nudge_acked = slot.acked;
        if slot.stream.is_none() {
            slot.stream = connect_once(self.peers[to.0 as usize]);
            if slot.stream.is_some() {
                slot.established = true;
            }
        }
        let frame = wire::encode_heartbeat(self.node, departing);
        let failed = match slot.stream.as_mut() {
            Some(stream) => wire::write_frame(stream, &frame).is_err(),
            None => true,
        };
        if failed {
            slot.stream = None;
            if super::comm_trace() {
                eprintln!("[comm] {} heartbeat to {to} dropped (peer unreachable)", self.node);
            }
        }
    }

    /// Send a cumulative ack for everything delivered from `to` (from a
    /// reader thread or teardown). Best-effort: a lost ack is healed by the
    /// peer's nudge-retransmit + our dup-drop-and-re-ack.
    fn send_ack(&self, to: NodeId) {
        if to.0 as usize >= self.outbound.len() {
            return;
        }
        let upto = {
            let mut rp = self.recv[to.0 as usize].lock().unwrap();
            if rp.acked_upto == rp.expected {
                return;
            }
            rp.acked_upto = rp.expected;
            rp.expected
        };
        let mut slot = self.outbound[to.0 as usize].lock().unwrap();
        if slot.departed || slot.lost {
            return;
        }
        if slot.stream.is_none() {
            slot.stream = connect_once(self.peers[to.0 as usize]);
            if slot.stream.is_some() {
                slot.established = true;
            }
        }
        let frame = wire::encode_ack(self.node, upto);
        if let Some(stream) = slot.stream.as_mut() {
            if wire::write_frame(stream, &frame).is_err() {
                slot.stream = None;
            }
        }
    }

    /// Peer `from` acked everything below `upto`: trim its ring.
    fn on_ack(&self, from: NodeId, upto: u64) {
        if from.0 as usize >= self.outbound.len() {
            return;
        }
        let mut slot = self.outbound[from.0 as usize].lock().unwrap();
        if upto > slot.acked {
            slot.acked = upto;
            while slot.ring.front().is_some_and(|(seq, _)| *seq < upto) {
                let (_, frame) = slot.ring.pop_front().unwrap();
                slot.ring_bytes -= frame.len();
            }
        }
    }
}

impl Communicator for TcpCommunicator {
    fn node(&self) -> NodeId {
        self.fabric.node
    }

    fn num_nodes(&self) -> u64 {
        self.fabric.peers.len() as u64
    }

    fn send_pilot(&self, pilot: Pilot) {
        if super::comm_trace() {
            eprintln!("[comm] {} pilot {} {} t{} -> {} (tcp)", self.fabric.node, pilot.msg, pilot.send_box, pilot.transfer.0, pilot.to);
        }
        let to = pilot.to;
        self.fabric.send_seq(to, |seq| wire::encode_pilot(&pilot, seq));
    }

    fn send_data(&self, to: NodeId, msg: MessageId, bytes: Vec<u8>) {
        if super::comm_trace() {
            eprintln!("[comm] {} data {} ({}B) -> {} (tcp)", self.fabric.node, msg, bytes.len(), to);
        }
        let from = self.fabric.node;
        self.fabric.send_seq(to, |seq| wire::encode_data(from, msg, &bytes, seq));
    }

    fn send_heartbeat(&self, to: NodeId, departing: bool) {
        self.fabric.send_beacon(to, departing);
    }

    fn poll(&self) -> Option<Inbound> {
        self.inbox.lock().unwrap().try_recv().ok()
    }
}

impl Drop for TcpCommunicator {
    fn drop(&mut self) {
        // Final cumulative acks first (best-effort, bounded): without them
        // a peer with a sub-ACK_EVERY tail of unacked frames would nudge-
        // retransmit into our dead listener and eventually escalate a
        // spurious peer-lost during perfectly clean shutdown.
        self.fabric.shutdown.store(true, Ordering::Relaxed);
        for i in 0..self.fabric.peers.len() {
            if i as u64 != self.fabric.node.0 {
                self.fabric.send_ack(NodeId(i as u64));
            }
        }
        // Teardown joins everything it spawned: stop the accept loop, close
        // our outbound streams so peers see EOF promptly, then force each
        // reader's blocking read to return by shutting its socket down —
        // bounded even against a wedged peer — and join it.
        for slot in &self.fabric.outbound {
            if let Some(stream) = slot.lock().unwrap().stream.take() {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        }
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        let mut conns = self.readers.conns.lock().unwrap();
        for (stream, join) in conns.drain(..) {
            let _ = stream.shutdown(std::net::Shutdown::Both);
            if let Some(j) = join {
                let _ = j.join();
            }
        }
    }
}

fn accept_loop(listener: TcpListener, fabric: Arc<Fabric>, readers: Arc<ReaderSet>) {
    let mut count = 0u64;
    while !fabric.shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_nonblocking(false);
                // Teardown needs a second handle to the socket to force a
                // blocked read to return; without one (fd exhaustion) the
                // connection cannot be supervised — refuse it and let the
                // peer's send-retry path reconnect.
                let Ok(handle) = stream.try_clone() else { continue };
                let fab = fabric.clone();
                count += 1;
                readers.active.fetch_add(1, Ordering::Acquire);
                let guard = ReaderGuard(readers.clone());
                let join = std::thread::Builder::new()
                    .name(format!("celerity-tcp-read-{count}"))
                    .spawn(move || reader_loop(stream, fab, guard))
                    .ok();
                // A failed spawn dropped the closure (and its guard), so
                // the gauge is already balanced; join is None then.
                readers.conns.lock().unwrap().push((handle, join));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
}

/// Decode, sequence and deliver frames from one accepted connection.
///
/// The peer's identity is learned from the first decoded frame (every
/// frame type carries `from`). Decode errors and sequence gaps sever the
/// connection — the peer's next write fails, putting *it* in charge of
/// reconnect + retransmit-from-acked; this side only has to dedup.
fn reader_loop(stream: TcpStream, fabric: Arc<Fabric>, _guard: ReaderGuard) {
    let mut r = BufReader::new(stream);
    let mut who: Option<NodeId> = None;
    loop {
        match wire::read_frame(&mut r) {
            Ok(Some(wire::WireMsg::Ack { from, upto })) => {
                who = Some(from);
                fabric.on_ack(from, upto);
            }
            Ok(Some(wire::WireMsg::Msg { seq, inbound })) => {
                let from = inbound.from();
                who = Some(from);
                if seq == wire::CTRL_SEQ {
                    // Control plane: unsequenced, exempt from dedup.
                    if let Inbound::Goodbye { .. } = inbound {
                        // Clean peer shutdown: stop sending (and never try
                        // to "recover" a stream to it).
                        if (from.0 as usize) < fabric.outbound.len() {
                            let mut slot = fabric.outbound[from.0 as usize].lock().unwrap();
                            slot.departed = true;
                            slot.ring.clear();
                            slot.ring_bytes = 0;
                        }
                    } else {
                        // Piggyback an ack on every heartbeat so senders
                        // trim their rings even on one-directional links.
                        fabric.send_ack(from);
                    }
                    if fabric.tx.send(inbound).is_err() {
                        break;
                    }
                    continue;
                }
                let verdict = {
                    let Some(rp) = fabric.recv.get(from.0 as usize) else { break };
                    let mut rp = rp.lock().unwrap();
                    if seq < rp.expected {
                        // Duplicate (injected dup, or a retransmit covering
                        // frames we already have): drop, and re-ack so the
                        // sender learns its ack was the thing that got lost.
                        Verdict::Dup
                    } else if seq > rp.expected {
                        rp.strikes += 1;
                        Verdict::Gap { strikes: rp.strikes, expected: rp.expected }
                    } else {
                        rp.expected += 1;
                        rp.strikes = 0;
                        let due = rp.expected - rp.acked_upto >= ACK_EVERY;
                        Verdict::Deliver { ack_due: due }
                    }
                };
                match verdict {
                    Verdict::Dup => fabric.send_ack(from),
                    Verdict::Gap { strikes, expected } => {
                        fabric.notice(
                            from,
                            FaultKind::OutOfSeq,
                            format!(
                                "frame seq {seq} from {from} arrived while expecting {expected} \
                                 (strike {strikes}/{STRIKE_MAX})"
                            ),
                            strikes > STRIKE_MAX,
                        );
                        // Sever: our ack state tells the peer where to
                        // resume; keeping the desynced stream would deliver
                        // out of order.
                        fabric.send_ack(from);
                        break;
                    }
                    Verdict::Deliver { ack_due } => {
                        if fabric.tx.send(inbound).is_err() {
                            break;
                        }
                        if ack_due {
                            fabric.send_ack(from);
                        }
                    }
                }
            }
            // Clean EOF: the sending peer closed its outbound stream.
            Ok(None) => break,
            Err(e) => {
                use std::io::ErrorKind;
                let kind = match e.kind() {
                    ErrorKind::InvalidData if e.to_string().contains("exceeds") => {
                        Some(FaultKind::Oversized)
                    }
                    ErrorKind::InvalidData => Some(FaultKind::Corrupt),
                    ErrorKind::UnexpectedEof => Some(FaultKind::Truncated),
                    // Connection reset during peer teardown is normal.
                    _ => None,
                };
                match (kind, who) {
                    (Some(k), Some(from)) => {
                        fabric.notice(from, k, format!("undecodable frame from {from}: {e}"), false);
                        // Sever; the peer's retransmit re-delivers the frame
                        // intact (our expected seq never advanced past it).
                        fabric.send_ack(from);
                    }
                    _ => {
                        if super::comm_trace() {
                            eprintln!("[comm] tcp reader: {e}");
                        }
                    }
                }
                break;
            }
        }
    }
}

enum Verdict {
    Deliver { ack_due: bool },
    Dup,
    Gap { strikes: u32, expected: u64 },
}

/// One bounded connect attempt (control frames and recovery — never the
/// startup-grace retry loop).
fn connect_once(addr: SocketAddr) -> Option<TcpStream> {
    match TcpStream::connect_timeout(&addr, CTRL_CONNECT_TIMEOUT) {
        Ok(stream) => {
            let _ = stream.set_nodelay(true);
            Some(stream)
        }
        Err(_) => None,
    }
}

fn connect_with_retry(addr: SocketAddr, deadline: Instant) -> Option<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                return Some(stream);
            }
            Err(_) if Instant::now() < deadline => std::thread::sleep(CONNECT_BACKOFF),
            Err(e) => {
                if super::comm_trace() {
                    eprintln!("[comm] tcp connect {addr} failed: {e}");
                }
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridBox;
    use crate::util::{BufferId, TaskId};
    use std::io::Write;
    use std::time::Duration;

    fn pilot(from: u64, to: u64, msg: u64) -> Pilot {
        Pilot {
            from: NodeId(from),
            to: NodeId(to),
            msg: MessageId(msg),
            buffer: BufferId(3),
            send_box: GridBox::d2((2, 0), (4, 8)),
            transfer: TaskId(9),
        }
    }

    /// Spin-poll with a deadline: TCP delivery is asynchronous.
    fn poll_one(c: &TcpCommunicator) -> Inbound {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(m) = c.poll() {
                return m;
            }
            assert!(Instant::now() < deadline, "no message within deadline");
            std::thread::yield_now();
        }
    }

    /// Like [`poll_one`] but skips non-fatal fault notices (reconnect /
    /// retransmit chatter during recovery tests).
    fn poll_payload(c: &TcpCommunicator) -> Inbound {
        loop {
            match poll_one(c) {
                Inbound::Fault { fatal: false, .. } => continue,
                m => return m,
            }
        }
    }

    #[test]
    fn pilots_and_data_are_routed() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        c0.send_pilot(pilot(0, 1, 7));
        c0.send_data(NodeId(1), MessageId(7), vec![1, 2, 3]);
        // One stream carries both frames: order within a peer pair holds.
        match poll_one(&c1) {
            Inbound::Pilot(p) => assert_eq!(p, pilot(0, 1, 7)),
            other => panic!("{other:?}"),
        }
        match poll_one(&c1) {
            Inbound::Data { from, msg, bytes } => {
                assert_eq!(from, NodeId(0));
                assert_eq!(msg, MessageId(7));
                assert_eq!(bytes, vec![1, 2, 3]);
            }
            other => panic!("{other:?}"),
        }
        assert!(c1.poll().is_none());
        assert!(c0.poll().is_none());
    }

    #[test]
    fn cross_thread_messaging_many() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t = std::thread::spawn(move || {
            for i in 0..200u64 {
                c1.send_data(NodeId(0), MessageId(i), vec![i as u8]);
            }
            c1 // keep alive until the receiver drained everything
        });
        let mut got = 0;
        while got < 200 {
            if let Inbound::Data { msg, bytes, .. } = poll_one(&c0) {
                assert_eq!(bytes, vec![msg.0 as u8]);
                got += 1;
            }
        }
        drop(t.join().unwrap());
    }

    #[test]
    fn large_payload_round_trips() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let big: Vec<u8> = (0..1_000_000u32).map(|i| (i % 251) as u8).collect();
        c0.send_data(NodeId(1), MessageId(1), big.clone());
        match poll_one(&c1) {
            Inbound::Data { bytes, .. } => assert_eq!(bytes, big),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn full_mesh_all_pairs() {
        let world = TcpWorld::bind_local(3).unwrap();
        let comms = world.communicators();
        for (i, c) in comms.iter().enumerate() {
            for j in 0..3u64 {
                if j != i as u64 {
                    c.send_data(NodeId(j), MessageId(i as u64), vec![i as u8, j as u8]);
                }
            }
        }
        for (j, c) in comms.iter().enumerate() {
            let mut seen = Vec::new();
            for _ in 0..2 {
                match poll_one(c) {
                    Inbound::Data { from, bytes, .. } => {
                        assert_eq!(bytes, vec![from.0 as u8, j as u8]);
                        seen.push(from.0);
                    }
                    other => panic!("{other:?}"),
                }
            }
            seen.sort();
            let want: Vec<u64> = (0..3).filter(|k| *k != j as u64).collect();
            assert_eq!(seen, want);
        }
    }

    /// Regression: an out-of-range `NodeId` (stale cluster config) used to
    /// index `outbound` unchecked and panic the sending thread; it must be
    /// reported and dropped through the unreachable-peer path instead.
    #[test]
    fn send_to_out_of_range_node_is_dropped_not_fatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let comms = world.communicators();
        comms[0].send_data(NodeId(5), MessageId(1), vec![1, 2, 3]);
        comms[0].send_pilot(pilot(0, 7, 2));
        // The in-range peer still works afterwards.
        comms[0].send_data(NodeId(1), MessageId(3), vec![9]);
        match poll_one(&comms[1]) {
            Inbound::Data { msg, bytes, .. } => {
                assert_eq!(msg, MessageId(3));
                assert_eq!(bytes, vec![9]);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Satellite regression: a bind conflict (port already taken) must
    /// come back as an `io::Result::Err` for the caller (`driver::run_node`
    /// / `celerity worker` print it and exit 2), never a panic.
    #[test]
    fn bind_conflict_is_an_error_not_a_panic() {
        let world = TcpWorld::bind_local(2).unwrap();
        let addrs = world.addrs();
        // Both listeners are alive: re-binding node 0's address must fail
        // gracefully.
        let err = TcpCommunicator::bind(NodeId(0), addrs);
        assert!(err.is_err(), "duplicate bind must surface as io::Error");
    }

    /// Satellite regression: reader/accept threads used to be detached and
    /// leak past cluster teardown. Drop must join them all — observed by
    /// the live-reader gauge hitting zero *immediately* after drop returns.
    #[test]
    fn teardown_joins_reader_and_accept_threads() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        // Establish streams in both directions so both nodes spawn readers.
        c0.send_data(NodeId(1), MessageId(1), vec![1]);
        c1.send_data(NodeId(0), MessageId(2), vec![2]);
        assert!(matches!(poll_one(&c1), Inbound::Data { .. }));
        assert!(matches!(poll_one(&c0), Inbound::Data { .. }));
        let g0 = c0.reader_gauge();
        let g1 = c1.reader_gauge();
        assert!(g0.active.load(Ordering::Acquire) >= 1, "node 0 spawned a reader");
        assert!(g1.active.load(Ordering::Acquire) >= 1, "node 1 spawned a reader");
        drop(c0);
        drop(c1);
        // Joined means *done*, synchronously — not "will exit eventually".
        assert_eq!(g0.active.load(Ordering::Acquire), 0, "node 0 readers leaked");
        assert_eq!(g1.active.load(Ordering::Acquire), 0, "node 1 readers leaked");
    }

    #[test]
    fn heartbeats_and_goodbyes_round_trip() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        c0.send_heartbeat(NodeId(1), false);
        match poll_one(&c1) {
            Inbound::Heartbeat { from } => assert_eq!(from, NodeId(0)),
            other => panic!("{other:?}"),
        }
        c0.send_heartbeat(NodeId(1), true);
        match poll_one(&c1) {
            Inbound::Goodbye { from } => assert_eq!(from, NodeId(0)),
            other => panic!("{other:?}"),
        }
    }

    /// A heartbeat to a dead peer must return promptly (single bounded
    /// connect attempt — no startup-grace retry loop) and not panic.
    #[test]
    fn heartbeat_to_dead_peer_is_fast_and_nonfatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        drop(c1);
        let t0 = Instant::now();
        c0.send_heartbeat(NodeId(1), false);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "heartbeat send must not sit in the connect-retry loop"
        );
    }

    #[test]
    fn send_to_departed_peer_is_dropped_not_fatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.set_connect_grace(Duration::from_millis(50));
        drop(c1);
        // Listener gone: connect may still succeed against the dead socket's
        // backlog or fail outright — either way the send must not panic and
        // must return promptly once the grace window lapses.
        std::thread::sleep(Duration::from_millis(60));
        let t0 = Instant::now();
        c0.send_data(NodeId(1), MessageId(0), vec![1]);
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    // ── reliability layer ───────────────────────────────────────────────

    /// Hand-build a sequenced frame the way `wire` does (tags/seal are
    /// private there; the CRC definition is public and pinned by vector
    /// tests, so impersonating a peer from a raw socket is a few lines).
    fn raw_frame(tag: u8, seq: u64, body: &[u8]) -> Vec<u8> {
        let mut pre = vec![tag];
        pre.extend_from_slice(&seq.to_le_bytes());
        pre.extend_from_slice(body);
        let crc = wire::crc32(&pre);
        let mut out = vec![tag];
        out.extend_from_slice(&seq.to_le_bytes());
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(body);
        out
    }

    /// Receive-side seq dedup: the same sequenced frame written twice is
    /// delivered exactly once; the next seq still flows.
    #[test]
    fn duplicate_frames_are_delivered_exactly_once() {
        let world = TcpWorld::bind_local(2).unwrap();
        let comms = world.communicators();
        let mut raw = TcpStream::connect(world_addr(&comms[1])).unwrap();
        let f0 = wire::encode_data(NodeId(0), MessageId(10), &[1], 0);
        let f1 = wire::encode_data(NodeId(0), MessageId(11), &[2], 1);
        raw.write_all(&f0).unwrap();
        raw.write_all(&f0).unwrap(); // injected duplicate
        raw.write_all(&f1).unwrap();
        raw.flush().unwrap();
        for want in [10u64, 11] {
            match poll_payload(&comms[1]) {
                Inbound::Data { msg, .. } => assert_eq!(msg, MessageId(want)),
                other => panic!("{other:?}"),
            }
        }
        std::thread::sleep(Duration::from_millis(100));
        assert!(comms[1].poll().is_none(), "duplicate must not be delivered twice");
    }

    /// A sequence gap (frames lost below TCP, i.e. injected) is reported
    /// as a non-fatal out-of-seq fault, not silently delivered.
    #[test]
    fn out_of_seq_frame_is_reported_not_delivered() {
        let world = TcpWorld::bind_local(2).unwrap();
        let comms = world.communicators();
        let mut raw = TcpStream::connect(world_addr(&comms[1])).unwrap();
        raw.write_all(&wire::encode_data(NodeId(0), MessageId(1), &[1], 5)).unwrap();
        raw.flush().unwrap();
        match poll_one(&comms[1]) {
            Inbound::Fault { from, kind, fatal, .. } => {
                assert_eq!(from, NodeId(0));
                assert_eq!(kind, FaultKind::OutOfSeq);
                assert!(!fatal, "first strike is not fatal");
            }
            other => panic!("{other:?}"),
        }
    }

    /// A frame declaring an absurd payload length is rejected before any
    /// allocation and surfaced as an attributed oversize fault.
    #[test]
    fn oversized_frame_is_reported() {
        let world = TcpWorld::bind_local(2).unwrap();
        let comms = world.communicators();
        let mut raw = TcpStream::connect(world_addr(&comms[1])).unwrap();
        // A valid first frame teaches the reader who it is talking to.
        raw.write_all(&wire::encode_data(NodeId(0), MessageId(1), &[7], 0)).unwrap();
        let mut body = Vec::new();
        body.extend_from_slice(&0u64.to_le_bytes()); // from
        body.extend_from_slice(&2u64.to_le_bytes()); // msg
        body.extend_from_slice(&(1u64 << 40).to_le_bytes()); // len: 1 TiB
        raw.write_all(&raw_frame(2, 1, &body)).unwrap();
        raw.flush().unwrap();
        assert!(matches!(poll_one(&comms[1]), Inbound::Data { .. }));
        match poll_one(&comms[1]) {
            Inbound::Fault { from, kind, fatal, .. } => {
                assert_eq!(from, NodeId(0));
                assert_eq!(kind, FaultKind::Oversized);
                assert!(!fatal);
            }
            other => panic!("{other:?}"),
        }
    }

    /// A CRC-corrupt frame is rejected and reported, attributed to the
    /// peer the stream belongs to.
    #[test]
    fn corrupt_frame_is_reported() {
        let world = TcpWorld::bind_local(2).unwrap();
        let comms = world.communicators();
        let mut raw = TcpStream::connect(world_addr(&comms[1])).unwrap();
        raw.write_all(&wire::encode_data(NodeId(0), MessageId(1), &[7], 0)).unwrap();
        let mut bad = wire::encode_data(NodeId(0), MessageId(2), &[1, 2, 3, 4], 1);
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        raw.write_all(&bad).unwrap();
        raw.flush().unwrap();
        assert!(matches!(poll_one(&comms[1]), Inbound::Data { .. }));
        match poll_one(&comms[1]) {
            Inbound::Fault { from, kind, .. } => {
                assert_eq!(from, NodeId(0));
                assert_eq!(kind, FaultKind::Corrupt);
            }
            other => panic!("{other:?}"),
        }
    }

    /// `break=` plan: the stream is severed mid-run; reconnect + ring
    /// retransmission must deliver every message exactly once, in order.
    #[test]
    fn break_plan_reconnects_and_resumes_exactly_once() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.set_fault_plan(&FaultPlan::parse("break=node0@frame3").unwrap());
        for i in 0..6u64 {
            c0.send_data(NodeId(1), MessageId(i), vec![i as u8]);
        }
        for want in 0..6u64 {
            match poll_payload(&c1) {
                Inbound::Data { msg, bytes, .. } => {
                    assert_eq!(msg, MessageId(want), "in order, exactly once");
                    assert_eq!(bytes, vec![want as u8]);
                }
                other => panic!("{other:?}"),
            }
        }
        // The sender observed (and reported) its own recovery.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(Inbound::Fault { kind: FaultKind::Reconnect, fatal: false, .. }) = c0.poll()
            {
                break;
            }
            assert!(Instant::now() < deadline, "no reconnect notice");
            std::thread::yield_now();
        }
    }

    /// Tail loss: every data write after the first is dropped by the
    /// injector; the heartbeat tick's ack-stall nudge must retransmit the
    /// ring and the receiver must end up with each message exactly once.
    #[test]
    fn dropped_tail_is_recovered_by_heartbeat_nudge() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.set_fault_plan(&FaultPlan::parse("seed=5 drop=1").unwrap());
        for i in 0..3u64 {
            c0.send_data(NodeId(1), MessageId(i), vec![i as u8]);
        }
        // Nothing (beyond the connect-time flush) arrives on its own; the
        // beacon path notices the ack stall and re-sends the ring.
        c0.send_heartbeat(NodeId(1), false);
        let mut got = Vec::new();
        while got.len() < 3 {
            match poll_payload(&c1) {
                Inbound::Data { msg, .. } => got.push(msg.0),
                Inbound::Heartbeat { .. } => {
                    // Keep ticking in case the first beacon raced the sends.
                    c0.send_heartbeat(NodeId(1), false);
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(got, vec![0, 1, 2], "in order, exactly once");
    }

    /// An inactive plan must be a no-op (no injector armed).
    #[test]
    fn inactive_fault_plan_is_a_no_op() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let _c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.set_fault_plan(&FaultPlan::parse("seed=9").unwrap());
        assert!(c0.fault_injector().is_none());
    }

    /// Peer address lookup for raw-socket tests.
    fn world_addr(c: &TcpCommunicator) -> SocketAddr {
        c.fabric.peers[c.fabric.node.0 as usize]
    }
}
