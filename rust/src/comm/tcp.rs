//! TCP transport: a [`Communicator`] whose peers talk over real sockets.
//!
//! Unlike the [`ChannelWorld`](super::ChannelWorld) thread fabric, this
//! transport crosses process boundaries: every node owns one listening
//! socket and lazily opens one outbound stream per peer, so a simulated
//! cluster can run as `n` threads of one process ([`TcpWorld::bind_local`])
//! *or* as `n` genuinely separate OS processes ([`TcpCommunicator::bind`]
//! with a shared address list — see the `celerity worker` CLI subcommand).
//!
//! Semantics match the channel transport exactly: non-blocking sends,
//! polled receipt, pilots racing ahead of (or behind) their payloads, and
//! sends to an already-departed peer silently dropped (that node has
//! shut down, so nobody is waiting for the bytes). Frames use the
//! length-prefixed format of [`super::wire`]; `TCP_NODELAY` is set on
//! every stream because the executor's latency — not bandwidth — is what
//! the paper's WaveSim workload stresses.

use super::{wire, Communicator, Inbound};
use crate::instruction::Pilot;
use crate::util::{MessageId, NodeId};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Default startup grace: how long outbound connects retry before giving
/// up. Separate worker processes start in arbitrary order; the first sender
/// may race a peer that has not bound its listener yet. Once the grace
/// window (measured from communicator creation) has passed, a refused
/// connection means the peer has departed and the send is dropped.
const CONNECT_GRACE: Duration = Duration::from_secs(10);
const CONNECT_BACKOFF: Duration = Duration::from_millis(20);
/// Accept-loop poll interval (the listener is non-blocking so the thread
/// can observe shutdown).
const ACCEPT_POLL: Duration = Duration::from_micros(500);

/// In-process convenience: bind `n` loopback listeners on ephemeral ports
/// and wire the full mesh. The TCP analogue of [`super::ChannelWorld`].
pub struct TcpWorld {
    comms: Vec<TcpCommunicator>,
}

impl TcpWorld {
    pub fn bind_local(num_nodes: u64) -> std::io::Result<TcpWorld> {
        let mut listeners = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..num_nodes {
            let l = TcpListener::bind(("127.0.0.1", 0))?;
            addrs.push(l.local_addr()?);
            listeners.push(l);
        }
        let comms = listeners
            .into_iter()
            .enumerate()
            .map(|(i, l)| TcpCommunicator::from_listener(NodeId(i as u64), l, addrs.clone()))
            .collect::<std::io::Result<Vec<_>>>()?;
        Ok(TcpWorld { comms })
    }

    /// The listen addresses, indexed by node id.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.comms[0].peers.clone()
    }

    /// All communicators at once (for spawning node threads).
    pub fn communicators(self) -> Vec<TcpCommunicator> {
        self.comms
    }
}

/// Socket-backed [`Communicator`]: one listener, `n` lazily-connected
/// outbound streams, a reader thread per accepted connection decoding
/// frames into the poll queue.
pub struct TcpCommunicator {
    node: NodeId,
    /// Listen addresses of the whole cluster, indexed by node id.
    peers: Vec<SocketAddr>,
    /// Outbound streams, lazily connected; one mutex per peer so sends to
    /// different peers never serialize against each other.
    outbound: Vec<Mutex<Option<TcpStream>>>,
    inbox: Mutex<mpsc::Receiver<Inbound>>,
    shutdown: Arc<AtomicBool>,
    /// Connect retries stop at this instant (creation + startup grace).
    connect_deadline: Instant,
}

impl TcpCommunicator {
    /// Bind the listener for `node` at `peers[node]` and become that node's
    /// endpoint of the mesh. Every process of a multi-process cluster calls
    /// this with the *same* address list and its own node id.
    pub fn bind(node: NodeId, peers: Vec<SocketAddr>) -> std::io::Result<TcpCommunicator> {
        let listener = TcpListener::bind(peers[node.0 as usize])?;
        Self::from_listener(node, listener, peers)
    }

    fn from_listener(
        node: NodeId,
        listener: TcpListener,
        peers: Vec<SocketAddr>,
    ) -> std::io::Result<TcpCommunicator> {
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel::<Inbound>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        // Thread-spawn failure (resource exhaustion) propagates as an
        // io::Error through bind/bind_local → driver::run_node, so the
        // `celerity worker` CLI can print a friendly message and exit 2
        // instead of aborting on a raw panic.
        std::thread::Builder::new()
            .name(format!("celerity-tcp-accept-{}", node.0))
            .spawn(move || accept_loop(listener, tx, flag))?;
        let outbound = peers.iter().map(|_| Mutex::new(None)).collect();
        Ok(TcpCommunicator {
            node,
            peers,
            outbound,
            inbox: Mutex::new(rx),
            shutdown,
            connect_deadline: Instant::now() + CONNECT_GRACE,
        })
    }

    /// Shrink the startup grace window (tests exercising departed peers).
    #[cfg(test)]
    fn set_connect_grace(&mut self, grace: Duration) {
        self.connect_deadline = Instant::now() + grace;
    }

    /// Write one frame to `to`, connecting on first use. Failures are
    /// swallowed like the channel transport's dropped-peer sends: a peer
    /// that cannot be reached anymore has already shut down.
    fn send_frame(&self, to: NodeId, frame: &[u8]) {
        // A node id beyond the peer list (stale config, wrong --peers
        // order) must not panic a reader/executor thread: report and drop
        // the frame like any other unreachable-peer send.
        if to.0 as usize >= self.outbound.len() {
            eprintln!(
                "[comm] {} send to {} dropped: node id out of range for this {}-node cluster (stale config?)",
                self.node,
                to,
                self.peers.len()
            );
            return;
        }
        let mut slot = self.outbound[to.0 as usize].lock().unwrap();
        if slot.is_none() {
            *slot = connect_with_retry(self.peers[to.0 as usize], self.connect_deadline);
        }
        let failed = match slot.as_mut() {
            Some(stream) => wire::write_frame(stream, frame).is_err(),
            None => true,
        };
        if failed {
            // Drop the stream so a later send re-attempts the connection
            // rather than writing into a known-broken pipe.
            *slot = None;
            if super::comm_trace() {
                eprintln!("[comm] {} tcp send to {} failed (peer gone)", self.node, to);
            }
        }
    }
}

impl Communicator for TcpCommunicator {
    fn node(&self) -> NodeId {
        self.node
    }

    fn num_nodes(&self) -> u64 {
        self.peers.len() as u64
    }

    fn send_pilot(&self, pilot: Pilot) {
        if super::comm_trace() {
            eprintln!("[comm] {} pilot {} {} t{} -> {} (tcp)", self.node, pilot.msg, pilot.send_box, pilot.transfer.0, pilot.to);
        }
        let to = pilot.to;
        self.send_frame(to, &wire::encode_pilot(&pilot));
    }

    fn send_data(&self, to: NodeId, msg: MessageId, bytes: Vec<u8>) {
        if super::comm_trace() {
            eprintln!("[comm] {} data {} ({}B) -> {} (tcp)", self.node, msg, bytes.len(), to);
        }
        self.send_frame(to, &wire::encode_data(self.node, msg, &bytes));
    }

    fn poll(&self) -> Option<Inbound> {
        self.inbox.lock().unwrap().try_recv().ok()
    }
}

impl Drop for TcpCommunicator {
    fn drop(&mut self) {
        // Stop the accept loop; reader threads exit on their own when the
        // peers' outbound streams close.
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

fn accept_loop(listener: TcpListener, tx: mpsc::Sender<Inbound>, shutdown: Arc<AtomicBool>) {
    let mut readers = 0u64;
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_nonblocking(false);
                let tx = tx.clone();
                readers += 1;
                let _ = std::thread::Builder::new()
                    .name(format!("celerity-tcp-read-{readers}"))
                    .spawn(move || reader_loop(stream, tx));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
}

fn reader_loop(stream: TcpStream, tx: mpsc::Sender<Inbound>) {
    let mut r = BufReader::new(stream);
    loop {
        match wire::read_frame(&mut r) {
            // Receiver side dropped: the local node is shutting down.
            Ok(Some(m)) => {
                if tx.send(m).is_err() {
                    break;
                }
            }
            // Clean EOF: the sending peer closed its outbound stream.
            Ok(None) => break,
            Err(e) => {
                // Connection reset during peer teardown is normal; anything
                // else indicates stream corruption and is worth a trace.
                if super::comm_trace() {
                    eprintln!("[comm] tcp reader: {e}");
                }
                break;
            }
        }
    }
}

fn connect_with_retry(addr: SocketAddr, deadline: Instant) -> Option<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                return Some(stream);
            }
            Err(_) if Instant::now() < deadline => std::thread::sleep(CONNECT_BACKOFF),
            Err(e) => {
                if super::comm_trace() {
                    eprintln!("[comm] tcp connect {addr} failed: {e}");
                }
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridBox;
    use crate::util::{BufferId, TaskId};
    use std::time::Duration;

    fn pilot(from: u64, to: u64, msg: u64) -> Pilot {
        Pilot {
            from: NodeId(from),
            to: NodeId(to),
            msg: MessageId(msg),
            buffer: BufferId(3),
            send_box: GridBox::d2((2, 0), (4, 8)),
            transfer: TaskId(9),
        }
    }

    /// Spin-poll with a deadline: TCP delivery is asynchronous.
    fn poll_one(c: &TcpCommunicator) -> Inbound {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(m) = c.poll() {
                return m;
            }
            assert!(Instant::now() < deadline, "no message within deadline");
            std::thread::yield_now();
        }
    }

    #[test]
    fn pilots_and_data_are_routed() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        c0.send_pilot(pilot(0, 1, 7));
        c0.send_data(NodeId(1), MessageId(7), vec![1, 2, 3]);
        // One stream carries both frames: order within a peer pair holds.
        match poll_one(&c1) {
            Inbound::Pilot(p) => assert_eq!(p, pilot(0, 1, 7)),
            other => panic!("{other:?}"),
        }
        match poll_one(&c1) {
            Inbound::Data { from, msg, bytes } => {
                assert_eq!(from, NodeId(0));
                assert_eq!(msg, MessageId(7));
                assert_eq!(bytes, vec![1, 2, 3]);
            }
            other => panic!("{other:?}"),
        }
        assert!(c1.poll().is_none());
        assert!(c0.poll().is_none());
    }

    #[test]
    fn cross_thread_messaging_many() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t = std::thread::spawn(move || {
            for i in 0..200u64 {
                c1.send_data(NodeId(0), MessageId(i), vec![i as u8]);
            }
            c1 // keep alive until the receiver drained everything
        });
        let mut got = 0;
        while got < 200 {
            if let Inbound::Data { msg, bytes, .. } = poll_one(&c0) {
                assert_eq!(bytes, vec![msg.0 as u8]);
                got += 1;
            }
        }
        drop(t.join().unwrap());
    }

    #[test]
    fn large_payload_round_trips() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let big: Vec<u8> = (0..1_000_000u32).map(|i| (i % 251) as u8).collect();
        c0.send_data(NodeId(1), MessageId(1), big.clone());
        match poll_one(&c1) {
            Inbound::Data { bytes, .. } => assert_eq!(bytes, big),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn full_mesh_all_pairs() {
        let world = TcpWorld::bind_local(3).unwrap();
        let comms = world.communicators();
        for (i, c) in comms.iter().enumerate() {
            for j in 0..3u64 {
                if j != i as u64 {
                    c.send_data(NodeId(j), MessageId(i as u64), vec![i as u8, j as u8]);
                }
            }
        }
        for (j, c) in comms.iter().enumerate() {
            let mut seen = Vec::new();
            for _ in 0..2 {
                match poll_one(c) {
                    Inbound::Data { from, bytes, .. } => {
                        assert_eq!(bytes, vec![from.0 as u8, j as u8]);
                        seen.push(from.0);
                    }
                    other => panic!("{other:?}"),
                }
            }
            seen.sort();
            let want: Vec<u64> = (0..3).filter(|k| *k != j as u64).collect();
            assert_eq!(seen, want);
        }
    }

    /// Regression: an out-of-range `NodeId` (stale cluster config) used to
    /// index `outbound` unchecked and panic the sending thread; it must be
    /// reported and dropped through the unreachable-peer path instead.
    #[test]
    fn send_to_out_of_range_node_is_dropped_not_fatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let comms = world.communicators();
        comms[0].send_data(NodeId(5), MessageId(1), vec![1, 2, 3]);
        comms[0].send_pilot(pilot(0, 7, 2));
        // The in-range peer still works afterwards.
        comms[0].send_data(NodeId(1), MessageId(3), vec![9]);
        match poll_one(&comms[1]) {
            Inbound::Data { msg, bytes, .. } => {
                assert_eq!(msg, MessageId(3));
                assert_eq!(bytes, vec![9]);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Satellite regression: a bind conflict (port already taken) must
    /// come back as an `io::Result::Err` for the caller (`driver::run_node`
    /// / `celerity worker` print it and exit 2), never a panic.
    #[test]
    fn bind_conflict_is_an_error_not_a_panic() {
        let world = TcpWorld::bind_local(2).unwrap();
        let addrs = world.addrs();
        // Both listeners are alive: re-binding node 0's address must fail
        // gracefully.
        let err = TcpCommunicator::bind(NodeId(0), addrs);
        assert!(err.is_err(), "duplicate bind must surface as io::Error");
    }

    #[test]
    fn send_to_departed_peer_is_dropped_not_fatal() {
        let world = TcpWorld::bind_local(2).unwrap();
        let mut comms = world.communicators();
        let c1 = comms.pop().unwrap();
        let mut c0 = comms.pop().unwrap();
        c0.set_connect_grace(Duration::from_millis(50));
        drop(c1);
        // Listener gone: connect may still succeed against the dead socket's
        // backlog or fail outright — either way the send must not panic and
        // must return promptly once the grace window lapses.
        std::thread::sleep(Duration::from_millis(60));
        let t0 = Instant::now();
        c0.send_data(NodeId(1), MessageId(0), vec![1]);
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
