//! Wire format of the socket transports.
//!
//! Every message travelling a byte stream is one self-delimiting *frame*,
//! hardened with a per-frame CRC32 and a per-peer sequence number:
//!
//! ```text
//! frame     := tag:u8 seq:u64 crc:u32 body
//! pilot     := tag=1, 11 × u64 LE
//!              (from, to, msg, buffer, transfer, min[0..3], max[0..3])
//! data      := tag=2, 3 × u64 LE (from, msg, len), len bytes of payload
//! heartbeat := tag=3, 1 × u64 LE (from)
//! goodbye   := tag=4, 1 × u64 LE (from)
//! ack       := tag=5, 2 × u64 LE (from, upto)
//! ```
//!
//! All integers are little-endian `u64` so the format is trivially
//! inspectable and has no alignment requirements. `crc` is the IEEE CRC-32
//! of `tag ++ seq ++ body`: any flipped bit in a frame — header or payload
//! — is detected at decode time instead of silently desynchronizing the
//! receive arbiter.
//!
//! *Data-plane* frames (pilot, data) carry a monotonically increasing
//! per-(sender → receiver) sequence number, the basis of the transport's
//! dedup-and-retransmit recovery: the receiver delivers seqs exactly once
//! and in order, and a cumulative `ack` frame (`upto` = all seqs below it
//! were delivered) lets the sender trim its retransmission ring.
//! *Control* frames (heartbeat, goodbye, ack) are unsequenced — they carry
//! [`CTRL_SEQ`] and are losable by design.
//!
//! A frame is decoded with exact-size reads; a clean EOF *between* frames
//! means the peer closed the connection (normal shutdown), an EOF *inside*
//! a frame is a protocol error.

use super::Inbound;
use crate::grid::GridBox;
use crate::grid::Point;
use crate::instruction::Pilot;
use crate::util::{BufferId, MessageId, NodeId, TaskId};
use std::io::{self, Read, Write};

const TAG_PILOT: u8 = 1;
const TAG_DATA: u8 = 2;
const TAG_HEARTBEAT: u8 = 3;
const TAG_GOODBYE: u8 = 4;
const TAG_ACK: u8 = 5;

/// Sequence number carried by unsequenced control frames.
pub const CTRL_SEQ: u64 = u64::MAX;

/// Upper bound on a data frame's payload: 1 GiB. A larger length field is
/// certain corruption (a single transfer of the simulated workloads is at
/// most a few MB); refusing it keeps a corrupt or malicious stream from
/// triggering an absurd allocation or an OOM panic in the reader thread.
pub const MAX_DATA_LEN: u64 = 1 << 30;

// ── CRC-32 (IEEE 802.3, reflected) ──────────────────────────────────────

static CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Incremental CRC-32 (start at [`Crc32::new`], feed bytes, [`Crc32::get`]).
#[derive(Debug)]
pub struct Crc32(u32);

impl Crc32 {
    pub fn new() -> Self {
        Crc32(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = CRC_TABLE[((self.0 ^ b as u32) & 0xFF) as usize] ^ (self.0 >> 8);
        }
    }

    pub fn get(&self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.get()
}

// ── encoding ────────────────────────────────────────────────────────────

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// tag + seq + crc placeholder; [`seal`] fills the crc in once the body is
/// appended.
fn begin(out: &mut Vec<u8>, tag: u8, seq: u64) {
    out.push(tag);
    put_u64(out, seq);
    out.extend_from_slice(&[0u8; 4]);
}

fn seal(out: &mut Vec<u8>) -> Vec<u8> {
    let mut c = Crc32::new();
    c.update(&out[..9]); // tag + seq
    c.update(&out[13..]); // body
    out[9..13].copy_from_slice(&c.get().to_le_bytes());
    std::mem::take(out)
}

/// Encode a pilot frame with its per-peer sequence number.
pub fn encode_pilot(p: &Pilot, seq: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(13 + 11 * 8);
    begin(&mut out, TAG_PILOT, seq);
    put_u64(&mut out, p.from.0);
    put_u64(&mut out, p.to.0);
    put_u64(&mut out, p.msg.0);
    put_u64(&mut out, p.buffer.0);
    put_u64(&mut out, p.transfer.0);
    for i in 0..3 {
        put_u64(&mut out, p.send_box.min[i]);
    }
    for i in 0..3 {
        put_u64(&mut out, p.send_box.max[i]);
    }
    seal(&mut out)
}

/// Encode a data frame with its per-peer sequence number.
pub fn encode_data(from: NodeId, msg: MessageId, bytes: &[u8], seq: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(13 + 3 * 8 + bytes.len());
    begin(&mut out, TAG_DATA, seq);
    put_u64(&mut out, from.0);
    put_u64(&mut out, msg.0);
    put_u64(&mut out, bytes.len() as u64);
    out.extend_from_slice(bytes);
    seal(&mut out)
}

/// Encode a heartbeat (or, with `departing`, a goodbye) frame.
pub fn encode_heartbeat(from: NodeId, departing: bool) -> Vec<u8> {
    let mut out = Vec::with_capacity(13 + 8);
    begin(&mut out, if departing { TAG_GOODBYE } else { TAG_HEARTBEAT }, CTRL_SEQ);
    put_u64(&mut out, from.0);
    seal(&mut out)
}

/// Encode a cumulative ack: `from` has delivered every seq below `upto`.
pub fn encode_ack(from: NodeId, upto: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(13 + 2 * 8);
    begin(&mut out, TAG_ACK, CTRL_SEQ);
    put_u64(&mut out, from.0);
    put_u64(&mut out, upto);
    seal(&mut out)
}

/// Write a frame to a stream in one call (the frames are built contiguously
/// so a single `write_all` keeps them atomic w.r.t. interleaving at the
/// application level — per-peer streams are additionally mutex-guarded).
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

// ── decoding ────────────────────────────────────────────────────────────

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// A pilot/data/heartbeat/goodbye message. Data-plane messages carry
    /// their sequence number; control messages carry [`CTRL_SEQ`].
    Msg { seq: u64, inbound: Inbound },
    /// Transport-internal cumulative ack (never surfaced to the executor).
    Ack { from: NodeId, upto: u64 },
}

/// Checked reader: verifies the running CRC against the header's claim.
struct BodyReader<'a, R: Read> {
    r: &'a mut R,
    crc: Crc32,
}

impl<R: Read> BodyReader<'_, R> {
    fn u64(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        self.crc.update(&b);
        Ok(u64::from_le_bytes(b))
    }

    fn bytes(&mut self, len: usize) -> io::Result<Vec<u8>> {
        let mut b = vec![0u8; len];
        self.r.read_exact(&mut b)?;
        self.crc.update(&b);
        Ok(b)
    }

    fn finish(self, want: u32) -> io::Result<()> {
        let got = self.crc.get();
        if got != want {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("crc mismatch (frame claims {want:#010x}, computed {got:#010x})"),
            ));
        }
        Ok(())
    }
}

/// Read one frame. `Ok(None)` means the peer closed the stream cleanly
/// between frames; a mid-frame EOF, an unknown tag, an oversized length
/// prefix or a CRC mismatch is an error (`ErrorKind::InvalidData` for the
/// protocol-level ones — the transport reports them instead of silently
/// dropping the stream).
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<WireMsg>> {
    let mut tag = [0u8; 1];
    // Distinguish clean EOF (0 bytes) from a real error.
    match r.read(&mut tag) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(ref e) if e.kind() == io::ErrorKind::Interrupted => return read_frame(r),
        Err(e) => return Err(e),
    }
    let mut head = [0u8; 12]; // seq + crc
    r.read_exact(&mut head)?;
    let seq = u64::from_le_bytes(head[..8].try_into().expect("8-byte slice"));
    let want_crc = u32::from_le_bytes(head[8..].try_into().expect("4-byte slice"));
    let mut body = BodyReader { r, crc: Crc32::new() };
    body.crc.update(&tag);
    body.crc.update(&head[..8]);
    match tag[0] {
        TAG_PILOT => {
            let from = NodeId(body.u64()?);
            let to = NodeId(body.u64()?);
            let msg = MessageId(body.u64()?);
            let buffer = BufferId(body.u64()?);
            let transfer = TaskId(body.u64()?);
            let mut min = [0u64; 3];
            let mut max = [0u64; 3];
            for m in &mut min {
                *m = body.u64()?;
            }
            for m in &mut max {
                *m = body.u64()?;
            }
            body.finish(want_crc)?;
            Ok(Some(WireMsg::Msg {
                seq,
                inbound: Inbound::Pilot(Pilot {
                    from,
                    to,
                    msg,
                    buffer,
                    send_box: GridBox { min: Point(min), max: Point(max) },
                    transfer,
                }),
            }))
        }
        TAG_DATA => {
            let from = NodeId(body.u64()?);
            let msg = MessageId(body.u64()?);
            let len = body.u64()?;
            if len > MAX_DATA_LEN {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("data frame length {len} exceeds {MAX_DATA_LEN}"),
                ));
            }
            let bytes = body.bytes(len as usize)?;
            body.finish(want_crc)?;
            Ok(Some(WireMsg::Msg { seq, inbound: Inbound::Data { from, msg, bytes } }))
        }
        TAG_HEARTBEAT => {
            let from = NodeId(body.u64()?);
            body.finish(want_crc)?;
            Ok(Some(WireMsg::Msg { seq, inbound: Inbound::Heartbeat { from } }))
        }
        TAG_GOODBYE => {
            let from = NodeId(body.u64()?);
            body.finish(want_crc)?;
            Ok(Some(WireMsg::Msg { seq, inbound: Inbound::Goodbye { from } }))
        }
        TAG_ACK => {
            let from = NodeId(body.u64()?);
            let upto = body.u64()?;
            body.finish(want_crc)?;
            Ok(Some(WireMsg::Ack { from, upto }))
        }
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unknown frame tag {other}"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    fn sample_pilot(seed: u64) -> Pilot {
        let mut rng = XorShift64::new(seed);
        let lo = [rng.next_below(100), rng.next_below(100), rng.next_below(100)];
        Pilot {
            from: NodeId(rng.next_below(32)),
            to: NodeId(rng.next_below(32)),
            msg: MessageId(rng.next_u64()),
            buffer: BufferId(rng.next_below(16)),
            send_box: GridBox {
                min: Point(lo),
                max: Point([
                    lo[0] + 1 + rng.next_below(50),
                    lo[1] + 1 + rng.next_below(50),
                    lo[2] + 1 + rng.next_below(50),
                ]),
            },
            transfer: TaskId(rng.next_u64()),
        }
    }

    fn expect_msg(m: Option<WireMsg>) -> (u64, Inbound) {
        match m {
            Some(WireMsg::Msg { seq, inbound }) => (seq, inbound),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pilot_frames_round_trip_with_seq() {
        for seed in 1..50 {
            let p = sample_pilot(seed);
            let frame = encode_pilot(&p, seed * 3);
            let mut cur = io::Cursor::new(frame);
            let (seq, inbound) = expect_msg(read_frame(&mut cur).unwrap());
            assert_eq!(seq, seed * 3);
            match inbound {
                Inbound::Pilot(q) => assert_eq!(p, q),
                other => panic!("{other:?}"),
            }
            assert!(read_frame(&mut cur).unwrap().is_none(), "clean EOF after frame");
        }
    }

    #[test]
    fn data_frames_round_trip() {
        let mut rng = XorShift64::new(3);
        for len in [0usize, 1, 7, 8, 1024, 100_000] {
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let frame = encode_data(NodeId(5), MessageId(99), &bytes, 17);
            let mut cur = io::Cursor::new(frame);
            let (seq, inbound) = expect_msg(read_frame(&mut cur).unwrap());
            assert_eq!(seq, 17);
            match inbound {
                Inbound::Data { from, msg, bytes: got } => {
                    assert_eq!(from, NodeId(5));
                    assert_eq!(msg, MessageId(99));
                    assert_eq!(got, bytes);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn back_to_back_frames_parse_in_order() {
        let p = sample_pilot(7);
        let mut stream = encode_pilot(&p, 0);
        stream.extend(encode_data(NodeId(1), MessageId(2), &[9, 9, 9], 1));
        stream.extend(encode_pilot(&p, 2));
        stream.extend(encode_ack(NodeId(1), 2));
        let mut cur = io::Cursor::new(stream);
        for want_seq in 0..3u64 {
            let (seq, _) = expect_msg(read_frame(&mut cur).unwrap());
            assert_eq!(seq, want_seq);
        }
        assert_eq!(
            read_frame(&mut cur).unwrap(),
            Some(WireMsg::Ack { from: NodeId(1), upto: 2 })
        );
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn heartbeat_and_goodbye_frames_round_trip() {
        for (departing, node) in [(false, 0u64), (false, 7), (true, 3)] {
            let frame = encode_heartbeat(NodeId(node), departing);
            let mut cur = io::Cursor::new(frame);
            let (seq, inbound) = expect_msg(read_frame(&mut cur).unwrap());
            assert_eq!(seq, CTRL_SEQ, "control frames are unsequenced");
            match inbound {
                Inbound::Heartbeat { from } => {
                    assert!(!departing);
                    assert_eq!(from, NodeId(node));
                }
                Inbound::Goodbye { from } => {
                    assert!(departing);
                    assert_eq!(from, NodeId(node));
                }
                other => panic!("{other:?}"),
            }
            assert!(read_frame(&mut cur).unwrap().is_none());
        }
    }

    #[test]
    fn crc32_matches_reference_vector() {
        // The classic IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let p = sample_pilot(13);
        let frame = encode_pilot(&p, 42);
        for i in 0..frame.len() {
            for bit in 0..8 {
                let mut bad = frame.clone();
                bad[i] ^= 1 << bit;
                let mut cur = io::Cursor::new(bad);
                match read_frame(&mut cur) {
                    // Flips in the tag byte may produce unknown-tag or a
                    // differently-shaped parse that still fails the CRC or
                    // hits EOF mid-frame; all are errors, none decode.
                    Err(_) => {}
                    Ok(got) => panic!("flip {i}:{bit} decoded as {got:?}"),
                }
            }
        }
    }

    #[test]
    fn corrupt_data_payload_is_detected() {
        let frame = encode_data(NodeId(2), MessageId(4), &[1, 2, 3, 4, 5, 6, 7, 8], 9);
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x10;
        let e = read_frame(&mut io::Cursor::new(bad)).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
        assert!(e.to_string().contains("crc mismatch"), "{e}");
    }

    #[test]
    fn truncated_frame_is_an_error() {
        let p = sample_pilot(11);
        let mut frame = encode_pilot(&p, 0);
        frame.truncate(frame.len() - 3);
        let mut cur = io::Cursor::new(frame);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn unknown_tag_is_an_error() {
        let mut frame = vec![42u8];
        frame.extend_from_slice(&[0u8; 12]);
        let mut cur = io::Cursor::new(frame);
        let e = read_frame(&mut cur).unwrap_err();
        assert!(e.to_string().contains("unknown frame tag"), "{e}");
    }

    #[test]
    fn absurd_data_length_is_rejected_before_allocation() {
        // A hand-built data frame claiming a 2^63-byte payload: the length
        // check must fire from the 24 header+field bytes alone.
        let mut out = Vec::new();
        begin(&mut out, TAG_DATA, 0);
        put_u64(&mut out, 0); // from
        put_u64(&mut out, 1); // msg
        put_u64(&mut out, 1u64 << 63); // len
        let frame = seal(&mut out);
        let e = read_frame(&mut io::Cursor::new(frame)).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
        assert!(e.to_string().contains("exceeds"), "{e}");
    }
}
