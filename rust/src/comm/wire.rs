//! Wire format of the socket transports.
//!
//! Every message travelling a byte stream is one self-delimiting *frame*:
//!
//! ```text
//! frame   := tag:u8 body
//! pilot     := tag=1, 11 × u64 LE
//!              (from, to, msg, buffer, transfer, min[0..3], max[0..3])
//! data      := tag=2, 3 × u64 LE (from, msg, len), len bytes of payload
//! heartbeat := tag=3, 1 × u64 LE (from)
//! goodbye   := tag=4, 1 × u64 LE (from)
//! ```
//!
//! All integers are little-endian `u64` so the format is trivially
//! inspectable and has no alignment requirements. A frame is decoded with
//! exact-size reads; a clean EOF *between* frames means the peer closed the
//! connection (normal shutdown), an EOF *inside* a frame is a protocol
//! error.

use super::Inbound;
use crate::grid::GridBox;
use crate::grid::Point;
use crate::instruction::Pilot;
use crate::util::{BufferId, MessageId, NodeId, TaskId};
use std::io::{self, Read, Write};

const TAG_PILOT: u8 = 1;
const TAG_DATA: u8 = 2;
const TAG_HEARTBEAT: u8 = 3;
const TAG_GOODBYE: u8 = 4;

/// Upper bound on a data frame's payload: 1 GiB. A larger length field is
/// certain corruption (a single transfer of the simulated workloads is at
/// most a few MB); refusing it keeps a corrupt stream from triggering an
/// absurd allocation.
pub const MAX_DATA_LEN: u64 = 1 << 30;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Encode a pilot frame.
pub fn encode_pilot(p: &Pilot) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 11 * 8);
    out.push(TAG_PILOT);
    put_u64(&mut out, p.from.0);
    put_u64(&mut out, p.to.0);
    put_u64(&mut out, p.msg.0);
    put_u64(&mut out, p.buffer.0);
    put_u64(&mut out, p.transfer.0);
    for i in 0..3 {
        put_u64(&mut out, p.send_box.min[i]);
    }
    for i in 0..3 {
        put_u64(&mut out, p.send_box.max[i]);
    }
    out
}

/// Encode a data frame.
pub fn encode_data(from: NodeId, msg: MessageId, bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 3 * 8 + bytes.len());
    out.push(TAG_DATA);
    put_u64(&mut out, from.0);
    put_u64(&mut out, msg.0);
    put_u64(&mut out, bytes.len() as u64);
    out.extend_from_slice(bytes);
    out
}

/// Encode a heartbeat (or, with `departing`, a goodbye) frame.
pub fn encode_heartbeat(from: NodeId, departing: bool) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + 8);
    out.push(if departing { TAG_GOODBYE } else { TAG_HEARTBEAT });
    put_u64(&mut out, from.0);
    out
}

/// Write a frame to a stream in one call (the frames are built contiguously
/// so a single `write_all` keeps them atomic w.r.t. interleaving at the
/// application level — per-peer streams are additionally mutex-guarded).
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read one frame. `Ok(None)` means the peer closed the stream cleanly
/// between frames; any mid-frame EOF or unknown tag is an error.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Inbound>> {
    let mut tag = [0u8; 1];
    // Distinguish clean EOF (0 bytes) from a real error.
    match r.read(&mut tag) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(ref e) if e.kind() == io::ErrorKind::Interrupted => return read_frame(r),
        Err(e) => return Err(e),
    }
    match tag[0] {
        TAG_PILOT => {
            let from = NodeId(read_u64(r)?);
            let to = NodeId(read_u64(r)?);
            let msg = MessageId(read_u64(r)?);
            let buffer = BufferId(read_u64(r)?);
            let transfer = TaskId(read_u64(r)?);
            let mut min = [0u64; 3];
            let mut max = [0u64; 3];
            for m in &mut min {
                *m = read_u64(r)?;
            }
            for m in &mut max {
                *m = read_u64(r)?;
            }
            Ok(Some(Inbound::Pilot(Pilot {
                from,
                to,
                msg,
                buffer,
                send_box: GridBox { min: Point(min), max: Point(max) },
                transfer,
            })))
        }
        TAG_DATA => {
            let from = NodeId(read_u64(r)?);
            let msg = MessageId(read_u64(r)?);
            let len = read_u64(r)?;
            if len > MAX_DATA_LEN {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("data frame length {len} exceeds {MAX_DATA_LEN}"),
                ));
            }
            let mut bytes = vec![0u8; len as usize];
            r.read_exact(&mut bytes)?;
            Ok(Some(Inbound::Data { from, msg, bytes }))
        }
        TAG_HEARTBEAT => Ok(Some(Inbound::Heartbeat { from: NodeId(read_u64(r)?) })),
        TAG_GOODBYE => Ok(Some(Inbound::Goodbye { from: NodeId(read_u64(r)?) })),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unknown frame tag {other}"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    fn sample_pilot(seed: u64) -> Pilot {
        let mut rng = XorShift64::new(seed);
        let lo = [rng.next_below(100), rng.next_below(100), rng.next_below(100)];
        Pilot {
            from: NodeId(rng.next_below(32)),
            to: NodeId(rng.next_below(32)),
            msg: MessageId(rng.next_u64()),
            buffer: BufferId(rng.next_below(16)),
            send_box: GridBox {
                min: Point(lo),
                max: Point([
                    lo[0] + 1 + rng.next_below(50),
                    lo[1] + 1 + rng.next_below(50),
                    lo[2] + 1 + rng.next_below(50),
                ]),
            },
            transfer: TaskId(rng.next_u64()),
        }
    }

    #[test]
    fn pilot_frames_round_trip() {
        for seed in 1..50 {
            let p = sample_pilot(seed);
            let frame = encode_pilot(&p);
            let mut cur = io::Cursor::new(frame);
            match read_frame(&mut cur).unwrap() {
                Some(Inbound::Pilot(q)) => assert_eq!(p, q),
                other => panic!("{other:?}"),
            }
            assert!(read_frame(&mut cur).unwrap().is_none(), "clean EOF after frame");
        }
    }

    #[test]
    fn data_frames_round_trip() {
        let mut rng = XorShift64::new(3);
        for len in [0usize, 1, 7, 8, 1024, 100_000] {
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let frame = encode_data(NodeId(5), MessageId(99), &bytes);
            let mut cur = io::Cursor::new(frame);
            match read_frame(&mut cur).unwrap() {
                Some(Inbound::Data { from, msg, bytes: got }) => {
                    assert_eq!(from, NodeId(5));
                    assert_eq!(msg, MessageId(99));
                    assert_eq!(got, bytes);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn back_to_back_frames_parse_in_order() {
        let p = sample_pilot(7);
        let mut stream = encode_pilot(&p);
        stream.extend(encode_data(NodeId(1), MessageId(2), &[9, 9, 9]));
        stream.extend(encode_pilot(&p));
        let mut cur = io::Cursor::new(stream);
        assert!(matches!(read_frame(&mut cur).unwrap(), Some(Inbound::Pilot(_))));
        assert!(matches!(read_frame(&mut cur).unwrap(), Some(Inbound::Data { .. })));
        assert!(matches!(read_frame(&mut cur).unwrap(), Some(Inbound::Pilot(_))));
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn heartbeat_and_goodbye_frames_round_trip() {
        for (departing, node) in [(false, 0u64), (false, 7), (true, 3)] {
            let frame = encode_heartbeat(NodeId(node), departing);
            let mut cur = io::Cursor::new(frame);
            match read_frame(&mut cur).unwrap() {
                Some(Inbound::Heartbeat { from }) => {
                    assert!(!departing);
                    assert_eq!(from, NodeId(node));
                }
                Some(Inbound::Goodbye { from }) => {
                    assert!(departing);
                    assert_eq!(from, NodeId(node));
                }
                other => panic!("{other:?}"),
            }
            assert!(read_frame(&mut cur).unwrap().is_none());
        }
    }

    #[test]
    fn truncated_frame_is_an_error() {
        let p = sample_pilot(11);
        let mut frame = encode_pilot(&p);
        frame.truncate(frame.len() - 3);
        let mut cur = io::Cursor::new(frame);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn unknown_tag_is_an_error() {
        let mut cur = io::Cursor::new(vec![42u8, 0, 0]);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn absurd_data_length_is_rejected() {
        let mut frame = vec![TAG_DATA];
        frame.extend_from_slice(&0u64.to_le_bytes());
        frame.extend_from_slice(&1u64.to_le_bytes());
        frame.extend_from_slice(&(MAX_DATA_LEN + 1).to_le_bytes());
        let mut cur = io::Cursor::new(frame);
        assert!(read_frame(&mut cur).is_err());
    }
}
