//! The communicator subsystem: peer-to-peer messaging between nodes
//! (§3.4, §4.2).
//!
//! The paper's implementation wraps MPI (`MPI_Isend`/`MPI_Irecv` plus
//! out-of-band *pilot messages*). This repo substitutes pluggable
//! transports with identical semantics — non-blocking sends, polling
//! receipt, pilots travelling eagerly ahead of data:
//!
//! - [`ChannelWorld`] / [`ChannelCommunicator`] ([`channel`]): in-process
//!   mpsc fabric; every node of the simulated cluster is a thread. Fastest,
//!   and the reference the socket transport is validated against.
//! - [`TcpWorld`] / [`TcpCommunicator`] ([`tcp`]): real sockets with the
//!   CRC32-checked, sequence-numbered frame format of [`wire`]; nodes may
//!   be threads of one process (`TcpWorld::bind_local`) or genuinely
//!   separate OS processes (`TcpCommunicator::bind` + the `celerity
//!   worker` CLI). Transient stream faults are survived transparently via
//!   ack/retransmit with reconnect (see [`tcp`]).
//! - [`NullCommunicator`]: the single-node stub.
//! - [`crate::fault::FaultyCommunicator`]: a deterministic chaos wrapper
//!   around any of the above, driven by a seeded
//!   [`crate::fault::FaultPlan`].
//!
//! Which transport a cluster uses is a [`Transport`] config value on
//! `driver::ClusterConfig`, orthogonal to the program being run — the
//! cross-transport tests in `rust/tests/distributed.rs` pin both fabrics
//! to byte-identical application results.
//!
//! The *receive arbitration* consuming these messages (matching pilots and
//! out-of-order payload fragments against `receive`/`split receive`/`await
//! receive` instructions) lives with the executor:
//! [`crate::executor::ReceiveArbiter`].

pub mod channel;
pub mod tcp;
pub mod wire;

pub use channel::{ChannelCommunicator, ChannelWorld, NullCommunicator};
pub use tcp::{TcpCommunicator, TcpWorld};

use crate::instruction::Pilot;
use crate::util::{MessageId, NodeId};
use std::sync::Arc;

/// A message arriving at a node.
#[derive(Debug, Clone, PartialEq)]
pub enum Inbound {
    /// A pilot announcing an upcoming data transfer (§3.4).
    Pilot(Pilot),
    /// The payload of a `send` instruction, tagged with its message id.
    Data { from: NodeId, msg: MessageId, bytes: Vec<u8> },
    /// A liveness beacon from a peer's heartbeat monitor.
    Heartbeat { from: NodeId },
    /// A peer's announcement of clean shutdown: it must no longer count
    /// toward failure detection.
    Goodbye { from: NodeId },
    /// A transport-level fault report: a detected-and-recovered wire fault
    /// (CRC mismatch, out-of-sequence frame, reconnect, retransmit) or —
    /// with `fatal` — an unrecoverable peer failure. The executor traces
    /// every report and surfaces fatal ones on its error stream instead of
    /// letting the fabric desynchronize silently.
    Fault { from: NodeId, kind: FaultKind, detail: String, fatal: bool },
}

/// What kind of transport fault an [`Inbound::Fault`] reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A frame failed its CRC check (or was otherwise undecodable).
    Corrupt,
    /// A data-plane frame skipped ahead of the expected sequence number.
    OutOfSeq,
    /// The stream ended mid-frame.
    Truncated,
    /// A frame declared a payload beyond [`wire::MAX_DATA_LEN`].
    Oversized,
    /// A broken stream was re-established.
    Reconnect,
    /// Unacked frames were re-sent after a reconnect or an ack stall.
    Retransmit,
    /// Recovery was exhausted: the peer is considered lost.
    PeerLost,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Corrupt => "corrupt",
            FaultKind::OutOfSeq => "out-of-seq",
            FaultKind::Truncated => "truncated",
            FaultKind::Oversized => "oversized",
            FaultKind::Reconnect => "reconnect",
            FaultKind::Retransmit => "retransmit",
            FaultKind::PeerLost => "peer-lost",
        }
    }
}

impl Inbound {
    /// The peer this message came from (any inbound traffic is proof of
    /// life, so the heartbeat monitor refreshes on every variant — except
    /// fault reports, which may implicate a peer that is already gone).
    pub fn from(&self) -> NodeId {
        match self {
            Inbound::Pilot(p) => p.from,
            Inbound::Data { from, .. } => *from,
            Inbound::Heartbeat { from } => *from,
            Inbound::Goodbye { from } => *from,
            Inbound::Fault { from, .. } => *from,
        }
    }
}

/// Node-local endpoint of the cluster fabric.
///
/// All operations are non-blocking: `send_*` enqueue toward the peer,
/// `poll` drains the local mailbox. This mirrors how the executor
/// integrates MPI: "an executor loop issuing ready instructions and polling
/// active ones for completion" (§4.1).
pub trait Communicator: Send {
    fn node(&self) -> NodeId;
    fn num_nodes(&self) -> u64;
    /// Transmit a pilot message to its destination (eager, §3.4).
    fn send_pilot(&self, pilot: Pilot);
    /// Non-blocking data send (`MPI_Isend` equivalent).
    fn send_data(&self, to: NodeId, msg: MessageId, bytes: Vec<u8>);
    /// Best-effort liveness signal (`departing` = clean-shutdown goodbye).
    /// Losable by design — the heartbeat monitor only needs *eventual*
    /// delivery — so transports without a control plane may ignore it.
    fn send_heartbeat(&self, _to: NodeId, _departing: bool) {}
    /// Drain one pending inbound message, if any.
    fn poll(&self) -> Option<Inbound>;
}

/// Shareable communicator handle (executor + its lanes).
pub type CommRef = Arc<dyn Communicator + Sync>;

/// Whether `CELERITY_COMM_TRACE` is set — cached once, because the check
/// sits on the per-message send path (env lookups take the process-wide
/// environment lock).
pub(crate) fn comm_trace() -> bool {
    static TRACE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *TRACE.get_or_init(|| std::env::var_os("CELERITY_COMM_TRACE").is_some())
}

/// Which fabric connects the nodes of a cluster run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Transport {
    /// In-process mpsc channels (nodes are threads). The default.
    #[default]
    Channel,
    /// Loopback TCP sockets (same node-per-thread layout, real kernel
    /// sockets in between — the fabric separate worker processes use).
    Tcp,
}

impl Transport {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Transport> {
        match s {
            "channel" => Some(Transport::Channel),
            "tcp" => Some(Transport::Tcp),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Transport::Channel => "channel",
            Transport::Tcp => "tcp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_parse_round_trips() {
        for t in [Transport::Channel, Transport::Tcp] {
            assert_eq!(Transport::parse(t.name()), Some(t));
        }
        assert_eq!(Transport::parse("mpi"), None);
        assert_eq!(Transport::default(), Transport::Channel);
    }
}
