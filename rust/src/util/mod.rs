//! Small shared utilities: deterministic RNG (no external `rand` available
//! offline), id newtypes, and a tiny property-testing helper used across the
//! test suite.

pub mod ids;
pub mod rng;
pub mod spsc;

pub use ids::*;
pub use rng::XorShift64;
