//! Bounded single-producer single-consumer queue.
//!
//! The paper's architecture (§4, Fig 5) mediates all inter-thread
//! communication through spsc queues so that the main thread, scheduler
//! thread, executor thread and backend threads never contend on shared
//! scheduling state. We implement a classic ring buffer with acquire/release
//! atomics; `send` parks briefly when full (backpressure), `recv` parks when
//! empty. Blocking uses a tiny spin-then-yield strategy because queue
//! residency is expected to be short (the consumer is a dedicated thread).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

struct Ring<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    capacity: usize,
    head: AtomicUsize, // next slot to read (consumer-owned)
    tail: AtomicUsize, // next slot to write (producer-owned)
    closed: AtomicBool,
}

// SAFETY: the ring is only ever shared between exactly one producer
// (`Sender`) and one consumer (`Receiver`), and every slot is accessed by at
// most one side at a time: the producer writes only slots in
// `[head, tail + 1)` it has claimed via the `tail` CAS-free protocol, the
// consumer reads only slots in `[head, tail)`, and the Release store on
// `tail` (resp. `head`) publishes the slot contents before the other side's
// Acquire load can observe the index move. `T: Send` is required because
// values physically move between the two threads.
unsafe impl<T: Send> Send for Ring<T> {}
// SAFETY: see above — all interior mutability is slot-exclusive under the
// head/tail protocol; the atomics themselves are Sync.
unsafe impl<T: Send> Sync for Ring<T> {}

/// Sending half; owned by exactly one thread.
pub struct Sender<T> {
    ring: Arc<Ring<T>>,
}

/// Receiving half; owned by exactly one thread.
pub struct Receiver<T> {
    ring: Arc<Ring<T>>,
}

/// Error returned when the peer has disconnected.
#[derive(Debug, PartialEq, Eq)]
pub struct Disconnected;

/// Create a bounded spsc channel with the given capacity (rounded up to a
/// power of two, minimum 2).
pub fn channel<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    let capacity = capacity.max(2).next_power_of_two();
    let buf = (0..capacity)
        .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
        .collect::<Vec<_>>()
        .into_boxed_slice();
    let ring = Arc::new(Ring {
        buf,
        capacity,
        head: AtomicUsize::new(0),
        tail: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
    });
    (Sender { ring: ring.clone() }, Receiver { ring })
}

const SPIN_LIMIT: u32 = 64;
const YIELD_LIMIT: u32 = 192;

fn backoff(iter: &mut u32) {
    if *iter < SPIN_LIMIT {
        std::hint::spin_loop();
    } else if *iter < YIELD_LIMIT {
        std::thread::yield_now();
    } else {
        // Long wait: stop burning the core (matters on small machines where
        // many runtime threads share few cores).
        std::thread::sleep(std::time::Duration::from_micros(100));
    }
    *iter += 1;
}

impl<T> Sender<T> {
    /// Push a value, blocking while the queue is full. Returns `Err` if the
    /// receiver has been dropped (value is lost in that case).
    pub fn send(&self, value: T) -> Result<(), Disconnected> {
        let ring = &*self.ring;
        let tail = ring.tail.load(Ordering::Relaxed);
        let mut iter = 0;
        loop {
            let head = ring.head.load(Ordering::Acquire);
            if tail.wrapping_sub(head) < ring.capacity {
                break;
            }
            if ring.closed.load(Ordering::Acquire) {
                return Err(Disconnected);
            }
            backoff(&mut iter);
        }
        if ring.closed.load(Ordering::Acquire) {
            return Err(Disconnected);
        }
        // SAFETY: `tail - head < capacity` (checked above), so slot
        // `tail & (capacity-1)` is unoccupied: the consumer has already read
        // past it (its Release store to `head` happened-before our Acquire
        // load). We are the only producer, so nobody else writes it.
        unsafe {
            (*ring.buf[tail & (ring.capacity - 1)].get()).write(value);
        }
        ring.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Non-blocking push. Returns the value back if the queue is full.
    pub fn try_send(&self, value: T) -> Result<(), Result<T, Disconnected>> {
        let ring = &*self.ring;
        if ring.closed.load(Ordering::Acquire) {
            return Err(Err(Disconnected));
        }
        let tail = ring.tail.load(Ordering::Relaxed);
        let head = ring.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == ring.capacity {
            return Err(Ok(value));
        }
        // SAFETY: same argument as `send` — the fullness check above proves
        // the slot is past the consumer's head, and single-producer ownership
        // makes the write exclusive.
        unsafe {
            (*ring.buf[tail & (ring.capacity - 1)].get()).write(value);
        }
        ring.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }
}

impl<T> Receiver<T> {
    /// Pop a value, blocking while the queue is empty. Returns `Err` once
    /// the queue is empty *and* the sender has been dropped.
    pub fn recv(&self) -> Result<T, Disconnected> {
        let mut iter = 0;
        loop {
            match self.try_recv() {
                Ok(v) => return Ok(v),
                Err(Some(Disconnected)) => return Err(Disconnected),
                Err(None) => backoff(&mut iter),
            }
        }
    }

    /// Non-blocking pop. `Err(None)` means empty-but-alive,
    /// `Err(Some(Disconnected))` means empty-and-peer-gone.
    pub fn try_recv(&self) -> Result<T, Option<Disconnected>> {
        let ring = &*self.ring;
        let head = ring.head.load(Ordering::Relaxed);
        let tail = ring.tail.load(Ordering::Acquire);
        if head == tail {
            if ring.closed.load(Ordering::Acquire) {
                // Re-check tail: sender may have pushed before closing.
                let tail = ring.tail.load(Ordering::Acquire);
                if head == tail {
                    return Err(Some(Disconnected));
                }
            } else {
                return Err(None);
            }
        }
        // SAFETY: `head != tail` here, so the producer's Release store to
        // `tail` (observed by the Acquire load above) happened-after it
        // initialized slot `head & (capacity-1)`. Reading by value and then
        // bumping `head` transfers ownership exactly once — the producer will
        // not overwrite the slot until it observes the new head.
        let value = unsafe { (*ring.buf[head & (ring.capacity - 1)].get()).assume_init_read() };
        ring.head.store(head.wrapping_add(1), Ordering::Release);
        Ok(value)
    }

    /// Drain everything currently visible in the queue.
    pub fn drain(&self) -> Vec<T> {
        let mut out = Vec::new();
        while let Ok(v) = self.try_recv() {
            out.push(v);
        }
        out
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
        // Drop any unread values.
        while let Ok(v) = self.try_recv() {
            drop(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = channel(8);
        for i in 0..8 {
            tx.send(i).unwrap();
        }
        for i in 0..8 {
            assert_eq!(rx.recv().unwrap(), i);
        }
        assert_eq!(rx.try_recv(), Err(None));
    }

    #[test]
    fn try_send_full_returns_value() {
        let (tx, rx) = channel(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(Ok(3)));
        assert_eq!(rx.recv().unwrap(), 1);
        tx.try_send(3).unwrap();
    }

    #[test]
    fn recv_after_sender_drop_drains_then_disconnects() {
        let (tx, rx) = channel(4);
        tx.send("a").unwrap();
        tx.send("b").unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), "a");
        assert_eq!(rx.recv().unwrap(), "b");
        assert_eq!(rx.recv(), Err(Disconnected));
    }

    #[test]
    fn send_after_receiver_drop_errors() {
        let (tx, rx) = channel(4);
        drop(rx);
        assert_eq!(tx.send(1), Err(Disconnected));
    }

    #[test]
    fn cross_thread_stress() {
        let (tx, rx) = channel(16);
        let n = 100_000u64;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                tx.send(i).unwrap();
            }
        });
        let mut expect = 0;
        while expect < n {
            let v = rx.recv().unwrap();
            assert_eq!(v, expect);
            expect += 1;
        }
        producer.join().unwrap();
    }

    #[test]
    fn drain_collects_pending() {
        let (tx, rx) = channel(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain(), vec![0, 1, 2, 3, 4]);
    }
}
