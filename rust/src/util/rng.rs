//! Deterministic xorshift64* RNG.
//!
//! The offline crate closure has no `rand`, and determinism is a feature for
//! us anyway: property tests and workload generators must be reproducible so
//! that benchmark rows are comparable across runs and the two executor
//! implementations (baseline vs IDAG) see *identical* workloads.

/// xorshift64* PRNG (Vigna 2016). Not cryptographic; plenty for tests and
/// synthetic workload generation.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a non-zero seed. A zero seed is mapped to a
    /// fixed odd constant (xorshift has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform value in `[0, bound)`. `bound` must be non-zero.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift rejection-free mapping; slight modulo bias is fine
        // for tests and workload generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform value in the inclusive range `[lo, hi]`.
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.next_below(items.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_fixed_up() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn next_below_in_bounds() {
        let mut r = XorShift64::new(7);
        for _ in 0..10_000 {
            let bound = r.next_range(1, 1000);
            assert!(r.next_below(bound) < bound);
        }
    }

    #[test]
    fn next_range_inclusive() {
        let mut r = XorShift64::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.next_range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi, "range endpoints should both occur");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64::new(11);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
