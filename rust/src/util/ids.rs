//! Strongly-typed id newtypes used across the runtime.
//!
//! Every graph layer and hardware resource gets its own id space, mirroring
//! the paper's nomenclature: tasks (T*), commands (C*), instructions (I*),
//! nodes (N*), devices (D*), memories (M*), buffers (B*), allocations (A*),
//! and message ids for pilot-message matching (§3.4).

use std::fmt;

macro_rules! id_type {
    ($(#[$meta:meta])* $name:ident, $prefix:expr) => {
        $(#[$meta])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
        pub struct $name(pub u64);

        impl $name {
            /// Raw numeric value.
            pub fn get(self) -> u64 {
                self.0
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{}{}", $prefix, self.0)
            }
        }

        impl From<u64> for $name {
            fn from(v: u64) -> Self {
                Self(v)
            }
        }
    };
}

id_type!(
    /// A task in the task graph (TDAG). Generated identically on all nodes.
    TaskId,
    "T"
);
id_type!(
    /// A command in the command graph (CDAG). Node-local numbering.
    CommandId,
    "C"
);
id_type!(
    /// An instruction in the instruction graph (IDAG). Node-local numbering.
    InstructionId,
    "I"
);
id_type!(
    /// A cluster node (MPI-rank equivalent).
    NodeId,
    "N"
);
id_type!(
    /// A device (GPU equivalent) local to one node.
    DeviceId,
    "D"
);
id_type!(
    /// A memory space. M0 = user host memory, M1 = pinned host memory,
    /// M2.. = device-native memories (§3.2).
    MemoryId,
    "M"
);
id_type!(
    /// A user-visible virtualized buffer.
    BufferId,
    "B"
);
id_type!(
    /// A backing allocation created by an `alloc` instruction (§3.2).
    AllocationId,
    "A"
);
id_type!(
    /// Message id tagging a `send` instruction; matched against pilot
    /// messages during receive arbitration (§3.4, §4.2).
    MessageId,
    "MSG"
);
id_type!(
    /// Id of a physical HLO kernel artifact registered with the runtime.
    KernelId,
    "K"
);
id_type!(
    /// A tenant job sharing one cluster. Each job gets its own TDAG/CDAG/
    /// IDAG namespace, buffer-id space, horizons and fences; the job id is
    /// packed into the high bits of every per-job id (see [`JobId::base`])
    /// so concurrent jobs never collide in any tracking structure.
    JobId,
    "J"
);

impl JobId {
    /// Bit position where the job tag starts inside a 64-bit id.
    pub const SHIFT: u32 = 48;
    /// Width of the job tag in bits.
    pub const BITS: u32 = 12;
    /// Maximum representable job id (4095 concurrent jobs per cluster).
    pub const MAX: u64 = (1 << Self::BITS) - 1;

    /// Numeric base of this job's id namespace: ids `base()..base()+2^48`
    /// belong to this job. Bits 60..63 are left untouched so flag bits such
    /// as the user-allocation marker (bit 62, `instruction::user_alloc_id`)
    /// survive tagging and round-trip through [`JobId::of`].
    pub fn base(self) -> u64 {
        debug_assert!(self.0 <= Self::MAX, "job id out of range");
        self.0 << Self::SHIFT
    }

    /// Recover the owning job from any tagged id. Only bits 48..60 are
    /// inspected, so this works on plain ids and on flag-carrying ids
    /// (user allocations) alike.
    pub fn of(raw: u64) -> JobId {
        JobId((raw >> Self::SHIFT) & Self::MAX)
    }
}

impl MemoryId {
    /// User-controlled host memory.
    pub const USER: MemoryId = MemoryId(0);
    /// DMA-capable page-locked host memory; staging area for sends/receives.
    pub const HOST: MemoryId = MemoryId(1);

    /// Native memory of device `d` under the canonical 1:1 mapping
    /// D0→M2, D1→M3, ... (§3.2).
    pub fn device_native(d: DeviceId) -> MemoryId {
        MemoryId(2 + d.0)
    }

    /// Whether this memory id denotes a device-native memory.
    pub fn is_device(self) -> bool {
        self.0 >= 2
    }

    /// Inverse of [`MemoryId::device_native`], if this is a device memory.
    pub fn to_device(self) -> Option<DeviceId> {
        self.is_device().then(|| DeviceId(self.0 - 2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_uses_paper_prefixes() {
        assert_eq!(TaskId(3).to_string(), "T3");
        assert_eq!(CommandId(5).to_string(), "C5");
        assert_eq!(InstructionId(24).to_string(), "I24");
        assert_eq!(NodeId(0).to_string(), "N0");
        assert_eq!(DeviceId(1).to_string(), "D1");
        assert_eq!(MemoryId(2).to_string(), "M2");
    }

    #[test]
    fn job_tag_round_trips_and_preserves_flags() {
        let j = JobId(3);
        let tagged = j.base() + 41;
        assert_eq!(JobId::of(tagged), j);
        assert_eq!(tagged & ((1 << JobId::SHIFT) - 1), 41);
        // The user-allocation flag (bit 62) survives tagging.
        let user_alloc = (1u64 << 62) | j.base() | 7;
        assert_eq!(JobId::of(user_alloc), j);
        // Job 0 (single-tenant wrappers) leaves ids numerically unchanged.
        assert_eq!(JobId(0).base(), 0);
        assert_eq!(JobId::of(5), JobId(0));
    }

    #[test]
    fn device_memory_mapping_is_canonical() {
        assert_eq!(MemoryId::device_native(DeviceId(0)), MemoryId(2));
        assert_eq!(MemoryId::device_native(DeviceId(3)), MemoryId(5));
        assert_eq!(MemoryId(4).to_device(), Some(DeviceId(2)));
        assert_eq!(MemoryId::USER.to_device(), None);
        assert_eq!(MemoryId::HOST.to_device(), None);
        assert!(!MemoryId::HOST.is_device());
        assert!(MemoryId(2).is_device());
    }
}
