//! Virtualized buffers.
//!
//! A Celerity buffer is a *virtual* n-dimensional array: the user sees a
//! single global index space, while the runtime materializes only the
//! subregions each memory actually accesses (§2.2). This module holds the
//! buffer *metadata* registry plus the typed [`Buffer`] handle of the
//! user-facing queue API; backing allocations live in the instruction
//! layer, and concrete bytes live with the executor.

use crate::dtype::{DType, Elem};
use crate::grid::{Range, Region};
use crate::util::BufferId;
use std::collections::HashMap;
use std::marker::PhantomData;

/// Typed handle to a virtualized buffer, carrying the element type in its
/// type parameter (Listing 1's `celerity::buffer<T, Dims>`). Handles are
/// cheap `Copy` tokens — the metadata lives in the [`BufferPool`].
pub struct Buffer<T: Elem> {
    id: BufferId,
    range: Range,
    _elem: PhantomData<fn() -> T>,
}

impl<T: Elem> Buffer<T> {
    /// Wrap a raw buffer id in a typed handle *without* checking the
    /// registered dtype. Queue operations re-validate against the pool, so
    /// a wrong cast surfaces as `QueueError::DTypeMismatch`, not UB.
    pub fn from_raw(id: BufferId, range: Range) -> Self {
        Buffer { id, range, _elem: PhantomData }
    }

    pub fn id(self) -> BufferId {
        self.id
    }

    /// Extent of the (virtual) global index space.
    pub fn range(self) -> Range {
        self.range
    }

    /// Number of elements in the full index space.
    pub fn len(self) -> u64 {
        self.range.size()
    }

    pub fn is_empty(self) -> bool {
        self.len() == 0
    }
}

// Manual impls: `T` is phantom, so no `T: Clone/Copy/...` bounds needed.
impl<T: Elem> Clone for Buffer<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T: Elem> Copy for Buffer<T> {}

impl<T: Elem> PartialEq for Buffer<T> {
    fn eq(&self, other: &Self) -> bool {
        self.id == other.id
    }
}
impl<T: Elem> Eq for Buffer<T> {}

impl<T: Elem> std::fmt::Debug for Buffer<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Buffer<{}x{}>({})", T::DTYPE, T::LANES, self.id)
    }
}

impl<T: Elem> From<Buffer<T>> for BufferId {
    fn from(b: Buffer<T>) -> BufferId {
        b.id
    }
}

/// Static description of one virtualized buffer.
#[derive(Debug, Clone)]
pub struct BufferInfo {
    pub id: BufferId,
    /// Extent of the (virtual) global index space.
    pub range: Range,
    /// Scalar type of each element lane.
    pub dtype: DType,
    /// Scalar lanes per element (3 for the "double3"-style N-body state).
    pub lanes: usize,
    /// Size of one element in bytes (`dtype.size() * lanes`).
    pub elem_size: usize,
    /// Debug name, e.g. `"P"` / `"V"` in the N-body listing.
    pub name: String,
    /// Region whose contents were supplied by the user (a host-initialized
    /// buffer starts fully initialized; others start fully uninitialized
    /// and reading them is a correctness error, §4.4).
    pub host_initialized: Region,
}

impl BufferInfo {
    /// Bytes needed to back the full virtual range (contiguously).
    pub fn full_size_bytes(&self) -> u64 {
        self.range.size() * self.elem_size as u64
    }
}

/// Registry of all live buffers. Shared (by clone) between graph layers;
/// buffers are append-only within a run, destruction is modelled by the
/// `free` instructions emitted when the last access completes.
#[derive(Debug, Clone, Default)]
pub struct BufferPool {
    infos: HashMap<BufferId, BufferInfo>,
    next: u64,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A pool whose buffer ids start at `base` (a job's `JobId::base()`), so
    /// buffers of concurrent jobs never collide in any per-buffer tracking
    /// structure across the scheduler and executor.
    pub fn with_base(base: u64) -> Self {
        BufferPool { infos: HashMap::new(), next: base }
    }

    /// Register a new buffer and return its id.
    pub fn create(
        &mut self,
        name: impl Into<String>,
        range: Range,
        dtype: DType,
        lanes: usize,
        host_initialized: bool,
    ) -> BufferId {
        let id = BufferId(self.next);
        self.next += 1;
        self.infos.insert(
            id,
            BufferInfo {
                id,
                range,
                dtype,
                lanes,
                elem_size: dtype.size() * lanes,
                name: name.into(),
                host_initialized: if host_initialized {
                    Region::full(range)
                } else {
                    Region::empty()
                },
            },
        );
        id
    }

    pub fn get(&self, id: BufferId) -> &BufferInfo {
        &self.infos[&id]
    }

    pub(crate) fn get_mut(&mut self, id: BufferId) -> &mut BufferInfo {
        self.infos.get_mut(&id).expect("unknown buffer id")
    }

    pub fn try_get(&self, id: BufferId) -> Option<&BufferInfo> {
        self.infos.get(&id)
    }

    pub fn iter(&self) -> impl Iterator<Item = &BufferInfo> {
        self.infos.values()
    }

    pub fn len(&self) -> usize {
        self.infos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.infos.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_assigns_sequential_ids() {
        let mut pool = BufferPool::new();
        let a = pool.create("P", Range::d1(128), DType::F64, 3, true);
        let b = pool.create("V", Range::d1(128), DType::F64, 3, false);
        assert_eq!(a, BufferId(0));
        assert_eq!(b, BufferId(1));
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.get(a).name, "P");
        assert_eq!(pool.get(a).elem_size, 24);
    }

    #[test]
    fn host_init_region_matches_flag() {
        let mut pool = BufferPool::new();
        let a = pool.create("init", Range::d2(4, 4), DType::F64, 1, true);
        let b = pool.create("raw", Range::d2(4, 4), DType::F64, 1, false);
        assert_eq!(pool.get(a).host_initialized.area(), 16);
        assert!(pool.get(b).host_initialized.is_empty());
    }

    #[test]
    fn full_size_bytes() {
        let mut pool = BufferPool::new();
        let a = pool.create("x", Range::d2(100, 10), DType::F64, 1, false);
        assert_eq!(pool.get(a).full_size_bytes(), 8000);
    }

    #[test]
    fn typed_handles_are_copy_tokens() {
        let b: Buffer<f32> = Buffer::from_raw(BufferId(7), Range::d1(32));
        let c = b;
        assert_eq!(b, c);
        assert_eq!(b.id(), BufferId(7));
        assert_eq!(b.len(), 32);
        assert_eq!(BufferId::from(b), BufferId(7));
        assert_eq!(format!("{b:?}"), "Buffer<f32x1>(B7)");
    }
}
