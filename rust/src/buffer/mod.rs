//! Virtualized buffers.
//!
//! A Celerity buffer is a *virtual* n-dimensional array: the user sees a
//! single global index space, while the runtime materializes only the
//! subregions each memory actually accesses (§2.2). This module holds the
//! buffer *metadata* registry; backing allocations live in the instruction
//! layer, and concrete bytes live with the executor.

use crate::grid::{Range, Region};
use crate::util::BufferId;
use std::collections::HashMap;

/// Static description of one virtualized buffer.
#[derive(Debug, Clone)]
pub struct BufferInfo {
    pub id: BufferId,
    /// Extent of the (virtual) global index space.
    pub range: Range,
    /// Size of one element in bytes.
    pub elem_size: usize,
    /// Debug name, e.g. `"P"` / `"V"` in the N-body listing.
    pub name: String,
    /// Region whose contents were supplied by the user at creation (a
    /// host-initialized buffer starts fully initialized; others start fully
    /// uninitialized and reading them is a correctness error, §4.4).
    pub host_initialized: Region,
}

impl BufferInfo {
    /// Bytes needed to back the full virtual range (contiguously).
    pub fn full_size_bytes(&self) -> u64 {
        self.range.size() * self.elem_size as u64
    }
}

/// Registry of all live buffers. Shared (by clone) between graph layers;
/// buffers are append-only within a run, destruction is modelled by the
/// `free` instructions emitted when the last access completes.
#[derive(Debug, Clone, Default)]
pub struct BufferPool {
    infos: HashMap<BufferId, BufferInfo>,
    next: u64,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new buffer and return its id.
    pub fn create(
        &mut self,
        name: impl Into<String>,
        range: Range,
        elem_size: usize,
        host_initialized: bool,
    ) -> BufferId {
        let id = BufferId(self.next);
        self.next += 1;
        self.infos.insert(
            id,
            BufferInfo {
                id,
                range,
                elem_size,
                name: name.into(),
                host_initialized: if host_initialized {
                    Region::full(range)
                } else {
                    Region::empty()
                },
            },
        );
        id
    }

    pub fn get(&self, id: BufferId) -> &BufferInfo {
        &self.infos[&id]
    }

    pub fn try_get(&self, id: BufferId) -> Option<&BufferInfo> {
        self.infos.get(&id)
    }

    pub fn iter(&self) -> impl Iterator<Item = &BufferInfo> {
        self.infos.values()
    }

    pub fn len(&self) -> usize {
        self.infos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.infos.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_assigns_sequential_ids() {
        let mut pool = BufferPool::new();
        let a = pool.create("P", Range::d1(128), 24, true);
        let b = pool.create("V", Range::d1(128), 24, false);
        assert_eq!(a, BufferId(0));
        assert_eq!(b, BufferId(1));
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.get(a).name, "P");
    }

    #[test]
    fn host_init_region_matches_flag() {
        let mut pool = BufferPool::new();
        let a = pool.create("init", Range::d2(4, 4), 8, true);
        let b = pool.create("raw", Range::d2(4, 4), 8, false);
        assert_eq!(pool.get(a).host_initialized.area(), 16);
        assert!(pool.get(b).host_initialized.is_empty());
    }

    #[test]
    fn full_size_bytes() {
        let mut pool = BufferPool::new();
        let a = pool.create("x", Range::d2(100, 10), 8, false);
        assert_eq!(pool.get(a).full_size_bytes(), 8000);
    }
}
