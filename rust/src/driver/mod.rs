//! The cluster driver: wires the full Fig-5 architecture together.
//!
//! [`run_cluster`] executes a user program SPMD-style — once per (simulated)
//! cluster node, each node running in its own thread with the full
//! three-thread architecture underneath:
//!
//! ```text
//! node thread (main)  ──spsc──▶  scheduler thread  ──spsc──▶  executor thread
//!   TaskManager                    CDAG+IDAG gen,                OoO engine,
//!   (TDAG gen)                     lookahead queue               recv arbitration
//!                                                                │ lanes (threads)
//!                                                                ▼
//!                                                       device/host/comm workers
//! ```
//!
//! Peer-to-peer communication flows through a [`ChannelWorld`], the
//! in-process MPI substitute.

use crate::command::SplitHint;
use crate::comm::{ChannelWorld, CommRef, NullCommunicator};
use crate::executor::{ExecEvent, ExecutorConfig, ExecutorHandle, ExecutorStats, Registry};
use crate::grid::Range;
use crate::scheduler::{SchedulerConfig, SchedulerHandle, SchedulerMsg, SchedulerOut, UserInit};
use crate::task::{EpochAction, RangeMapper, TaskDecl, TaskManager};
use crate::util::{spsc, BufferId, NodeId, TaskId};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Configuration of an in-process cluster run.
#[derive(Clone)]
pub struct ClusterConfig {
    pub num_nodes: u64,
    pub num_devices: u64,
    pub host_lanes: usize,
    pub lookahead: bool,
    pub d2d: bool,
    pub node_hint: SplitHint,
    pub device_hint: SplitHint,
    pub registry: Registry,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            num_nodes: 1,
            num_devices: 1,
            host_lanes: 4,
            lookahead: true,
            d2d: true,
            node_hint: SplitHint::D1,
            device_hint: SplitHint::D1,
            registry: Registry::new(),
        }
    }
}

/// Per-node result of a cluster run.
#[derive(Debug)]
pub struct NodeReport {
    pub node: NodeId,
    pub executor: ExecutorStats,
    pub instructions_generated: u64,
    pub commands_generated: u64,
    pub resizes_emitted: u64,
    pub bytes_allocated: u64,
    pub max_queue_len: usize,
    /// Runtime errors (§4.4) observed on this node.
    pub errors: Vec<String>,
}

/// The per-node user-facing queue: buffer creation + command-group
/// submission + synchronization, mirroring Listing 1's API surface.
pub struct NodeQueue {
    pub node: NodeId,
    pub cfg: ClusterConfig,
    tm: TaskManager,
    sched: SchedulerHandle,
    exec: ExecutorHandle,
    errors: Vec<String>,
    fence_counter: Arc<AtomicU64>,
}

impl NodeQueue {
    /// Create a virtualized buffer visible to subsequent tasks.
    pub fn create_buffer(
        &mut self,
        name: impl Into<String>,
        range: Range,
        elem_size: usize,
        host_initialized: bool,
    ) -> BufferId {
        let id = self.tm.create_buffer(name, range, elem_size, host_initialized);
        self.sched
            .send(SchedulerMsg::Buffers(self.tm.buffers().clone()));
        if host_initialized {
            // Materialize the user-memory (M0) allocation, zero-filled;
            // `init_buffer_*` overwrites it with concrete data.
            self.sched.send(SchedulerMsg::UserData(UserInit {
                alloc: crate::instruction::user_alloc_id(id),
                covers: crate::grid::GridBox::full(range),
                elem_size,
                bytes: Vec::new(),
            }));
        }
        id
    }

    /// Supply the contents of a host-initialized buffer as raw bytes.
    pub fn init_buffer_bytes(&mut self, buffer: BufferId, bytes: Vec<u8>) {
        let info = self.tm.buffers().get(buffer).clone();
        assert_eq!(
            bytes.len() as u64,
            info.range.size() * info.elem_size as u64,
            "init size mismatch for {buffer}"
        );
        self.sched.send(SchedulerMsg::UserData(UserInit {
            alloc: crate::instruction::user_alloc_id(buffer),
            covers: crate::grid::GridBox::full(info.range),
            elem_size: info.elem_size,
            bytes,
        }));
    }

    /// Supply the contents of a host-initialized buffer as f32 values.
    pub fn init_buffer_f32(&mut self, buffer: BufferId, values: &[f32]) {
        let mut bytes = Vec::with_capacity(values.len() * 4);
        for v in values {
            bytes.extend_from_slice(&v.to_ne_bytes());
        }
        self.init_buffer_bytes(buffer, bytes);
    }

    /// Submit a command group (Listing 1's `q.submit`).
    pub fn submit(&mut self, decl: TaskDecl) -> TaskId {
        let id = self.tm.submit(decl);
        self.forward_tasks();
        id
    }

    /// Barrier: wait until everything submitted so far has executed.
    pub fn wait(&mut self) {
        self.tm.barrier();
        self.forward_tasks();
        let side = self.exec.wait_epoch(EpochAction::Barrier);
        self.collect_errors(side);
    }

    /// Read back the full contents of a buffer as raw bytes (convenience
    /// fence: internally a host task reading the buffer with an `all`
    /// range mapper, followed by a barrier).
    pub fn fence_bytes(&mut self, buffer: BufferId) -> Vec<u8> {
        let info = self.tm.buffers().get(buffer).clone();
        // The registry is shared across all node threads: namespace the
        // fence task by node so each node's sink closure stays distinct.
        let name = format!(
            "__fence_{}_{}_{}",
            self.node,
            buffer,
            self.fence_counter.fetch_add(1, Ordering::Relaxed)
        );
        let sink: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        let sink_c = sink.clone();
        self.cfg.registry.register_host_task(
            name.clone(),
            Arc::new(move |ctx| {
                *sink_c.lock().unwrap() = ctx.view(0).read_region_bytes();
            }),
        );
        self.submit(
            TaskDecl::host(name, info.range).read(buffer, RangeMapper::All),
        );
        self.wait();
        let bytes = std::mem::take(&mut *sink.lock().unwrap());
        assert_eq!(bytes.len() as u64, info.range.size() * info.elem_size as u64);
        bytes
    }

    /// Read back a buffer as `f32`s.
    pub fn fence_f32(&mut self, buffer: BufferId) -> Vec<f32> {
        let bytes = self.fence_bytes(buffer);
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_ne_bytes(c.try_into().unwrap()))
            .collect()
    }

    /// Read back a buffer as `f64`s.
    pub fn fence_f64(&mut self, buffer: BufferId) -> Vec<f64> {
        let bytes = self.fence_bytes(buffer);
        bytes
            .chunks_exact(8)
            .map(|c| f64::from_ne_bytes(c.try_into().unwrap()))
            .collect()
    }

    /// TDAG debug diagnostics observed so far (§4.4 uninitialized reads).
    pub fn take_debug_events(&mut self) -> Vec<crate::task::DebugEvent> {
        self.tm.take_debug_events()
    }

    fn forward_tasks(&mut self) {
        for t in self.tm.take_new_tasks() {
            self.sched.send(SchedulerMsg::Task(t));
        }
        // Drain pending error events without blocking.
        while let Ok(ev) = self.exec.events.try_recv() {
            match ev {
                ExecEvent::Error(e) => self.errors.push(e),
                ExecEvent::Epoch(..) => {}
            }
        }
    }

    fn collect_errors(&mut self, side: Vec<ExecEvent>) {
        for ev in side {
            if let ExecEvent::Error(e) = ev {
                self.errors.push(e);
            }
        }
    }

    fn shutdown(mut self) -> NodeReport {
        self.tm.shutdown();
        self.forward_tasks();
        let side = self.exec.wait_epoch(EpochAction::Shutdown);
        self.collect_errors(side);
        let sched = self.sched.join();
        let executor = self.exec.join();
        NodeReport {
            node: self.node,
            executor,
            instructions_generated: sched.instructions_generated,
            commands_generated: sched.commands_generated,
            resizes_emitted: sched.idag().resizes_emitted,
            bytes_allocated: sched.idag().bytes_allocated,
            max_queue_len: sched.max_queue_len,
            errors: self.errors,
        }
    }
}

fn make_node(cfg: &ClusterConfig, node: NodeId, comm: CommRef) -> NodeQueue {
    let tm = TaskManager::new();
    let (out_tx, out_rx) = spsc::channel::<SchedulerOut>(4096);
    let sched = SchedulerHandle::spawn(
        SchedulerConfig {
            node,
            num_nodes: cfg.num_nodes,
            num_devices: cfg.num_devices,
            node_hint: cfg.node_hint,
            device_hint: cfg.device_hint,
            d2d: cfg.d2d,
            lookahead: cfg.lookahead,
            horizon_flush: 2,
        },
        tm.buffers().clone(),
        out_tx,
    );
    let exec = ExecutorHandle::spawn(
        ExecutorConfig {
            node,
            host_lanes: cfg.host_lanes,
            registry: cfg.registry.clone(),
        },
        comm,
        out_rx,
    );
    NodeQueue {
        node,
        cfg: cfg.clone(),
        tm,
        sched,
        exec,
        errors: Vec::new(),
        fence_counter: Arc::new(AtomicU64::new(0)),
    }
}

/// Run `program` SPMD on an in-process cluster: one OS thread per node,
/// each with its own scheduler/executor stack, connected by a
/// [`ChannelWorld`]. Returns per-node reports.
pub fn run_cluster<F>(cfg: ClusterConfig, program: F) -> Vec<NodeReport>
where
    F: Fn(&mut NodeQueue) + Send + Sync + 'static,
{
    assert!(cfg.num_nodes >= 1);
    if cfg.num_nodes == 1 {
        let comm: CommRef = Arc::new(NullCommunicator(NodeId(0)));
        let mut q = make_node(&cfg, NodeId(0), comm);
        program(&mut q);
        return vec![q.shutdown()];
    }
    let world = ChannelWorld::new(cfg.num_nodes);
    let comms = world.communicators();
    let program = Arc::new(program);
    let mut joins = Vec::new();
    for (i, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        let program = program.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("celerity-node-{i}"))
                .spawn(move || {
                    let comm: CommRef = Arc::new(comm);
                    let mut q = make_node(&cfg, NodeId(i as u64), comm);
                    program(&mut q);
                    q.shutdown()
                })
                .expect("spawn node thread"),
        );
    }
    joins
        .into_iter()
        .map(|j| j.join().expect("node thread panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::executor::KernelCtx;
    use crate::grid::Point;

    fn registry_iota_double() -> Registry {
        let registry = Registry::new();
        registry.register_kernel(
            "iota",
            Arc::new(|ctx: &KernelCtx| {
                let v = ctx.view(0);
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    v.write_f32(Point::d1(i), i as f32);
                }
            }),
        );
        registry.register_kernel(
            "sum_all",
            Arc::new(|ctx: &KernelCtx| {
                // out[i] = sum(in[j] for all j) + in[i]; requires the full
                // buffer (all-gather pattern, like N-body).
                let inp = ctx.view(0);
                let out = ctx.view(1);
                let n = inp.binding.region.bounding_box().max[0];
                let mut total = 0f32;
                for j in 0..n {
                    total += inp.read_f32(Point::d1(j));
                }
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    out.write_f32(Point::d1(i), total + inp.read_f32(Point::d1(i)));
                }
            }),
        );
        registry
    }

    #[test]
    fn single_node_two_devices_numerics() {
        let cfg = ClusterConfig {
            num_devices: 2,
            registry: registry_iota_double(),
            ..Default::default()
        };
        let result: Arc<Mutex<Vec<f32>>> = Arc::new(Mutex::new(vec![]));
        let result_c = result.clone();
        let reports = run_cluster(cfg, move |q| {
            let n = Range::d1(128);
            let a = q.create_buffer("A", n, 4, false);
            let b = q.create_buffer("B", n, 4, false);
            q.submit(
                TaskDecl::device("iota", n)
                    .discard_write(a, RangeMapper::OneToOne)
                    .kernel("iota"),
            );
            q.submit(
                TaskDecl::device("sum_all", n)
                    .read(a, RangeMapper::All)
                    .discard_write(b, RangeMapper::OneToOne)
                    .kernel("sum_all"),
            );
            *result_c.lock().unwrap() = q.fence_f32(b);
        });
        assert_eq!(reports.len(), 1);
        assert!(reports[0].errors.is_empty(), "{:?}", reports[0].errors);
        let got = result.lock().unwrap();
        let total: f32 = (0..128).map(|i| i as f32).sum();
        for i in 0..128 {
            assert_eq!(got[i], total + i as f32, "element {i}");
        }
    }

    /// The flagship integration test: a 4-node × 2-device cluster running
    /// an all-gather pattern where every node needs every other node's
    /// data — exercising push/await-push, pilots, receive arbitration and
    /// multi-device coherence, with numerics checked on every node.
    #[test]
    fn four_nodes_all_gather_numerics() {
        let cfg = ClusterConfig {
            num_nodes: 4,
            num_devices: 2,
            registry: registry_iota_double(),
            ..Default::default()
        };
        let results: Arc<Mutex<Vec<(u64, Vec<f32>)>>> = Arc::new(Mutex::new(vec![]));
        let results_c = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let n = Range::d1(256);
            let a = q.create_buffer("A", n, 4, false);
            let b = q.create_buffer("B", n, 4, false);
            q.submit(
                TaskDecl::device("iota", n)
                    .discard_write(a, RangeMapper::OneToOne)
                    .kernel("iota"),
            );
            q.submit(
                TaskDecl::device("sum_all", n)
                    .read(a, RangeMapper::All)
                    .discard_write(b, RangeMapper::OneToOne)
                    .kernel("sum_all"),
            );
            let got = q.fence_f32(b);
            results_c.lock().unwrap().push((q.node.0, got));
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "node {}: {:?}", r.node, r.errors);
        }
        let results = results.lock().unwrap();
        assert_eq!(results.len(), 4);
        let total: f32 = (0..256).map(|i| i as f32).sum();
        for (node, got) in results.iter() {
            assert_eq!(got.len(), 256);
            for i in 0..256 {
                assert_eq!(got[i], total + i as f32, "node {node} element {i}");
            }
        }
    }

    /// Iterated exchange: two nodes ping-pong through multiple timesteps,
    /// verifying steady-state communication (replicas invalidated by every
    /// write) and horizon pruning under a real executor.
    #[test]
    fn two_nodes_iterated_allgather() {
        let registry = registry_iota_double();
        registry.register_kernel(
            "relax",
            Arc::new(|ctx: &KernelCtx| {
                // a'[i] = (sum of all a) / n  + small identity part
                let inp = ctx.view(0);
                let out = ctx.view(1);
                let n = inp.binding.region.bounding_box().max[0];
                let mut total = 0f32;
                for j in 0..n {
                    total += inp.read_f32(Point::d1(j));
                }
                let mean = total / n as f32;
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    out.write_f32(Point::d1(i), 0.5 * inp.read_f32(Point::d1(i)) + 0.5 * mean);
                }
            }),
        );
        let cfg = ClusterConfig {
            num_nodes: 2,
            num_devices: 2,
            registry,
            ..Default::default()
        };
        let results: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(vec![]));
        let results_c = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let n = Range::d1(64);
            let a = q.create_buffer("A", n, 4, false);
            let b = q.create_buffer("B", n, 4, false);
            q.submit(
                TaskDecl::device("iota", n)
                    .discard_write(a, RangeMapper::OneToOne)
                    .kernel("iota"),
            );
            for _ in 0..5 {
                q.submit(
                    TaskDecl::device("relax", n)
                        .read(a, RangeMapper::All)
                        .discard_write(b, RangeMapper::OneToOne)
                        .kernel("relax"),
                );
                q.submit(
                    TaskDecl::device("relax", n)
                        .read(b, RangeMapper::All)
                        .discard_write(a, RangeMapper::OneToOne)
                        .kernel("relax"),
                );
            }
            // NB: fence first, then lock — taking the shared mutex before
            // the fence would serialize nodes that must communicate.
            let got = q.fence_f32(a);
            results_c.lock().unwrap().push(got);
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "{:?}", r.errors);
        }
        // Reference computation.
        let mut reference: Vec<f32> = (0..64).map(|i| i as f32).collect();
        for _ in 0..10 {
            let mean = reference.iter().sum::<f32>() / 64.0;
            reference = reference.iter().map(|v| 0.5 * v + 0.5 * mean).collect();
        }
        let results = results.lock().unwrap();
        assert_eq!(results.len(), 2);
        for got in results.iter() {
            for i in 0..64 {
                assert!(
                    (got[i] - reference[i]).abs() < 1e-3,
                    "element {i}: {} vs {}",
                    got[i],
                    reference[i]
                );
            }
        }
    }

    #[test]
    fn reports_carry_scheduler_stats() {
        let cfg = ClusterConfig {
            registry: registry_iota_double(),
            ..Default::default()
        };
        let reports = run_cluster(cfg, |q| {
            let n = Range::d1(32);
            let a = q.create_buffer("A", n, 4, false);
            q.submit(
                TaskDecl::device("iota", n)
                    .discard_write(a, RangeMapper::OneToOne)
                    .kernel("iota"),
            );
        });
        let r = &reports[0];
        assert!(r.instructions_generated > 0);
        assert!(r.commands_generated > 0);
        assert!(r.executor.retired as u64 >= r.instructions_generated);
    }
}
