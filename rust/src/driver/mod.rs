//! The cluster driver: wires the full Fig-5 architecture together.
//!
//! A [`Cluster`] owns one node's runtime stack — the scheduler thread, the
//! executor thread and the comm fabric endpoint — and hands out any number
//! of independent [`Queue`]s (`cluster.create_queue()`), one per *job*.
//! Jobs are fully isolated tenants: each queue has its own TDAG/CDAG/IDAG
//! id namespace (the job tag lives in the id high bits), its own buffer-id
//! space, its own horizons, fences and §4.4 error stream — while the
//! scheduler thread interleaves compilation across jobs and the executor
//! arbitrates shared device lanes and memory arenas between them
//! (weighted round-robin + optional admission limits).
//!
//! ```text
//! job threads (main)  ──mpsc──▶  scheduler thread  ──spsc──▶  executor thread
//!   TaskManager per job            per-job CDAG+IDAG            OoO engine,
//!   (TDAG gen)                     cores, lookahead             fair-share dispatch,
//!                                                               recv arbitration
//!                                                               │ lanes (threads)
//!                                                               ▼
//!                                                      device/host/comm workers
//! ```
//!
//! [`run_cluster`] keeps the classic single-tenant surface: it executes a
//! user program SPMD-style — once per (simulated) cluster node, each node
//! running a one-job cluster. [`run_cluster_jobs`] runs several programs
//! concurrently as jobs of one shared cluster on every node.
//!
//! The user program talks to a typed [`Queue`] (Listing 1): typed buffer
//! creation, command-group submission (`q.submit(|cgh| ...)`), typed
//! initialization/fences, and `Result`-based §4.4 error propagation.
//! Peer-to-peer communication flows through the transport selected by
//! [`ClusterConfig::transport`]: a [`ChannelWorld`] (in-process MPI
//! substitute, the default) or a loopback [`TcpWorld`](crate::comm::TcpWorld)
//! mesh — the same fabric the `celerity worker` CLI uses to run each node
//! as a separate OS process ([`run_node`] is the per-process entry point).

use crate::buffer::Buffer;
use crate::comm::{ChannelWorld, CommRef, NullCommunicator, TcpWorld, Transport};
use crate::command::SplitHint;
use crate::dtype::{self, Elem};
use crate::executor::{
    EventHub, ExecEvent, ExecutorConfig, ExecutorHandle, ExecutorStats, Registry,
};
use crate::grid::Range;
use crate::scheduler::{SchedulerConfig, SchedulerHandle, SchedulerMsg, SchedulerOut, UserInit};
use crate::task::{CommandGroup, EpochAction, QueueError, RangeMapper, TaskDecl, TaskManager};
use crate::util::{spsc, BufferId, JobId, NodeId, TaskId};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Configuration of an in-process cluster run.
#[derive(Clone)]
pub struct ClusterConfig {
    pub num_nodes: u64,
    pub num_devices: u64,
    pub host_lanes: usize,
    pub lookahead: bool,
    pub d2d: bool,
    pub node_hint: SplitHint,
    pub device_hint: SplitHint,
    pub registry: Registry,
    /// Fabric connecting the nodes (ignored for single-node runs).
    pub transport: Transport,
    /// Lower all-gather/broadcast patterns to collective ring commands
    /// instead of p2p push/await-push pairs (default: on).
    pub collectives: bool,
    /// Direct device transfers on the p2p path (default: on): sends read
    /// device-resident data straight from the device backing and receives
    /// land in the consuming device's allocation, eliding the pinned-host
    /// (M1) staging round trip. `--no-direct-comm` turns it off (ablation;
    /// byte-identical results either way).
    pub direct_comm: bool,
    /// Declare a silent peer dead after this many milliseconds (`None`
    /// disables liveness monitoring — the in-process default, where a dead
    /// "node" is a panic the driver already surfaces). Multi-process
    /// deployments (`celerity launch`/`worker`) set this so a killed worker
    /// produces an attributed cluster error instead of a hang.
    pub heartbeat_timeout_ms: Option<u64>,
    /// Deterministic comm-fabric chaos plan (`--fault-plan`, see
    /// [`crate::fault::FaultPlan`]). On the TCP fabric faults are injected
    /// at the wire level below the retransmission layer, so a run under an
    /// active plan must still produce byte-identical results; on the
    /// channel fabric drops/delays/dups apply at the message level (no
    /// recovery — for testing detection, not transparency). `kill=` sites
    /// only apply to separate-process workers and are ignored in-process.
    pub fault_plan: Option<crate::fault::FaultPlan>,
    /// Weighted round-robin dispatch between jobs sharing the executor
    /// (default on). Off = a single global FIFO — the fairness ablation,
    /// where a heavy job's backlog head-of-line-blocks light jobs.
    pub fair_share: bool,
    /// Per-job cap on dispatched-but-not-retired instructions in the
    /// executor; 0 means unlimited.
    pub admission_limit: usize,
    /// Per-job fair-share weights, indexed by job id (creation order);
    /// missing entries default to 1.
    pub job_weights: Vec<u32>,
    /// Run the static instruction-graph verifier ([`crate::verify`]) inside
    /// every scheduler core (`--verify`): race/lifetime/coherence/pilot
    /// violations surface as §4.4 runtime errors naming the offending
    /// instruction pair and region. Off by default; the verifier-off cost
    /// is one branch per scheduler batch.
    pub verify: bool,
    /// Keep every compiled instruction and run the performance analyzer
    /// ([`crate::analyze`]) over each job's stream at shutdown
    /// (`--analyze`): per-memory peak-allocation bounds, the cost-weighted
    /// critical path and the lint findings land in
    /// [`NodeReport::analyze`]. Off by default.
    pub analyze: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            num_nodes: 1,
            num_devices: 1,
            host_lanes: 4,
            lookahead: true,
            d2d: true,
            node_hint: SplitHint::D1,
            device_hint: SplitHint::D1,
            registry: Registry::new(),
            transport: Transport::Channel,
            collectives: true,
            direct_comm: true,
            heartbeat_timeout_ms: None,
            fault_plan: None,
            fair_share: true,
            admission_limit: 0,
            job_weights: Vec::new(),
            verify: false,
            analyze: false,
        }
    }
}

impl ClusterConfig {
    /// Fluent construction with defaults for everything not set — the
    /// single place new knobs land, so adding one does not ripple through
    /// every struct-literal call site.
    pub fn builder() -> ClusterConfigBuilder {
        ClusterConfigBuilder { cfg: ClusterConfig::default() }
    }
}

/// Builder for [`ClusterConfig`]; see [`ClusterConfig::builder`].
#[derive(Clone)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

macro_rules! builder_setters {
    ($($(#[$doc:meta])* $name:ident: $ty:ty),* $(,)?) => {
        $(
            $(#[$doc])*
            pub fn $name(mut self, v: $ty) -> Self {
                self.cfg.$name = v;
                self
            }
        )*
    };
}

impl ClusterConfigBuilder {
    builder_setters! {
        num_nodes: u64,
        num_devices: u64,
        host_lanes: usize,
        lookahead: bool,
        d2d: bool,
        node_hint: SplitHint,
        device_hint: SplitHint,
        registry: Registry,
        transport: Transport,
        collectives: bool,
        direct_comm: bool,
        heartbeat_timeout_ms: Option<u64>,
        fault_plan: Option<crate::fault::FaultPlan>,
        fair_share: bool,
        admission_limit: usize,
        job_weights: Vec<u32>,
        verify: bool,
        analyze: bool,
    }

    pub fn build(self) -> ClusterConfig {
        self.cfg
    }
}

impl SchedulerConfig {
    /// Derive one node's scheduler configuration from the cluster
    /// configuration — the single derivation point, so per-job knobs do not
    /// have to be threaded through every spawn site by hand. The `job`
    /// field stays at the default; the scheduler thread stamps it per job
    /// when it lazily creates each job's compiler core.
    pub fn for_node(cfg: &ClusterConfig, node: NodeId) -> SchedulerConfig {
        SchedulerConfig {
            job: JobId(0),
            node,
            num_nodes: cfg.num_nodes,
            num_devices: cfg.num_devices,
            node_hint: cfg.node_hint,
            device_hint: cfg.device_hint,
            d2d: cfg.d2d,
            lookahead: cfg.lookahead,
            horizon_flush: 2,
            collectives: cfg.collectives,
            direct_comm: cfg.direct_comm,
            verify: cfg.verify,
            analyze: cfg.analyze,
        }
    }
}

impl ExecutorConfig {
    /// Derive one node's executor configuration from the cluster
    /// configuration (companion to [`SchedulerConfig::for_node`]).
    pub fn for_node(cfg: &ClusterConfig, node: NodeId) -> ExecutorConfig {
        ExecutorConfig {
            node,
            host_lanes: cfg.host_lanes,
            registry: cfg.registry.clone(),
            heartbeat: cfg
                .heartbeat_timeout_ms
                .map(crate::executor::HeartbeatConfig::from_timeout_ms),
            fair_share: cfg.fair_share,
            admission_limit: cfg.admission_limit,
            job_weights: cfg.job_weights.clone(),
        }
    }
}

/// Per-job result of a cluster run: the job's §4.4 error stream and the
/// fault notices it observed, fully attributed — one job's errors never
/// appear in another job's report.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub job: JobId,
    /// Runtime errors (§4.4) attributed to this job (plus cluster-wide
    /// conditions, which every job observes).
    pub errors: Vec<String>,
    /// Non-fatal comm-fabric fault notices observed by this job.
    pub faults: Vec<String>,
}

/// Per-node result of a cluster run. Executor and scheduler statistics are
/// aggregated over all jobs that ran on the node; `jobs` carries the
/// per-job breakdown of errors and faults.
#[derive(Debug)]
pub struct NodeReport {
    pub node: NodeId,
    pub executor: ExecutorStats,
    pub instructions_generated: u64,
    pub commands_generated: u64,
    pub resizes_emitted: u64,
    pub bytes_allocated: u64,
    pub max_queue_len: usize,
    /// Runtime errors (§4.4) observed on this node (union over jobs).
    pub errors: Vec<String>,
    /// Non-fatal comm-fabric fault notices (corrupt frame rejected,
    /// reconnects, retransmissions). Repaired or contained by the fabric:
    /// reported for observability, never a failure by themselves.
    pub faults: Vec<String>,
    /// Per-job reports, in job-creation order.
    pub jobs: Vec<JobReport>,
    /// Performance-analysis reports, one per job core, in job order —
    /// populated only on [`ClusterConfig::analyze`] runs.
    pub analyze: Vec<crate::analyze::Report>,
}

/// The per-job user-facing queue, mirroring Listing 1's API surface:
/// typed buffer creation + command-group submission + synchronization.
/// Obtained from [`Cluster::create_queue`]; any number of queues (jobs)
/// can share one cluster, each with an isolated id namespace, its own
/// horizons and fences, and an attributed error stream.
///
/// Every fallible operation returns [`QueueError`] instead of panicking:
/// shape/dtype mismatches are caught before any instruction is generated,
/// and §4.4 runtime errors observed while waiting surface as
/// [`QueueError::Runtime`] (they are additionally accumulated into
/// [`NodeReport::errors`]). Only this job's errors ever surface here: the
/// executor routes every event to the job it is attributed to.
pub struct Queue {
    pub node: NodeId,
    pub cfg: ClusterConfig,
    job: JobId,
    tm: TaskManager,
    sched: mpsc::Sender<(JobId, SchedulerMsg)>,
    events: EventHub,
    reports: Arc<Mutex<Vec<JobReport>>>,
    errors: Vec<String>,
    faults: Vec<String>,
    /// How many of `errors` have already been surfaced through a
    /// `Result`; everything beyond this is reported by the next `wait()`.
    errors_reported: usize,
    fence_counter: Arc<AtomicU64>,
}

impl Queue {
    /// The job this queue belongs to.
    pub fn job(&self) -> JobId {
        self.job
    }

    fn send(&self, msg: SchedulerMsg) {
        // A send can only fail after the cluster shut the scheduler thread
        // down, at which point this job's outcome is already sealed.
        let _ = self.sched.send((self.job, msg));
    }

    /// Create a typed virtualized buffer, visible to subsequent tasks.
    /// Contents start *uninitialized*: reading them before a producer task
    /// or [`Queue::init`] is a §4.4 correctness error.
    pub fn create_buffer<T: Elem>(&mut self, name: impl Into<String>, range: Range) -> Buffer<T> {
        let buf = self.tm.create_buffer::<T>(name, range, false);
        self.send(SchedulerMsg::Buffers(self.tm.buffers().clone()));
        buf
    }

    /// Create a typed buffer and supply its full contents in one step.
    pub fn create_buffer_init<T: Elem>(
        &mut self,
        name: impl Into<String>,
        range: Range,
        data: &[T],
    ) -> Result<Buffer<T>, QueueError> {
        let buf = self.create_buffer::<T>(name, range);
        self.init(buf, data)?;
        Ok(buf)
    }

    /// Supply the full contents of a buffer as typed elements. Must happen
    /// before any task consumes the buffer; the length must match the
    /// buffer's index-space size exactly.
    pub fn init<T: Elem>(&mut self, buffer: Buffer<T>, data: &[T]) -> Result<(), QueueError> {
        let info = self.check_typed(buffer)?;
        if data.len() as u64 != info.1 {
            return Err(QueueError::ShapeMismatch {
                buffer: buffer.id(),
                expected_elems: info.1,
                got_elems: data.len() as u64,
            });
        }
        self.tm.mark_host_initialized(buffer.id());
        // Re-announce the pool (host_initialized changed), then materialize
        // the user-memory (M0) allocation with the concrete bytes — ordered
        // through the scheduler pipeline ahead of any consuming task.
        self.send(SchedulerMsg::Buffers(self.tm.buffers().clone()));
        self.send(SchedulerMsg::UserData(UserInit {
            alloc: crate::instruction::user_alloc_id(buffer.id()),
            covers: crate::grid::GridBox::full(buffer.range()),
            elem_size: dtype::elem_size::<T>(),
            bytes: dtype::to_bytes(data),
        }));
        Ok(())
    }

    /// Submit a command group (Listing 1's `q.submit`): the closure
    /// declares typed accessors and the kernel launch on the handler.
    pub fn submit(&mut self, build: impl FnOnce(&mut CommandGroup)) -> Result<TaskId, QueueError> {
        let id = self.tm.submit_group(build)?;
        self.forward_tasks();
        Ok(id)
    }

    /// Submit a pre-built task declaration — the compatibility escape hatch
    /// onto the internal IR (`TaskDecl`) underneath command groups.
    pub fn submit_decl(&mut self, decl: TaskDecl) -> TaskId {
        let id = self.tm.submit(decl);
        self.forward_tasks();
        id
    }

    /// Barrier: wait until everything submitted so far has executed. Any
    /// §4.4 error not yet surfaced through a `Result` — including errors
    /// drained asynchronously by earlier `submit` calls — comes back as
    /// [`QueueError::Runtime`] (each error is reported exactly once; all
    /// errors additionally accumulate into [`NodeReport::errors`]).
    pub fn wait(&mut self) -> Result<(), QueueError> {
        self.tm.barrier();
        self.forward_tasks();
        let side = self.events.wait_epoch(self.job, EpochAction::Barrier);
        self.collect_errors(side);
        if self.errors.len() > self.errors_reported {
            let fresh = self.errors[self.errors_reported..].to_vec();
            self.errors_reported = self.errors.len();
            return Err(QueueError::Runtime(fresh));
        }
        Ok(())
    }

    /// Read back the full contents of a buffer as typed elements
    /// (convenience fence: internally a host task reading the buffer with
    /// an `all` range mapper, followed by a barrier).
    pub fn fence<T: Elem>(&mut self, buffer: Buffer<T>) -> Result<Vec<T>, QueueError> {
        self.check_typed(buffer)?;
        let bytes = self.fence_bytes(buffer.id())?;
        Ok(dtype::from_bytes(&bytes))
    }

    /// Untyped fence: the full buffer contents as raw bytes.
    pub fn fence_bytes(&mut self, buffer: BufferId) -> Result<Vec<u8>, QueueError> {
        let info = match self.tm.buffers().try_get(buffer) {
            Some(info) => info.clone(),
            None => return Err(QueueError::UnknownBuffer(buffer)),
        };
        // The registry is shared across all node threads: namespace the
        // fence task by node so each node's sink closure stays distinct.
        let name = format!(
            "__fence_{}_{}_{}",
            self.node,
            buffer,
            self.fence_counter.fetch_add(1, Ordering::Relaxed)
        );
        let sink: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        let sink_c = sink.clone();
        self.cfg.registry.register_host_task(
            name.clone(),
            Arc::new(move |ctx| {
                *sink_c.lock().expect("fence sink lock poisoned") = ctx.view(0).read_region_bytes();
            }),
        );
        self.submit_decl(TaskDecl::host(name, info.range).read(buffer, RangeMapper::All));
        self.wait()?;
        let bytes = std::mem::take(&mut *sink.lock().expect("fence sink lock poisoned"));
        if bytes.len() as u64 != info.range.size() * info.elem_size as u64 {
            return Err(QueueError::ShapeMismatch {
                buffer,
                expected_elems: info.range.size(),
                got_elems: bytes.len() as u64 / info.elem_size.max(1) as u64,
            });
        }
        Ok(bytes)
    }

    /// TDAG debug diagnostics observed so far (§4.4 uninitialized reads).
    pub fn take_debug_events(&mut self) -> Vec<crate::task::DebugEvent> {
        self.tm.take_debug_events()
    }

    /// Validate a typed handle against the registered buffer metadata;
    /// returns `(elem_size, elems)` on success.
    fn check_typed<T: Elem>(&self, buffer: Buffer<T>) -> Result<(usize, u64), QueueError> {
        let info = self
            .tm
            .buffers()
            .try_get(buffer.id())
            .ok_or(QueueError::UnknownBuffer(buffer.id()))?;
        if info.dtype != T::DTYPE || info.lanes != T::LANES {
            return Err(QueueError::DTypeMismatch {
                buffer: buffer.id(),
                expected: info.dtype,
                expected_lanes: info.lanes,
                got: T::DTYPE,
                got_lanes: T::LANES,
            });
        }
        Ok((info.elem_size, info.range.size()))
    }

    fn forward_tasks(&mut self) {
        for t in self.tm.take_new_tasks() {
            crate::trace::instant(
                self.node.0,
                crate::trace::Track::Main,
                crate::trace::EventKind::TaskSubmit { task: t.id.0 },
            );
            self.send(SchedulerMsg::Task(t));
        }
        // Drain pending error events for this job without blocking.
        while let Some(ev) = self.events.try_recv(self.job) {
            match ev {
                ExecEvent::Error(e) => self.errors.push(e),
                ExecEvent::Fault(f) => self.faults.push(f),
                ExecEvent::Epoch(..) => {}
            }
        }
    }

    fn collect_errors(&mut self, side: Vec<ExecEvent>) {
        for ev in side {
            match ev {
                ExecEvent::Error(e) => self.errors.push(e),
                ExecEvent::Fault(f) => self.faults.push(f),
                ExecEvent::Epoch(..) => {}
            }
        }
    }

    /// End this job: run its shutdown epoch, retire its scheduler core and
    /// deposit its [`JobReport`] with the owning [`Cluster`]. Other jobs on
    /// the cluster keep running. Returns the report (also available later
    /// through [`NodeReport::jobs`]).
    pub fn finish(mut self) -> JobReport {
        self.tm.shutdown();
        self.forward_tasks();
        let side = self.events.wait_epoch(self.job, EpochAction::Shutdown);
        self.collect_errors(side);
        // Retire this job's compiler core; the scheduler thread keeps
        // serving the remaining jobs.
        self.send(SchedulerMsg::Shutdown);
        // This thread's trace events (task submits) live in its
        // thread-local buffer; publish them before the job thread exits.
        crate::trace::flush_thread();
        let report = JobReport { job: self.job, errors: self.errors, faults: self.faults };
        self.reports.lock().expect("report lock poisoned").push(report.clone());
        report
    }
}

/// One node's runtime stack — scheduler thread, executor thread, comm
/// fabric endpoint — shared by any number of concurrently-running jobs.
/// Hand out one [`Queue`] per job with [`Cluster::create_queue`]; when all
/// jobs have [`Queue::finish`]ed, [`Cluster::shutdown`] tears the stack
/// down and returns the aggregated [`NodeReport`].
///
/// SPMD determinism: create queues in the same order on every node, so a
/// job gets the same [`JobId`] — and therefore the same id namespace and
/// comm message tags — cluster-wide.
pub struct Cluster {
    node: NodeId,
    cfg: ClusterConfig,
    sched: SchedulerHandle,
    exec: ExecutorHandle,
    reports: Arc<Mutex<Vec<JobReport>>>,
    next_job: u64,
}

impl Cluster {
    /// Bring up one node's scheduler/executor threads on an
    /// externally-built communicator.
    pub fn new(cfg: &ClusterConfig, node: NodeId, comm: CommRef) -> Cluster {
        let (out_tx, out_rx) = spsc::channel::<SchedulerOut>(4096);
        let sched = SchedulerHandle::spawn(SchedulerConfig::for_node(cfg, node), out_tx);
        let exec = ExecutorHandle::spawn(ExecutorConfig::for_node(cfg, node), comm, out_rx);
        Cluster {
            node,
            cfg: cfg.clone(),
            sched,
            exec,
            reports: Arc::new(Mutex::new(Vec::new())),
            next_job: 0,
        }
    }

    pub fn node(&self) -> NodeId {
        self.node
    }

    /// Create the next job's queue. Jobs are numbered in creation order;
    /// all nodes must create their queues in the same order (SPMD).
    pub fn create_queue(&mut self) -> Queue {
        let job = JobId(self.next_job);
        assert!(job.0 <= JobId::MAX, "too many jobs on one cluster");
        self.next_job += 1;
        // Register before any work is submitted so cluster-wide broadcasts
        // can never miss this job.
        self.exec.events.register(job);
        Queue {
            node: self.node,
            cfg: self.cfg.clone(),
            job,
            tm: TaskManager::with_job(job),
            sched: self.sched.sender(),
            events: self.exec.events.clone(),
            reports: self.reports.clone(),
            errors: Vec::new(),
            faults: Vec::new(),
            errors_reported: 0,
            fence_counter: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Tear down the node's runtime stack after every queue has finished
    /// and aggregate scheduler/executor statistics across jobs.
    pub fn shutdown(self) -> NodeReport {
        // Dropping the handle's sender ends the scheduler thread (flushing
        // any job cores that never saw an explicit shutdown); the executor
        // then sees its inbox close and exits once drained.
        let cores = self.sched.join();
        let executor = self.exec.join();
        let mut jobs = std::mem::take(&mut *self.reports.lock().expect("report lock poisoned"));
        jobs.sort_by_key(|r| r.job);
        // Late events (e.g. a fault notice raced with the last fence) are
        // still in the hub; fold them into the owning job's report.
        for r in &mut jobs {
            while let Some(ev) = self.exec.events.try_recv(r.job) {
                match ev {
                    ExecEvent::Error(e) => r.errors.push(e),
                    ExecEvent::Fault(f) => r.faults.push(f),
                    ExecEvent::Epoch(..) => {}
                }
            }
        }
        crate::trace::flush_thread();
        let mut report = NodeReport {
            node: self.node,
            executor,
            instructions_generated: 0,
            commands_generated: 0,
            resizes_emitted: 0,
            bytes_allocated: 0,
            max_queue_len: 0,
            errors: jobs.iter().flat_map(|j| j.errors.iter().cloned()).collect(),
            faults: jobs.iter().flat_map(|j| j.faults.iter().cloned()).collect(),
            jobs,
            analyze: Vec::new(),
        };
        for (_, core) in &cores {
            report.instructions_generated += core.instructions_generated;
            report.commands_generated += core.commands_generated;
            report.resizes_emitted += core.idag().resizes_emitted;
            report.bytes_allocated += core.idag().bytes_allocated;
            report.max_queue_len = report.max_queue_len.max(core.max_queue_len);
            if self.cfg.analyze {
                report.analyze.push(core.analyze(&crate::analyze::AnalyzeConfig::default()));
            }
        }
        report
    }
}

/// Run one node of a cluster against an externally-built communicator and
/// return its report: a one-job cluster wrapping the multi-tenant stack.
/// This is the per-process entry point of multi-process deployments
/// (`celerity worker` builds a
/// [`TcpCommunicator`](crate::comm::TcpCommunicator) from its peer list and
/// calls this); [`run_cluster`] uses it for every node thread.
pub fn run_node<F>(cfg: &ClusterConfig, node: NodeId, comm: CommRef, program: F) -> NodeReport
where
    F: Fn(&mut Queue),
{
    let mut cluster = Cluster::new(cfg, node, comm);
    let mut q = cluster.create_queue();
    program(&mut q);
    q.finish();
    cluster.shutdown()
}


/// Run `program` SPMD on an in-process cluster: one OS thread per node,
/// each with its own scheduler/executor stack, connected by the fabric
/// selected in [`ClusterConfig::transport`]. Returns per-node reports.
///
/// Panics if the transport cannot be brought up (e.g. the loopback TCP
/// mesh fails to bind); use [`try_run_cluster`] where that should surface
/// as an `io::Result` instead — the `celerity run` CLI does, printing a
/// friendly error and exiting 2.
pub fn run_cluster<F>(cfg: ClusterConfig, program: F) -> Vec<NodeReport>
where
    F: Fn(&mut Queue) + Send + Sync + 'static,
{
    try_run_cluster(cfg, program).expect("bind cluster transport")
}

/// [`run_cluster`] with transport-setup failures propagated as
/// `io::Result` instead of a panic.
pub fn try_run_cluster<F>(cfg: ClusterConfig, program: F) -> std::io::Result<Vec<NodeReport>>
where
    F: Fn(&mut Queue) + Send + Sync + 'static,
{
    let program = Arc::new(program);
    spawn_nodes(cfg, move |cfg, node, comm| {
        let program = program.clone();
        run_node(cfg, node, comm, move |q| program(q))
    })
}

/// A job's user program in a multi-tenant run: executed SPMD like
/// [`run_cluster`]'s, once per node, against that job's queue.
pub type JobProgram = Arc<dyn Fn(&mut Queue) + Send + Sync>;

/// Run several programs concurrently as jobs of one shared cluster: on
/// every node, one [`Cluster`] is brought up, one [`Queue`] per program is
/// created (in program order, so job ids agree cluster-wide), and each
/// program runs on its own thread against its queue. Scheduler compilation
/// interleaves across the jobs and the executor arbitrates shared lanes
/// and memory between them; each job keeps its own id namespace, horizons,
/// fences and attributed error stream.
pub fn run_cluster_jobs(
    cfg: ClusterConfig,
    programs: Vec<JobProgram>,
) -> std::io::Result<Vec<NodeReport>> {
    spawn_nodes(cfg, move |cfg, node, comm| {
        let mut cluster = Cluster::new(cfg, node, comm);
        let queues: Vec<Queue> = programs.iter().map(|_| cluster.create_queue()).collect();
        let joins: Vec<_> = queues
            .into_iter()
            .zip(programs.iter().cloned())
            .map(|(mut q, p)| {
                std::thread::Builder::new()
                    .name(format!("celerity-job-{}-{}", node.0, q.job()))
                    .spawn(move || {
                        p(&mut q);
                        q.finish();
                    })
                    .expect("spawn job thread")
            })
            .collect();
        for j in joins {
            j.join().expect("job thread panicked");
        }
        cluster.shutdown()
    })
}

/// Shared SPMD bring-up: build the transport fabric, then run `node_main`
/// once per node (on this thread for a single node, on one thread per node
/// otherwise).
fn spawn_nodes<F>(cfg: ClusterConfig, node_main: F) -> std::io::Result<Vec<NodeReport>>
where
    F: Fn(&ClusterConfig, NodeId, CommRef) -> NodeReport + Send + Sync + 'static,
{
    assert!(cfg.num_nodes >= 1);
    if cfg.num_nodes == 1 {
        let comm: CommRef = Arc::new(NullCommunicator(NodeId(0)));
        return Ok(vec![node_main(&cfg, NodeId(0), comm)]);
    }
    let mut cfg = cfg;
    if cfg.fault_plan.as_ref().map_or(false, |p| p.is_active())
        && cfg.heartbeat_timeout_ms.is_none()
    {
        // Tail-loss recovery rides on heartbeat beacons (the ack-stall
        // nudge re-sends unacked frames): an active chaos plan forces
        // liveness monitoring on.
        cfg.heartbeat_timeout_ms = Some(5_000);
    }
    let plan = cfg.fault_plan.as_ref().filter(|p| p.is_active());
    let comms: Vec<CommRef> = match cfg.transport {
        Transport::Channel => ChannelWorld::new(cfg.num_nodes)
            .communicators()
            .into_iter()
            .map(|c| match plan {
                // Message-level chaos: no wire format to corrupt, no
                // retransmission — detection testing, not transparency.
                Some(p) => Arc::new(crate::fault::FaultyCommunicator::wrap(
                    Box::new(c),
                    p.clone(),
                )) as CommRef,
                None => Arc::new(c) as CommRef,
            })
            .collect(),
        Transport::Tcp => TcpWorld::bind_local(cfg.num_nodes)?
            .communicators()
            .into_iter()
            .map(|mut c| {
                if let Some(p) = plan {
                    // Wire-level chaos below the retransmission layer: the
                    // fabric repairs the damage transparently.
                    c.set_fault_plan(p);
                }
                Arc::new(c) as CommRef
            })
            .collect(),
    };
    let node_main = Arc::new(node_main);
    let mut joins = Vec::new();
    for (i, comm) in comms.into_iter().enumerate() {
        let cfg = cfg.clone();
        let node_main = node_main.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("celerity-node-{i}"))
                .spawn(move || node_main(&cfg, NodeId(i as u64), comm))
                .expect("spawn node thread"),
        );
    }
    Ok(joins
        .into_iter()
        .map(|j| j.join().expect("node thread panicked"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtype::DType;
    use crate::executor::KernelCtx;
    use crate::grid::Point;

    fn registry_iota_double() -> Registry {
        let registry = Registry::new();
        registry.register_kernel(
            "iota",
            Arc::new(|ctx: &KernelCtx| {
                let v = ctx.view(0);
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    v.write_f32(Point::d1(i), i as f32);
                }
            }),
        );
        registry.register_kernel(
            "sum_all",
            Arc::new(|ctx: &KernelCtx| {
                // out[i] = sum(in[j] for all j) + in[i]; requires the full
                // buffer (all-gather pattern, like N-body).
                let inp = ctx.view(0);
                let out = ctx.view(1);
                let n = inp.binding.region.bounding_box().max[0];
                let mut total = 0f32;
                for j in 0..n {
                    total += inp.read_f32(Point::d1(j));
                }
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    out.write_f32(Point::d1(i), total + inp.read_f32(Point::d1(i)));
                }
            }),
        );
        registry
    }

    #[test]
    fn single_node_two_devices_numerics() {
        let cfg = ClusterConfig {
            num_devices: 2,
            registry: registry_iota_double(),
            ..Default::default()
        };
        let result: Arc<Mutex<Vec<f32>>> = Arc::new(Mutex::new(vec![]));
        let result_c = result.clone();
        let reports = run_cluster(cfg, move |q| {
            let n = Range::d1(128);
            let a = q.create_buffer::<f32>("A", n);
            let b = q.create_buffer::<f32>("B", n);
            q.submit(|cgh| {
                cgh.discard_write(a, RangeMapper::OneToOne);
                cgh.parallel_for("iota", n);
            })
            .expect("submit iota");
            q.submit(|cgh| {
                cgh.read(a, RangeMapper::All);
                cgh.discard_write(b, RangeMapper::OneToOne);
                cgh.parallel_for("sum_all", n);
            })
            .expect("submit sum_all");
            *result_c.lock().unwrap() = q.fence(b).expect("fence");
        });
        assert_eq!(reports.len(), 1);
        assert!(reports[0].errors.is_empty(), "{:?}", reports[0].errors);
        let got = result.lock().unwrap();
        let total: f32 = (0..128).map(|i| i as f32).sum();
        for i in 0..128 {
            assert_eq!(got[i], total + i as f32, "element {i}");
        }
    }

    /// The flagship integration test: a 4-node × 2-device cluster running
    /// an all-gather pattern where every node needs every other node's
    /// data — exercising push/await-push, pilots, receive arbitration and
    /// multi-device coherence, with numerics checked on every node.
    #[test]
    fn four_nodes_all_gather_numerics() {
        let cfg = ClusterConfig {
            num_nodes: 4,
            num_devices: 2,
            registry: registry_iota_double(),
            ..Default::default()
        };
        let results: Arc<Mutex<Vec<(u64, Vec<f32>)>>> = Arc::new(Mutex::new(vec![]));
        let results_c = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let n = Range::d1(256);
            let a = q.create_buffer::<f32>("A", n);
            let b = q.create_buffer::<f32>("B", n);
            q.submit(|cgh| {
                cgh.discard_write(a, RangeMapper::OneToOne);
                cgh.parallel_for("iota", n);
            })
            .expect("submit iota");
            q.submit(|cgh| {
                cgh.read(a, RangeMapper::All);
                cgh.discard_write(b, RangeMapper::OneToOne);
                cgh.parallel_for("sum_all", n);
            })
            .expect("submit sum_all");
            let got = q.fence(b).expect("fence");
            results_c.lock().unwrap().push((q.node.0, got));
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "node {}: {:?}", r.node, r.errors);
        }
        let results = results.lock().unwrap();
        assert_eq!(results.len(), 4);
        let total: f32 = (0..256).map(|i| i as f32).sum();
        for (node, got) in results.iter() {
            assert_eq!(got.len(), 256);
            for i in 0..256 {
                assert_eq!(got[i], total + i as f32, "node {node} element {i}");
            }
        }
    }

    /// Iterated exchange: two nodes ping-pong through multiple timesteps,
    /// verifying steady-state communication (replicas invalidated by every
    /// write) and horizon pruning under a real executor.
    #[test]
    fn two_nodes_iterated_allgather() {
        let registry = registry_iota_double();
        registry.register_kernel(
            "relax",
            Arc::new(|ctx: &KernelCtx| {
                // a'[i] = (sum of all a) / n  + small identity part
                let inp = ctx.view(0);
                let out = ctx.view(1);
                let n = inp.binding.region.bounding_box().max[0];
                let mut total = 0f32;
                for j in 0..n {
                    total += inp.read_f32(Point::d1(j));
                }
                let mean = total / n as f32;
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    out.write_f32(Point::d1(i), 0.5 * inp.read_f32(Point::d1(i)) + 0.5 * mean);
                }
            }),
        );
        let cfg = ClusterConfig {
            num_nodes: 2,
            num_devices: 2,
            registry,
            ..Default::default()
        };
        let results: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(vec![]));
        let results_c = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let n = Range::d1(64);
            let a = q.create_buffer::<f32>("A", n);
            let b = q.create_buffer::<f32>("B", n);
            q.submit(|cgh| {
                cgh.discard_write(a, RangeMapper::OneToOne);
                cgh.parallel_for("iota", n);
            })
            .expect("submit iota");
            for _ in 0..5 {
                q.submit(|cgh| {
                    cgh.read(a, RangeMapper::All);
                    cgh.discard_write(b, RangeMapper::OneToOne);
                    cgh.parallel_for("relax", n);
                })
                .expect("submit relax a->b");
                q.submit(|cgh| {
                    cgh.read(b, RangeMapper::All);
                    cgh.discard_write(a, RangeMapper::OneToOne);
                    cgh.parallel_for("relax", n);
                })
                .expect("submit relax b->a");
            }
            // NB: fence first, then lock — taking the shared mutex before
            // the fence would serialize nodes that must communicate.
            let got = q.fence(a).expect("fence");
            results_c.lock().unwrap().push(got);
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "{:?}", r.errors);
        }
        // Reference computation.
        let mut reference: Vec<f32> = (0..64).map(|i| i as f32).collect();
        for _ in 0..10 {
            let mean = reference.iter().sum::<f32>() / 64.0;
            reference = reference.iter().map(|v| 0.5 * v + 0.5 * mean).collect();
        }
        let results = results.lock().unwrap();
        assert_eq!(results.len(), 2);
        for got in results.iter() {
            for i in 0..64 {
                assert!(
                    (got[i] - reference[i]).abs() < 1e-3,
                    "element {i}: {} vs {}",
                    got[i],
                    reference[i]
                );
            }
        }
    }

    #[test]
    fn reports_carry_scheduler_stats() {
        let cfg = ClusterConfig {
            registry: registry_iota_double(),
            ..Default::default()
        };
        let reports = run_cluster(cfg, |q| {
            let n = Range::d1(32);
            let a = q.create_buffer::<f32>("A", n);
            q.submit(|cgh| {
                cgh.discard_write(a, RangeMapper::OneToOne);
                cgh.parallel_for("iota", n);
            })
            .expect("submit iota");
        });
        let r = &reports[0];
        assert!(r.instructions_generated > 0);
        assert!(r.commands_generated > 0);
        assert!(r.executor.retired as u64 >= r.instructions_generated);
    }

    // ── typed round-trips (new-API coverage) ────────────────────────────

    fn registry_typed() -> Registry {
        let registry = Registry::new();
        registry.register_kernel(
            "scale_f32",
            Arc::new(|ctx: &KernelCtx| {
                let inp = ctx.view(0);
                let out = ctx.view(1);
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    out.write_f32(Point::d1(i), inp.read_f32(Point::d1(i)) * 2.0);
                }
            }),
        );
        registry.register_kernel(
            "shift_i32",
            Arc::new(|ctx: &KernelCtx| {
                let inp = ctx.view(0);
                let out = ctx.view(1);
                for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                    out.write_i32(Point::d1(i), inp.read_i32(Point::d1(i)) + 7);
                }
            }),
        );
        registry
    }

    fn typed_roundtrip(num_nodes: u64) {
        let cfg = ClusterConfig {
            num_nodes,
            num_devices: 2,
            registry: registry_typed(),
            ..Default::default()
        };
        let results: Arc<Mutex<Vec<(Vec<f32>, Vec<i32>)>>> = Arc::new(Mutex::new(vec![]));
        let results_c = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let n = Range::d1(96);
            let src: Vec<f32> = (0..96).map(|i| i as f32 * 0.5).collect();
            let isrc: Vec<i32> = (0..96).map(|i| i - 48).collect();
            let a = q.create_buffer_init("A", n, &src).expect("init A");
            let b = q.create_buffer::<f32>("B", n);
            let c = q.create_buffer_init("C", n, &isrc).expect("init C");
            let d = q.create_buffer::<i32>("D", n);
            q.submit(|cgh| {
                cgh.read(a, RangeMapper::OneToOne);
                cgh.discard_write(b, RangeMapper::OneToOne);
                cgh.parallel_for("scale_f32", n);
            })
            .expect("submit scale_f32");
            q.submit(|cgh| {
                cgh.read(c, RangeMapper::OneToOne);
                cgh.discard_write(d, RangeMapper::OneToOne);
                cgh.parallel_for("shift_i32", n);
            })
            .expect("submit shift_i32");
            let fb = q.fence(b).expect("fence f32");
            let fd = q.fence(d).expect("fence i32");
            results_c.lock().unwrap().push((fb, fd));
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "node {}: {:?}", r.node, r.errors);
        }
        let results = results.lock().unwrap();
        assert_eq!(results.len(), num_nodes as usize);
        for (fb, fd) in results.iter() {
            for i in 0..96usize {
                assert_eq!(fb[i], i as f32, "f32 element {i}");
                assert_eq!(fd[i], i as i32 - 48 + 7, "i32 element {i}");
            }
        }
    }

    #[test]
    fn typed_init_kernel_fence_roundtrip_single_node() {
        typed_roundtrip(1);
    }

    #[test]
    fn typed_init_kernel_fence_roundtrip_two_nodes() {
        typed_roundtrip(2);
    }

    #[test]
    fn dtype_mismatched_fence_returns_err() {
        let cfg = ClusterConfig {
            registry: registry_typed(),
            ..Default::default()
        };
        let reports = run_cluster(cfg, |q| {
            let n = Range::d1(16);
            let a = q.create_buffer::<f32>("A", n);
            // Forge an i32-typed view of the f32 buffer: the queue must
            // reject it with a typed error, not panic.
            let forged: Buffer<i32> = Buffer::from_raw(a.id(), a.range());
            match q.fence(forged) {
                Err(QueueError::DTypeMismatch { buffer, expected, got, .. }) => {
                    assert_eq!(buffer, a.id());
                    assert_eq!(expected, DType::F32);
                    assert_eq!(got, DType::I32);
                }
                other => panic!("expected DTypeMismatch, got {other:?}"),
            }
            // Same for typed init through a forged handle.
            assert!(matches!(
                q.init(forged, &[0i32; 16]),
                Err(QueueError::DTypeMismatch { .. })
            ));
        });
        assert!(reports[0].errors.is_empty(), "{:?}", reports[0].errors);
    }

    #[test]
    fn shape_mismatched_init_returns_err() {
        let cfg = ClusterConfig {
            registry: registry_typed(),
            ..Default::default()
        };
        let reports = run_cluster(cfg, |q| {
            let n = Range::d1(32);
            let a = q.create_buffer::<f32>("A", n);
            match q.init(a, &[1.0f32; 31]) {
                Err(QueueError::ShapeMismatch { expected_elems, got_elems, .. }) => {
                    assert_eq!(expected_elems, 32);
                    assert_eq!(got_elems, 31);
                }
                other => panic!("expected ShapeMismatch, got {other:?}"),
            }
            // Unknown buffers are typed errors too.
            let ghost: Buffer<f32> = Buffer::from_raw(BufferId(999), n);
            assert!(matches!(
                q.fence(ghost),
                Err(QueueError::UnknownBuffer(BufferId(999)))
            ));
            // A command group without a launch is rejected before reaching
            // the TDAG.
            assert!(matches!(
                q.submit(|cgh| {
                    cgh.read(a, RangeMapper::All);
                }),
                Err(QueueError::IncompleteCommandGroup)
            ));
        });
        assert!(reports[0].errors.is_empty(), "{:?}", reports[0].errors);
    }
}
