//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! L3 hot path.
//!
//! Python runs exactly once (`make artifacts`): `python/compile/aot.py`
//! lowers the JAX/Pallas kernels to HLO *text* plus a manifest. This module
//! parses the manifest, compiles each artifact on the PJRT CPU client, and
//! exposes typed executables the device-kernel lanes call — Python is never
//! on the request path.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

// The element type of a kernel argument is the same `DType` the buffer
// registry and accessor bindings use — one definition for the whole stack.
pub use crate::dtype::DType;

/// Shape + dtype of one kernel input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }

    fn parse(s: &str) -> Result<ArgSpec> {
        let (k, d) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("bad arg spec '{s}'"))?;
        let dtype = DType::parse(k).ok_or_else(|| anyhow!("unsupported dtype '{k}'"))?;
        let dims = d
            .split('x')
            .map(|x| x.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSpec { dtype, dims })
    }
}

/// One compiled kernel artifact.
pub struct PjrtKernel {
    pub name: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the PJRT CPU client is thread-safe; executions from multiple lane
// threads are supported (each execute call marshals its own buffers). The
// xla crate merely lacks the auto-trait because of raw pointers.
unsafe impl Send for PjrtKernel {}
unsafe impl Sync for PjrtKernel {}

/// Argument payload for [`PjrtKernel::call`].
pub enum ArgBytes<'a> {
    /// Dense row-major f32/i32 bytes (from a `BindingView`).
    Bytes(&'a [u8]),
    /// A scalar parameter, expanded to the declared (1,) i32 spec.
    ScalarI32(i32),
}

impl PjrtKernel {
    /// Execute with positional arguments; returns dense row-major bytes per
    /// output. Input byte lengths may be *shorter* than the spec (edge
    /// chunks, growing buffers); they are zero-padded at the tail, matching
    /// the zero-boundary / masked-history conventions of the kernels.
    pub fn call(&self, args: &[ArgBytes]) -> Result<Vec<Vec<u8>>> {
        if args.len() != self.inputs.len() {
            bail!(
                "kernel '{}' expects {} args, got {}",
                self.name,
                self.inputs.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (spec, arg) in self.inputs.iter().zip(args) {
            let lit = match (spec.dtype, arg) {
                (DType::F32, ArgBytes::Bytes(bytes)) => {
                    let mut vals = vec![0f32; spec.elements()];
                    let n = bytes.len() / 4;
                    if n > vals.len() {
                        bail!("kernel '{}': arg too large ({n} > {})", self.name, vals.len());
                    }
                    for (i, c) in bytes.chunks_exact(4).enumerate() {
                        vals[i] = f32::from_ne_bytes(c.try_into().expect("4-byte chunk"));
                    }
                    let dims: Vec<i64> = spec.dims.iter().map(|d| *d as i64).collect();
                    xla::Literal::vec1(&vals).reshape(&dims)?
                }
                (DType::I32, ArgBytes::ScalarI32(v)) => {
                    let dims: Vec<i64> = spec.dims.iter().map(|d| *d as i64).collect();
                    xla::Literal::vec1(&[*v]).reshape(&dims)?
                }
                (DType::I32, ArgBytes::Bytes(bytes)) => {
                    let mut vals = vec![0i32; spec.elements()];
                    for (i, c) in bytes.chunks_exact(4).enumerate() {
                        vals[i] = i32::from_ne_bytes(c.try_into().expect("4-byte chunk"));
                    }
                    let dims: Vec<i64> = spec.dims.iter().map(|d| *d as i64).collect();
                    xla::Literal::vec1(&vals).reshape(&dims)?
                }
                (DType::F32, ArgBytes::ScalarI32(_)) => {
                    bail!("kernel '{}': scalar passed for f32 arg", self.name)
                }
                (DType::F64 | DType::U32, _) => bail!(
                    "kernel '{}': dtype {} has no PJRT marshalling path yet",
                    self.name,
                    spec.dtype
                ),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack n-tuples.
        let elems = result.to_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for (lit, spec) in elems.iter().zip(&self.outputs) {
            let bytes = match spec.dtype {
                DType::F32 => {
                    let vals = lit.to_vec::<f32>()?;
                    let mut b = Vec::with_capacity(vals.len() * 4);
                    for v in vals {
                        b.extend_from_slice(&v.to_ne_bytes());
                    }
                    b
                }
                DType::I32 => {
                    let vals = lit.to_vec::<i32>()?;
                    let mut b = Vec::with_capacity(vals.len() * 4);
                    for v in vals {
                        b.extend_from_slice(&v.to_ne_bytes());
                    }
                    b
                }
                DType::F64 | DType::U32 => bail!(
                    "kernel '{}': dtype {} has no PJRT marshalling path yet",
                    self.name,
                    spec.dtype
                ),
            };
            out.push(bytes);
        }
        Ok(out)
    }
}

/// The PJRT runtime client: a CPU PJRT client plus the compiled artifact
/// set loaded from a manifest.
pub struct RuntimeClient {
    kernels: HashMap<String, Arc<PjrtKernel>>,
    pub platform: String,
}

impl RuntimeClient {
    /// Load and compile every artifact listed in `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<RuntimeClient> {
        let dir = dir.as_ref();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt (run `make artifacts`)", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        let platform = client.platform_name();
        let mut kernels = HashMap::new();
        for line in manifest.lines().filter(|l| !l.trim().is_empty()) {
            let mut parts = line.split('\t');
            let name = parts.next().ok_or_else(|| anyhow!("bad manifest line"))?;
            let file = parts.next().ok_or_else(|| anyhow!("bad manifest line"))?;
            let ins = parts
                .next()
                .and_then(|s| s.strip_prefix("in="))
                .ok_or_else(|| anyhow!("bad manifest line"))?;
            let outs = parts
                .next()
                .and_then(|s| s.strip_prefix("out="))
                .ok_or_else(|| anyhow!("bad manifest line"))?;
            let inputs = ins.split(',').map(ArgSpec::parse).collect::<Result<Vec<_>>>()?;
            let outputs = outs.split(',').map(ArgSpec::parse).collect::<Result<Vec<_>>>()?;
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            kernels.insert(
                name.to_string(),
                Arc::new(PjrtKernel { name: name.to_string(), inputs, outputs, exe }),
            );
        }
        Ok(RuntimeClient { kernels, platform })
    }

    pub fn kernel(&self, name: &str) -> Option<Arc<PjrtKernel>> {
        self.kernels.get(name).cloned()
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.kernels.keys().map(|s| s.as_str()).collect()
    }
}

/// Default artifacts directory (workspace-relative).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = default_artifacts_dir();
        d.join("manifest.txt").exists().then_some(d)
    }

    #[test]
    fn argspec_parsing() {
        assert_eq!(
            ArgSpec::parse("f32:256x3").unwrap(),
            ArgSpec { dtype: DType::F32, dims: vec![256, 3] }
        );
        assert_eq!(
            ArgSpec::parse("i32:1").unwrap(),
            ArgSpec { dtype: DType::I32, dims: vec![1] }
        );
        // f64 manifests parse with the unified DType (8-byte scalars)...
        assert_eq!(ArgSpec::parse("f64:2").unwrap().bytes(), 16);
        // ...but unknown dtypes are still rejected.
        assert!(ArgSpec::parse("f16:2").is_err());
        assert_eq!(ArgSpec::parse("f32:8x4").unwrap().bytes(), 128);
    }

    #[test]
    fn loads_and_executes_nbody_update() {
        // Requires `make artifacts`; skipped otherwise so `cargo test`
        // stays green on a fresh checkout.
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = RuntimeClient::load(&dir).expect("load artifacts");
        let k = rt.kernel("nbody_update").expect("nbody_update");
        // p' = p + v*dt with dt = 1e-3
        let c = k.inputs[0].dims[0];
        let v: Vec<u8> = (0..c * 3).flat_map(|_| 1f32.to_ne_bytes()).collect();
        let p: Vec<u8> = (0..c * 3).flat_map(|_| 2f32.to_ne_bytes()).collect();
        let out = k
            .call(&[ArgBytes::Bytes(&v), ArgBytes::Bytes(&p)])
            .expect("execute");
        assert_eq!(out.len(), 1);
        let first = f32::from_ne_bytes(out[0][0..4].try_into().unwrap());
        assert!((first - 2.001).abs() < 1e-6, "{first}");
    }

    #[test]
    fn pjrt_timestep_matches_manifest_shapes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = RuntimeClient::load(&dir).expect("load artifacts");
        let k = rt.kernel("nbody_timestep").expect("nbody_timestep");
        assert_eq!(k.inputs.len(), 3);
        assert_eq!(k.inputs[2], ArgSpec { dtype: DType::I32, dims: vec![1] });
        let n = k.inputs[0].dims[0];
        let c = k.inputs[1].dims[0];
        let p: Vec<u8> = (0..n * 3).flat_map(|i| ((i % 7) as f32).to_ne_bytes()).collect();
        let v: Vec<u8> = (0..c * 3).flat_map(|_| 0f32.to_ne_bytes()).collect();
        let out = k
            .call(&[ArgBytes::Bytes(&p), ArgBytes::Bytes(&v), ArgBytes::ScalarI32(0)])
            .expect("execute");
        assert_eq!(out[0].len(), c * 3 * 4);
        // Forces on distinct bodies are finite.
        for chunk in out[0].chunks_exact(4) {
            let f = f32::from_ne_bytes(chunk.try_into().unwrap());
            assert!(f.is_finite());
        }
    }
}
