//! `celerity` CLI: graph dumps, quick simulations, and live cluster runs.
//!
//! ```text
//! celerity graph  --app nbody --nodes 2 --devices 2 --dump tdag,cdag,idag
//! celerity sim    --app rsim  --nodes 8 --devices 4 [--baseline] [--no-lookahead]
//! celerity run    --app wavesim --nodes 4 --transport tcp|channel [--jobs 2] [--trace out.json]
//! celerity worker --app wavesim --node 1 --peers 127.0.0.1:7700,127.0.0.1:7701
//! celerity launch -n 4 -- nbody --steps 4
//! ```
//!
//! `graph` prints Graphviz dot for the requested intermediate
//! representations of the chosen application (Fig 2 / Fig 4 artifacts);
//! `sim` runs the discrete-event cluster simulator and reports the virtual
//! makespan (one row of Fig 6); `run` executes the app on the live
//! in-process cluster with real bytes over the chosen transport; `worker`
//! runs ONE node of a multi-process cluster over TCP — launch one worker
//! per node with the same `--peers` list (order defines node ids) and
//! compare the printed fence digests, which must agree across nodes and
//! with a 1-node `run`; `launch` does all of that in one command — port
//! allocation, worker spawning, prefixed log streaming, digest
//! cross-checking and exit-code aggregation — with worker heartbeats on
//! so a killed node fails the whole run instead of hanging it.

use celerity::analyze::{analyze_stream, AnalyzeConfig, LintConfig, LintLevel};
use celerity::apps;
use celerity::command::{CdagGenerator, SplitHint};
use celerity::comm::{CommRef, TcpCommunicator, Transport};
use celerity::driver::{run_cluster_jobs, run_node, try_run_cluster, ClusterConfig, JobProgram, Queue};
use celerity::grid::{GridBox, Range, Region};
use celerity::instruction::{IdagConfig, IdagGenerator};
use celerity::launch::{self, LaunchConfig};
use celerity::scheduler::{Scheduler, SchedulerConfig};
use celerity::sim::{simulate, ExecModel, SimConfig};
use celerity::task::{QueueError, RangeMapper, TaskManager};
use celerity::trace;
use celerity::util::NodeId;
use std::sync::{Arc, Mutex};

fn build_app(tm: &mut TaskManager, app: &str, steps: u64) {
    match app {
        "nbody" => {
            let range = Range::d1(4096);
            let p = tm.create_buffer::<[f32; 3]>("P", range, true);
            let v = tm.create_buffer::<[f32; 3]>("V", range, true);
            for _ in 0..steps {
                tm.submit_group(|cgh| {
                    cgh.read(p, RangeMapper::All);
                    cgh.read_write(v, RangeMapper::OneToOne);
                    cgh.parallel_for("timestep", range).work_per_item(4096.0 * 20.0);
                })
                .expect("submit timestep");
                tm.submit_group(|cgh| {
                    cgh.read(v, RangeMapper::OneToOne);
                    cgh.read_write(p, RangeMapper::OneToOne);
                    cgh.parallel_for("update", range).work_per_item(2.0);
                })
                .expect("submit update");
            }
        }
        "rsim" => {
            let width = 4096u64;
            let r = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
            let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
            for t in 1..steps {
                let prev = Region::from(GridBox::d2((0, 0), (t, width)));
                tm.submit_group(|cgh| {
                    cgh.read(r, RangeMapper::Fixed(prev));
                    cgh.read(vis, RangeMapper::All);
                    cgh.write(r, RangeMapper::RowSlice(t));
                    cgh.parallel_for("radiosity", Range::d1(width))
                        .work_per_item(t as f64 * 100.0);
                })
                .expect("submit radiosity");
            }
        }
        "wavesim" => {
            let range = Range::d2(1024, 256);
            let bufs = [
                tm.create_buffer::<f32>("U0", range, true),
                tm.create_buffer::<f32>("U1", range, true),
                tm.create_buffer::<f32>("U2", range, true),
            ];
            for s in 0..steps as usize {
                let (p, c, n) = (bufs[s % 3], bufs[(s + 1) % 3], bufs[(s + 2) % 3]);
                tm.submit_group(|cgh| {
                    cgh.read(p, RangeMapper::Neighborhood(Range::d2(1, 0)));
                    cgh.read(c, RangeMapper::Neighborhood(Range::d2(1, 0)));
                    cgh.write(n, RangeMapper::OneToOne);
                    cgh.parallel_for("wavesim", range).work_per_item(10.0);
                })
                .expect("submit wavesim");
            }
        }
        other => {
            eprintln!("unknown app '{other}' (expected nbody|rsim|wavesim)");
            std::process::exit(2);
        }
    }
}

/// Submit the chosen app on a live queue and fence its result buffer.
/// Runtime failures (§4.4 errors, heartbeat-detected peer deaths) come
/// back as `Err` so the caller exits with an attributed message instead
/// of a panic backtrace.
fn run_live_app(q: &mut Queue, app: &str, steps: u64) -> Result<Vec<u8>, QueueError> {
    match app {
        "nbody" => {
            let (p, _v) = apps::nbody::submit(q, 1024, steps as usize)?;
            q.fence_bytes(p.id())
        }
        "rsim" => {
            let (r, _vis) = apps::rsim::submit(q, steps.max(2), 256, false)?;
            q.fence_bytes(r.id())
        }
        "wavesim" => {
            let out = apps::wavesim::submit(q, 64, 64, steps as usize)?;
            q.fence_bytes(out.id())
        }
        other => {
            eprintln!("unknown app '{other}' (expected nbody|rsim|wavesim)");
            std::process::exit(2);
        }
    }
}

/// FNV-1a digest of a fence result — cheap cross-process comparison.
fn digest(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn arg(args: &[String], key: &str, default: &str) -> String {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

/// Parse a numeric flag with a friendly error instead of a panic.
fn num_arg(args: &[String], key: &str, default: &str) -> u64 {
    let raw = arg(args, key, default);
    raw.parse().unwrap_or_else(|_| {
        eprintln!("celerity: invalid {key} '{raw}' (expected a non-negative integer)");
        std::process::exit(2);
    })
}

/// Optional flag: `None` when absent.
fn opt_arg(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

/// Every value of a repeatable flag, in order of appearance
/// (`--deny alloc-churn --deny staged-copy-on-direct-path`).
fn multi_arg(args: &[String], key: &str) -> Vec<String> {
    args.iter()
        .enumerate()
        .filter(|(_, a)| *a == key)
        .filter_map(|(i, _)| args.get(i + 1).cloned())
        .collect()
}

fn opt_num_arg(args: &[String], key: &str) -> Option<u64> {
    let raw = opt_arg(args, key)?;
    Some(raw.parse().unwrap_or_else(|_| {
        eprintln!("celerity: invalid {key} '{raw}' (expected a non-negative integer)");
        std::process::exit(2);
    }))
}

/// `--fault-plan "seed=7 drop=0.01 ..."`, falling back to the
/// `CELERITY_FAULT_PLAN` environment variable. Exits 2 on a malformed plan.
fn fault_plan_arg(args: &[String]) -> Option<celerity::fault::FaultPlan> {
    let raw = opt_arg(args, "--fault-plan")
        .or_else(|| std::env::var("CELERITY_FAULT_PLAN").ok().filter(|s| !s.trim().is_empty()))?;
    match celerity::fault::FaultPlan::parse(&raw) {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("celerity: invalid fault plan '{raw}': {e}");
            std::process::exit(2);
        }
    }
}

/// Per-node one-line summary of repaired comm faults (noise-free even
/// under heavy chaos plans; `--trace` has the full event list).
fn report_faults(node: NodeId, faults: &[String]) {
    if !faults.is_empty() {
        eprintln!(
            "node {node}: {} comm fault notice(s) absorbed by the fabric (first: {})",
            faults.len(),
            faults[0]
        );
    }
}

/// Drain the trace recorder, write the Chrome JSON (and optional Graphviz)
/// artifacts, and print the derived scheduler-lag summary.
fn export_trace(json_path: &str, dot_path: Option<&str>) {
    let tr = trace::drain();
    if let Err(e) = tr.validate() {
        // A malformed trace is a bug worth hearing about, but the run's
        // numerical result already stands — don't fail it retroactively.
        eprintln!("celerity: trace failed validation: {e}");
    }
    if let Err(e) = std::fs::write(json_path, trace::chrome::to_chrome_json(&tr)) {
        eprintln!("celerity: cannot write trace '{json_path}': {e}");
        std::process::exit(2);
    }
    println!("{}", tr.scheduler_lag());
    println!("trace: {} events -> {json_path}", tr.len());
    if let Some(p) = dot_path {
        if let Err(e) = std::fs::write(p, trace::dot::to_dot(&tr)) {
            eprintln!("celerity: cannot write trace dot '{p}': {e}");
            std::process::exit(2);
        }
        println!("trace dot: {p}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let cmd = args.get(1).map(String::as_str).unwrap_or("help");
    let app = arg(&args, "--app", "nbody");
    let nodes: u64 = num_arg(&args, "--nodes", "2");
    let devices: u64 = num_arg(&args, "--devices", "2");
    let steps: u64 = num_arg(&args, "--steps", "2");
    let collectives = !args.iter().any(|a| a == "--no-collectives");
    let direct_comm = !args.iter().any(|a| a == "--no-direct-comm");
    let verify = args.iter().any(|a| a == "--verify");
    let analyze_on = args.iter().any(|a| a == "--analyze");

    match cmd {
        "graph" => {
            let dump = arg(&args, "--dump", "tdag,cdag,idag");
            let mut tm = TaskManager::new();
            build_app(&mut tm, &app, steps);
            let tasks = tm.take_new_tasks();
            if dump.contains("tdag") {
                println!("{}", tm.to_dot());
            }
            let mut cg = CdagGenerator::new(NodeId(0), nodes, SplitHint::D1, tm.buffers().clone());
            for t in &tasks {
                cg.compile(t);
            }
            let cmds = cg.take_new_commands();
            if dump.contains("cdag") {
                println!("{}", cg.to_dot());
            }
            if dump.contains("idag") {
                let mut ig = IdagGenerator::new(
                    IdagConfig {
                        node: NodeId(0),
                        num_nodes: nodes,
                        num_devices: devices,
                        ..Default::default()
                    },
                    tm.buffers().clone(),
                );
                for c in &cmds {
                    ig.compile(c);
                }
                println!("{}", ig.to_dot());
            }
        }
        "analyze" => {
            // Offline compilation, one scheduler per node — the same
            // streams `--verify` audits and the live cluster executes —
            // then the static analyzer over each: resource bounds,
            // cost-weighted critical path and performance lints.
            let lookahead = !args.iter().any(|a| a == "--no-lookahead");
            let json = args.iter().any(|a| a == "--json");
            let mut lint_cfg = LintConfig::new();
            for (key, level) in [
                ("--allow", LintLevel::Allow),
                ("--warn", LintLevel::Warn),
                ("--deny", LintLevel::Deny),
            ] {
                for name in multi_arg(&args, key) {
                    if let Err(e) = lint_cfg.set(&name, level) {
                        eprintln!("celerity analyze: {e}");
                        std::process::exit(2);
                    }
                }
            }
            let mut tm = TaskManager::new();
            build_app(&mut tm, &app, steps);
            tm.shutdown();
            let tasks = tm.take_new_tasks();
            let acfg = AnalyzeConfig {
                lints: lint_cfg,
                num_devices: Some(devices),
                ..Default::default()
            };
            let mut denied = false;
            let mut rendered = Vec::new();
            for node in 0..nodes {
                let scfg = SchedulerConfig {
                    node: NodeId(node),
                    num_nodes: nodes,
                    num_devices: devices,
                    collectives,
                    direct_comm,
                    lookahead,
                    ..Default::default()
                };
                let mut sched = Scheduler::new(scfg, tm.buffers().clone());
                let mut instructions = Vec::new();
                for t in &tasks {
                    let (batch, _pilots) = sched.process(t);
                    instructions.extend(batch);
                }
                let (batch, _pilots) = sched.flush_now();
                instructions.extend(batch);
                let mut compile_errors: Vec<String> =
                    sched.take_errors().iter().map(|e| e.to_string()).collect();
                compile_errors.extend(sched.take_idag_errors());
                if !compile_errors.is_empty() {
                    for e in &compile_errors {
                        eprintln!("celerity analyze: node {node}: {e}");
                    }
                    std::process::exit(2);
                }
                let report = analyze_stream(NodeId(node), tm.buffers(), &instructions, &acfg);
                denied |= report.deny_count() > 0;
                rendered.push(if json { report.render_json() } else { report.render_human() });
            }
            if json {
                println!("[{}]", rendered.join(","));
            } else {
                for r in &rendered {
                    println!("{r}");
                }
            }
            if denied {
                std::process::exit(1);
            }
        }
        "sim" => {
            let cfg = SimConfig {
                num_nodes: nodes,
                num_devices: devices,
                exec: if args.iter().any(|a| a == "--baseline") {
                    ExecModel::Baseline
                } else {
                    ExecModel::Idag
                },
                lookahead: !args.iter().any(|a| a == "--no-lookahead"),
                direct_comm,
                verify,
                ..Default::default()
            };
            let r = simulate(&cfg, |tm| build_app(tm, &app, steps));
            println!(
                "app={app} nodes={nodes} devices={devices} steps={steps} exec={:?} lookahead={}",
                cfg.exec, cfg.lookahead
            );
            println!(
                "makespan {:.6} s | {} instructions | {} comm bytes | {} resizes | {} B allocated",
                r.makespan, r.instructions, r.comm_bytes, r.resizes, r.allocated_bytes
            );
            if verify {
                for v in &r.violations {
                    eprintln!("sim: {v}");
                }
                println!("verify: {} violation(s)", r.violations.len());
                if !r.violations.is_empty() {
                    std::process::exit(1);
                }
            }
        }
        "run" => {
            let transport = Transport::parse(&arg(&args, "--transport", "channel"))
                .unwrap_or_else(|| {
                    eprintln!("unknown transport (expected channel|tcp)");
                    std::process::exit(2);
                });
            let jobs: u64 = num_arg(&args, "--jobs", "1");
            if jobs == 0 {
                eprintln!("celerity run: --jobs must be at least 1");
                std::process::exit(2);
            }
            let trace_json = opt_arg(&args, "--trace");
            let trace_dot = opt_arg(&args, "--trace-dot");
            if trace_json.is_some() || trace_dot.is_some() {
                trace::enable();
            }
            let cfg = ClusterConfig::builder()
                .num_nodes(nodes)
                .num_devices(devices)
                .registry(apps::reference_registry())
                .transport(transport)
                .collectives(collectives)
                .direct_comm(direct_comm)
                .heartbeat_timeout_ms(opt_num_arg(&args, "--heartbeat-timeout"))
                .fault_plan(fault_plan_arg(&args))
                .fair_share(!args.iter().any(|a| a == "--no-fair-share"))
                .admission_limit(num_arg(&args, "--admission-limit", "0") as usize)
                .verify(verify)
                .analyze(analyze_on)
                .build();
            // (job, node, digest): sorted at the end so per-job digest rows
            // come out in a deterministic order regardless of thread timing.
            let digests: Arc<Mutex<Vec<(u64, u64, u64)>>> = Arc::new(Mutex::new(Vec::new()));
            let t0 = std::time::Instant::now();
            let result = if jobs > 1 {
                // Multi-tenant: `--jobs N` runs N concurrent instances of
                // the app as jobs of one shared cluster per node.
                let programs: Vec<JobProgram> = (0..jobs)
                    .map(|_| {
                        let dc = digests.clone();
                        let app_c = app.clone();
                        Arc::new(move |q: &mut Queue| match run_live_app(q, &app_c, steps) {
                            Ok(bytes) => {
                                dc.lock()
                                    .expect("digest lock poisoned")
                                    .push((q.job().0, q.node.0, digest(&bytes)))
                            }
                            Err(e) => eprintln!("node {} job {} failed: {e}", q.node, q.job()),
                        }) as JobProgram
                    })
                    .collect();
                run_cluster_jobs(cfg, programs)
            } else {
                let dc = digests.clone();
                let app_c = app.clone();
                try_run_cluster(cfg, move |q| match run_live_app(q, &app_c, steps) {
                    Ok(bytes) => {
                        dc.lock().expect("digest lock poisoned").push((
                            0,
                            q.node.0,
                            digest(&bytes),
                        ))
                    }
                    Err(e) => eprintln!("node {} failed: {e}", q.node),
                })
            };
            let reports = match result {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("celerity run: cannot bring up the {} transport: {e}", transport.name());
                    std::process::exit(2);
                }
            };
            let wall = t0.elapsed().as_secs_f64();
            for r in &reports {
                for jr in &r.jobs {
                    for e in &jr.errors {
                        if jobs > 1 {
                            eprintln!("node {} job {} error: {e}", r.node, jr.job);
                        } else {
                            eprintln!("node {} error: {e}", r.node);
                        }
                    }
                }
                report_faults(r.node, &r.faults);
                for rep in &r.analyze {
                    println!("{}", rep.render_human());
                }
            }
            let mut digests = digests.lock().expect("digest lock poisoned").clone();
            digests.sort();
            for (job, node, d) in &digests {
                if jobs > 1 {
                    println!("job {job} {}", launch::digest_marker(NodeId(*node), *d));
                } else {
                    println!("{}", launch::digest_marker(NodeId(*node), *d));
                }
            }
            let complete = digests.len() as u64 == nodes * jobs;
            // Every job's digest must agree across all nodes (jobs may of
            // course differ from each other).
            let agree = complete
                && (0..jobs).all(|j| {
                    let mut per_job = digests.iter().filter(|(job, _, _)| *job == j);
                    let first = per_job.next().map(|t| t.2);
                    per_job.all(|t| Some(t.2) == first)
                });
            println!(
                "app={app} nodes={nodes} devices={devices} steps={steps} jobs={jobs} transport={} wall={wall:.3}s digests_agree={agree}",
                transport.name()
            );
            if let Some(p) = &trace_json {
                export_trace(p, trace_dot.as_deref());
            } else if let Some(p) = &trace_dot {
                let tr = trace::drain();
                if let Err(e) = std::fs::write(p, trace::dot::to_dot(&tr)) {
                    eprintln!("celerity: cannot write trace dot '{p}': {e}");
                    std::process::exit(2);
                }
                println!("trace dot: {p}");
            }
            if !agree || reports.iter().any(|r| !r.errors.is_empty()) {
                std::process::exit(1);
            }
        }
        "worker" => {
            let node = NodeId(num_arg(&args, "--node", "0"));
            let peers_raw = arg(&args, "--peers", "");
            let mut peers: Vec<std::net::SocketAddr> = Vec::new();
            for entry in peers_raw.split(',').filter(|s| !s.is_empty()) {
                match entry.parse() {
                    Ok(a) => peers.push(a),
                    Err(e) => {
                        eprintln!(
                            "celerity worker: invalid --peers entry '{entry}': {e} (expected host:port, e.g. 127.0.0.1:7700)"
                        );
                        std::process::exit(2);
                    }
                }
            }
            // A 1-address peer list is a valid degenerate run: one worker
            // process, no communication — useful for digest comparison.
            if peers.is_empty() {
                eprintln!(
                    "celerity worker: --peers requires at least one host:port address (comma-separated, order defines node ids)"
                );
                std::process::exit(2);
            }
            if node.0 as usize >= peers.len() {
                eprintln!(
                    "celerity worker: --node {} out of range for a {}-address --peers list (node ids are 0..{})",
                    node.0,
                    peers.len(),
                    peers.len() - 1
                );
                std::process::exit(2);
            }
            let trace_json = opt_arg(&args, "--trace");
            if trace_json.is_some() {
                trace::enable();
            }
            // Test-only fault injection: `--fault-node I --fault-exit-after MS`
            // hard-kills this process mid-run so the heartbeat path can be
            // exercised end-to-end from the launcher.
            if opt_num_arg(&args, "--fault-node") == Some(node.0) {
                let after = opt_num_arg(&args, "--fault-exit-after").unwrap_or(500);
                std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(after));
                    eprintln!("celerity worker: injected fault on node {node}: exiting");
                    std::process::exit(3);
                });
            }
            let fault_plan = fault_plan_arg(&args);
            let mut heartbeat_timeout_ms = opt_num_arg(&args, "--heartbeat-timeout");
            if fault_plan.as_ref().map_or(false, |p| p.is_active())
                && heartbeat_timeout_ms.is_none()
            {
                // Tail-loss recovery rides on heartbeat beacons (the
                // ack-stall nudge): an active chaos plan forces liveness on.
                heartbeat_timeout_ms = Some(launch::DEFAULT_HEARTBEAT_TIMEOUT_MS);
            }
            let cfg = ClusterConfig::builder()
                .num_nodes(peers.len() as u64)
                .num_devices(devices)
                .registry(apps::reference_registry())
                .transport(Transport::Tcp)
                .collectives(collectives)
                .direct_comm(direct_comm)
                .heartbeat_timeout_ms(heartbeat_timeout_ms)
                .verify(verify)
                .analyze(analyze_on)
                .build();
            let bind_addr = peers[node.0 as usize];
            let comm: CommRef = match TcpCommunicator::bind(node, peers) {
                Ok(mut c) => {
                    if let Some(plan) = &fault_plan {
                        c.set_fault_plan(plan);
                    }
                    if let Some(inj) = c.fault_injector() {
                        // `kill=nodeN@frameM`: hard-kill this process once
                        // its outbound frame counter trips the site — the
                        // unrecoverable-death case the launcher's fail-fast
                        // and the peers' heartbeats must both handle.
                        std::thread::spawn(move || loop {
                            if inj.kill_requested() {
                                eprintln!(
                                    "celerity worker: fault plan kill site tripped on node {node}: exiting"
                                );
                                std::process::exit(3);
                            }
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        });
                    }
                    Arc::new(c)
                }
                Err(e) => {
                    // Environment/config problem, not an application error:
                    // exit 2 like the other CLI-usage failures.
                    eprintln!("celerity worker: cannot bind listener on {bind_addr}: {e}");
                    std::process::exit(2);
                }
            };
            let app_c = app.clone();
            let out: Arc<Mutex<Result<Vec<u8>, QueueError>>> = Arc::new(Mutex::new(Ok(Vec::new())));
            let oc = out.clone();
            let report = run_node(&cfg, node, comm, move |q| {
                *oc.lock().expect("output lock poisoned") = run_live_app(q, &app_c, steps);
            });
            for e in &report.errors {
                eprintln!("node {} error: {e}", report.node);
            }
            report_faults(report.node, &report.faults);
            for rep in &report.analyze {
                println!("{}", rep.render_human());
                // One atomic marker line per analyzed job core: the
                // contract `celerity launch` aggregates into its report.
                println!(
                    "{}",
                    launch::analyze_marker(
                        node,
                        rep.deny_count() as u64,
                        rep.findings.len() as u64
                    )
                );
            }
            if let Some(p) = &trace_json {
                export_trace(p, None);
            }
            match &*out.lock().expect("output lock poisoned") {
                Ok(bytes) => {
                    // One atomic marker line (single write): the contract
                    // `celerity launch` and the tests parse. Interleaving
                    // with other nodes' output cannot corrupt it.
                    println!("{}", launch::digest_marker(node, digest(bytes)));
                }
                Err(e) => {
                    eprintln!("node {node} failed: {e}");
                    std::process::exit(1);
                }
            }
            if !report.errors.is_empty() {
                std::process::exit(1);
            }
        }
        "launch" => {
            // Flags before `--` belong to the launcher; the first token
            // after it names the app and the rest pass through to every
            // worker verbatim.
            let sep = args.iter().position(|a| a == "--").unwrap_or_else(|| {
                eprintln!(
                    "celerity launch: missing '--' separator (usage: celerity launch -n 4 -- nbody --steps 4)"
                );
                std::process::exit(2);
            });
            let (own, rest) = args.split_at(sep);
            let Some(launch_app) = rest.get(1).cloned() else {
                eprintln!("celerity launch: missing app after '--' (nbody|rsim|wavesim)");
                std::process::exit(2);
            };
            let n = opt_num_arg(own, "-n")
                .or_else(|| opt_num_arg(own, "--nodes"))
                .unwrap_or(2);
            if n == 0 {
                eprintln!("celerity launch: -n must be at least 1");
                std::process::exit(2);
            }
            let mut lcfg = LaunchConfig::new(n, launch_app);
            lcfg.app_args = rest[2..].to_vec();
            if let Some(ms) = opt_num_arg(own, "--heartbeat-timeout") {
                lcfg.heartbeat_timeout_ms = ms;
            }
            lcfg.trace = opt_arg(own, "--trace");
            if own.iter().any(|a| a == "--no-fail-fast") {
                lcfg.fail_fast = false;
            }
            if let Some(ms) = opt_num_arg(own, "--fail-fast-grace") {
                lcfg.fail_fast_grace_ms = ms;
            }
            if let Some(raw) = opt_arg(own, "--fault-plan") {
                // Validate here for a friendly error; workers re-parse the
                // same string (it is forwarded verbatim).
                if let Err(e) = celerity::fault::FaultPlan::parse(&raw) {
                    eprintln!("celerity launch: invalid --fault-plan '{raw}': {e}");
                    std::process::exit(2);
                }
                lcfg.fault_plan = Some(raw);
            }
            let t0 = std::time::Instant::now();
            let report = match launch::launch(&lcfg) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("celerity launch: cannot start the cluster: {e}");
                    std::process::exit(2);
                }
            };
            for e in &report.errors {
                eprintln!("[launch] {e}");
            }
            let first = report.digests.iter().flatten().next();
            println!(
                "launch: {} nodes, wall={:.3}s, digests_agree={}, {}",
                lcfg.nodes,
                t0.elapsed().as_secs_f64(),
                first.is_some() && report.digests.iter().all(|d| d.as_ref() == first),
                if report.success() { "ok" } else { "FAILED" },
            );
            if !report.success() {
                std::process::exit(1);
            }
        }
        _ => {
            println!("usage: celerity graph|analyze|sim|run|worker|launch --app nbody|rsim|wavesim [--nodes N] [--devices D] [--steps S]");
            println!("  graph:  --dump tdag,cdag,idag   (Graphviz dot on stdout)");
            println!("  analyze: [--no-lookahead] [--json] [--allow NAME] [--warn NAME] [--deny NAME]   (static per-node performance report: peak-memory bounds, cost-weighted critical path, width profile and lints; NAME is a lint or 'all', flags repeat; deny findings exit 1)");
            println!("  sim:    [--baseline] [--no-lookahead] [--no-direct-comm] [--verify]");
            println!("  run:    [--transport channel|tcp] [--jobs N] [--no-fair-share] [--admission-limit N] [--no-collectives] [--no-direct-comm] [--verify] [--analyze] [--trace out.json] [--trace-dot out.dot] [--heartbeat-timeout MS] [--fault-plan \"seed=7 drop=0.01 ...\"]   (live in-process cluster; --jobs N runs N concurrent tenant jobs)");
            println!("  worker: --node I --peers a:p[,b:p,...] [--heartbeat-timeout MS] [--trace out.json] [--no-collectives] [--no-direct-comm] [--verify] [--analyze] [--fault-plan PLAN]   (one node of a multi-process TCP cluster; a single address is a valid 1-node run)");
            println!("  launch: -n N [--heartbeat-timeout MS] [--trace base] [--fault-plan PLAN] [--no-fail-fast] [--fail-fast-grace MS] -- <app> [worker args...]   (spawn N worker processes, stream logs, cross-check digests)");
            println!("  --verify: static instruction-graph verification (races, lifetimes, coherence, comm matching) — violations surface as runtime errors and fail the run");
            println!("  --analyze: post-run performance analysis of each compiled stream (run/worker; launch aggregates the workers' CELERITY-ANALYZE markers and fails on deny findings)");
            println!("  fault plans: seed=N drop=P dup=P corrupt=P delay=LO..HIms break=nodeN@frameM kill=nodeN@frameM (CELERITY_FAULT_PLAN env fallback)");
        }
    }
}
