//! `celerity` CLI: graph dumps and quick simulations.
//!
//! ```text
//! celerity graph --app nbody --nodes 2 --devices 2 --dump tdag,cdag,idag
//! celerity sim   --app rsim  --nodes 8 --devices 4 [--baseline] [--no-lookahead]
//! ```
//!
//! `graph` prints Graphviz dot for the requested intermediate
//! representations of the chosen application (Fig 2 / Fig 4 artifacts);
//! `sim` runs the discrete-event cluster simulator and reports the virtual
//! makespan (one row of Fig 6).

use celerity::command::{CdagGenerator, SplitHint};
use celerity::grid::{GridBox, Range, Region};
use celerity::instruction::{IdagConfig, IdagGenerator};
use celerity::sim::{simulate, ExecModel, SimConfig};
use celerity::task::{RangeMapper, TaskManager};
use celerity::util::NodeId;

fn build_app(tm: &mut TaskManager, app: &str, steps: u64) {
    match app {
        "nbody" => {
            let range = Range::d1(4096);
            let p = tm.create_buffer::<[f32; 3]>("P", range, true);
            let v = tm.create_buffer::<[f32; 3]>("V", range, true);
            for _ in 0..steps {
                tm.submit_group(|cgh| {
                    cgh.read(p, RangeMapper::All);
                    cgh.read_write(v, RangeMapper::OneToOne);
                    cgh.parallel_for("timestep", range).work_per_item(4096.0 * 20.0);
                })
                .expect("submit timestep");
                tm.submit_group(|cgh| {
                    cgh.read(v, RangeMapper::OneToOne);
                    cgh.read_write(p, RangeMapper::OneToOne);
                    cgh.parallel_for("update", range).work_per_item(2.0);
                })
                .expect("submit update");
            }
        }
        "rsim" => {
            let width = 4096u64;
            let r = tm.create_buffer::<f32>("R", Range::d2(steps, width), true);
            let vis = tm.create_buffer::<f32>("VIS", Range::d2(width, 64), true);
            for t in 1..steps {
                let prev = Region::from(GridBox::d2((0, 0), (t, width)));
                tm.submit_group(|cgh| {
                    cgh.read(r, RangeMapper::Fixed(prev));
                    cgh.read(vis, RangeMapper::All);
                    cgh.write(r, RangeMapper::RowSlice(t));
                    cgh.parallel_for("radiosity", Range::d1(width))
                        .work_per_item(t as f64 * 100.0);
                })
                .expect("submit radiosity");
            }
        }
        "wavesim" => {
            let range = Range::d2(1024, 256);
            let bufs = [
                tm.create_buffer::<f32>("U0", range, true),
                tm.create_buffer::<f32>("U1", range, true),
                tm.create_buffer::<f32>("U2", range, true),
            ];
            for s in 0..steps as usize {
                let (p, c, n) = (bufs[s % 3], bufs[(s + 1) % 3], bufs[(s + 2) % 3]);
                tm.submit_group(|cgh| {
                    cgh.read(p, RangeMapper::Neighborhood(Range::d2(1, 0)));
                    cgh.read(c, RangeMapper::Neighborhood(Range::d2(1, 0)));
                    cgh.write(n, RangeMapper::OneToOne);
                    cgh.parallel_for("wavesim", range).work_per_item(10.0);
                })
                .expect("submit wavesim");
            }
        }
        other => {
            eprintln!("unknown app '{other}' (expected nbody|rsim|wavesim)");
            std::process::exit(2);
        }
    }
}

fn arg(args: &[String], key: &str, default: &str) -> String {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let cmd = args.get(1).map(String::as_str).unwrap_or("help");
    let app = arg(&args, "--app", "nbody");
    let nodes: u64 = arg(&args, "--nodes", "2").parse().unwrap();
    let devices: u64 = arg(&args, "--devices", "2").parse().unwrap();
    let steps: u64 = arg(&args, "--steps", "2").parse().unwrap();

    match cmd {
        "graph" => {
            let dump = arg(&args, "--dump", "tdag,cdag,idag");
            let mut tm = TaskManager::new();
            build_app(&mut tm, &app, steps);
            let tasks = tm.take_new_tasks();
            if dump.contains("tdag") {
                println!("{}", tm.to_dot());
            }
            let mut cg = CdagGenerator::new(NodeId(0), nodes, SplitHint::D1, tm.buffers().clone());
            for t in &tasks {
                cg.compile(t);
            }
            let cmds = cg.take_new_commands();
            if dump.contains("cdag") {
                println!("{}", cg.to_dot());
            }
            if dump.contains("idag") {
                let mut ig = IdagGenerator::new(
                    IdagConfig {
                        node: NodeId(0),
                        num_nodes: nodes,
                        num_devices: devices,
                        ..Default::default()
                    },
                    tm.buffers().clone(),
                );
                for c in &cmds {
                    ig.compile(c);
                }
                println!("{}", ig.to_dot());
            }
        }
        "sim" => {
            let cfg = SimConfig {
                num_nodes: nodes,
                num_devices: devices,
                exec: if args.iter().any(|a| a == "--baseline") {
                    ExecModel::Baseline
                } else {
                    ExecModel::Idag
                },
                lookahead: !args.iter().any(|a| a == "--no-lookahead"),
                ..Default::default()
            };
            let r = simulate(&cfg, |tm| build_app(tm, &app, steps));
            println!(
                "app={app} nodes={nodes} devices={devices} steps={steps} exec={:?} lookahead={}",
                cfg.exec, cfg.lookahead
            );
            println!(
                "makespan {:.6} s | {} instructions | {} comm bytes | {} resizes | {} B allocated",
                r.makespan, r.instructions, r.comm_bytes, r.resizes, r.allocated_bytes
            );
        }
        _ => {
            println!("usage: celerity graph|sim --app nbody|rsim|wavesim [--nodes N] [--devices D] [--steps S]");
            println!("  graph: --dump tdag,cdag,idag   (Graphviz dot on stdout)");
            println!("  sim:   [--baseline] [--no-lookahead]");
        }
    }
}
