//! Deterministic fault injection for the comm fabric.
//!
//! A [`FaultPlan`] is parsed from `--fault-plan` / `CELERITY_FAULT_PLAN`
//! and describes reproducible chaos:
//!
//! ```text
//! seed=7 drop=0.01 delay=0..5ms dup=0.005 corrupt=0.002 break=node1@frame200 kill=node2@frame500
//! ```
//!
//! * `seed=N` — seeds the per-peer [`XorShift64`] streams; the same plan
//!   and seed reproduce the same per-peer fault sequence.
//! * `drop=P` / `dup=P` / `corrupt=P` — per-frame probabilities in [0, 1].
//! * `delay=LO..HIms` (or a single `delay=3ms`, `us` also accepted) —
//!   uniform extra latency per frame.
//! * `break=nodeN@frameM` — node N severs the outbound stream carrying its
//!   M-th data-plane frame, once (exercises reconnect+resume).
//! * `kill=nodeN@frameM` — node N's worker process exits with code 3
//!   after its M-th frame (multi-process `celerity launch` only).
//!
//! Faults are applied *below* the reliability layer: on the TCP fabric a
//! [`FaultInjector`] mutates encoded wire frames inside
//! [`TcpCommunicator`](crate::comm::TcpCommunicator), where CRC32 +
//! sequence numbers + ack/retransmit recover them transparently (fence
//! digests stay byte-identical to a fault-free run). The message-level
//! [`FaultyCommunicator`] wrapper applies drop/delay/dup to *any*
//! transport — on the in-process channel fabric, which has no wire-level
//! recovery, drops and dups exercise detection and graceful degradation
//! rather than transparent repair (`corrupt` is ignored there: without a
//! CRC the corruption would be silent, which is worse than nothing).

use crate::comm::{Communicator, Inbound};
use crate::instruction::Pilot;
use crate::util::{MessageId, NodeId, XorShift64};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A parsed, deterministic fault plan. See the module docs for grammar.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-frame probability the frame is silently not written.
    pub drop: f64,
    /// Per-frame probability the frame is written twice.
    pub dup: f64,
    /// Per-frame probability one byte of the frame is flipped on the wire.
    pub corrupt: f64,
    /// Uniform extra per-frame latency, microseconds (inclusive range).
    pub delay_min_us: u64,
    pub delay_max_us: u64,
    /// (node, frame): sever that node's outbound streams once at frame N.
    pub break_at: Option<(u64, u64)>,
    /// (node, frame): that node's worker process exits(3) at frame N.
    pub kill_at: Option<(u64, u64)>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 1,
            drop: 0.0,
            dup: 0.0,
            corrupt: 0.0,
            delay_min_us: 0,
            delay_max_us: 0,
            break_at: None,
            kill_at: None,
        }
    }
}

impl FaultPlan {
    /// Parse the `key=value ...` plan grammar. Unknown keys, bad numbers
    /// and out-of-range probabilities are reported, not ignored — a typo
    /// in a chaos plan must not silently produce a fault-free run.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for tok in s.split_whitespace() {
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| format!("fault plan: expected key=value, got '{tok}'"))?;
            match key {
                "seed" => {
                    plan.seed = val
                        .parse()
                        .map_err(|_| format!("fault plan: bad seed '{val}'"))?
                }
                "drop" => plan.drop = parse_prob(key, val)?,
                "dup" => plan.dup = parse_prob(key, val)?,
                "corrupt" => plan.corrupt = parse_prob(key, val)?,
                "delay" => (plan.delay_min_us, plan.delay_max_us) = parse_delay(val)?,
                "break" => plan.break_at = Some(parse_site(key, val)?),
                "kill" => plan.kill_at = Some(parse_site(key, val)?),
                other => {
                    return Err(format!(
                        "fault plan: unknown key '{other}' \
                         (expected seed/drop/delay/dup/corrupt/break/kill)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Plan from `CELERITY_FAULT_PLAN`, if the variable is set.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        match std::env::var("CELERITY_FAULT_PLAN") {
            Ok(s) if !s.trim().is_empty() => Self::parse(&s).map(Some),
            _ => Ok(None),
        }
    }

    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        self.drop > 0.0
            || self.dup > 0.0
            || self.corrupt > 0.0
            || self.delay_max_us > 0
            || self.break_at.is_some()
            || self.kill_at.is_some()
    }
}

fn parse_prob(key: &str, val: &str) -> Result<f64, String> {
    let p: f64 = val
        .parse()
        .map_err(|_| format!("fault plan: bad {key} probability '{val}'"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("fault plan: {key}={val} outside [0, 1]"));
    }
    Ok(p)
}

/// `LO..HIms`, `LO..HIus`, or a single `Nms`/`Nus`.
fn parse_delay(val: &str) -> Result<(u64, u64), String> {
    let (num, scale) = if let Some(v) = val.strip_suffix("ms") {
        (v, 1000)
    } else if let Some(v) = val.strip_suffix("us") {
        (v, 1)
    } else {
        return Err(format!("fault plan: delay '{val}' needs a ms/us suffix"));
    };
    let (lo, hi) = match num.split_once("..") {
        Some((lo, hi)) => (lo, hi),
        None => (num, num),
    };
    let lo: u64 = lo
        .parse()
        .map_err(|_| format!("fault plan: bad delay bound in '{val}'"))?;
    let hi: u64 = hi
        .parse()
        .map_err(|_| format!("fault plan: bad delay bound in '{val}'"))?;
    if lo > hi {
        return Err(format!("fault plan: delay '{val}' has lo > hi"));
    }
    Ok((lo * scale, hi * scale))
}

/// `nodeN@frameM`.
fn parse_site(key: &str, val: &str) -> Result<(u64, u64), String> {
    let err = || format!("fault plan: {key}='{val}' (expected nodeN@frameM)");
    let (node, frame) = val.split_once('@').ok_or_else(err)?;
    let node = node.strip_prefix("node").ok_or_else(err)?;
    let frame = frame.strip_prefix("frame").ok_or_else(err)?;
    Ok((
        node.parse().map_err(|_| err())?,
        frame.parse().map_err(|_| err())?,
    ))
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "seed={}", self.seed)?;
        if self.drop > 0.0 {
            write!(f, " drop={}", self.drop)?;
        }
        if self.delay_max_us > 0 {
            write!(f, " delay={}..{}us", self.delay_min_us, self.delay_max_us)?;
        }
        if self.dup > 0.0 {
            write!(f, " dup={}", self.dup)?;
        }
        if self.corrupt > 0.0 {
            write!(f, " corrupt={}", self.corrupt)?;
        }
        if let Some((n, fr)) = self.break_at {
            write!(f, " break=node{n}@frame{fr}")?;
        }
        if let Some((n, fr)) = self.kill_at {
            write!(f, " kill=node{n}@frame{fr}")?;
        }
        Ok(())
    }
}

/// What happens to one outbound data-plane frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fate {
    Deliver,
    /// Silently lose the frame (the reliability layer must re-deliver it).
    Drop,
    /// Write the frame twice (receive-side seq dedup must drop one).
    Duplicate,
    /// Flip one byte of the written copy (CRC must reject it; the sender's
    /// retained original is what retransmission re-delivers).
    Corrupt,
}

/// Everything injected into one frame.
#[derive(Debug, Clone, Copy)]
pub struct FrameFaults {
    pub fate: Fate,
    pub delay: Option<Duration>,
    /// This frame trips the one-shot `break=` point: sever streams now.
    pub break_now: bool,
}

/// Per-node injector state shared by every send path of one communicator.
/// Frame fates are sampled from per-peer [`XorShift64`] streams (see
/// [`FaultInjector::peer_rng`]), so the fault sequence each peer link sees
/// is a deterministic function of (plan seed, sender, receiver, frame
/// index on that link) regardless of cross-peer thread interleaving.
pub struct FaultInjector {
    plan: FaultPlan,
    node: NodeId,
    /// Data-plane frames sent by this node (drives `break=`/`kill=`).
    frames: AtomicU64,
    broke: AtomicBool,
    kill: AtomicBool,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan, node: NodeId) -> Self {
        FaultInjector {
            plan,
            node,
            frames: AtomicU64::new(0),
            broke: AtomicBool::new(false),
            kill: AtomicBool::new(false),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The deterministic fault stream for one (sender, peer) link.
    pub fn peer_rng(&self, peer: NodeId) -> XorShift64 {
        XorShift64::new(
            self.plan
                .seed
                .wrapping_add(self.node.0.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_add(peer.0.wrapping_mul(0xD1B5_4A32_D192_ED03)),
        )
    }

    /// Stamp one outbound data-plane frame: advance the node-wide frame
    /// counter (arming `break=`/`kill=` trip points) and sample this
    /// frame's fate from the link's rng.
    pub fn on_frame(&self, rng: &mut XorShift64) -> FrameFaults {
        let n = self.frames.fetch_add(1, Ordering::Relaxed) + 1;
        let mut break_now = false;
        if let Some((node, at)) = self.plan.break_at {
            if node == self.node.0 && n >= at && !self.broke.swap(true, Ordering::Relaxed) {
                break_now = true;
            }
        }
        if let Some((node, at)) = self.plan.kill_at {
            if node == self.node.0 && n >= at {
                self.kill.store(true, Ordering::Relaxed);
            }
        }
        // Fixed sampling order: every decision draws exactly once so the
        // stream position stays aligned across fates.
        let drop = rng.chance(self.plan.drop);
        let corrupt = rng.chance(self.plan.corrupt);
        let dup = rng.chance(self.plan.dup);
        let delay = if self.plan.delay_max_us > 0 {
            let us = rng.next_range(self.plan.delay_min_us, self.plan.delay_max_us);
            (us > 0).then(|| Duration::from_micros(us))
        } else {
            None
        };
        let fate = if drop {
            Fate::Drop
        } else if corrupt {
            Fate::Corrupt
        } else if dup {
            Fate::Duplicate
        } else {
            Fate::Deliver
        };
        FrameFaults { fate, delay, break_now }
    }

    /// `kill=` tripped: the worker process should exit(3). Only honored by
    /// `celerity worker` (killing an in-process cluster would take every
    /// node with it); [`crate::driver::try_run_cluster`] ignores it.
    pub fn kill_requested(&self) -> bool {
        self.kill.load(Ordering::Relaxed)
    }

    pub fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }
}

/// Message-level chaos wrapper for any [`Communicator`] — the fabric-
/// agnostic injection point (`try_run_cluster` uses it for the channel
/// transport; the TCP fabric injects at the wire level instead, where
/// recovery can repair the damage). Drop/delay/dup only: see module docs.
pub struct FaultyCommunicator {
    inner: Box<dyn Communicator + Sync>,
    injector: Arc<FaultInjector>,
    rng: Mutex<XorShift64>,
}

impl FaultyCommunicator {
    pub fn wrap(inner: Box<dyn Communicator + Sync>, plan: FaultPlan) -> Self {
        let node = inner.node();
        let injector = Arc::new(FaultInjector::new(plan, node));
        // One message stream for all peers: channel sends are routed by
        // the inner communicator, so per-peer streams would have to
        // duplicate its routing logic for no determinism gain.
        let rng = Mutex::new(injector.peer_rng(node));
        FaultyCommunicator { inner, injector, rng }
    }

    pub fn injector(&self) -> Arc<FaultInjector> {
        self.injector.clone()
    }

    fn faults(&self) -> FrameFaults {
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        self.injector.on_frame(&mut rng)
    }
}

impl Communicator for FaultyCommunicator {
    fn node(&self) -> NodeId {
        self.inner.node()
    }

    fn num_nodes(&self) -> u64 {
        self.inner.num_nodes()
    }

    fn send_pilot(&self, pilot: Pilot) {
        let f = self.faults();
        if let Some(d) = f.delay {
            std::thread::sleep(d);
        }
        match f.fate {
            Fate::Drop => {}
            Fate::Duplicate => {
                self.inner.send_pilot(pilot.clone());
                self.inner.send_pilot(pilot);
            }
            // Corruption of a typed in-process message would be silent —
            // deliver it intact instead (wire-level injection covers it).
            Fate::Deliver | Fate::Corrupt => self.inner.send_pilot(pilot),
        }
    }

    fn send_data(&self, to: NodeId, msg: MessageId, bytes: Vec<u8>) {
        let f = self.faults();
        if let Some(d) = f.delay {
            std::thread::sleep(d);
        }
        match f.fate {
            Fate::Drop => {}
            Fate::Duplicate => {
                self.inner.send_data(to, msg, bytes.clone());
                self.inner.send_data(to, msg, bytes);
            }
            Fate::Deliver | Fate::Corrupt => self.inner.send_data(to, msg, bytes),
        }
    }

    fn send_heartbeat(&self, to: NodeId, departing: bool) {
        // Control plane is exempt: liveness detection must stay sound.
        self.inner.send_heartbeat(to, departing);
    }

    fn poll(&self) -> Option<Inbound> {
        self.inner.poll()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_issue_grammar() {
        let p = FaultPlan::parse(
            "seed=7 drop=0.01 delay=0..5ms dup=0.005 corrupt=0.002 \
             break=node1@frame200 kill=node2@frame500",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.drop, 0.01);
        assert_eq!(p.dup, 0.005);
        assert_eq!(p.corrupt, 0.002);
        assert_eq!((p.delay_min_us, p.delay_max_us), (0, 5000));
        assert_eq!(p.break_at, Some((1, 200)));
        assert_eq!(p.kill_at, Some((2, 500)));
        assert!(p.is_active());
    }

    #[test]
    fn parses_scalar_delay_and_us_suffix() {
        let p = FaultPlan::parse("delay=3ms").unwrap();
        assert_eq!((p.delay_min_us, p.delay_max_us), (3000, 3000));
        let p = FaultPlan::parse("delay=10..250us").unwrap();
        assert_eq!((p.delay_min_us, p.delay_max_us), (10, 250));
    }

    #[test]
    fn rejects_malformed_plans() {
        for bad in [
            "drop",              // no value
            "drop=1.5",          // probability out of range
            "drop=x",            // not a number
            "delay=5",           // missing unit
            "delay=9..2ms",      // lo > hi
            "break=1@200",       // missing node/frame prefixes
            "kill=node2",        // missing @frame
            "jitter=0.1",        // unknown key
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn empty_plan_is_inactive_and_display_round_trips() {
        let p = FaultPlan::parse("").unwrap();
        assert!(!p.is_active());
        let p = FaultPlan::parse("seed=9 drop=0.25 delay=1..2ms break=node0@frame3").unwrap();
        let q = FaultPlan::parse(&p.to_string()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn injector_streams_are_deterministic_per_link() {
        let plan = FaultPlan::parse("seed=42 drop=0.3 dup=0.2 corrupt=0.1 delay=0..2ms").unwrap();
        let sample = |peer: u64| {
            let inj = FaultInjector::new(plan.clone(), NodeId(0));
            let mut rng = inj.peer_rng(NodeId(peer));
            (0..256).map(|_| inj.on_frame(&mut rng).fate).collect::<Vec<_>>()
        };
        assert_eq!(sample(1), sample(1), "same link, same stream");
        assert_ne!(sample(1), sample(2), "links draw independent streams");
        let fates = sample(1);
        assert!(fates.iter().any(|f| *f == Fate::Drop));
        assert!(fates.iter().any(|f| *f == Fate::Duplicate));
        assert!(fates.iter().any(|f| *f == Fate::Deliver));
    }

    #[test]
    fn break_trips_once_and_kill_latches() {
        let plan = FaultPlan::parse("break=node3@frame2 kill=node3@frame4").unwrap();
        let inj = FaultInjector::new(plan, NodeId(3));
        let mut rng = inj.peer_rng(NodeId(0));
        let breaks: Vec<bool> = (0..6).map(|_| inj.on_frame(&mut rng).break_now).collect();
        assert_eq!(breaks, [false, true, false, false, false, false]);
        assert!(inj.kill_requested());
        // A different node never trips this plan's sites.
        let other = FaultInjector::new(
            FaultPlan::parse("break=node3@frame1 kill=node3@frame1").unwrap(),
            NodeId(1),
        );
        let mut rng = other.peer_rng(NodeId(0));
        for _ in 0..4 {
            assert!(!other.on_frame(&mut rng).break_now);
        }
        assert!(!other.kill_requested());
    }
}
