//! # celerity-idag
//!
//! Reproduction of *"Concurrent Scheduling of High-Level Parallel Programs
//! on Multi-GPU Systems"* (Knorr, Salzmann, Thoman, Fahringer 2025): a
//! Celerity-style runtime with **instruction-graph scheduling**.
//!
//! ## User API
//!
//! Programs talk to a typed, Listing-1-style queue ([`driver::Queue`]):
//! buffers are typed handles ([`buffer::Buffer<T>`]) whose element layout
//! ([`dtype::DType`] + lanes) the runtime derives allocations, transfers
//! and dependencies from; work is submitted as *command groups* that scope
//! accessor declarations and the kernel launch into one closure; and every
//! fallible operation returns [`task::QueueError`] instead of panicking:
//!
//! ```no_run
//! # // no_run: rustdoc test binaries lack the libxla rpath of this image.
//! # use celerity::driver::{run_cluster, ClusterConfig};
//! # use celerity::grid::Range;
//! # use celerity::task::RangeMapper;
//! let reports = run_cluster(ClusterConfig::default(), |q| {
//!     let n = Range::d1(1024);
//!     let a = q.create_buffer::<f32>("A", n);
//!     q.submit(|cgh| {
//!         cgh.discard_write(a, RangeMapper::OneToOne);
//!         cgh.parallel_for("iota", n);
//!     })
//!     .expect("submit");
//!     let data: Vec<f32> = q.fence(a).expect("fence");
//! });
//! ```
//!
//! ## Module map
//!
//! The library is organized along the paper's three graph layers plus the
//! substrates they need:
//!
//! - [`dtype`] — the shared element-type system (`DType`, `Elem`) used by
//!   buffers, accessor bindings and the PJRT argument specs
//! - [`grid`] — index-space algebra (boxes, regions, region maps)
//! - [`dag`] — shared DAG storage with horizon-based pruning
//! - [`buffer`] — typed buffer handles + the buffer metadata registry
//! - [`task`] — command groups, accessors/range mappers and the TDAG
//! - [`command`] — per-node CDAG generation with push/await-push (§2.4) and
//!   collective-group detection (all-gather/broadcast → one
//!   [`Collective`](command::CommandKind::Collective) command instead of
//!   O(n²) p2p pairs; p2p fallback for every other geometry)
//! - [`instruction`] — the IDAG: the paper's core contribution (§3),
//!   including the direct-device-transfer lowering (sends read
//!   device-resident data in place, receives land in the consuming
//!   device's allocation; the pinned-host M1 detour is the fallback and
//!   the `--no-direct-comm` ablation)
//! - [`scheduler`] — scheduler thread with lookahead / resize elision
//!   (§4.3); one compiler core per tenant job, interleaved in bounded
//!   batches so no job's compilation stream starves another's
//! - [`executor`] — out-of-order engine, receive arbitration, collective
//!   ring engine, baseline (§4.1–4.2); multi-tenant dispatch arbitration
//!   ([`executor::ReadySet`]: weighted round-robin + admission limits) and
//!   per-job event routing ([`executor::EventHub`])
//! - [`comm`] — the p2p subsystem: the [`Communicator`](comm::Communicator)
//!   trait, the in-process [`ChannelWorld`](comm::ChannelWorld), the
//!   loopback/cross-process [`TcpWorld`](comm::TcpWorld) with its
//!   CRC32-guarded, sequence-numbered [`wire`](comm::wire) format and
//!   ack/retransmit recovery layer, and the [`Transport`](comm::Transport)
//!   selector
//! - [`fault`] — deterministic comm-fabric chaos: the seeded
//!   [`FaultPlan`](fault::FaultPlan) grammar (`--fault-plan "seed=7
//!   drop=0.01 …"`), the per-link [`FaultInjector`](fault::FaultInjector)
//!   the TCP fabric consults below its recovery layer, and the
//!   message-level [`FaultyCommunicator`](fault::FaultyCommunicator)
//!   wrapper for the channel fabric
//! - [`driver`] — the multi-tenant [`Cluster`](driver::Cluster) handle
//!   (one node's scheduler/executor stack, handing out one typed
//!   [`Queue`](driver::Queue) per concurrent job), the in-process SPMD
//!   cluster runners ([`run_cluster`](driver::run_cluster) single-tenant,
//!   [`run_cluster_jobs`](driver::run_cluster_jobs) multi-tenant) and the
//!   per-process entry point ([`run_node`](driver::run_node)) used by
//!   `celerity worker` for multi-process TCP clusters
//! - [`trace`] — low-overhead event timeline (thread-local buffers behind
//!   one atomic gate) recording scheduler compile batches and per-lane
//!   issue/exec/retire; exports Chrome-tracing JSON
//!   ([`trace::chrome`], multi-tenant instructions annotated with their
//!   job), a Graphviz DAG with critical-path annotation
//!   ([`trace::dot`]), and the `scheduler_lag` concurrency metric
//! - [`launch`] — multi-process orchestration behind `celerity launch`:
//!   port allocation, worker spawning/rendezvous, prefixed log streaming,
//!   fence-digest cross-checking and exit-code aggregation; worker
//!   liveness is guarded by heartbeats over the comm fabric
//!   ([`executor::heartbeat`])
//! - [`verify`] — static instruction-graph verification (`--verify`): a
//!   [`Verifier`](verify::Verifier) absorbs each scheduler batch and checks
//!   race-freedom (every conflicting access pair ordered by a dependency
//!   path), allocation lifetime, read coherence/initialization, pilot/
//!   message-id matching and structural invariants — without executing
//!   anything; [`verify_cluster`](verify::verify_cluster) additionally
//!   matches sends/receives/collective geometry across the nodes' compiled
//!   streams. Violations surface as §4.4 runtime errors naming the
//!   offending instruction pair and region
//! - [`analyze`] — `celerity analyze`: cost-model-driven performance lints
//!   and resource bounds over the same streams the verifier consumes —
//!   per-memory peak-allocation bounds (antichain reasoning over the
//!   dependency order), the cost-weighted critical path with an even-split
//!   ideal and `scheduler_bound` ratio, a per-horizon-span width profile,
//!   and a registry of named anti-pattern lints
//!   ([`analyze::lints`]) at allow/warn/deny levels
//! - `runtime` — PJRT wrapper executing AOT-compiled HLO kernels
//!   (requires the `pjrt` feature and an XLA toolchain)
//! - [`sim`] — discrete-event cluster simulator for the Fig 6 scaling study
//! - [`apps`] — the three benchmark applications (N-body, RSim, WaveSim)
//!
//! ## Scheduler hot path
//!
//! Scheduling runs concurrently with execution (Fig 5), so the per-command
//! cost of the scheduler's inner loop bounds the whole system (§4.1). The
//! latency-critical pieces and their design:
//!
//! - [`grid::RegionMap`] — sorted major-dimension interval index with
//!   bounding-box early exit, `Arc`-shared values (splits copy pointers,
//!   not payloads), batched `update_boxes` and borrowing
//!   `for_each_intersecting`/`for_each_in_region` visitors;
//! - [`dag::Dag`] — incrementally maintained execution front (`front()` is
//!   `O(front)`, not `O(live)`) and interned dependency sets;
//! - [`scheduler::Scheduler::process_batch`] — the scheduler thread drains
//!   a run of tasks per wakeup, computes each command's requirement set
//!   once for the §4.3 lookahead, and emits one batched `SchedulerOut`.
//!
//! `cargo bench --bench micro_scheduler` measures each component and
//! writes `BENCH_scheduler.local.json` (gitignored; CI redirects to the
//! canonical `BENCH_scheduler.json` via `BENCH_SCHEDULER_JSON` and gates
//! regressions with `scripts/bench_gate.py` — see the "Scheduler
//! performance" section of the README). `cargo bench --bench
//! strong_scaling` measures the live cluster across node counts and
//! transports (see the "Distributed execution" section).

// Panic hygiene: library code must justify every panic path. `.unwrap()` is
// banned outside tests (use `.expect("why this cannot fail")` or a real
// error path); `scripts/lint_panics.py` additionally audits the remaining
// expect/panic sites against an allowlist in CI.
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

pub mod analyze;
pub mod apps;
pub mod buffer;
pub mod comm;
pub mod command;
pub mod dag;
pub mod driver;
pub mod dtype;
pub mod executor;
pub mod fault;
pub mod grid;
pub mod instruction;
pub mod launch;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod task;
pub mod trace;
pub mod util;
pub mod verify;
