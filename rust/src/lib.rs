//! # celerity-idag
//!
//! Reproduction of *"Concurrent Scheduling of High-Level Parallel Programs
//! on Multi-GPU Systems"* (Knorr, Salzmann, Thoman, Fahringer 2025): a
//! Celerity-style runtime with **instruction-graph scheduling**.
//!
//! The library is organized along the paper's three graph layers plus the
//! substrates they need:
//!
//! - [`grid`] — index-space algebra (boxes, regions, region maps)
//! - [`dag`] — shared DAG storage with horizon-based pruning
//! - [`task`] — user-facing buffers/accessors/range mappers and the TDAG
//! - [`command`] — per-node CDAG generation with push/await-push (§2.4)
//! - [`instruction`] — the IDAG: the paper's core contribution (§3)
//! - [`scheduler`] — scheduler thread with lookahead / resize elision (§4.3)
//! - [`executor`] — out-of-order engine, receive arbitration, baseline (§4.1–4.2)
//! - [`comm`] — communicator: Isend/Irecv + pilot messages over channels
//! - [`runtime`] — PJRT wrapper executing AOT-compiled HLO kernels
//! - [`sim`] — discrete-event cluster simulator for the Fig 6 scaling study
//! - [`apps`] — the three benchmark applications (N-body, RSim, WaveSim)

pub mod buffer;
pub mod comm;
pub mod command;
pub mod dag;
pub mod driver;
pub mod executor;
pub mod grid;
pub mod apps;
pub mod instruction;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod task;
pub mod util;
