//! `celerity launch`: single-command bring-up of a multi-process cluster.
//!
//! `celerity launch -n 8 -- nbody --steps 4` replaces eight hand-typed
//! `celerity worker` invocations: it allocates loopback ports, spawns one
//! worker process per node with the rendezvous peer list, streams each
//! worker's output with a `[node i]` prefix, cross-checks the fence digests
//! the workers print, and aggregates exit codes into a single pass/fail.
//!
//! Workers are launched with heartbeats on by default (see
//! [`crate::executor::HeartbeatMonitor`]), so a worker that dies mid-run
//! takes the cluster down with an attributed error within the heartbeat
//! timeout instead of hanging the launcher forever.
//!
//! The digest cross-check rides on a dedicated marker line: workers print
//! exactly one [`DIGEST_MARKER`] line on success, atomically via a single
//! write, so concurrent node output cannot interleave inside it
//! (`rust/tests/launch_cli.rs` parses the same contract).

use crate::util::NodeId;
use std::io::{BufRead, BufReader};
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

/// First token of the one machine-parseable line a worker prints on
/// success. Kept stable: `rust/tests/launch_cli.rs` and external
/// harnesses grep for it.
pub const DIGEST_MARKER: &str = "CELERITY-DIGEST";

/// Format the marker line: `CELERITY-DIGEST node=<i> value=<hex16>`.
pub fn digest_marker(node: NodeId, digest: u64) -> String {
    format!("{DIGEST_MARKER} node={} value={digest:016x}", node.0)
}

/// Parse a marker line back into `(node, digest)`. Tolerates surrounding
/// whitespace but nothing interleaved inside the line.
pub fn parse_digest_marker(line: &str) -> Option<(u64, u64)> {
    let mut words = line.split_whitespace();
    if words.next()? != DIGEST_MARKER {
        return None;
    }
    let node = words.next()?.strip_prefix("node=")?.parse().ok()?;
    let value = u64::from_str_radix(words.next()?.strip_prefix("value=")?, 16).ok()?;
    Some((node, value))
}

/// First token of the one-line analyzer summary a worker prints under
/// `--analyze` (companion to [`DIGEST_MARKER`]; the launcher aggregates it
/// into [`LaunchReport::analyze`] and fails the run on deny findings).
pub const ANALYZE_MARKER: &str = "CELERITY-ANALYZE";

/// Format the analyzer marker:
/// `CELERITY-ANALYZE node=<i> deny=<d> findings=<f>`.
pub fn analyze_marker(node: NodeId, deny: u64, findings: u64) -> String {
    format!("{ANALYZE_MARKER} node={} deny={deny} findings={findings}", node.0)
}

/// Parse an analyzer marker back into `(node, deny, findings)`.
pub fn parse_analyze_marker(line: &str) -> Option<(u64, u64, u64)> {
    let mut words = line.split_whitespace();
    if words.next()? != ANALYZE_MARKER {
        return None;
    }
    let node = words.next()?.strip_prefix("node=")?.parse().ok()?;
    let deny = words.next()?.strip_prefix("deny=")?.parse().ok()?;
    let findings = words.next()?.strip_prefix("findings=")?.parse().ok()?;
    Some((node, deny, findings))
}

/// Launcher configuration (the `celerity launch` CLI fills this in).
#[derive(Clone)]
#[derive(Debug)]
pub struct LaunchConfig {
    pub nodes: u64,
    /// Application name, forwarded to every worker as `--app`.
    pub app: String,
    /// Extra arguments forwarded to every worker verbatim (`--steps 4`,
    /// `--devices 2`, `--no-collectives`, ...).
    pub app_args: Vec<String>,
    /// Worker heartbeat timeout; 0 disables liveness monitoring.
    pub heartbeat_timeout_ms: u64,
    /// Base path for per-node Chrome trace JSON (`<base>.node<i>.json`).
    pub trace: Option<String>,
    /// Worker binary; defaults to the launcher's own executable.
    pub worker_exe: Option<PathBuf>,
    /// Kill surviving workers once one worker fails (default on). Without
    /// it the launcher waits for the survivors' own heartbeat detectors,
    /// which may be configured slow — or off.
    pub fail_fast: bool,
    /// How long fail-fast lets survivors wind down on their own (their
    /// heartbeat detectors produce better-attributed errors than SIGKILL)
    /// before killing them.
    pub fail_fast_grace_ms: u64,
    /// Deterministic chaos plan forwarded to every worker verbatim
    /// (`--fault-plan`); workers apply their own node-scoped sites.
    pub fault_plan: Option<String>,
}

impl LaunchConfig {
    pub fn new(nodes: u64, app: impl Into<String>) -> LaunchConfig {
        LaunchConfig {
            nodes,
            app: app.into(),
            app_args: Vec::new(),
            heartbeat_timeout_ms: DEFAULT_HEARTBEAT_TIMEOUT_MS,
            trace: None,
            worker_exe: None,
            fail_fast: true,
            fail_fast_grace_ms: DEFAULT_FAIL_FAST_GRACE_MS,
            fault_plan: None,
        }
    }
}

/// Default fail-fast grace window: long enough for survivors' heartbeat
/// detectors (when configured tighter than this) to fire first and report
/// an attributed peer-death error, short enough that no worker outlives a
/// dead cluster by more than a few seconds.
pub const DEFAULT_FAIL_FAST_GRACE_MS: u64 = 5_000;

/// Default worker heartbeat timeout for launched clusters: generous enough
/// for slow CI machines, small enough that a killed worker fails the run
/// in seconds, not forever.
pub const DEFAULT_HEARTBEAT_TIMEOUT_MS: u64 = 10_000;

/// Aggregated outcome of one launched cluster run.
#[derive(Debug)]
pub struct LaunchReport {
    /// Per-node exit code (`None` = terminated by a signal).
    pub exit_codes: Vec<Option<i32>>,
    /// Per-node fence digest parsed from the marker line (`None` = the
    /// worker never printed one, e.g. it died).
    pub digests: Vec<Option<u64>>,
    /// Per-node `(deny, findings)` counts parsed from the worker's
    /// [`ANALYZE_MARKER`] line; `None` unless the run passed `--analyze`
    /// (and the worker survived to print it).
    pub analyze: Vec<Option<(u64, u64)>>,
    /// Launcher-level failures, each attributed to a node where possible.
    pub errors: Vec<String>,
}

impl LaunchReport {
    /// Everything exited 0, every digest arrived, and they all agree.
    pub fn success(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Reserve `n` distinct loopback ports by binding ephemeral listeners,
/// recording their addresses, and releasing them. The tiny window between
/// release and worker bind is benign on loopback: the kernel does not
/// re-hand an ephemeral port while its previous owner lingers in TIME_WAIT.
pub fn allocate_ports(n: u64) -> std::io::Result<Vec<SocketAddr>> {
    let mut listeners = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?);
        listeners.push(l); // hold all n at once so the ports are distinct
    }
    Ok(addrs)
}

/// Spawn the cluster, stream its output, and aggregate the outcome.
///
/// Blocking: returns when every worker has exited. Two mechanisms bound
/// the wait when a worker dies: survivors' heartbeat detectors abort them
/// with attributed errors within the heartbeat timeout, and the launcher's
/// own fail-fast supervision ([`LaunchConfig::fail_fast`], default on)
/// kills any survivor that outlives the grace window regardless.
pub fn launch(cfg: &LaunchConfig) -> std::io::Result<LaunchReport> {
    assert!(cfg.nodes >= 1, "launch needs at least one node");
    let peers = allocate_ports(cfg.nodes)?
        .iter()
        .map(|a| a.to_string())
        .collect::<Vec<_>>()
        .join(",");
    let exe = match &cfg.worker_exe {
        Some(p) => p.clone(),
        None => std::env::current_exe()?,
    };

    let digests: Arc<Mutex<Vec<Option<u64>>>> =
        Arc::new(Mutex::new(vec![None; cfg.nodes as usize]));
    let analyzes: Arc<Mutex<Vec<Option<(u64, u64)>>>> =
        Arc::new(Mutex::new(vec![None; cfg.nodes as usize]));
    let mut children: Vec<Child> = Vec::new();
    let mut streamers = Vec::new();
    for i in 0..cfg.nodes {
        let mut cmd = Command::new(&exe);
        cmd.arg("worker")
            .arg("--app")
            .arg(&cfg.app)
            .arg("--node")
            .arg(i.to_string())
            .arg("--peers")
            .arg(&peers)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped());
        if cfg.heartbeat_timeout_ms > 0 {
            cmd.arg("--heartbeat-timeout").arg(cfg.heartbeat_timeout_ms.to_string());
        }
        if let Some(base) = &cfg.trace {
            cmd.arg("--trace").arg(format!("{base}.node{i}.json"));
        }
        if let Some(plan) = &cfg.fault_plan {
            cmd.arg("--fault-plan").arg(plan);
        }
        cmd.args(&cfg.app_args);
        let mut child = match cmd.spawn() {
            Ok(c) => c,
            Err(e) => {
                // Take down what already started rather than leaking
                // half a cluster of orphans waiting on a rendezvous that
                // will never complete.
                for mut c in children {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                return Err(e);
            }
        };
        let stdout = child.stdout.take().expect("stdout piped");
        let stderr = child.stderr.take().expect("stderr piped");
        let dg = digests.clone();
        let an = analyzes.clone();
        streamers.push(std::thread::spawn(move || {
            for line in BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if let Some((node, value)) = parse_digest_marker(&line) {
                    let mut dg = dg.lock().expect("digest lock poisoned");
                    if let Some(slot) = dg.get_mut(node as usize) {
                        *slot = Some(value);
                    }
                } else if let Some((node, deny, findings)) = parse_analyze_marker(&line) {
                    let mut an = an.lock().expect("analyze lock poisoned");
                    if let Some(slot) = an.get_mut(node as usize) {
                        *slot = Some((deny, findings));
                    }
                }
                println!("[node {i}] {line}");
            }
        }));
        streamers.push(std::thread::spawn(move || {
            for line in BufReader::new(stderr).lines() {
                let Ok(line) = line else { break };
                eprintln!("[node {i}] {line}");
            }
        }));
        children.push(child);
    }

    let (exit_codes, fail_fast_killed, root_cause) = supervise(&mut children, cfg);
    for s in streamers {
        let _ = s.join();
    }

    let digests = Arc::try_unwrap(digests)
        .map(|m| m.into_inner().expect("digest lock poisoned"))
        .unwrap_or_else(|arc| arc.lock().expect("digest lock poisoned").clone());
    let analyzes = Arc::try_unwrap(analyzes)
        .map(|m| m.into_inner().expect("analyze lock poisoned"))
        .unwrap_or_else(|arc| arc.lock().expect("analyze lock poisoned").clone());
    let mut errors = Vec::new();
    // Report the root-cause node first: the worker that failed first
    // explains every downstream abort and fail-fast kill.
    let order: Vec<usize> = match root_cause {
        Some(r) => std::iter::once(r)
            .chain((0..exit_codes.len()).filter(|&i| i != r))
            .collect(),
        None => (0..exit_codes.len()).collect(),
    };
    for i in order {
        match exit_codes[i] {
            Some(0) => {}
            Some(c) => errors.push(format!("node {i} exited with code {c}")),
            None if fail_fast_killed[i] => errors.push(format!(
                "node {i} terminated by fail-fast: node {} failed and node {i} \
                 did not wind down within the {} ms grace window",
                root_cause.unwrap_or(i),
                cfg.fail_fast_grace_ms
            )),
            None => errors.push(format!("node {i} was killed by a signal")),
        }
    }
    for (i, d) in digests.iter().enumerate() {
        if d.is_none() && exit_codes.get(i) == Some(&Some(0)) {
            errors.push(format!("node {i} exited 0 but printed no digest marker"));
        }
    }
    let seen: Vec<(usize, u64)> =
        digests.iter().enumerate().filter_map(|(i, d)| d.map(|v| (i, v))).collect();
    if let Some(((first_node, first), rest)) = seen.split_first() {
        for (i, v) in rest {
            if v != first {
                errors.push(format!(
                    "digest mismatch: node {first_node} got {first:016x} but node {i} got {v:016x}"
                ));
            }
        }
    }
    // Deny-level analyzer findings fail the launch like any other per-node
    // failure (the worker reports them; warn-level findings are advisory).
    for (i, a) in analyzes.iter().enumerate() {
        if let Some((deny, findings)) = a {
            if *deny > 0 {
                errors.push(format!(
                    "node {i}: analyzer reported {deny} deny finding(s) (of {findings} total)"
                ));
            }
        }
    }
    Ok(LaunchReport { exit_codes, digests, analyze: analyzes, errors })
}

/// Reap workers without blocking on any single one. Returns per-node exit
/// codes, which nodes the launcher itself killed, and the index of the
/// first failing node (the root cause) if any.
///
/// With `fail_fast` (the default), the first nonzero/signal exit starts a
/// grace window in which survivors may wind down on their own — their
/// heartbeat detectors produce attributed errors SIGKILL cannot. Survivors
/// that outlive the window are killed: no worker outlives a dead cluster
/// indefinitely, even with heartbeats disabled.
fn supervise(
    children: &mut [Child],
    cfg: &LaunchConfig,
) -> (Vec<Option<i32>>, Vec<bool>, Option<usize>) {
    use std::time::{Duration, Instant};
    let n = children.len();
    // Outer None = still running; inner None = killed by a signal.
    let mut codes: Vec<Option<Option<i32>>> = vec![None; n];
    let mut killed = vec![false; n];
    let mut root_cause: Option<usize> = None;
    let mut deadline: Option<Instant> = None;
    loop {
        let mut running = 0usize;
        for (i, child) in children.iter_mut().enumerate() {
            if codes[i].is_some() {
                continue;
            }
            match child.try_wait() {
                Ok(Some(status)) => {
                    codes[i] = Some(status.code());
                    if status.code() != Some(0) && root_cause.is_none() {
                        root_cause = Some(i);
                        if cfg.fail_fast {
                            deadline = Some(
                                Instant::now()
                                    + Duration::from_millis(cfg.fail_fast_grace_ms),
                            );
                        }
                    }
                }
                Ok(None) => running += 1,
                Err(e) => {
                    // Plain "launch:" prefix: "[launch]" is reserved for
                    // the final error list, whose first line names the
                    // root cause (tests and users key on that contract).
                    eprintln!("launch: waiting on node {i}: {e}");
                    codes[i] = Some(None);
                    root_cause.get_or_insert(i);
                }
            }
        }
        if running == 0 {
            break;
        }
        if let Some(d) = deadline {
            if Instant::now() >= d {
                for (i, child) in children.iter_mut().enumerate() {
                    if codes[i].is_none() {
                        eprintln!(
                            "launch: fail-fast: killing node {i} (grace window expired)"
                        );
                        let _ = child.kill();
                        killed[i] = true;
                    }
                }
                // The kills are reaped by the next try_wait round.
                deadline = None;
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    let codes = codes
        .into_iter()
        .map(|c| c.expect("supervise exits only once every child is reaped"))
        .collect();
    (codes, killed, root_cause)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_marker_round_trips() {
        let line = digest_marker(NodeId(3), 0xdead_beef_0123_4567);
        assert_eq!(parse_digest_marker(&line), Some((3, 0xdead_beef_0123_4567)));
        // Prefix noise must not parse: the marker is a whole-line contract.
        assert_eq!(parse_digest_marker(&format!("x {line}")), None);
        assert_eq!(parse_digest_marker("CELERITY-DIGEST node=1"), None);
        assert_eq!(parse_digest_marker("CELERITY-DIGEST node=1 value=xyz"), None);
        assert_eq!(parse_digest_marker("unrelated output"), None);
    }

    #[test]
    fn analyze_marker_round_trips() {
        let line = analyze_marker(NodeId(2), 1, 4);
        assert_eq!(line, "CELERITY-ANALYZE node=2 deny=1 findings=4");
        assert_eq!(parse_analyze_marker(&line), Some((2, 1, 4)));
        // The two marker grammars never cross-parse.
        assert_eq!(parse_digest_marker(&line), None);
        assert_eq!(parse_analyze_marker(&digest_marker(NodeId(2), 7)), None);
        assert_eq!(parse_analyze_marker("CELERITY-ANALYZE node=2 deny=x findings=4"), None);
        assert_eq!(parse_analyze_marker("CELERITY-ANALYZE node=2"), None);
    }

    #[test]
    fn allocated_ports_are_distinct_and_bindable() {
        let addrs = allocate_ports(4).expect("allocate");
        assert_eq!(addrs.len(), 4);
        let mut ports: Vec<u16> = addrs.iter().map(|a| a.port()).collect();
        ports.sort_unstable();
        ports.dedup();
        assert_eq!(ports.len(), 4, "ports must be distinct");
        // And actually free again: a worker must be able to bind them.
        for a in &addrs {
            TcpListener::bind(a).expect("released port must be bindable");
        }
    }

    #[test]
    fn report_aggregation_flags_failures() {
        let ok = LaunchReport {
            exit_codes: vec![Some(0), Some(0)],
            digests: vec![Some(7), Some(7)],
            analyze: vec![None, None],
            errors: vec![],
        };
        assert!(ok.success());
        let bad = LaunchReport {
            exit_codes: vec![Some(0), Some(1)],
            digests: vec![Some(7), None],
            analyze: vec![None, None],
            errors: vec!["node 1 exited with code 1".into()],
        };
        assert!(!bad.success());
    }
}
