//! The task layer: user-facing API and TDAG generation (§2.4).
//!
//! Tasks represent operations "the cluster will execute collectively". The
//! task graph is generated identically on all nodes, with dependencies
//! computed "as if the program were executing on a single device" — at the
//! granularity of individual buffer regions, not whole buffers (§2.3).

mod access;
mod group;
mod manager;

pub use access::{Access, AccessMode, RangeMapper};
pub use group::{Accessor, CommandGroup, QueueError};
pub use manager::{DebugEvent, TaskManager};

use crate::grid::Range;
use crate::util::TaskId;
use std::sync::Arc;

/// What an epoch synchronizes (§3.5). Epochs are graph-based barriers
/// between the runtime and the user-controlled main thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpochAction {
    /// The implicit initial epoch; original producer of host-initialized
    /// buffer contents.
    Init,
    /// An explicit `queue.wait()` barrier.
    Barrier,
    /// Runtime shutdown; last node of every graph.
    Shutdown,
}

/// The operation a task performs.
#[derive(Debug, Clone)]
pub enum TaskKind {
    /// Launch a data-parallel kernel over `range`, distributed across all
    /// devices in the cluster.
    DeviceCompute {
        range: Range,
        accesses: Vec<Access>,
        /// Name of the AOT-compiled kernel artifact to execute (real mode);
        /// sim mode only uses the cost hint.
        kernel: Option<String>,
        /// Cost model hint: abstract work units (≈flops) per work item.
        work_per_item: f64,
    },
    /// Run a host functor over `range`, split across nodes but executed in
    /// host threads.
    HostTask { range: Range, accesses: Vec<Access>, work_per_item: f64 },
    /// Graph-based synchronization with the main thread (§3.5).
    Epoch(EpochAction),
    /// Scheduling-complexity bound; prunes tracking structures (§3.5).
    Horizon,
}

impl TaskKind {
    /// Declared buffer accesses, if this is a compute-like task.
    pub fn accesses(&self) -> &[Access] {
        match self {
            TaskKind::DeviceCompute { accesses, .. } | TaskKind::HostTask { accesses, .. } => {
                accesses
            }
            _ => &[],
        }
    }

    /// Kernel index space, if compute-like.
    pub fn execution_range(&self) -> Option<Range> {
        match self {
            TaskKind::DeviceCompute { range, .. } | TaskKind::HostTask { range, .. } => {
                Some(*range)
            }
            _ => None,
        }
    }
}

/// A node of the task graph. Self-contained (carries its dependency list) so
/// `Arc<Task>` can be shipped to the scheduler thread without sharing the
/// graph structure.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    pub name: String,
    pub kind: TaskKind,
    /// Predecessors with the reason for the edge.
    pub deps: Vec<(TaskId, crate::dag::DepKind)>,
    /// Length of the longest dependency chain ending at this task; drives
    /// horizon generation.
    pub critical_path: u64,
}

impl Task {
    pub fn is_horizon(&self) -> bool {
        matches!(self.kind, TaskKind::Horizon)
    }

    pub fn is_epoch(&self) -> bool {
        matches!(self.kind, TaskKind::Epoch(_))
    }
}

/// Builder for submitting a task to the queue: the command-group equivalent
/// of Listing 1, in builder form.
///
/// ```no_run
/// # // no_run: rustdoc test binaries lack the libxla rpath of this image.
/// # use celerity::task::*; use celerity::grid::Range; use celerity::util::BufferId;
/// let decl = TaskDecl::device("timestep", Range::d1(4096))
///     .read(BufferId(0), RangeMapper::All)
///     .read_write(BufferId(1), RangeMapper::OneToOne)
///     .kernel("nbody_timestep");
/// assert_eq!(decl.accesses.len(), 2);
/// ```
#[derive(Debug, Clone)]
pub struct TaskDecl {
    pub name: String,
    pub range: Range,
    pub accesses: Vec<Access>,
    pub kernel: Option<String>,
    pub work_per_item: f64,
    pub on_host: bool,
}

impl TaskDecl {
    /// Start a device-kernel task over the given index space.
    pub fn device(name: impl Into<String>, range: Range) -> Self {
        TaskDecl {
            name: name.into(),
            range,
            accesses: Vec::new(),
            kernel: None,
            work_per_item: 1.0,
            on_host: false,
        }
    }

    /// Start a host-task over the given index space.
    pub fn host(name: impl Into<String>, range: Range) -> Self {
        TaskDecl { on_host: true, ..TaskDecl::device(name, range) }
    }

    /// Typed [`crate::buffer::Buffer`] handles and raw
    /// [`BufferId`](crate::util::BufferId)s are both accepted.
    pub fn access(
        mut self,
        buffer: impl Into<crate::util::BufferId>,
        mode: AccessMode,
        mapper: RangeMapper,
    ) -> Self {
        self.accesses.push(Access::new(buffer.into(), mode, mapper));
        self
    }

    pub fn read(self, buffer: impl Into<crate::util::BufferId>, mapper: RangeMapper) -> Self {
        self.access(buffer, AccessMode::Read, mapper)
    }

    pub fn write(self, buffer: impl Into<crate::util::BufferId>, mapper: RangeMapper) -> Self {
        self.access(buffer, AccessMode::Write, mapper)
    }

    pub fn read_write(self, buffer: impl Into<crate::util::BufferId>, mapper: RangeMapper) -> Self {
        self.access(buffer, AccessMode::ReadWrite, mapper)
    }

    pub fn discard_write(
        self,
        buffer: impl Into<crate::util::BufferId>,
        mapper: RangeMapper,
    ) -> Self {
        self.access(buffer, AccessMode::DiscardWrite, mapper)
    }

    /// Attach the name of the AOT kernel artifact to execute in real mode.
    pub fn kernel(mut self, name: impl Into<String>) -> Self {
        self.kernel = Some(name.into());
        self
    }

    /// Cost-model hint for sim mode: abstract work units per work item.
    pub fn work_per_item(mut self, w: f64) -> Self {
        self.work_per_item = w;
        self
    }

    pub(crate) fn into_kind(self) -> (String, TaskKind) {
        let name = self.name;
        let kind = if self.on_host {
            TaskKind::HostTask {
                range: self.range,
                accesses: self.accesses,
                work_per_item: self.work_per_item,
            }
        } else {
            TaskKind::DeviceCompute {
                range: self.range,
                accesses: self.accesses,
                kernel: self.kernel,
                work_per_item: self.work_per_item,
            }
        };
        (name, kind)
    }
}

/// Reference-counted task handle shared between threads.
pub type TaskRef = Arc<Task>;
