//! The typed command-group API (Listing 1).
//!
//! A command group scopes accessor declarations and the kernel launch into
//! one closure, mirroring Celerity/SYCL:
//!
//! ```no_run
//! # // no_run: rustdoc test binaries lack the libxla rpath of this image.
//! # use celerity::driver::{run_cluster, ClusterConfig};
//! # use celerity::grid::Range;
//! # use celerity::task::RangeMapper;
//! # let _ = run_cluster(ClusterConfig::default(), |q| {
//! let n = Range::d1(1024);
//! let a = q.create_buffer::<f32>("A", n);
//! let b = q.create_buffer::<f32>("B", n);
//! q.submit(|cgh| {
//!     cgh.discard_write(a, RangeMapper::OneToOne);
//!     cgh.parallel_for("iota", n);
//! })
//! .unwrap();
//! q.submit(|cgh| {
//!     cgh.read(a, RangeMapper::All);
//!     cgh.discard_write(b, RangeMapper::OneToOne);
//!     cgh.parallel_for("prefix_mean", n);
//! })
//! .unwrap();
//! let out: Vec<f32> = q.fence(b).unwrap();
//! # });
//! ```
//!
//! The builder lowers to [`TaskDecl`], which stays the internal IR consumed
//! by the TDAG generator — the typed surface is a veneer, not a new graph
//! layer.

use super::{Access, AccessMode, RangeMapper, TaskDecl};
use crate::buffer::Buffer;
use crate::dtype::{DType, Elem};
use crate::grid::Range;
use crate::util::BufferId;
use std::fmt;

/// Errors surfaced by the typed queue API ([`crate::driver::Queue`]):
/// shape/dtype mismatches of typed init/fence operations, malformed command
/// groups, and §4.4 runtime errors observed while synchronizing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueueError {
    /// The command-group closure declared no kernel launch or host task.
    IncompleteCommandGroup,
    /// A typed handle refers to a buffer this queue never created.
    UnknownBuffer(BufferId),
    /// Element count does not match the buffer's index-space size.
    ShapeMismatch {
        buffer: BufferId,
        expected_elems: u64,
        got_elems: u64,
    },
    /// The handle's element layout disagrees with the registered buffer.
    DTypeMismatch {
        buffer: BufferId,
        expected: DType,
        expected_lanes: usize,
        got: DType,
        got_lanes: usize,
    },
    /// §4.4 correctness errors reported by the scheduler or executor while
    /// waiting (overlapping writes, out-of-bounds accesses, missing
    /// kernels, stalls).
    Runtime(Vec<String>),
}

impl fmt::Display for QueueError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QueueError::IncompleteCommandGroup => {
                write!(f, "command group declared no parallel_for or host task")
            }
            QueueError::UnknownBuffer(b) => write!(f, "unknown buffer {b}"),
            QueueError::ShapeMismatch { buffer, expected_elems, got_elems } => write!(
                f,
                "shape mismatch on {buffer}: buffer holds {expected_elems} elements, got {got_elems}"
            ),
            QueueError::DTypeMismatch { buffer, expected, expected_lanes, got, got_lanes } => {
                write!(
                    f,
                    "dtype mismatch on {buffer}: buffer is {expected}x{expected_lanes}, \
                     handle is {got}x{got_lanes}"
                )
            }
            QueueError::Runtime(errs) => {
                write!(f, "{} runtime error(s): {}", errs.len(), errs.join("; "))
            }
        }
    }
}

impl std::error::Error for QueueError {}

/// A declared accessor: proof that `buffer` was registered with the command
/// group, plus its position in the task's access list (the `ctx.view(i)`
/// index seen by the kernel functor).
#[derive(Debug, Clone, Copy)]
pub struct Accessor<T: Elem> {
    pub buffer: Buffer<T>,
    pub mode: AccessMode,
    /// Declaration index: `KernelCtx::view(index)` is this accessor's view.
    pub index: usize,
}

/// Collects accessor declarations and the kernel launch of one command
/// group; handed to the closure passed to `Queue::submit` /
/// `TaskManager::submit_group`.
#[derive(Debug)]
pub struct CommandGroup {
    accesses: Vec<Access>,
    name: Option<String>,
    kernel: Option<String>,
    range: Option<Range>,
    on_host: bool,
    work_per_item: f64,
}

impl CommandGroup {
    /// Sole constructor: `work_per_item` defaults to 1.0 (one abstract
    /// work unit per item), matching `TaskDecl`'s default.
    pub(crate) fn new() -> Self {
        CommandGroup {
            accesses: Vec::new(),
            name: None,
            kernel: None,
            range: None,
            on_host: false,
            work_per_item: 1.0,
        }
    }

    fn access<T: Elem>(
        &mut self,
        buffer: Buffer<T>,
        mode: AccessMode,
        mapper: RangeMapper,
    ) -> Accessor<T> {
        let index = self.accesses.len();
        self.accesses.push(Access::new(buffer.id(), mode, mapper));
        Accessor { buffer, mode, index }
    }

    /// Declare a consumer access.
    pub fn read<T: Elem>(&mut self, buffer: Buffer<T>, mapper: RangeMapper) -> Accessor<T> {
        self.access(buffer, AccessMode::Read, mapper)
    }

    /// Declare a producer access that overwrites the mapped region.
    pub fn write<T: Elem>(&mut self, buffer: Buffer<T>, mapper: RangeMapper) -> Accessor<T> {
        self.access(buffer, AccessMode::Write, mapper)
    }

    /// Declare a read-modify-write access.
    pub fn read_write<T: Elem>(&mut self, buffer: Buffer<T>, mapper: RangeMapper) -> Accessor<T> {
        self.access(buffer, AccessMode::ReadWrite, mapper)
    }

    /// Declare a producer access that does not preserve prior contents.
    pub fn discard_write<T: Elem>(
        &mut self,
        buffer: Buffer<T>,
        mapper: RangeMapper,
    ) -> Accessor<T> {
        self.access(buffer, AccessMode::DiscardWrite, mapper)
    }

    /// Launch a device kernel over `range`. `kernel` names both the task
    /// and the registered kernel implementation / AOT artifact.
    pub fn parallel_for(&mut self, kernel: impl Into<String>, range: Range) -> &mut Self {
        let kernel = kernel.into();
        self.name = Some(kernel.clone());
        self.kernel = Some(kernel);
        self.range = Some(range);
        self.on_host = false;
        self
    }

    /// Launch a host task over `range` (split across nodes, executed in
    /// host threads).
    pub fn host_task(&mut self, name: impl Into<String>, range: Range) -> &mut Self {
        self.name = Some(name.into());
        self.kernel = None;
        self.range = Some(range);
        self.on_host = true;
        self
    }

    /// Cost-model hint for sim mode: abstract work units per work item.
    pub fn work_per_item(&mut self, w: f64) -> &mut Self {
        self.work_per_item = w;
        self
    }

    /// Lower to the internal IR. Errors if the closure never declared a
    /// launch.
    pub(crate) fn into_decl(self) -> Result<TaskDecl, QueueError> {
        let (Some(name), Some(range)) = (self.name, self.range) else {
            return Err(QueueError::IncompleteCommandGroup);
        };
        let mut decl = if self.on_host {
            TaskDecl::host(name, range)
        } else {
            TaskDecl::device(name, range)
        };
        decl.accesses = self.accesses;
        decl.work_per_item = self.work_per_item;
        decl.kernel = self.kernel;
        Ok(decl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::BufferId;

    fn buf(id: u64, n: u64) -> Buffer<f32> {
        Buffer::from_raw(BufferId(id), Range::d1(n))
    }

    #[test]
    fn builds_device_decl_in_declaration_order() {
        let mut cgh = CommandGroup::new();
        let a = cgh.read(buf(0, 64), RangeMapper::All);
        let b = cgh.discard_write(buf(1, 64), RangeMapper::OneToOne);
        cgh.parallel_for("iota", Range::d1(64)).work_per_item(3.0);
        assert_eq!(a.index, 0);
        assert_eq!(b.index, 1);
        assert_eq!(b.mode, AccessMode::DiscardWrite);
        let decl = cgh.into_decl().unwrap();
        assert_eq!(decl.name, "iota");
        assert_eq!(decl.kernel.as_deref(), Some("iota"));
        assert!(!decl.on_host);
        assert_eq!(decl.work_per_item, 3.0);
        assert_eq!(decl.accesses.len(), 2);
        assert_eq!(decl.accesses[0].buffer, BufferId(0));
        assert_eq!(decl.accesses[0].mode, AccessMode::Read);
        assert_eq!(decl.accesses[1].buffer, BufferId(1));
        assert_eq!(decl.accesses[1].mode, AccessMode::DiscardWrite);
    }

    #[test]
    fn builds_host_decl() {
        let mut cgh = CommandGroup::new();
        cgh.read(buf(2, 16), RangeMapper::All);
        cgh.host_task("sink", Range::d1(16));
        let decl = cgh.into_decl().unwrap();
        assert!(decl.on_host);
        assert_eq!(decl.name, "sink");
        assert!(decl.kernel.is_none());
    }

    #[test]
    fn missing_launch_is_an_error_not_a_panic() {
        let mut cgh = CommandGroup::new();
        cgh.read(buf(0, 8), RangeMapper::All);
        assert_eq!(cgh.into_decl().unwrap_err(), QueueError::IncompleteCommandGroup);
    }

    #[test]
    fn errors_render_for_humans() {
        let e = QueueError::DTypeMismatch {
            buffer: BufferId(3),
            expected: DType::F32,
            expected_lanes: 1,
            got: DType::I32,
            got_lanes: 1,
        };
        let s = e.to_string();
        assert!(s.contains("B3") && s.contains("f32") && s.contains("i32"), "{s}");
    }
}
