//! Access modes and range mappers.
//!
//! Accessors are the metadata channel between the user program and the
//! scheduler (§2.1): they declare *how* (mode) and *where* (range mapper) a
//! kernel touches a buffer, which is "sufficient for Celerity to compute
//! data locality and dataflow resulting from an arbitrary subdivision of
//! work within the cluster".

use crate::grid::{GridBox, Point, Range, Region};
use crate::util::BufferId;

/// How a kernel accesses a buffer region.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessMode {
    /// Consumer access; creates dataflow dependencies.
    Read,
    /// Producer access; overwrites the region completely.
    Write,
    /// Read-modify-write.
    ReadWrite,
    /// Producer access that does not preserve previous contents; carries no
    /// dataflow dependency on earlier producers (used e.g. by the RSim
    /// "workaround" zero-init kernel, §5.2).
    DiscardWrite,
}

impl AccessMode {
    /// Whether this access consumes previous buffer contents.
    pub fn is_consumer(self) -> bool {
        matches!(self, AccessMode::Read | AccessMode::ReadWrite)
    }

    /// Whether this access produces new buffer contents.
    pub fn is_producer(self) -> bool {
        matches!(self, AccessMode::Write | AccessMode::ReadWrite | AccessMode::DiscardWrite)
    }
}

/// The relationship between kernel index space and buffer index space
/// (§2.1). Applied to a *chunk* (sub-box) of the kernel index space, a
/// mapper yields the buffer region the chunk accesses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RangeMapper {
    /// Kernel and buffer index space are identical.
    OneToOne,
    /// Every chunk accesses the entire buffer (the N-body "all-gather").
    All,
    /// Every chunk accesses the same fixed buffer region (RSim uses this to
    /// read all previously produced rows and append one new row).
    Fixed(Region),
    /// One-to-one dilated by a per-axis margin, clamped to the buffer range
    /// (stencil halo exchange; WaveSim uses margin `[1, 1]`).
    Neighborhood(Range),
    /// Collapse the kernel index along `dim`: a chunk accesses the buffer
    /// rows matching its extent on all axes except `dim`, which spans fully.
    Slice(usize),
    /// One-to-one with a constant offset into the buffer.
    Shift(Point),
    /// Map a 1-D kernel chunk onto the *columns* of one fixed buffer row:
    /// chunk `[c0, c1)` → buffer box `[(row, c0), (row+1, c1))`. This is the
    /// write pattern of RSim's appended row — device splits of the kernel
    /// index space produce disjoint column ranges (§4.4 requirement).
    RowSlice(u64),
}

impl RangeMapper {
    /// Map a chunk of the kernel index space onto the buffer index space.
    ///
    /// `kernel_range` is the full kernel index space of the task and
    /// `buffer_range` the full buffer extent (needed for `All`,
    /// `Neighborhood` clamping and `Slice`).
    pub fn apply(&self, chunk: &GridBox, _kernel_range: Range, buffer_range: Range) -> Region {
        if chunk.is_empty() {
            return Region::empty();
        }
        match self {
            RangeMapper::OneToOne => {
                Region::from(chunk.intersection(&GridBox::full(buffer_range)))
            }
            RangeMapper::All => Region::full(buffer_range),
            RangeMapper::Fixed(r) => r.clone(),
            RangeMapper::Neighborhood(margin) => {
                Region::from(chunk.dilated(*margin, buffer_range))
            }
            RangeMapper::Slice(dim) => {
                let mut b = *chunk;
                b.min[*dim] = 0;
                b.max[*dim] = buffer_range[*dim];
                Region::from(b.intersection(&GridBox::full(buffer_range)))
            }
            RangeMapper::Shift(offset) => {
                let b = chunk.translated(*offset);
                Region::from(b.intersection(&GridBox::full(buffer_range)))
            }
            RangeMapper::RowSlice(row) => {
                let b = GridBox::d2((*row, chunk.min[0]), (*row + 1, chunk.max[0]));
                Region::from(b.intersection(&GridBox::full(buffer_range)))
            }
        }
    }
}

/// One declared buffer access of a task: the accessor metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Access {
    pub buffer: BufferId,
    pub mode: AccessMode,
    pub mapper: RangeMapper,
}

impl Access {
    pub fn new(buffer: BufferId, mode: AccessMode, mapper: RangeMapper) -> Self {
        Access { buffer, mode, mapper }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KR: Range = Range([64, 1, 1]);
    const BR: Range = Range([64, 1, 1]);

    #[test]
    fn one_to_one_maps_identically() {
        let chunk = GridBox::d1(16, 32);
        assert_eq!(RangeMapper::OneToOne.apply(&chunk, KR, BR), Region::from(chunk));
    }

    #[test]
    fn one_to_one_clamps_to_buffer() {
        // Kernel larger than buffer: access clamps (matches SYCL UB-avoidance).
        let chunk = GridBox::d1(48, 64);
        let small = Range::d1(56);
        assert_eq!(
            RangeMapper::OneToOne.apply(&chunk, KR, small),
            Region::from(GridBox::d1(48, 56))
        );
    }

    #[test]
    fn all_ignores_chunk() {
        let r = RangeMapper::All.apply(&GridBox::d1(0, 1), KR, BR);
        assert_eq!(r, Region::full(BR));
    }

    #[test]
    fn fixed_returns_fixed() {
        let fix = Region::from(GridBox::d1(10, 20));
        assert_eq!(
            RangeMapper::Fixed(fix.clone()).apply(&GridBox::d1(0, 64), KR, BR),
            fix
        );
    }

    #[test]
    fn neighborhood_dilates_and_clamps() {
        let m = RangeMapper::Neighborhood(Range::d1(2));
        assert_eq!(m.apply(&GridBox::d1(0, 8), KR, BR), Region::from(GridBox::d1(0, 10)));
        assert_eq!(m.apply(&GridBox::d1(56, 64), KR, BR), Region::from(GridBox::d1(54, 64)));
        assert_eq!(m.apply(&GridBox::d1(16, 32), KR, BR), Region::from(GridBox::d1(14, 34)));
    }

    #[test]
    fn neighborhood_2d() {
        let kr = Range::d2(8, 8);
        let br = Range::d2(8, 8);
        let m = RangeMapper::Neighborhood(Range::d2(1, 1));
        let r = m.apply(&GridBox::d2((2, 2), (4, 4)), kr, br);
        assert_eq!(r, Region::from(GridBox::d2((1, 1), (5, 5))));
    }

    #[test]
    fn slice_spans_full_axis() {
        let kr = Range::d2(8, 8);
        let br = Range::d2(8, 8);
        let m = RangeMapper::Slice(1);
        let r = m.apply(&GridBox::d2((2, 3), (4, 5)), kr, br);
        assert_eq!(r, Region::from(GridBox::d2((2, 0), (4, 8))));
    }

    #[test]
    fn shift_translates() {
        let m = RangeMapper::Shift(Point::d1(8));
        assert_eq!(m.apply(&GridBox::d1(0, 8), KR, BR), Region::from(GridBox::d1(8, 16)));
        // shifted past the end clamps away
        assert_eq!(m.apply(&GridBox::d1(60, 64), KR, BR), Region::empty());
    }

    #[test]
    fn row_slice_maps_chunk_to_columns() {
        let kr = Range::d1(16);
        let br = Range::d2(8, 16);
        let m = RangeMapper::RowSlice(3);
        assert_eq!(
            m.apply(&GridBox::d1(4, 12), kr, br),
            Region::from(GridBox::d2((3, 4), (4, 12)))
        );
        // Row outside the buffer clamps away.
        assert!(RangeMapper::RowSlice(9).apply(&GridBox::d1(0, 4), kr, br).is_empty());
    }

    #[test]
    fn mode_predicates() {
        assert!(AccessMode::Read.is_consumer() && !AccessMode::Read.is_producer());
        assert!(AccessMode::Write.is_producer() && !AccessMode::Write.is_consumer());
        assert!(AccessMode::ReadWrite.is_consumer() && AccessMode::ReadWrite.is_producer());
        assert!(AccessMode::DiscardWrite.is_producer() && !AccessMode::DiscardWrite.is_consumer());
    }

    #[test]
    fn empty_chunk_maps_empty() {
        for m in [
            RangeMapper::OneToOne,
            RangeMapper::All,
            RangeMapper::Neighborhood(Range::d1(1)),
        ] {
            assert!(m.apply(&GridBox::EMPTY, KR, BR).is_empty(), "{m:?}");
        }
    }
}
