//! TDAG generation: element-granular dependency tracking, horizons, epochs.

use super::{Access, CommandGroup, EpochAction, QueueError, Task, TaskDecl, TaskKind, TaskRef};
use crate::buffer::{Buffer, BufferPool};
use crate::dag::{Dag, Dep, DepKind};
use crate::dtype::{DType, Elem};
use crate::grid::{Region, RegionMap};
use crate::util::{BufferId, JobId, TaskId};
use std::collections::HashMap;
use std::sync::Arc;

/// Default horizon step: a new horizon is emitted whenever the critical path
/// grew by this many tasks since the last horizon (follows Celerity's
/// default; §3.5 / [23]).
pub const DEFAULT_HORIZON_STEP: u64 = 4;

/// A diagnostic produced by the user-facing debug facilities (§4.4).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DebugEvent {
    /// A consumer access covers a region that no task has produced and that
    /// was not host-initialized.
    UninitializedRead { task: TaskId, buffer: BufferId, region: Region },
}

/// Per-buffer TDAG tracking state.
#[derive(Debug)]
struct BufferState {
    /// Last producer task of every buffer element.
    last_writers: RegionMap<TaskId>,
    /// Consumers since the last write of every element (anti-dependency
    /// sources).
    readers_since: RegionMap<Vec<TaskId>>,
    /// Which elements hold defined values (host-init or produced).
    initialized: RegionMap<bool>,
}

/// Generates the task graph from a stream of command-group submissions.
///
/// Owns the [`BufferPool`] (buffers are created through the queue) and the
/// per-buffer region tracking. Emits horizon and epoch tasks interleaved
/// with user tasks; new tasks accumulate in an outbox drained by the queue
/// and shipped to the scheduler thread.
pub struct TaskManager {
    dag: Dag<TaskRef>,
    buffers: BufferPool,
    states: HashMap<BufferId, BufferState>,
    outbox: Vec<TaskRef>,
    debug_events: Vec<DebugEvent>,
    horizon_step: u64,
    max_critical_path: u64,
    last_horizon_cp: u64,
    /// The most recent horizon (not yet applied).
    current_horizon: Option<TaskId>,
    /// The horizon before that; applied = substituted for older producers.
    applied_horizon: Option<TaskId>,
    /// The most recent epoch; implicit dependency of everything after it.
    last_epoch: TaskId,
}

impl TaskManager {
    /// Create a manager; generates the initial epoch immediately.
    pub fn new() -> Self {
        Self::with_horizon_step(DEFAULT_HORIZON_STEP)
    }

    /// Create a manager with a custom horizon step (tests, ablations).
    pub fn with_horizon_step(horizon_step: u64) -> Self {
        Self::with_job_and_horizon_step(JobId(0), horizon_step)
    }

    /// Create a manager whose task and buffer ids live in `job`'s namespace
    /// (high-bit tagged, see [`JobId::base`]). Job 0 is the single-tenant
    /// default and yields numerically unchanged ids.
    pub fn with_job(job: JobId) -> Self {
        Self::with_job_and_horizon_step(job, DEFAULT_HORIZON_STEP)
    }

    /// Combined constructor underneath the convenience wrappers.
    pub fn with_job_and_horizon_step(job: JobId, horizon_step: u64) -> Self {
        let base = job.base();
        let mut tm = TaskManager {
            dag: Dag::with_base(base),
            buffers: BufferPool::with_base(base),
            states: HashMap::new(),
            outbox: Vec::new(),
            debug_events: Vec::new(),
            horizon_step,
            max_critical_path: 0,
            last_horizon_cp: 0,
            current_horizon: None,
            applied_horizon: None,
            last_epoch: TaskId(0),
        };
        let init = tm.push_task("init".into(), TaskKind::Epoch(EpochAction::Init), vec![]);
        tm.last_epoch = init;
        tm
    }

    /// Create a typed buffer. `host_initialized` buffers start fully
    /// defined, with the initial epoch as their original producer.
    pub fn create_buffer<T: Elem>(
        &mut self,
        name: impl Into<String>,
        range: crate::grid::Range,
        host_initialized: bool,
    ) -> Buffer<T> {
        let id = self.create_buffer_raw(name, range, T::DTYPE, T::LANES, host_initialized);
        Buffer::from_raw(id, range)
    }

    /// Untyped creation path shared by the typed wrapper and tests that
    /// only care about element *size*.
    pub(crate) fn create_buffer_raw(
        &mut self,
        name: impl Into<String>,
        range: crate::grid::Range,
        dtype: DType,
        lanes: usize,
        host_initialized: bool,
    ) -> BufferId {
        let id = self.buffers.create(name, range, dtype, lanes, host_initialized);
        let info = self.buffers.get(id);
        self.states.insert(
            id,
            BufferState {
                last_writers: RegionMap::new(info.range, self.last_epoch),
                readers_since: RegionMap::new(info.range, Vec::new()),
                initialized: RegionMap::new(info.range, host_initialized),
            },
        );
        id
    }

    /// Retroactively mark a buffer host-initialized: the user supplied its
    /// full contents (`Queue::init`) before any task produced them. The
    /// init epoch is already every element's last writer, so only the
    /// initialization tracking changes.
    pub(crate) fn mark_host_initialized(&mut self, id: BufferId) {
        let range = self.buffers.get(id).range;
        self.buffers.get_mut(id).host_initialized = Region::full(range);
        if let Some(st) = self.states.get_mut(&id) {
            st.initialized.update_region(&Region::full(range), true);
        }
    }

    pub fn buffers(&self) -> &BufferPool {
        &self.buffers
    }

    /// Submit a typed command group (the Listing-1 `q.submit(...)` surface
    /// for graph-only consumers: the simulator, benches and graph dumps).
    /// Returns the id of the generated task.
    pub fn submit_group(
        &mut self,
        build: impl FnOnce(&mut CommandGroup),
    ) -> Result<TaskId, QueueError> {
        let mut cgh = CommandGroup::new();
        build(&mut cgh);
        Ok(self.submit(cgh.into_decl()?))
    }

    /// Submit one task declaration (the internal IR underneath command
    /// groups); returns the id of the generated task. May additionally
    /// generate a horizon task into the outbox.
    pub fn submit(&mut self, decl: TaskDecl) -> TaskId {
        let (name, kind) = decl.into_kind();
        let deps = self.compute_deps(&kind, &name);
        let tid = self.push_task(name, kind, deps);
        self.apply_access_updates(tid);
        self.maybe_generate_horizon();
        tid
    }

    /// Submit an explicit barrier epoch (`queue.wait()`).
    pub fn barrier(&mut self) -> TaskId {
        self.push_epoch(EpochAction::Barrier)
    }

    /// Submit the final shutdown epoch.
    pub fn shutdown(&mut self) -> TaskId {
        self.push_epoch(EpochAction::Shutdown)
    }

    fn push_epoch(&mut self, action: EpochAction) -> TaskId {
        // An epoch depends on the entire execution front.
        let deps: Vec<(TaskId, DepKind)> = self
            .dag
            .front()
            .into_iter()
            .map(|id| (TaskId(id), DepKind::Sync))
            .collect();
        let tid = self.push_task(format!("{action:?}").to_lowercase(), TaskKind::Epoch(action), deps);
        self.last_epoch = tid;
        // The epoch subsumes every earlier producer: later tasks can depend
        // on the epoch alone.
        for st in self.states.values_mut() {
            st.last_writers.apply_to_region(
                &Region::full(st.last_writers.extent().range()),
                |w| if w.0 < tid.0 { tid } else { *w },
            );
            st.readers_since
                .update_region(&Region::full(st.readers_since.extent().range()), Vec::new());
        }
        self.current_horizon = None;
        self.applied_horizon = None;
        tid
    }

    /// Drain tasks generated since the last call (user tasks, horizons,
    /// epochs) in submission order.
    pub fn take_new_tasks(&mut self) -> Vec<TaskRef> {
        std::mem::take(&mut self.outbox)
    }

    /// Drain debug diagnostics (§4.4).
    pub fn take_debug_events(&mut self) -> Vec<DebugEvent> {
        std::mem::take(&mut self.debug_events)
    }

    /// Live task-graph size (bounded by the horizon mechanism).
    pub fn live_tasks(&self) -> usize {
        self.dag.len()
    }

    /// Total tasks ever generated.
    pub fn total_tasks(&self) -> u64 {
        self.dag.total_created()
    }

    /// Access the task graph (tests, graph dumps).
    pub fn dag(&self) -> &Dag<TaskRef> {
        &self.dag
    }

    /// Render the TDAG as Graphviz dot.
    pub fn to_dot(&self) -> String {
        self.dag.to_dot("tdag", |t| format!("{} {}", t.id, t.name))
    }

    fn compute_deps(&mut self, kind: &TaskKind, task_name: &str) -> Vec<(TaskId, DepKind)> {
        let mut deps: Vec<(TaskId, DepKind)> = Vec::new();
        let add = |id: TaskId, kind: DepKind, deps: &mut Vec<(TaskId, DepKind)>| {
            if !deps.iter().any(|(d, _)| *d == id) {
                deps.push((id, kind));
            }
        };
        let range = kind.execution_range().unwrap_or(crate::grid::Range::UNIT);
        for access in kind.accesses() {
            let info = self.buffers.get(access.buffer);
            let region = access
                .mapper
                .apply(&crate::grid::GridBox::full(range), range, info.range);
            let st = &self.states[&access.buffer];
            if access.mode.is_consumer() {
                // Dataflow on the last writer of each fragment.
                st.last_writers.for_each_in_region(&region, |_, writer| {
                    add(*writer, DepKind::Dataflow, &mut deps);
                });
                // Uninitialized-read detection (§4.4).
                let uninit = st
                    .initialized
                    .region_where(|v| !*v)
                    .intersection(&region);
                if !uninit.is_empty() {
                    log::warn!(
                        "task '{task_name}': reading uninitialized region {uninit} of buffer {}",
                        info.name
                    );
                    self.debug_events.push(DebugEvent::UninitializedRead {
                        task: TaskId(self.dag.total_created()),
                        buffer: access.buffer,
                        region: uninit,
                    });
                }
            }
            if access.mode.is_producer() {
                // Anti-dependencies on readers since the last write.
                st.readers_since.for_each_in_region(&region, |_, readers| {
                    for r in readers {
                        add(*r, DepKind::Anti, &mut deps);
                    }
                });
                // Output dependency on the previous writer (ordering only;
                // for DiscardWrite this is still required for the IDAG's
                // allocation lifetime reasoning).
                st.last_writers.for_each_in_region(&region, |_, writer| {
                    add(*writer, DepKind::Output, &mut deps);
                });
            }
        }
        // Everything depends at least on the last epoch.
        if deps.is_empty() {
            add(self.last_epoch, DepKind::Sync, &mut deps);
        }
        deps
    }

    fn apply_access_updates(&mut self, tid: TaskId) {
        let task = self.dag.get(tid.0).expect("epoch task id resolves in the TDAG").payload.clone();
        let range = task.kind.execution_range().unwrap_or(crate::grid::Range::UNIT);
        for Access { buffer, mode, mapper } in task.kind.accesses() {
            let info = self.buffers.get(*buffer);
            let region = mapper.apply(&crate::grid::GridBox::full(range), range, info.range);
            let st = self.states.get_mut(buffer).expect("buffer state tracked since create_buffer");
            if mode.is_producer() {
                st.last_writers.update_region(&region, tid);
                st.readers_since.update_region(&region, Vec::new());
                st.initialized.update_region(&region, true);
            } else {
                st.readers_since.apply_to_region(&region, |rs| {
                    let mut rs = rs.clone();
                    if !rs.contains(&tid) {
                        rs.push(tid);
                    }
                    rs
                });
            }
        }
    }

    fn push_task(
        &mut self,
        name: String,
        kind: TaskKind,
        deps: Vec<(TaskId, DepKind)>,
    ) -> TaskId {
        let id = TaskId(self.dag.total_created());
        let critical_path = deps
            .iter()
            .filter_map(|(d, _)| self.dag.get(d.0))
            .map(|n| n.payload.critical_path + 1)
            .max()
            .unwrap_or(0);
        self.max_critical_path = self.max_critical_path.max(critical_path);
        let task = Arc::new(Task { id, name, kind, deps: deps.clone(), critical_path });
        self.dag.push(
            task.clone(),
            deps.iter().map(|(d, k)| Dep { from: d.0, kind: *k }),
        );
        self.outbox.push(task);
        id
    }

    /// Emit a horizon when the critical path grew by `horizon_step` (§3.5).
    fn maybe_generate_horizon(&mut self) {
        if self.max_critical_path < self.last_horizon_cp + self.horizon_step {
            return;
        }
        self.last_horizon_cp = self.max_critical_path;
        let deps: Vec<(TaskId, DepKind)> = self
            .dag
            .front()
            .into_iter()
            .map(|id| (TaskId(id), DepKind::Sync))
            .collect();
        let hid = self.push_task("horizon".into(), TaskKind::Horizon, deps);

        // Apply the *previous* horizon: it now subsumes all older producers
        // and readers, bounding tracking-structure size.
        if let Some(prev) = self.current_horizon.take() {
            for st in self.states.values_mut() {
                st.last_writers.apply_to_region(
                    &Region::full(st.last_writers.extent().range()),
                    |w| if w.0 < prev.0 { prev } else { *w },
                );
                st.readers_since.apply_to_region(
                    &Region::full(st.readers_since.extent().range()),
                    |rs| {
                        let newer: Vec<TaskId> =
                            rs.iter().copied().filter(|r| r.0 >= prev.0).collect();
                        if newer.len() == rs.len() && !rs.is_empty() {
                            rs.clone()
                        } else if rs.is_empty() {
                            Vec::new()
                        } else {
                            let mut v = vec![prev];
                            v.extend(newer);
                            v
                        }
                    },
                );
            }
            // Prune TDAG storage: nothing before the applied horizon can be
            // referenced anymore.
            self.dag.prune_before(prev.0);
            self.applied_horizon = Some(prev);
        }
        self.current_horizon = Some(hid);
    }
}

impl Default for TaskManager {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::{GridBox, Range};
    use crate::task::RangeMapper;

    fn nbody_like(tm: &mut TaskManager, steps: usize) -> (BufferId, BufferId) {
        let n = Range::d1(64);
        let p = tm.create_buffer::<[f64; 3]>("P", n, true).id();
        let v = tm.create_buffer::<[f64; 3]>("V", n, true).id();
        for _ in 0..steps {
            tm.submit(
                TaskDecl::device("timestep", n)
                    .read(p, RangeMapper::All)
                    .read_write(v, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("update", n)
                    .read(v, RangeMapper::OneToOne)
                    .read_write(p, RangeMapper::OneToOne),
            );
        }
        (p, v)
    }

    #[test]
    fn nbody_forms_linear_chain() {
        // Fig 2: the N-body TDAG is a linear dependency chain.
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        nbody_like(&mut tm, 2);
        let tasks: Vec<TaskRef> = tm.take_new_tasks();
        // init epoch + 4 tasks
        assert_eq!(tasks.len(), 5);
        // T2 (update) depends on T1 (timestep): dataflow on V, anti on P.
        let t2 = &tasks[2];
        assert!(t2.deps.iter().any(|(d, k)| d.0 == 1 && *k == DepKind::Dataflow));
        // T3 (timestep 2) depends on T2 via dataflow on P.
        let t3 = &tasks[3];
        assert!(t3.deps.iter().any(|(d, k)| d.0 == 2 && *k == DepKind::Dataflow));
        // ...and anti/dataflow on T1 via V.
        assert!(t3.deps.iter().any(|(d, _)| d.0 == 1));
    }

    #[test]
    fn independent_tasks_share_no_deps() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(16);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let ta = tm.submit(TaskDecl::device("ta", n).read_write(a, RangeMapper::OneToOne));
        let tb = tm.submit(TaskDecl::device("tb", n).read_write(b, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let find = |id: TaskId| tasks.iter().find(|t| t.id == id).unwrap().clone();
        // Both depend only on the init epoch.
        assert!(find(ta).deps.iter().all(|(d, _)| d.0 == 0));
        assert!(find(tb).deps.iter().all(|(d, _)| d.0 == 0));
    }

    #[test]
    fn disjoint_writes_no_false_deps() {
        // Region granularity: writes to disjoint halves are independent.
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(100);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let lo = RangeMapper::Fixed(Region::from(GridBox::d1(0, 50)));
        let hi = RangeMapper::Fixed(Region::from(GridBox::d1(50, 100)));
        let t1 = tm.submit(TaskDecl::device("lo", n).write(b, lo));
        let t2 = tm.submit(TaskDecl::device("hi", n).write(b, hi.clone()));
        let t3 = tm.submit(TaskDecl::device("rd_hi", n).read(b, hi));
        let tasks = tm.take_new_tasks();
        let find = |id: TaskId| tasks.iter().find(|t| t.id == id).unwrap().clone();
        assert!(!find(t2).deps.iter().any(|(d, _)| *d == t1), "disjoint writes independent");
        // Reader of hi half depends only on t2, not t1.
        assert!(find(t3).deps.iter().any(|(d, _)| *d == t2));
        assert!(!find(t3).deps.iter().any(|(d, _)| *d == t1));
    }

    #[test]
    fn anti_dependency_on_readers() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(16);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let _w1 = tm.submit(TaskDecl::device("w1", n).write(b, RangeMapper::OneToOne));
        let r = tm.submit(TaskDecl::device("r", n).read(b, RangeMapper::OneToOne));
        let w2 = tm.submit(TaskDecl::device("w2", n).write(b, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let w2t = tasks.iter().find(|t| t.id == w2).unwrap();
        assert!(w2t.deps.iter().any(|(d, k)| *d == r && *k == DepKind::Anti));
    }

    #[test]
    fn discard_write_carries_no_dataflow() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(16);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let w1 = tm.submit(TaskDecl::device("w1", n).write(b, RangeMapper::OneToOne));
        let dw = tm.submit(TaskDecl::device("dw", n).discard_write(b, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let dwt = tasks.iter().find(|t| t.id == dw).unwrap();
        // Output ordering still exists, but no Dataflow edge.
        assert!(dwt.deps.iter().any(|(d, k)| *d == w1 && *k == DepKind::Output));
        assert!(!dwt.deps.iter().any(|(_, k)| *k == DepKind::Dataflow));
    }

    #[test]
    fn uninitialized_read_detected() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(16);
        let b = tm.create_buffer::<f64>("B", n, false).id();
        tm.submit(TaskDecl::device("w_half", n).write(
            b,
            RangeMapper::Fixed(Region::from(GridBox::d1(0, 8))),
        ));
        tm.submit(TaskDecl::device("r_all", n).read(b, RangeMapper::All));
        let evs = tm.take_debug_events();
        assert_eq!(evs.len(), 1);
        match &evs[0] {
            DebugEvent::UninitializedRead { buffer, region, .. } => {
                assert_eq!(*buffer, b);
                assert_eq!(*region, Region::from(GridBox::d1(8, 16)));
            }
        }
    }

    #[test]
    fn host_initialized_read_is_clean() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(16);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        tm.submit(TaskDecl::device("r", n).read(b, RangeMapper::All));
        assert!(tm.take_debug_events().is_empty());
    }

    #[test]
    fn horizons_generated_and_bound_tracking() {
        let mut tm = TaskManager::with_horizon_step(2);
        let (_, _) = nbody_like(&mut tm, 20);
        let tasks = tm.take_new_tasks();
        let horizons = tasks.iter().filter(|t| t.is_horizon()).count();
        assert!(horizons >= 8, "expected many horizons, got {horizons}");
        // Tracking is bounded: live TDAG much smaller than total generated.
        assert!(tm.live_tasks() < 20, "live={}", tm.live_tasks());
        assert_eq!(tm.total_tasks(), tasks.len() as u64);
        // Every non-initial task's deps resolve within the outbox.
        for t in &tasks {
            for (d, _) in &t.deps {
                assert!(tasks.iter().any(|u| u.id == *d), "{} dep {d} missing", t.id);
            }
        }
    }

    #[test]
    fn horizon_subsumes_old_producers() {
        let mut tm = TaskManager::with_horizon_step(2);
        let n = Range::d1(16);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        let b = tm.create_buffer::<f64>("B", n, true).id();
        // Write A once early, then churn on B to force horizons.
        tm.submit(TaskDecl::device("wa", n).read_write(a, RangeMapper::OneToOne));
        for _ in 0..10 {
            tm.submit(TaskDecl::device("wb", n).read_write(b, RangeMapper::OneToOne));
        }
        // A later read of A must depend on a *horizon*, not the pruned task.
        let r = tm.submit(TaskDecl::device("ra", n).read(a, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let rt = tasks.iter().find(|t| t.id == r).unwrap();
        let dep_is_horizon = rt.deps.iter().any(|(d, _)| {
            tasks.iter().any(|t| t.id == *d && t.is_horizon())
        });
        assert!(dep_is_horizon, "deps: {:?}", rt.deps);
    }

    #[test]
    fn epoch_resets_tracking() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(16);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let w = tm.submit(TaskDecl::device("w", n).read_write(b, RangeMapper::OneToOne));
        let e = tm.barrier();
        let r = tm.submit(TaskDecl::device("r", n).read(b, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let rt = tasks.iter().find(|t| t.id == r).unwrap();
        // Reader depends on the epoch, not the pre-epoch writer.
        assert!(rt.deps.iter().any(|(d, _)| *d == e));
        assert!(!rt.deps.iter().any(|(d, _)| *d == w));
        // The epoch itself depends on the writer (front).
        let et = tasks.iter().find(|t| t.id == e).unwrap();
        assert!(et.deps.iter().any(|(d, _)| *d == w));
    }

    #[test]
    fn shutdown_epoch_depends_on_front() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(16);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let ta = tm.submit(TaskDecl::device("ta", n).read_write(a, RangeMapper::OneToOne));
        let tb = tm.submit(TaskDecl::device("tb", n).read_write(b, RangeMapper::OneToOne));
        let sd = tm.shutdown();
        let tasks = tm.take_new_tasks();
        let sdt = tasks.iter().find(|t| t.id == sd).unwrap();
        assert!(sdt.deps.iter().any(|(d, _)| *d == ta));
        assert!(sdt.deps.iter().any(|(d, _)| *d == tb));
    }

    #[test]
    fn job_namespace_tags_every_task_and_buffer() {
        let mut tm = TaskManager::with_job(JobId(5));
        let n = Range::d1(16);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        assert_eq!(JobId::of(b.0), JobId(5));
        let t = tm.submit(TaskDecl::device("w", n).read_write(b, RangeMapper::OneToOne));
        assert_eq!(JobId::of(t.0), JobId(5));
        let e = tm.barrier();
        let tasks = tm.take_new_tasks();
        assert!(tasks.iter().all(|t| JobId::of(t.id.0) == JobId(5)));
        // Epoch deps stay inside the namespace.
        let et = tasks.iter().find(|t| t.id == e).unwrap();
        assert!(et.deps.iter().all(|(d, _)| JobId::of(d.0) == JobId(5)));
    }

    #[test]
    fn critical_path_tracks_chain_depth() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        nbody_like(&mut tm, 3);
        let tasks = tm.take_new_tasks();
        // Linear chain: each user task one deeper than its predecessor.
        let cps: Vec<u64> = tasks.iter().map(|t| t.critical_path).collect();
        assert!(cps.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(*cps.last().unwrap() as usize, tasks.len() - 1);
    }
}
