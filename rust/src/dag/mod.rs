//! Generic DAG storage shared by the three intermediate representations.
//!
//! The TDAG, CDAG and IDAG all need the same mechanics: append-only nodes
//! with typed dependency edges, an *execution front* (nodes without
//! successors, Fig 4 caption), and epoch-based pruning so that tracking
//! structures stay bounded (the horizon mechanism, §3.5). `Dag<N>` provides
//! exactly that, with the payload type supplied per layer.
//!
//! Two hot-path properties (§4.1 — horizons and epochs run at a fixed
//! cadence through the scheduler's inner loop):
//!
//! - the execution front is maintained **incrementally** on `push` /
//!   `prune_before` instead of rescanning every live node, so `front()` is
//!   `O(front)`;
//! - dependency sets are **interned**: repeated identical predecessor lists
//!   (ubiquitous in data-parallel programs, where every chunk of a task
//!   depends on the same producers) share one allocation.

pub mod reach;

use std::collections::{BTreeSet, HashMap, HashSet};
use std::fmt;
use std::sync::Arc;

/// Why a dependency edge exists. Mirrors the edge coloring of Fig 2:
/// dataflow (black), anti- and output dependencies (green), and
/// graph-synchronization dependencies via horizons/epochs (violet/orange).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DepKind {
    /// True dataflow: consumer reads what producer wrote.
    Dataflow,
    /// Anti-dependency: writer must wait for earlier reader.
    Anti,
    /// Output dependency: writer-after-writer ordering.
    Output,
    /// Synchronization through horizon/epoch nodes.
    Sync,
}

impl fmt::Display for DepKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DepKind::Dataflow => "dataflow",
            DepKind::Anti => "anti",
            DepKind::Output => "output",
            DepKind::Sync => "sync",
        };
        f.write_str(s)
    }
}

/// A dependency edge: `from` must complete before `to` may start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dep {
    pub from: u64,
    pub kind: DepKind,
}

/// One node of a DAG: a payload plus its predecessor list. The predecessor
/// list is a shared slice — identical dependency sets are interned by
/// [`Dag::push`].
#[derive(Debug, Clone)]
pub struct DagNode<N> {
    pub id: u64,
    pub payload: N,
    pub deps: Arc<[Dep]>,
    /// Number of recorded successors (maintained for front tracking).
    succ_count: usize,
}

impl<N> DagNode<N> {
    /// Predecessor ids, deduplicated, in insertion order.
    pub fn dep_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.deps.iter().map(|d| d.from)
    }
}

/// Interned dependency sets are only worth caching while they repeat;
/// pruning invalidates old sets anyway, so the cache is simply bounded and
/// dropped wholesale when it overflows.
const DEP_CACHE_MAX: usize = 1024;

/// Append-only DAG with pruning. Node ids are assigned monotonically and are
/// never reused; pruned nodes simply disappear from the map (the horizon
/// mechanism guarantees nothing references them anymore).
#[derive(Debug)]
pub struct Dag<N> {
    nodes: HashMap<u64, DagNode<N>>,
    order: Vec<u64>, // topological (insertion) order of live nodes
    /// Live nodes without successors, maintained incrementally. Sorted so
    /// `front()` reproduces insertion (= id) order.
    frontier: BTreeSet<u64>,
    /// Interning cache for repeated dependency sets (the `Arc` doubles as
    /// the key via `Borrow<[Dep]>`, so each set is stored once).
    dep_sets: HashSet<Arc<[Dep]>>,
    next_id: u64,
}

impl<N> Default for Dag<N> {
    fn default() -> Self {
        Dag {
            nodes: HashMap::new(),
            order: Vec::new(),
            frontier: BTreeSet::new(),
            dep_sets: HashSet::new(),
            next_id: 0,
        }
    }
}

impl<N> Dag<N> {
    pub fn new() -> Self {
        Self::default()
    }

    /// A DAG whose node ids start at `base` instead of 0. Multi-tenant id
    /// namespacing: each job's graphs use `base = JobId::base()`, so ids
    /// stay monotonic within the namespace and every invariant (`front`,
    /// `prune_before`, `check_acyclic`) holds unchanged — the tag rides
    /// along in the high bits.
    pub fn with_base(base: u64) -> Self {
        Dag { next_id: base, ..Default::default() }
    }

    /// Append a node with the given dependencies. Dependencies on unknown
    /// (already pruned or never existing) nodes are silently dropped — by
    /// the horizon invariant a pruned node has already completed, so the
    /// edge is vacuously satisfied. Duplicate edges keep the strongest
    /// ordering requirement (first-kind wins; kinds are equivalent for
    /// execution).
    pub fn push(&mut self, payload: N, deps: impl IntoIterator<Item = Dep>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let mut uniq: Vec<Dep> = Vec::new();
        for d in deps {
            if d.from == id || !self.nodes.contains_key(&d.from) {
                continue;
            }
            if uniq.iter().any(|u| u.from == d.from) {
                continue;
            }
            uniq.push(d);
        }
        for d in &uniq {
            let n = self.nodes.get_mut(&d.from).expect("dep target is live");
            if n.succ_count == 0 {
                self.frontier.remove(&d.from);
            }
            n.succ_count += 1;
        }
        let deps = self.intern_deps(uniq);
        self.nodes
            .insert(id, DagNode { id, payload, deps, succ_count: 0 });
        self.order.push(id);
        self.frontier.insert(id);
        id
    }

    fn intern_deps(&mut self, uniq: Vec<Dep>) -> Arc<[Dep]> {
        if let Some(shared) = self.dep_sets.get(uniq.as_slice()) {
            return shared.clone();
        }
        let shared: Arc<[Dep]> = uniq.into();
        if self.dep_sets.len() >= DEP_CACHE_MAX {
            self.dep_sets.clear();
        }
        self.dep_sets.insert(shared.clone());
        shared
    }

    /// Number of live (unpruned) nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Total number of nodes ever created.
    pub fn total_created(&self) -> u64 {
        self.next_id
    }

    pub fn get(&self, id: u64) -> Option<&DagNode<N>> {
        self.nodes.get(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut DagNode<N>> {
        self.nodes.get_mut(&id)
    }

    pub fn contains(&self, id: u64) -> bool {
        self.nodes.contains_key(&id)
    }

    /// Live nodes in topological (insertion) order.
    pub fn iter(&self) -> impl Iterator<Item = &DagNode<N>> {
        self.order.iter().filter_map(|id| self.nodes.get(id))
    }

    /// The *execution front*: live nodes that no other live node depends on.
    /// A horizon node "by definition depends on all instructions on the
    /// current execution front" (§3.6). Maintained incrementally; this is
    /// `O(front)`, not `O(live nodes)`.
    pub fn front(&self) -> Vec<u64> {
        self.frontier.iter().copied().collect()
    }

    /// Drop all nodes with `id < before`. Used when a horizon is applied:
    /// everything older has completed and can no longer be referenced.
    pub fn prune_before(&mut self, before: u64) -> usize {
        let dead: Vec<u64> = self.order.iter().copied().filter(|&id| id < before).collect();
        if dead.is_empty() {
            return 0;
        }
        for id in &dead {
            if let Some(n) = self.nodes.remove(id) {
                // Decrement successor counts of surviving predecessors.
                // (Edges point backwards, so predecessors of dead nodes are
                // normally dead themselves — this is belt and braces.)
                for d in n.deps.iter() {
                    if let Some(p) = self.nodes.get_mut(&d.from) {
                        p.succ_count -= 1;
                        if p.succ_count == 0 {
                            self.frontier.insert(d.from);
                        }
                    }
                }
            }
            self.frontier.remove(id);
        }
        self.order.retain(|id| *id >= before);
        // Surviving nodes may still point at pruned predecessors; those
        // edges are vacuously satisfied. Drop them so dep walks stay
        // consistent. (All retained edges target ids >= before, which are
        // exactly the surviving nodes.)
        for n in self.nodes.values_mut() {
            if n.deps.iter().any(|d| d.from < before) {
                n.deps = n.deps.iter().copied().filter(|d| d.from >= before).collect();
            }
        }
        // Cached dep sets may embed pruned ids; drop them wholesale.
        self.dep_sets.clear();
        dead.len()
    }

    /// Verify the topological-order invariant: every edge points backwards.
    pub fn check_acyclic(&self) -> bool {
        self.iter().all(|n| n.deps.iter().all(|d| d.from < n.id))
    }

    /// Render the graph in Graphviz dot format, labelling nodes with `f`.
    pub fn to_dot(&self, name: &str, f: impl Fn(&N) -> String) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "digraph \"{name}\" {{");
        let _ = writeln!(s, "  rankdir=TB; node [shape=box, fontname=\"monospace\"];");
        for n in self.iter() {
            let _ = writeln!(s, "  n{} [label=\"{}\"];", n.id, f(&n.payload).replace('"', "'"));
            for d in n.deps.iter() {
                let color = match d.kind {
                    DepKind::Dataflow => "black",
                    DepKind::Anti | DepKind::Output => "darkgreen",
                    DepKind::Sync => "purple",
                };
                let _ = writeln!(s, "  n{} -> n{} [color={color}];", d.from, n.id);
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dep(from: u64) -> Dep {
        Dep { from, kind: DepKind::Dataflow }
    }

    /// Recompute the execution front from scratch: live nodes that no other
    /// live node depends on.
    fn recomputed_front<N>(g: &Dag<N>) -> Vec<u64> {
        let mut has_succ: std::collections::HashSet<u64> = std::collections::HashSet::new();
        for n in g.iter() {
            for d in n.dep_ids() {
                has_succ.insert(d);
            }
        }
        g.iter().filter(|n| !has_succ.contains(&n.id)).map(|n| n.id).collect()
    }

    #[test]
    fn push_assigns_monotonic_ids() {
        let mut g: Dag<&str> = Dag::new();
        let a = g.push("a", []);
        let b = g.push("b", [dep(a)]);
        assert_eq!((a, b), (0, 1));
        assert_eq!(g.len(), 2);
        assert!(g.check_acyclic());
    }

    #[test]
    fn duplicate_and_self_deps_dropped() {
        let mut g: Dag<&str> = Dag::new();
        let a = g.push("a", []);
        let b = g.push("b", [dep(a), dep(a), Dep { from: 1, kind: DepKind::Anti }]);
        assert_eq!(g.get(b).unwrap().deps.len(), 1);
    }

    #[test]
    fn unknown_deps_are_vacuous() {
        let mut g: Dag<&str> = Dag::new();
        let a = g.push("a", [dep(999)]);
        assert!(g.get(a).unwrap().deps.is_empty());
    }

    #[test]
    fn front_tracks_successors() {
        let mut g: Dag<&str> = Dag::new();
        let a = g.push("a", []);
        let b = g.push("b", [dep(a)]);
        let c = g.push("c", [dep(a)]);
        assert_eq!(g.front(), vec![b, c]);
        let h = g.push("horizon", [dep(b), dep(c)]);
        assert_eq!(g.front(), vec![h]);
    }

    #[test]
    fn prune_removes_old_and_fixes_counts() {
        let mut g: Dag<&str> = Dag::new();
        let a = g.push("a", []);
        let b = g.push("b", [dep(a)]);
        let c = g.push("c", [dep(b)]);
        assert_eq!(g.prune_before(c), 2);
        assert_eq!(g.len(), 1);
        assert!(g.get(c).unwrap().deps.is_empty());
        assert_eq!(g.front(), vec![c]);
        // Ids keep counting up after pruning.
        let d = g.push("d", [dep(c)]);
        assert_eq!(d, 3);
    }

    #[test]
    fn identical_dep_sets_are_interned() {
        let mut g: Dag<&str> = Dag::new();
        let a = g.push("a", []);
        let b = g.push("b", []);
        let c = g.push("c", [dep(a), dep(b)]);
        let d = g.push("d", [dep(a), dep(b)]);
        let cd = g.get(c).unwrap().deps.clone();
        let dd = g.get(d).unwrap().deps.clone();
        assert!(Arc::ptr_eq(&cd, &dd), "equal dep sets must share one allocation");
        // Different sets do not alias.
        let e = g.push("e", [dep(a)]);
        assert!(!Arc::ptr_eq(&cd, &g.get(e).unwrap().deps.clone()));
    }

    /// Satellite: the incrementally maintained front matches a
    /// from-scratch recomputation under interleaved `push`/`prune_before`.
    #[test]
    fn frontier_matches_recomputation_under_interleaving() {
        use crate::util::XorShift64;
        let mut rng = XorShift64::new(0xF00D);
        let mut g: Dag<u64> = Dag::new();
        let mut pruned_below = 0u64;
        for step in 0..2000u64 {
            if step % 97 == 96 && g.total_created() > pruned_below + 4 {
                // Prune a random prefix of the live window (horizon apply).
                let span = g.total_created() - pruned_below;
                pruned_below += 1 + rng.next_below(span - 2);
                g.prune_before(pruned_below);
            } else {
                // Push with 0..=3 deps on random recent nodes.
                let n_deps = rng.next_below(4);
                let lo = pruned_below;
                let hi = g.total_created();
                let deps: Vec<Dep> = (0..n_deps)
                    .filter(|_| hi > lo)
                    .map(|_| dep(lo + rng.next_below(hi - lo)))
                    .collect();
                g.push(step, deps);
            }
            assert_eq!(
                g.front(),
                recomputed_front(&g),
                "front diverged at step {step} (pruned_below={pruned_below})"
            );
            assert!(g.check_acyclic());
        }
        assert!(g.total_created() > 1500);
    }

    #[test]
    fn with_base_namespaces_ids() {
        let base = 7u64 << 48;
        let mut g: Dag<&str> = Dag::with_base(base);
        let a = g.push("a", []);
        let b = g.push("b", [dep(a)]);
        assert_eq!((a, b), (base, base + 1));
        assert_eq!(g.total_created(), base + 2);
        assert!(g.check_acyclic());
        assert_eq!(g.front(), vec![b]);
        // Pruning relative to an in-namespace horizon works as at base 0.
        assert_eq!(g.prune_before(base + 1), 1);
        assert_eq!(g.front(), vec![b]);
    }

    #[test]
    fn dot_output_mentions_all_nodes() {
        let mut g: Dag<&str> = Dag::new();
        let a = g.push("alpha", []);
        g.push("beta", [dep(a)]);
        let dot = g.to_dot("t", |s| s.to_string());
        assert!(dot.contains("alpha") && dot.contains("beta"));
        assert!(dot.contains("n0 -> n1"));
    }
}
