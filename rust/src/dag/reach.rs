//! Compressed ancestor sets over a topologically ordered instruction
//! stream — the reachability core shared by the static verifier
//! ([`crate::verify`]) and the performance analyzer ([`crate::analyze`]).
//!
//! Instruction ids are assigned monotonically and every dependency edge
//! points backwards, so arrival order *is* a topological order. Each node
//! gets a [`Reach`]: a `floor` (every dense index below it is an ancestor)
//! plus a word-aligned bitset covering `[floor, self)`. Horizons and
//! epochs depend on the entire execution front, which makes them
//! dominators: once verified complete their set collapses to
//! `floor == self` ([`Reach::collapsed`]), so bitsets only ever span the
//! instructions between two boundaries, not the whole history — mirroring
//! the §3.5 memory argument of the scheduler itself.

/// Ancestor set of one instruction, in dense stream order: every index
/// `< floor` is an ancestor; indexes in `[floor, self)` are ancestors iff
/// their (absolute, word-aligned) bit is set.
#[derive(Debug, Clone)]
pub struct Reach {
    floor: usize,
    /// First stored word: `floor / 64`. Bit `i` lives in word `i / 64`.
    base: usize,
    bits: Vec<u64>,
}

impl Reach {
    /// An empty set above `floor`: exactly the indices `< floor`.
    pub fn with_floor(floor: usize) -> Reach {
        Reach { floor, base: floor / 64, bits: Vec::new() }
    }

    /// The collapsed set of a verified dominator at dense index `at`:
    /// every older index is an ancestor, nothing is stored.
    pub fn collapsed(at: usize) -> Reach {
        Reach::with_floor(at)
    }

    /// Every dense index below this is an ancestor.
    pub fn floor(&self) -> usize {
        self.floor
    }

    pub fn contains(&self, idx: usize) -> bool {
        if idx < self.floor {
            return true;
        }
        let word = idx / 64;
        if word < self.base {
            return false;
        }
        self.bits
            .get(word - self.base)
            .is_some_and(|w| w & (1u64 << (idx % 64)) != 0)
    }

    pub fn set(&mut self, idx: usize) {
        let word = idx / 64;
        debug_assert!(word >= self.base);
        let at = word - self.base;
        if at >= self.bits.len() {
            self.bits.resize(at + 1, 0);
        }
        self.bits[at] |= 1u64 << (idx % 64);
    }

    /// Union another (ancestor's) set into this one. The other set's floor
    /// must not exceed ours — callers build sets with
    /// `floor = max(dep floors)`, which guarantees it.
    pub fn absorb(&mut self, other: &Reach) {
        debug_assert!(other.base <= self.base);
        let from = self.base.saturating_sub(other.base);
        for (k, w) in other.bits.iter().enumerate().skip(from) {
            let at = other.base + k - self.base;
            if at >= self.bits.len() {
                self.bits.resize(at + 1, 0);
            }
            self.bits[at] |= w;
        }
    }

    /// Build the ancestor set of a node from its (dense) dependency
    /// indexes, given the sets of every earlier node: floor = max dep
    /// floor, bits = deps themselves plus the union of their bits.
    pub fn from_deps(dep_idxs: &[usize], prior: &[Reach]) -> Reach {
        let floor = dep_idxs.iter().map(|&d| prior[d].floor).max().unwrap_or(0);
        let mut reach = Reach::with_floor(floor);
        for &d in dep_idxs {
            if d >= floor {
                reach.set(d);
            }
            // Everything below the dep's floor is below our floor too or
            // covered by its words (`dep.base <= reach.base` always, since
            // floors grow monotonically along dependency chains).
            reach.absorb(&prior[d]);
        }
        reach
    }

    /// First dense index in `[floor, upto)` that is *not* an ancestor, if
    /// any — the §3.5 boundary-domination check: a horizon/epoch at `upto`
    /// must reach every older instruction before its set may collapse.
    pub fn first_unreached(&self, upto: usize) -> Option<usize> {
        (self.floor..upto).find(|&i| !self.contains(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_and_bits_compose() {
        let mut r = Reach::with_floor(100);
        r.set(130);
        assert!(r.contains(0) && r.contains(99));
        assert!(!r.contains(100) && !r.contains(129));
        assert!(r.contains(130));
        assert!(!r.contains(131));
    }

    #[test]
    fn collapsed_contains_exactly_below() {
        let r = Reach::collapsed(64);
        assert!(r.contains(63));
        assert!(!r.contains(64));
        assert_eq!(r.floor(), 64);
    }

    #[test]
    fn from_deps_unions_floors_and_bits() {
        // 0 ← 1, 0 ← 2, then 3 depends on {1, 2}.
        let r0 = Reach::with_floor(0);
        let mut r1 = Reach::with_floor(0);
        r1.set(0);
        let mut r2 = Reach::with_floor(0);
        r2.set(0);
        let prior = vec![r0, r1, r2];
        let r3 = Reach::from_deps(&[1, 2], &prior);
        assert!(r3.contains(0) && r3.contains(1) && r3.contains(2));
        assert!(!r3.contains(3));
        assert_eq!(r3.first_unreached(3), None);
    }

    #[test]
    fn from_deps_through_collapsed_dominator() {
        // A collapsed boundary at 70 gives its dependents floor 70, so
        // word-misaligned older bits are still covered.
        let prior = vec![Reach::collapsed(70); 71];
        let r = Reach::from_deps(&[70], &prior);
        assert_eq!(r.floor(), 70);
        assert!(r.contains(69));
        assert!(r.contains(70), "direct dep above the floor must be set");
        assert!(!r.contains(71));
    }

    #[test]
    fn first_unreached_finds_the_gap() {
        let mut r = Reach::with_floor(10);
        r.set(10);
        r.set(12);
        assert_eq!(r.first_unreached(13), Some(11));
        assert_eq!(r.first_unreached(11), None);
    }

    #[test]
    fn absorb_handles_word_offsets() {
        let mut low = Reach::with_floor(0);
        low.set(5);
        low.set(200);
        let mut high = Reach::with_floor(128);
        high.absorb(&low);
        // Below our floor is implicit; stored words at/above base survive.
        assert!(high.contains(5), "below floor");
        assert!(high.contains(200), "absorbed word");
        assert!(!high.contains(199));
    }
}
