//! `celerity analyze`: cost-model-driven performance lints and resource
//! bounds over the instruction graph, computed statically.
//!
//! The analyzer consumes exactly what the verifier ([`crate::verify`])
//! consumes — one node's instruction stream in generation order — plus the
//! calibrated [`CostModel`] the discrete-event simulator prices with, and
//! produces a [`Report`]:
//!
//! - **Resource bounds** — a per-memory *peak allocation bound*: at every
//!   allocation we sum the sizes of all allocations not provably freed
//!   before it (free not an ancestor in the [`Reach`] relation), i.e. the
//!   worst case over every execution order the dependency edges permit.
//!   An out-of-order executor (§4.1) may realize any of those orders, so
//!   the stream order's footprint alone would under-report.
//! - **Concurrency diagnostics** — the cost-weighted critical path (one
//!   exact chain, recovered by backtracking through the max-cost
//!   dependency), total work, the even-split ideal `work / devices`, and a
//!   `scheduler_bound` ratio saying how far dependency structure keeps the
//!   stream from that ideal; plus a per-span width profile between
//!   horizons (`span work / span critical path` ≈ average parallelism).
//! - **Performance lints** ([`lints`]) — named anti-pattern detectors at
//!   allow/warn/deny levels, covering the regressions each scheduler
//!   feature exists to prevent: resize churn (lookahead, §4.3), staged
//!   copies (direct device transfers, §3.4), p2p fan-outs (collective
//!   lowering), oversized allocations, and false serialization on the
//!   critical path.
//!
//! Everything here is static: no execution, no simulation, O(stream)
//! memory. `celerity analyze` (see `main.rs`) runs it per node over the
//! same offline compilation the `graph` verb performs.

pub mod lints;

pub use lints::{Finding, Lint, LintConfig, LintLevel, LINTS};

use crate::buffer::BufferPool;
use crate::dag::reach::Reach;
use crate::grid::{GridBox, Region};
use crate::instruction::{InstructionKind, InstructionRef};
use crate::sim::CostModel;
use crate::util::{AllocationId, BufferId, MemoryId, NodeId};
use std::collections::{HashMap, HashSet};
use std::fmt::Write as _;

/// Configuration for one analysis pass.
#[derive(Debug, Clone, Default)]
pub struct AnalyzeConfig {
    /// Pricing model (shared with the simulator).
    pub cost: CostModel,
    /// Lint levels (registry defaults unless overridden).
    pub lints: LintConfig,
    /// Devices assumed by the even-split ideal; inferred from the stream
    /// (max kernel device + 1) when `None`.
    pub num_devices: Option<u64>,
}

/// Peak-allocation bound for one memory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryBound {
    pub memory: MemoryId,
    /// Upper bound on bytes simultaneously allocated in this memory under
    /// any dependency-respecting execution order.
    pub peak_bytes: u64,
    /// Allocations placed in this memory over the whole stream.
    pub allocs: usize,
    /// Raw id of the allocation instruction attaining the bound.
    pub at_instr: u64,
}

/// Width profile of one inter-horizon span.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanProfile {
    /// Raw ids of the first/last instruction in the span.
    pub start: u64,
    pub end: u64,
    pub instructions: usize,
    /// Summed instruction cost in the span (s).
    pub work: f64,
    /// Critical path restricted to the span (s); dependencies leaving the
    /// span contribute nothing.
    pub critical: f64,
    /// Average parallelism `work / critical` (0 for cost-free spans).
    pub width: f64,
}

/// The full analysis result for one node's stream.
#[derive(Debug, Clone)]
pub struct Report {
    pub node: NodeId,
    pub instructions: usize,
    /// Devices the even-split ideal divides over.
    pub num_devices: u64,
    /// Cost-weighted critical path through the stream (s).
    pub critical_path: f64,
    /// Summed cost of every instruction (s).
    pub total_work: f64,
    /// Even-split ideal makespan `total_work / num_devices` (s).
    pub ideal: f64,
    /// `critical_path / ideal`: 1.0 means the dependency structure admits
    /// the even split; large values mean the schedule is serialized far
    /// beyond what the work requires.
    pub scheduler_bound: f64,
    /// Raw ids along one exact critical chain, in stream order.
    pub critical_instrs: Vec<u64>,
    /// Peak-allocation bounds, one per touched memory (user memory M0 is
    /// not allocated by the runtime and is excluded).
    pub memory: Vec<MemoryBound>,
    /// Width profile per inter-horizon span.
    pub spans: Vec<SpanProfile>,
    /// Lint findings at warn level or above, in (lint, instruction) order.
    pub findings: Vec<Finding>,
}

/// Analyze one node's instruction stream. The stream must be in
/// generation order (dependencies backwards), as produced by the
/// scheduler; malformed streams should go through [`crate::verify`]
/// first — the analyzer skips unresolvable dependency edges.
pub fn analyze_stream(
    node: NodeId,
    buffers: &BufferPool,
    instructions: &[InstructionRef],
    cfg: &AnalyzeConfig,
) -> Report {
    let n = instructions.len();

    // Dense dependency resolution + ancestor sets (shared with verify).
    let mut index: HashMap<u64, usize> = HashMap::with_capacity(n);
    let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
    let mut reach: Vec<Reach> = Vec::with_capacity(n);
    for (cur, instr) in instructions.iter().enumerate() {
        let dep_idxs: Vec<usize> = instr
            .deps
            .iter()
            .filter_map(|(d, _)| index.get(&d.0).copied())
            .filter(|&d| d < cur)
            .collect();
        let mut r = Reach::from_deps(&dep_idxs, &reach);
        if matches!(instr.kind, InstructionKind::Horizon | InstructionKind::Epoch(_))
            && r.first_unreached(cur).is_none()
        {
            r = Reach::collapsed(cur);
        }
        reach.push(r);
        deps.push(dep_idxs);
        index.insert(instr.id.0, cur);
    }

    // Cost-weighted critical path: forward DP, then recover one exact
    // chain by backtracking through the max-cost dependency at each step
    // (no float-equality comparisons against the makespan).
    let dur: Vec<f64> = instructions.iter().map(|i| cfg.cost.price(&i.kind, buffers)).collect();
    let mut cp = vec![0.0f64; n];
    for i in 0..n {
        let longest = deps[i].iter().map(|&d| cp[d]).fold(0.0f64, f64::max);
        cp[i] = dur[i] + longest;
    }
    let critical_path = cp.iter().copied().fold(0.0f64, f64::max);
    let mut chain: Vec<usize> = Vec::new();
    if n > 0 {
        let mut at = (0..n).fold(0, |best, i| if cp[i] > cp[best] { i } else { best });
        loop {
            chain.push(at);
            let Some(&d) = deps[at].iter().max_by(|&&a, &&b| cp[a].total_cmp(&cp[b])) else {
                break;
            };
            at = d;
        }
        chain.reverse();
    }
    let critical_instrs: Vec<u64> = chain.iter().map(|&i| instructions[i].id.0).collect();

    let num_devices = cfg
        .num_devices
        .unwrap_or_else(|| {
            instructions
                .iter()
                .filter_map(|i| match &i.kind {
                    InstructionKind::DeviceKernel { device, .. } => Some(device.0 + 1),
                    _ => None,
                })
                .max()
                .unwrap_or(1)
        })
        .max(1);
    let total_work: f64 = dur.iter().sum();
    let ideal = total_work / num_devices as f64;
    let scheduler_bound = if ideal > 0.0 { critical_path / ideal } else { 1.0 };

    // Width profile between horizons/epochs.
    let mut spans = Vec::new();
    let mut span_start = 0usize;
    for (i, instr) in instructions.iter().enumerate() {
        let boundary = matches!(instr.kind, InstructionKind::Horizon | InstructionKind::Epoch(_));
        let end = if boundary {
            i
        } else if i + 1 == n {
            i + 1
        } else {
            continue;
        };
        if end > span_start {
            spans.push(span_profile(instructions, &deps, &dur, span_start, end));
        }
        if boundary {
            span_start = i + 1;
        }
    }

    let memory = memory_bounds(instructions, &reach);
    let findings = run_lints(node, instructions, &chain, cfg);

    Report {
        node,
        instructions: n,
        num_devices,
        critical_path,
        total_work,
        ideal,
        scheduler_bound,
        critical_instrs,
        memory,
        spans,
        findings,
    }
}

fn span_profile(
    instructions: &[InstructionRef],
    deps: &[Vec<usize>],
    dur: &[f64],
    start: usize,
    end: usize,
) -> SpanProfile {
    let mut scp = vec![0.0f64; end - start];
    let mut work = 0.0;
    for i in start..end {
        let longest = deps[i]
            .iter()
            .filter(|&&d| d >= start)
            .map(|&d| scp[d - start])
            .fold(0.0f64, f64::max);
        scp[i - start] = dur[i] + longest;
        work += dur[i];
    }
    let critical = scp.iter().copied().fold(0.0f64, f64::max);
    let width = if critical > 0.0 { work / critical } else { 0.0 };
    SpanProfile {
        start: instructions[start].id.0,
        end: instructions[end - 1].id.0,
        instructions: end - start,
        work,
        critical,
        width,
    }
}

// ─────────────────────────────────────────────────────────────────────────
// Peak-memory bound
// ─────────────────────────────────────────────────────────────────────────

struct AllocRec {
    idx: usize,
    raw: u64,
    memory: MemoryId,
    size: u64,
    freed: Option<usize>,
}

/// Antichain bound per memory: at each allocation, every earlier
/// allocation whose free is not an *ancestor* may still be live in some
/// permitted execution order, so its bytes count against this one.
fn memory_bounds(instructions: &[InstructionRef], reach: &[Reach]) -> Vec<MemoryBound> {
    let mut recs: Vec<AllocRec> = Vec::new();
    let mut by_alloc: HashMap<AllocationId, usize> = HashMap::new();
    for (i, instr) in instructions.iter().enumerate() {
        match &instr.kind {
            InstructionKind::Alloc { alloc, memory, size_bytes, .. }
                if *memory != MemoryId::USER =>
            {
                by_alloc.insert(*alloc, recs.len());
                recs.push(AllocRec {
                    idx: i,
                    raw: instr.id.0,
                    memory: *memory,
                    size: *size_bytes,
                    freed: None,
                });
            }
            InstructionKind::Free { alloc, .. } => {
                if let Some(&r) = by_alloc.get(alloc) {
                    recs[r].freed = Some(i);
                }
            }
            _ => {}
        }
    }
    let mut mems: Vec<MemoryId> = recs.iter().map(|r| r.memory).collect();
    mems.sort_unstable_by_key(|m| m.0);
    mems.dedup();
    let mut bounds = Vec::with_capacity(mems.len());
    for m in mems {
        let of_m: Vec<&AllocRec> = recs.iter().filter(|r| r.memory == m).collect();
        let mut peak = 0u64;
        let mut at = of_m[0].raw;
        for probe in &of_m {
            let live: u64 = of_m
                .iter()
                .filter(|a| {
                    a.idx <= probe.idx
                        && !a.freed.is_some_and(|f| reach[probe.idx].contains(f))
                })
                .map(|a| a.size)
                .sum();
            if live > peak {
                peak = live;
                at = probe.raw;
            }
        }
        bounds.push(MemoryBound { memory: m, peak_bytes: peak, allocs: of_m.len(), at_instr: at });
    }
    bounds
}

// ─────────────────────────────────────────────────────────────────────────
// Lint detectors
// ─────────────────────────────────────────────────────────────────────────

/// One byte-level access (mirrors the verifier's dispatch exactly).
struct Acc {
    alloc: AllocationId,
    region: Region,
    write: bool,
}

fn accesses(node: NodeId, kind: &InstructionKind) -> Vec<Acc> {
    match kind {
        InstructionKind::Send { send_box, src_alloc, .. } => {
            vec![Acc { alloc: *src_alloc, region: Region::from(*send_box), write: false }]
        }
        InstructionKind::Receive { region, dst_alloc, .. }
        | InstructionKind::SplitReceive { region, dst_alloc, .. } => {
            vec![Acc { alloc: *dst_alloc, region: region.clone(), write: true }]
        }
        InstructionKind::Collective { region, slices, dst_alloc, .. } => {
            let own = slices
                .get(node.0 as usize)
                .map(|s| Region::from(*s))
                .unwrap_or_else(Region::empty);
            let inbound = region.difference(&own);
            let mut acc = Vec::new();
            if !own.is_empty() {
                acc.push(Acc { alloc: *dst_alloc, region: own, write: false });
            }
            if !inbound.is_empty() {
                acc.push(Acc { alloc: *dst_alloc, region: inbound, write: true });
            }
            acc
        }
        InstructionKind::Copy { copy_box, src_alloc, dst_alloc, .. } => vec![
            Acc { alloc: *src_alloc, region: Region::from(*copy_box), write: false },
            Acc { alloc: *dst_alloc, region: Region::from(*copy_box), write: true },
        ],
        InstructionKind::DeviceKernel { bindings, .. }
        | InstructionKind::HostTask { bindings, .. } => {
            let mut acc = Vec::new();
            for b in bindings {
                if b.region.is_empty() {
                    continue;
                }
                if b.mode.is_consumer() {
                    acc.push(Acc { alloc: b.alloc, region: b.region.clone(), write: false });
                }
                if b.mode.is_producer() {
                    acc.push(Acc { alloc: b.alloc, region: b.region.clone(), write: true });
                }
            }
            acc
        }
        _ => Vec::new(),
    }
}

/// Is the critical-path edge `d → i` implied by a data relationship?
fn edge_justified(node: NodeId, d: &InstructionRef, i: &InstructionRef) -> bool {
    use InstructionKind as K;
    let sync = |k: &K| matches!(k, K::Horizon | K::Epoch(_) | K::AwaitReceive { .. });
    if sync(&d.kind) || sync(&i.kind) {
        return true;
    }
    let da = accesses(node, &d.kind);
    let ia = accesses(node, &i.kind);
    // Lifetime edges: alloc before users/free, free after users, and the
    // free → alloc ordering the generator emits for memory reuse.
    match (&d.kind, &i.kind) {
        (K::Alloc { alloc, .. }, K::Free { alloc: fa, .. }) if alloc == fa => return true,
        (K::Free { .. }, K::Alloc { .. }) => return true,
        (K::Alloc { alloc, .. }, _) if ia.iter().any(|a| a.alloc == *alloc) => return true,
        (_, K::Free { alloc, .. }) if da.iter().any(|a| a.alloc == *alloc) => return true,
        _ => {}
    }
    // Data edges: overlapping accesses to one allocation, ≥1 side writing.
    da.iter().any(|x| {
        ia.iter()
            .any(|y| x.alloc == y.alloc && (x.write || y.write) && x.region.intersects(&y.region))
    })
}

fn run_lints(
    node: NodeId,
    instructions: &[InstructionRef],
    chain: &[usize],
    cfg: &AnalyzeConfig,
) -> Vec<Finding> {
    let mut candidates: Vec<(&'static str, Option<u64>, String)> = Vec::new();

    // alloc-churn: a new buffer-backing allocation covering a box this
    // buffer previously had allocated *and freed* on the same memory — the
    // resize chain the §4.3 lookahead exists to elide.
    let mut freed_covers: HashMap<(BufferId, MemoryId), Vec<GridBox>> = HashMap::new();
    let mut live_covers: HashMap<AllocationId, (BufferId, MemoryId, GridBox)> = HashMap::new();
    let mut churn: HashMap<(BufferId, MemoryId), (u64, usize)> = HashMap::new();
    for instr in instructions {
        match &instr.kind {
            InstructionKind::Alloc { alloc, memory, buffer: Some(b), covers, .. } => {
                let key = (*b, *memory);
                let regrow = freed_covers
                    .get(&key)
                    .is_some_and(|old| old.iter().any(|o| covers.contains(o)));
                if regrow {
                    churn.entry(key).or_insert((instr.id.0, 0)).1 += 1;
                }
                live_covers.insert(*alloc, (*b, *memory, *covers));
            }
            InstructionKind::Free { alloc, .. } => {
                if let Some((b, m, covers)) = live_covers.remove(alloc) {
                    freed_covers.entry((b, m)).or_default().push(covers);
                }
            }
            _ => {}
        }
    }
    for ((b, m), (anchor, count)) in churn {
        candidates.push((
            lints::ALLOC_CHURN,
            Some(anchor),
            format!(
                "{b} on {m} re-allocated {count} time(s) over a previously freed box — \
                 enable lookahead to batch the resizes"
            ),
        ));
    }

    // oversized-allocation: a buffer-backing allocation whose covered box
    // is ≥4× larger than everything ever accessed in it.
    let mut tracks: HashMap<AllocationId, (u64, BufferId, MemoryId, GridBox, Region)> =
        HashMap::new();
    for instr in instructions {
        if let InstructionKind::Alloc { alloc, memory, buffer: Some(b), covers, .. } = &instr.kind
        {
            if *memory != MemoryId::USER {
                tracks.insert(*alloc, (instr.id.0, *b, *memory, *covers, Region::empty()));
            }
            continue;
        }
        for a in accesses(node, &instr.kind) {
            if let Some(t) = tracks.get_mut(&a.alloc) {
                t.4 = t.4.union(&a.region);
            }
        }
    }
    for (anchor, b, m, covers, used) in tracks.into_values() {
        let covered = covers.area();
        if covered >= 1024 && used.area() * 4 < covered {
            candidates.push((
                lints::OVERSIZED_ALLOCATION,
                Some(anchor),
                format!(
                    "allocation for {b} on {m} covers {covered} elements but only {} are \
                     ever accessed",
                    used.area()
                ),
            ));
        }
    }

    // staged-copy-on-direct-path: payloads hopping through pinned host
    // memory where §3.4 staging elision applies — a d2h copy feeding a
    // host-sourced send, or a host-landed receive feeding an h2d copy.
    // SplitReceive is exempt: the consumer split makes the M1 detour the
    // correct lowering there.
    let mut host_writes: HashMap<AllocationId, Vec<(Region, bool)>> = HashMap::new();
    let mut staged: HashMap<BufferId, (u64, usize)> = HashMap::new();
    for instr in instructions {
        match &instr.kind {
            InstructionKind::Copy {
                buffer, copy_box, src_memory, dst_memory, src_alloc, dst_alloc, ..
            } => {
                if src_memory.is_device() && *dst_memory == MemoryId::HOST {
                    host_writes
                        .entry(*dst_alloc)
                        .or_default()
                        .push((Region::from(*copy_box), false));
                }
                if *src_memory == MemoryId::HOST && dst_memory.is_device() {
                    let from_receive = host_writes.get(src_alloc).is_some_and(|ws| {
                        ws.iter()
                            .any(|(r, recv)| *recv && r.intersects(&Region::from(*copy_box)))
                    });
                    if from_receive {
                        staged.entry(*buffer).or_insert((instr.id.0, 0)).1 += 1;
                    }
                }
            }
            InstructionKind::Receive { region, dst_memory, dst_alloc, .. } => {
                if *dst_memory == MemoryId::HOST {
                    host_writes.entry(*dst_alloc).or_default().push((region.clone(), true));
                }
            }
            InstructionKind::Send { buffer, send_box, src_memory, src_alloc, .. } => {
                if *src_memory == MemoryId::HOST {
                    let from_device = host_writes.get(src_alloc).is_some_and(|ws| {
                        ws.iter()
                            .any(|(r, recv)| !*recv && r.intersects(&Region::from(*send_box)))
                    });
                    if from_device {
                        staged.entry(*buffer).or_insert((instr.id.0, 0)).1 += 1;
                    }
                }
            }
            _ => {}
        }
    }
    for (b, (anchor, count)) in staged {
        candidates.push((
            lints::STAGED_COPY,
            Some(anchor),
            format!(
                "{count} transfer(s) of {b} staged through pinned host memory — enable \
                 direct device transfers"
            ),
        ));
    }

    // missed-collective: sends of one buffer fanning out to ≥2 peers for
    // one producing task, with matching receives and no collective — the
    // all-gather shape the CDAG collective pass should have fused.
    let mut fan_out: HashMap<(BufferId, Option<u64>), HashSet<u64>> = HashMap::new();
    let mut fan_anchor: HashMap<BufferId, u64> = HashMap::new();
    let mut received: HashSet<BufferId> = HashSet::new();
    let mut collected: HashSet<BufferId> = HashSet::new();
    for instr in instructions {
        match &instr.kind {
            InstructionKind::Send { buffer, target, .. } => {
                let task = instr.task.as_ref().map(|t| t.id.0);
                fan_out.entry((*buffer, task)).or_default().insert(target.0);
                fan_anchor.entry(*buffer).or_insert(instr.id.0);
            }
            InstructionKind::Receive { buffer, .. }
            | InstructionKind::SplitReceive { buffer, .. } => {
                received.insert(*buffer);
            }
            InstructionKind::Collective { buffer, .. } => {
                collected.insert(*buffer);
            }
            _ => {}
        }
    }
    let mut gathers: HashMap<BufferId, usize> = HashMap::new();
    for ((b, _), targets) in &fan_out {
        if targets.len() >= 2 {
            *gathers.entry(*b).or_insert(0) += 1;
        }
    }
    for (b, groups) in gathers {
        if received.contains(&b) && !collected.contains(&b) {
            candidates.push((
                lints::MISSED_COLLECTIVE,
                fan_anchor.get(&b).copied(),
                format!(
                    "{groups} all-gather-shaped transfer(s) of {b} lowered as p2p fan-out — \
                     enable collective lowering"
                ),
            ));
        }
    }

    // false-serialization: every hop of the recovered critical chain is a
    // real dependency edge; flag the ones no data relationship implies.
    for w in chain.windows(2) {
        let (d, i) = (&instructions[w[0]], &instructions[w[1]]);
        if !edge_justified(node, d, i) {
            candidates.push((
                lints::FALSE_SERIALIZATION,
                Some(i.id.0),
                format!(
                    "critical-path edge \"{}\" → \"{}\" is not implied by any data \
                     relationship",
                    d.label(),
                    i.label()
                ),
            ));
        }
    }

    candidates.sort_by(|a, b| (a.0, a.1, &a.2).cmp(&(b.0, b.1, &b.2)));
    candidates
        .into_iter()
        .filter_map(|(lint, instr, message)| {
            let level = cfg.lints.level_of(lint);
            if level == LintLevel::Allow {
                None
            } else {
                Some(Finding { lint, level, instr, message })
            }
        })
        .collect()
}

// ─────────────────────────────────────────────────────────────────────────
// Rendering
// ─────────────────────────────────────────────────────────────────────────

impl Report {
    /// Findings at deny level (non-zero fails `celerity analyze`).
    pub fn deny_count(&self) -> usize {
        self.findings.iter().filter(|f| f.level == LintLevel::Deny).count()
    }

    /// Human-readable report (what the CLI prints by default).
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "node {}: {} instructions on {} device(s)",
            self.node, self.instructions, self.num_devices
        );
        let _ = writeln!(
            out,
            "  critical path {} across {} instructions; total work {}; even-split ideal {}; \
             scheduler-bound {:.2}x",
            fmt_time(self.critical_path),
            self.critical_instrs.len(),
            fmt_time(self.total_work),
            fmt_time(self.ideal),
            self.scheduler_bound
        );
        for m in &self.memory {
            let _ = writeln!(
                out,
                "  peak memory {}: {} over {} allocation(s), attained at I{}",
                m.memory,
                fmt_bytes(m.peak_bytes),
                m.allocs,
                m.at_instr
            );
        }
        if !self.spans.is_empty() {
            let mean = self.spans.iter().map(|s| s.width).sum::<f64>() / self.spans.len() as f64;
            if let Some(s) = self.spans.iter().min_by(|a, b| a.width.total_cmp(&b.width)) {
                let _ = writeln!(
                    out,
                    "  width profile: {} span(s), mean {:.2}, narrowest {:.2} (I{}..I{})",
                    self.spans.len(),
                    mean,
                    s.width,
                    s.start,
                    s.end
                );
            }
        }
        if self.findings.is_empty() {
            let _ = writeln!(out, "  findings: none");
        } else {
            let _ = writeln!(
                out,
                "  findings ({} deny / {} total):",
                self.deny_count(),
                self.findings.len()
            );
            for f in &self.findings {
                let _ = writeln!(out, "    {f}");
            }
        }
        out
    }

    /// Machine-readable report (one JSON object; `--json`).
    pub fn render_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"node\":{},\"instructions\":{},\"num_devices\":{}",
            self.node.0, self.instructions, self.num_devices
        );
        let _ = write!(
            out,
            ",\"critical_path\":{},\"total_work\":{},\"ideal\":{},\"scheduler_bound\":{}",
            json_f64(self.critical_path),
            json_f64(self.total_work),
            json_f64(self.ideal),
            json_f64(self.scheduler_bound)
        );
        let chain: Vec<String> = self.critical_instrs.iter().map(|i| i.to_string()).collect();
        let _ = write!(out, ",\"critical_instrs\":[{}]", chain.join(","));
        let mems: Vec<String> = self
            .memory
            .iter()
            .map(|m| {
                format!(
                    "{{\"memory\":{},\"peak_bytes\":{},\"allocs\":{},\"at_instr\":{}}}",
                    m.memory.0, m.peak_bytes, m.allocs, m.at_instr
                )
            })
            .collect();
        let _ = write!(out, ",\"memory\":[{}]", mems.join(","));
        let spans: Vec<String> = self
            .spans
            .iter()
            .map(|s| {
                format!(
                    "{{\"start\":{},\"end\":{},\"instructions\":{},\"work\":{},\
                     \"critical\":{},\"width\":{}}}",
                    s.start,
                    s.end,
                    s.instructions,
                    json_f64(s.work),
                    json_f64(s.critical),
                    json_f64(s.width)
                )
            })
            .collect();
        let _ = write!(out, ",\"spans\":[{}]", spans.join(","));
        let findings: Vec<String> = self
            .findings
            .iter()
            .map(|f| {
                let instr = f.instr.map(|i| i.to_string()).unwrap_or_else(|| "null".into());
                format!(
                    "{{\"lint\":\"{}\",\"level\":\"{}\",\"instr\":{},\"message\":\"{}\"}}",
                    f.lint,
                    f.level,
                    instr,
                    json_escape(&f.message)
                )
            })
            .collect();
        let _ = write!(out, ",\"findings\":[{}]}}", findings.join(","));
        out
    }
}

fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DepKind;
    use crate::grid::Range;
    use crate::instruction::{AccessBinding, Instruction};
    use crate::scheduler::{Scheduler, SchedulerConfig};
    use crate::task::{AccessMode, RangeMapper, TaskDecl, TaskManager};
    use crate::util::{DeviceId, InstructionId, MessageId};
    use std::sync::Arc;

    fn instr(id: u64, kind: InstructionKind, deps: &[u64]) -> InstructionRef {
        Arc::new(Instruction {
            id: InstructionId(id),
            kind,
            deps: deps.iter().map(|&d| (InstructionId(d), DepKind::Dataflow)).collect(),
            task: None,
        })
    }

    fn alloc(
        id: u64,
        a: u64,
        mem: MemoryId,
        buffer: Option<BufferId>,
        covers: GridBox,
    ) -> InstructionRef {
        alloc_after(id, a, mem, buffer, covers, &[])
    }

    fn alloc_after(
        id: u64,
        a: u64,
        mem: MemoryId,
        buffer: Option<BufferId>,
        covers: GridBox,
        deps: &[u64],
    ) -> InstructionRef {
        instr(
            id,
            InstructionKind::Alloc {
                alloc: AllocationId(a),
                memory: mem,
                buffer,
                covers,
                size_bytes: covers.area() * 8,
            },
            deps,
        )
    }

    fn free(id: u64, a: u64, mem: MemoryId, deps: &[u64]) -> InstructionRef {
        instr(
            id,
            InstructionKind::Free { alloc: AllocationId(a), memory: mem, size_bytes: 0 },
            deps,
        )
    }

    fn kernel(id: u64, a: u64, mode: AccessMode, region: GridBox, deps: &[u64]) -> InstructionRef {
        instr(
            id,
            InstructionKind::DeviceKernel {
                device: DeviceId(0),
                chunk: region,
                bindings: vec![AccessBinding {
                    buffer: BufferId(0),
                    mode,
                    region: Region::from(region),
                    alloc: AllocationId(a),
                    alloc_box: region,
                    dtype: crate::dtype::DType::F64,
                    lanes: 1,
                }],
                work_per_item: 1.0,
                kernel: None,
            },
            deps,
        )
    }

    fn run(stream: &[InstructionRef]) -> Report {
        analyze_stream(NodeId(0), &BufferPool::new(), stream, &AnalyzeConfig::default())
    }

    #[test]
    fn chain_serializes_critical_path_fan_out_does_not() {
        let bx = GridBox::d1(0, 64);
        let serial = run(&[
            alloc(1, 7, MemoryId(2), None, bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[1]),
            kernel(3, 7, AccessMode::ReadWrite, bx, &[2]),
        ]);
        let wide = run(&[
            alloc(1, 7, MemoryId(2), None, bx),
            kernel(2, 7, AccessMode::DiscardWrite, GridBox::d1(0, 32), &[1]),
            kernel(3, 7, AccessMode::DiscardWrite, GridBox::d1(32, 64), &[1]),
        ]);
        assert!(serial.critical_path > wide.critical_path);
        assert_eq!(serial.critical_instrs, vec![1, 2, 3]);
        assert!(serial.total_work > wide.total_work);
        assert!(serial.scheduler_bound > wide.scheduler_bound);
    }

    #[test]
    fn peak_memory_is_an_antichain_bound_not_stream_order() {
        let bx = GridBox::d1(0, 64); // 512 B at 8 B/elem
        // Free of A ordered before B's alloc: never concurrently live.
        let ordered = run(&[
            alloc(1, 7, MemoryId(2), None, bx),
            free(2, 7, MemoryId(2), &[1]),
            alloc_after(3, 8, MemoryId(2), None, bx, &[2]),
        ]);
        assert_eq!(ordered.memory.len(), 1);
        assert_eq!(ordered.memory[0].peak_bytes, 512);
        assert_eq!(ordered.memory[0].allocs, 2);
        // Same stream order but no edge from the free to the second alloc:
        // an out-of-order executor may hold both at once, so the bound
        // must say 1024 even though the free precedes in stream order.
        let unordered = run(&[
            alloc(1, 7, MemoryId(2), None, bx),
            free(2, 7, MemoryId(2), &[1]),
            alloc(3, 8, MemoryId(2), None, bx),
        ]);
        assert_eq!(unordered.memory[0].peak_bytes, 1024);
        assert_eq!(unordered.memory[0].at_instr, 3);
    }

    #[test]
    fn alloc_churn_fires_once_with_count() {
        let bx = GridBox::d1(0, 64);
        let grown = GridBox::d1(0, 128);
        let b = Some(BufferId(0));
        let r = run(&[
            alloc(1, 7, MemoryId(2), b, bx),
            free(2, 7, MemoryId(2), &[1]),
            alloc(3, 8, MemoryId(2), b, grown),
            free(4, 8, MemoryId(2), &[3]),
            alloc(5, 9, MemoryId(2), b, grown),
        ]);
        let churn: Vec<_> =
            r.findings.iter().filter(|f| f.lint == lints::ALLOC_CHURN).collect();
        assert_eq!(churn.len(), 1, "one aggregated finding: {:?}", r.findings);
        assert_eq!(churn[0].instr, Some(3));
        assert!(churn[0].message.contains("2 time(s)"), "{}", churn[0].message);
    }

    #[test]
    fn oversized_allocation_fires_for_sparse_use_only() {
        let big = GridBox::d1(0, 2048);
        let sparse = run(&[
            alloc(1, 7, MemoryId(2), Some(BufferId(0)), big),
            kernel(2, 7, AccessMode::DiscardWrite, GridBox::d1(0, 64), &[1]),
        ]);
        let over: Vec<_> =
            sparse.findings.iter().filter(|f| f.lint == lints::OVERSIZED_ALLOCATION).collect();
        assert_eq!(over.len(), 1, "{:?}", sparse.findings);
        assert_eq!(over[0].instr, Some(1));
        let dense = run(&[
            alloc(1, 7, MemoryId(2), Some(BufferId(0)), big),
            kernel(2, 7, AccessMode::DiscardWrite, big, &[1]),
        ]);
        assert!(
            dense.findings.iter().all(|f| f.lint != lints::OVERSIZED_ALLOCATION),
            "{:?}",
            dense.findings
        );
    }

    #[test]
    fn false_serialization_flags_only_data_free_critical_edges() {
        let bx = GridBox::d1(0, 64);
        // K3 writes a different allocation but carries an edge to K2.
        let spurious = run(&[
            alloc(1, 7, MemoryId(2), None, bx),
            alloc(2, 8, MemoryId(2), None, bx),
            kernel(3, 7, AccessMode::DiscardWrite, bx, &[1]),
            kernel(4, 8, AccessMode::DiscardWrite, bx, &[2, 3]),
        ]);
        let fs: Vec<_> =
            spurious.findings.iter().filter(|f| f.lint == lints::FALSE_SERIALIZATION).collect();
        assert_eq!(fs.len(), 1, "{:?}", spurious.findings);
        assert_eq!(fs[0].instr, Some(4));
        // Same shape, but K4 actually reads what K3 wrote: justified.
        let real = run(&[
            alloc(1, 7, MemoryId(2), None, bx),
            kernel(3, 7, AccessMode::DiscardWrite, bx, &[1]),
            kernel(4, 7, AccessMode::Read, bx, &[3]),
        ]);
        assert!(
            real.findings.iter().all(|f| f.lint != lints::FALSE_SERIALIZATION),
            "{:?}",
            real.findings
        );
    }

    #[test]
    fn staged_copy_fires_for_d2h_send_hop() {
        let bx = GridBox::d1(0, 64);
        let stream = vec![
            alloc(1, 7, MemoryId(2), None, bx),
            alloc(2, 8, MemoryId::HOST, None, bx),
            kernel(3, 7, AccessMode::DiscardWrite, bx, &[1]),
            instr(
                4,
                InstructionKind::Copy {
                    buffer: BufferId(0),
                    copy_box: bx,
                    src_memory: MemoryId(2),
                    dst_memory: MemoryId::HOST,
                    src_alloc: AllocationId(7),
                    src_box: bx,
                    dst_alloc: AllocationId(8),
                    dst_box: bx,
                },
                &[3, 2],
            ),
            instr(
                5,
                InstructionKind::Send {
                    buffer: BufferId(0),
                    send_box: bx,
                    target: NodeId(1),
                    msg: MessageId(0),
                    src_memory: MemoryId::HOST,
                    src_alloc: AllocationId(8),
                    src_box: bx,
                },
                &[4],
            ),
        ];
        let r = run(&stream);
        let staged: Vec<_> =
            r.findings.iter().filter(|f| f.lint == lints::STAGED_COPY).collect();
        assert_eq!(staged.len(), 1, "{:?}", r.findings);
        assert_eq!(staged[0].instr, Some(5));
    }

    #[test]
    fn lint_levels_filter_and_deny_counts() {
        let bx = GridBox::d1(0, 64);
        let b = Some(BufferId(0));
        let stream = vec![
            alloc(1, 7, MemoryId(2), b, bx),
            free(2, 7, MemoryId(2), &[1]),
            alloc(3, 8, MemoryId(2), b, bx),
        ];
        let mut cfg = AnalyzeConfig::default();
        cfg.lints.set("all", LintLevel::Allow).expect("all");
        let silent = analyze_stream(NodeId(0), &BufferPool::new(), &stream, &cfg);
        assert!(silent.findings.is_empty(), "{:?}", silent.findings);
        cfg.lints.set(lints::ALLOC_CHURN, LintLevel::Deny).expect("known");
        let deny = analyze_stream(NodeId(0), &BufferPool::new(), &stream, &cfg);
        assert_eq!(deny.deny_count(), 1, "{:?}", deny.findings);
    }

    #[test]
    fn report_renders_human_and_valid_shaped_json() {
        let bx = GridBox::d1(0, 64);
        let r = run(&[
            alloc(1, 7, MemoryId(2), None, bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[1]),
            instr(3, InstructionKind::Horizon, &[2]),
        ]);
        let human = r.render_human();
        assert!(human.contains("critical path"), "{human}");
        assert!(human.contains("peak memory M2"), "{human}");
        let json = r.render_json();
        assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
        assert!(json.contains("\"critical_path\":"), "{json}");
        assert!(json.contains("\"peak_bytes\":512"), "{json}");
        assert!(!json.contains("NaN") && !json.contains("inf"), "{json}");
    }

    // ── compiled streams: the shipped pipeline is lint-clean ────────────

    type Streams = Vec<(NodeId, Vec<InstructionRef>)>;

    fn compile(nodes: u64, lookahead: bool, f: impl Fn(&mut TaskManager)) -> (Streams, BufferPool) {
        let mut tm = TaskManager::new();
        f(&mut tm);
        tm.shutdown();
        let tasks = tm.take_new_tasks();
        let mut streams = Vec::new();
        for node in 0..nodes {
            let cfg = SchedulerConfig {
                node: NodeId(node),
                num_nodes: nodes,
                num_devices: 2,
                lookahead,
                ..Default::default()
            };
            let mut sched = Scheduler::new(cfg, tm.buffers().clone());
            let mut instructions = Vec::new();
            for t in &tasks {
                let (is, _) = sched.process(t);
                instructions.extend(is);
            }
            let (is, _) = sched.flush_now();
            instructions.extend(is);
            assert!(sched.take_errors().is_empty());
            streams.push((NodeId(node), instructions));
        }
        (streams, tm.buffers().clone())
    }

    fn nbody(tm: &mut TaskManager) {
        let r = Range::d1(256);
        let p = tm.create_buffer::<[f64; 3]>("P", r, true).id();
        let v = tm.create_buffer::<[f64; 3]>("V", r, true).id();
        for _ in 0..3 {
            tm.submit(
                TaskDecl::device("timestep", r)
                    .read(p, RangeMapper::All)
                    .read_write(v, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("update", r)
                    .read(v, RangeMapper::OneToOne)
                    .read_write(p, RangeMapper::OneToOne),
            );
        }
    }

    #[test]
    fn compiled_nbody_is_lint_clean_and_reports_bounds() {
        for nodes in [1u64, 2] {
            let (streams, buffers) = compile(nodes, true, nbody);
            for (node, instructions) in &streams {
                let r = analyze_stream(*node, &buffers, instructions, &AnalyzeConfig::default());
                assert_eq!(r.findings, vec![], "node {node} of {nodes}");
                assert!(r.critical_path > 0.0);
                assert!(r.total_work >= r.critical_path);
                assert!(!r.memory.is_empty(), "device allocations must be bounded");
                assert!(r.memory.iter().all(|m| m.peak_bytes > 0));
                assert!(!r.critical_instrs.is_empty());
                assert!(r.scheduler_bound > 0.0, "bound {}", r.scheduler_bound);
            }
        }
    }
}
