//! The performance-lint registry: named lints at allow/warn/deny levels.
//!
//! Each lint names one scheduling anti-pattern the instruction graph makes
//! statically visible. Levels follow the compiler-lint convention: `allow`
//! suppresses the finding, `warn` reports it, `deny` reports it *and*
//! makes `celerity analyze` exit non-zero — CI runs the shipped examples
//! at deny level, so a lowering regression that reintroduces an
//! anti-pattern fails the build instead of shipping as a silent slowdown.

use std::collections::HashMap;
use std::fmt;

/// Severity of a lint (compiler-lint convention).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LintLevel {
    /// Suppressed: the detector still runs, the finding is dropped.
    Allow,
    /// Reported in the findings list.
    Warn,
    /// Reported, and the analyze verb exits non-zero.
    Deny,
}

impl LintLevel {
    pub fn name(self) -> &'static str {
        match self {
            LintLevel::Allow => "allow",
            LintLevel::Warn => "warn",
            LintLevel::Deny => "deny",
        }
    }

    /// Parse a CLI level name.
    pub fn parse(s: &str) -> Option<LintLevel> {
        match s {
            "allow" => Some(LintLevel::Allow),
            "warn" => Some(LintLevel::Warn),
            "deny" => Some(LintLevel::Deny),
            _ => None,
        }
    }
}

impl fmt::Display for LintLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One registered lint.
#[derive(Debug, Clone, Copy)]
pub struct Lint {
    pub name: &'static str,
    /// One-line description of the anti-pattern it catches.
    pub summary: &'static str,
    pub default: LintLevel,
}

/// A dependency edge on the cost-weighted critical path that no data
/// relationship implies — pure serialization lengthening the makespan.
pub const FALSE_SERIALIZATION: &str = "false-serialization";
/// A transfer staged through pinned host memory although the direct
/// device path (§3.4) could have carried it.
pub const STAGED_COPY: &str = "staged-copy-on-direct-path";
/// All-gather-shaped p2p fan-in (sends to every peer + receives of the
/// same transfer) that the CDAG collective pass did not fuse.
pub const MISSED_COLLECTIVE: &str = "missed-collective";
/// Repeated same-shape alloc/free of one buffer's backing across epochs —
/// the resize chain the §4.3 lookahead exists to elide.
pub const ALLOC_CHURN: &str = "alloc-churn";
/// A backing allocation far larger than the union of boxes any
/// instruction ever touches in it.
pub const OVERSIZED_ALLOCATION: &str = "oversized-allocation";

/// Every registered lint, in display order.
pub const LINTS: &[Lint] = &[
    Lint {
        name: FALSE_SERIALIZATION,
        summary: "critical-path edge not implied by any data dependency",
        default: LintLevel::Warn,
    },
    Lint {
        name: STAGED_COPY,
        summary: "transfer staged through host memory on the direct device path",
        default: LintLevel::Warn,
    },
    Lint {
        name: MISSED_COLLECTIVE,
        summary: "all-gather-shaped p2p fan-in the collective pass did not fuse",
        default: LintLevel::Warn,
    },
    Lint {
        name: ALLOC_CHURN,
        summary: "repeated same-shape alloc/free the lookahead should elide",
        default: LintLevel::Warn,
    },
    Lint {
        name: OVERSIZED_ALLOCATION,
        summary: "allocation far larger than the union of accessed boxes",
        default: LintLevel::Warn,
    },
];

/// Look up a lint by name.
pub fn lint(name: &str) -> Option<&'static Lint> {
    LINTS.iter().find(|l| l.name == name)
}

/// Per-run level overrides on top of the registry defaults.
#[derive(Debug, Clone, Default)]
pub struct LintConfig {
    overrides: HashMap<&'static str, LintLevel>,
}

impl LintConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Override one lint's level. `name` may be `all`. Unknown names are
    /// an error (the CLI reports them instead of silently ignoring a
    /// typo'd `--deny`).
    pub fn set(&mut self, name: &str, level: LintLevel) -> Result<(), String> {
        if name == "all" {
            for l in LINTS {
                self.overrides.insert(l.name, level);
            }
            return Ok(());
        }
        match lint(name) {
            Some(l) => {
                self.overrides.insert(l.name, level);
                Ok(())
            }
            None => Err(format!(
                "unknown lint '{name}' (known: {})",
                LINTS.iter().map(|l| l.name).collect::<Vec<_>>().join(", ")
            )),
        }
    }

    /// The effective level of a lint (override, else registry default).
    pub fn level_of(&self, name: &str) -> LintLevel {
        self.overrides
            .get(name)
            .copied()
            .or_else(|| lint(name).map(|l| l.default))
            .unwrap_or(LintLevel::Allow)
    }
}

/// One reported finding.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Registry name of the lint that fired.
    pub lint: &'static str,
    /// Effective level it fired at (never [`LintLevel::Allow`]).
    pub level: LintLevel,
    /// Raw id of the instruction anchoring the finding, if one.
    pub instr: Option<u64>,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]: {}", self.level, self.lint, self.message)?;
        if let Some(i) = self.instr {
            write!(f, " (I{i})")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_the_five_seed_lints() {
        assert_eq!(LINTS.len(), 5);
        for name in [
            FALSE_SERIALIZATION,
            STAGED_COPY,
            MISSED_COLLECTIVE,
            ALLOC_CHURN,
            OVERSIZED_ALLOCATION,
        ] {
            assert!(lint(name).is_some(), "{name} must be registered");
        }
    }

    #[test]
    fn config_overrides_and_all() {
        let mut cfg = LintConfig::new();
        assert_eq!(cfg.level_of(ALLOC_CHURN), LintLevel::Warn);
        cfg.set(ALLOC_CHURN, LintLevel::Deny).expect("known lint");
        assert_eq!(cfg.level_of(ALLOC_CHURN), LintLevel::Deny);
        cfg.set("all", LintLevel::Allow).expect("all is valid");
        assert_eq!(cfg.level_of(ALLOC_CHURN), LintLevel::Allow);
        assert_eq!(cfg.level_of(STAGED_COPY), LintLevel::Allow);
        assert!(cfg.set("no-such-lint", LintLevel::Warn).is_err());
    }

    #[test]
    fn finding_renders_level_lint_and_anchor() {
        let f = Finding {
            lint: ALLOC_CHURN,
            level: LintLevel::Deny,
            instr: Some(42),
            message: "B0 on M2 resized 31 times".into(),
        };
        assert_eq!(f.to_string(), "deny[alloc-churn]: B0 on M2 resized 31 times (I42)");
    }
}
