//! The three benchmark applications of §5: N-body, RSim, WaveSim.
//!
//! Each app provides, in its submodule:
//!
//! - `submit`: the Celerity-style SPMD program (typed command-group
//!   submissions against a [`Queue`](crate::driver::Queue)),
//! - `register_reference_kernels`: pure-Rust kernel implementations with
//!   the exact numerics of `python/compile/kernels/ref.py`,
//! - `register_pjrt_kernels` (behind the `pjrt` feature): closures that
//!   execute the AOT-compiled JAX/Pallas artifacts via `crate::runtime`,
//! - `reference`: a sequential golden model used by the tests and the
//!   end-to-end driver to validate results.

pub mod nbody;
pub mod rsim;
pub mod wavesim;

/// A [`Registry`](crate::executor::Registry) with every app's pure-Rust
/// reference kernels — the one-stop setup used by the CLI (`run`/`worker`),
/// the live strong-scaling bench and integration tests.
pub fn reference_registry() -> crate::executor::Registry {
    let registry = crate::executor::Registry::new();
    nbody::register_reference_kernels(&registry);
    rsim::register_reference_kernels(&registry);
    wavesim::register_reference_kernels(&registry);
    registry
}

/// Physics constants; must match `python/compile/kernels/ref.py`.
pub mod consts {
    /// Integration time step.
    pub const DT: f32 = 1e-3;
    /// Body mass.
    pub const M: f32 = 1.0;
    /// Gravitational softening.
    pub const EPS2: f32 = 1e-4;
    /// Wave propagation coefficient (c·dt/dx)².
    pub const WAVE_C: f32 = 0.25;
    /// Radiosity reflectance normalization.
    pub const RSIM_NORM: f32 = 0.5;
}

#[cfg(test)]
mod tests {
    /// Constants must stay in sync with ref.py; this test pins the values
    /// the artifacts were compiled with.
    #[test]
    fn constants_pinned() {
        use super::consts::*;
        assert_eq!(DT, 1e-3);
        assert_eq!(M, 1.0);
        assert_eq!(EPS2, 1e-4);
        assert_eq!(WAVE_C, 0.25);
        assert_eq!(RSIM_NORM, 0.5);
    }
}
