//! Direct N-body simulation (Listing 1): the all-gather access pattern.

use super::consts::{DT, EPS2, M};
use crate::buffer::Buffer;
use crate::driver::Queue;
use crate::executor::{KernelCtx, Registry};
use crate::grid::{Point, Range};
#[cfg(feature = "pjrt")]
use crate::runtime::{ArgBytes, RuntimeClient};
use crate::task::QueueError;
use std::sync::Arc;

/// Deterministic initial state: positions on a perturbed lattice,
/// velocities zero. Returns (P, V) as "double3"-style elements.
pub fn initial_state(n: usize) -> (Vec<[f32; 3]>, Vec<[f32; 3]>) {
    let mut rng = crate::util::XorShift64::new(0x5EED + n as u64);
    let mut p = Vec::with_capacity(n);
    for i in 0..n {
        let mut e = [0f32; 3];
        for (d, lane) in e.iter_mut().enumerate() {
            *lane = (i as f32 * 0.37 + d as f32) * 0.01 + rng.next_f64() as f32 * 0.1;
        }
        p.push(e);
    }
    (p, vec![[0f32; 3]; n])
}

/// Submit the Listing-1 program: `steps` iterations of timestep + update.
/// Buffers `p` and `v` hold one `double3`-style element (3×f32 = 12 B) per
/// body. Returns the typed (P, V) buffer handles.
pub fn submit(
    q: &mut Queue,
    n: u64,
    steps: usize,
) -> Result<(Buffer<[f32; 3]>, Buffer<[f32; 3]>), QueueError> {
    let range = Range::d1(n);
    let (p0, v0) = initial_state(n as usize);
    let p = q.create_buffer_init("P", range, &p0)?;
    let v = q.create_buffer_init("V", range, &v0)?;
    // Cost hint: the inner j-loop makes each work item O(N).
    let work = n as f64 * 20.0;
    for _ in 0..steps {
        q.submit(|cgh| {
            cgh.read(p, crate::task::RangeMapper::All);
            cgh.read_write(v, crate::task::RangeMapper::OneToOne);
            cgh.parallel_for("nbody_timestep", range).work_per_item(work);
        })?;
        q.submit(|cgh| {
            cgh.read(v, crate::task::RangeMapper::OneToOne);
            cgh.read_write(p, crate::task::RangeMapper::OneToOne);
            cgh.parallel_for("nbody_update", range).work_per_item(2.0);
        })?;
    }
    Ok((p, v))
}

/// Force on body at `pi` from all bodies in `p_all` (softened gravity,
/// numerics of ref.py).
fn force(p_all: &[f32], pi: [f32; 3]) -> [f32; 3] {
    let mut f = [0f32; 3];
    for j in 0..p_all.len() / 3 {
        let d = [
            p_all[j * 3] - pi[0],
            p_all[j * 3 + 1] - pi[1],
            p_all[j * 3 + 2] - pi[2],
        ];
        let dist2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2] + EPS2;
        let inv_d3 = dist2.powf(-1.5);
        f[0] += d[0] * inv_d3;
        f[1] += d[1] * inv_d3;
        f[2] += d[2] * inv_d3;
    }
    f
}

/// Pure-Rust kernels with ref.py numerics.
pub fn register_reference_kernels(registry: &Registry) {
    registry.register_kernel(
        "nbody_timestep",
        Arc::new(|ctx: &KernelCtx| {
            let p = ctx.view(0); // read all
            let v = ctx.view(1); // read_write one-to-one
            let n = p.binding.region.bounding_box().max[0] as usize;
            let mut p_all = vec![0f32; n * 3];
            for j in 0..n {
                // Buffers store one 12-byte element per body; elementwise
                // access goes through a 3-wide f32 view.
                let e = p.read_elem3(Point::d1(j as u64));
                p_all[j * 3..j * 3 + 3].copy_from_slice(&e);
            }
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                let pi = [
                    p_all[i as usize * 3],
                    p_all[i as usize * 3 + 1],
                    p_all[i as usize * 3 + 2],
                ];
                let f = force(&p_all, pi);
                let mut vi = v.read_elem3(Point::d1(i));
                for d in 0..3 {
                    vi[d] += M * f[d] * DT;
                }
                v.write_elem3(Point::d1(i), vi);
            }
        }),
    );
    registry.register_kernel(
        "nbody_update",
        Arc::new(|ctx: &KernelCtx| {
            let v = ctx.view(0);
            let p = ctx.view(1);
            for i in ctx.chunk.min[0]..ctx.chunk.max[0] {
                let vi = v.read_elem3(Point::d1(i));
                let mut pi = p.read_elem3(Point::d1(i));
                for d in 0..3 {
                    pi[d] += vi[d] * DT;
                }
                p.write_elem3(Point::d1(i), pi);
            }
        }),
    );
}

/// Kernels that execute the AOT-compiled JAX/Pallas artifacts. The artifact
/// shapes (N, chunk) must match the cluster split — see `aot.py` defaults.
#[cfg(feature = "pjrt")]
pub fn register_pjrt_kernels(registry: &Registry, rt: &Arc<RuntimeClient>) {
    let timestep = rt.kernel("nbody_timestep").expect("artifact nbody_timestep");
    registry.register_kernel(
        "nbody_timestep",
        Arc::new(move |ctx: &KernelCtx| {
            let p = ctx.view(0);
            let v = ctx.view(1);
            let offset = ctx.chunk.min[0] as i32;
            let p_bytes = p.read_region_bytes();
            let v_bytes = v.read_region_bytes();
            let out = timestep
                .call(&[
                    ArgBytes::Bytes(&p_bytes),
                    ArgBytes::Bytes(&v_bytes),
                    ArgBytes::ScalarI32(offset),
                ])
                .expect("nbody_timestep execute");
            v.write_region_bytes(&out[0]);
        }),
    );
    let update = rt.kernel("nbody_update").expect("artifact nbody_update");
    registry.register_kernel(
        "nbody_update",
        Arc::new(move |ctx: &KernelCtx| {
            let v = ctx.view(0);
            let p = ctx.view(1);
            let v_bytes = v.read_region_bytes();
            let p_bytes = p.read_region_bytes();
            let out = update
                .call(&[ArgBytes::Bytes(&v_bytes), ArgBytes::Bytes(&p_bytes)])
                .expect("nbody_update execute");
            p.write_region_bytes(&out[0]);
        }),
    );
}

/// Sequential golden model: returns final P after `steps` iterations, as
/// flat interleaved xyz.
pub fn reference(n: usize, steps: usize) -> Vec<f32> {
    let (p0, v0) = initial_state(n);
    let mut p: Vec<f32> = p0.into_iter().flatten().collect();
    let mut v: Vec<f32> = v0.into_iter().flatten().collect();
    for _ in 0..steps {
        let snapshot = p.clone();
        for i in 0..n {
            let pi = [snapshot[i * 3], snapshot[i * 3 + 1], snapshot[i * 3 + 2]];
            let f = force(&snapshot, pi);
            for d in 0..3 {
                v[i * 3 + d] += M * f[d] * DT;
            }
        }
        for i in 0..n * 3 {
            p[i] += v[i] * DT;
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::{run_cluster, ClusterConfig};
    use std::sync::Mutex;

    #[test]
    fn cluster_matches_reference_2x2() {
        let registry = Registry::new();
        register_reference_kernels(&registry);
        let cfg = ClusterConfig {
            num_nodes: 2,
            num_devices: 2,
            registry,
            ..Default::default()
        };
        let results = Arc::new(Mutex::new(Vec::new()));
        let rc = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let (p, _v) = submit(q, 64, 3).expect("submit nbody");
            let got: Vec<f32> = q.fence(p).expect("fence").into_iter().flatten().collect();
            rc.lock().unwrap().push(got);
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "{:?}", r.errors);
        }
        let want = reference(64, 3);
        for got in results.lock().unwrap().iter() {
            assert_eq!(got.len(), want.len());
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() < 1e-4,
                    "i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn initial_state_deterministic() {
        let (a, _) = initial_state(32);
        let (b, _) = initial_state(32);
        assert_eq!(a, b);
    }
}
