//! RSim: the iterative radiosity kernel with a *growing* access pattern
//! (§5): each time step appends one row to the result buffer after reading
//! all previous rows. "This pattern causes frequent allocation resizes
//! unless scheduler lookahead (§4.3) is active."

use super::consts::RSIM_NORM;
use crate::buffer::Buffer;
use crate::driver::Queue;
use crate::executor::{KernelCtx, Registry};
use crate::grid::{GridBox, Point, Range, Region};
#[cfg(feature = "pjrt")]
use crate::runtime::{ArgBytes, RuntimeClient};
use crate::task::{QueueError, RangeMapper};
use std::sync::Arc;

/// Deterministic visibility/reflectance matrix (row-major W × W) and the
/// initial emission row.
pub fn initial_scene(width: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = crate::util::XorShift64::new(0xCAFE + width as u64);
    let mut vis = Vec::with_capacity(width * width);
    for _ in 0..width * width {
        vis.push((rng.next_f64() as f32) * 0.2);
    }
    let mut row0 = vec![0f32; width];
    for (i, v) in row0.iter_mut().enumerate() {
        *v = 1.0 + (i % 5) as f32 * 0.1;
    }
    (vis, row0)
}

/// Submit the radiosity iteration: `steps` rows appended to an
/// (steps × width) result buffer. `workaround`: submit the §5.2 zero-init
/// kernel first, pre-allocating the whole buffer (the baseline-runtime
/// workaround; with IDAG lookahead it is unnecessary).
pub fn submit(
    q: &mut Queue,
    steps: u64,
    width: u64,
    workaround: bool,
) -> Result<(Buffer<f32>, Buffer<f32>), QueueError> {
    let (vis0, row0) = initial_scene(width as usize);
    // Row 0 = emission; rest zero.
    let mut r0 = vec![0f32; (steps * width) as usize];
    r0[..width as usize].copy_from_slice(&row0);
    let r = q.create_buffer_init("R", Range::d2(steps, width), &r0)?;
    let vis = q.create_buffer_init("VIS", Range::d2(width, width), &vis0)?;

    if workaround {
        // "a no-op kernel which zero-initializes (and thus allocates) the
        // entire buffer at the start of the program" — §5.2. Read-write
        // keeps row 0 intact.
        q.submit(|cgh| {
            cgh.read_write(r, RangeMapper::Fixed(Region::full(Range::d2(steps, width))));
            cgh.parallel_for("rsim_touch", Range::d1(width)).work_per_item(1.0);
        })?;
    }

    for t in 1..steps {
        let prev = Region::from(GridBox::d2((0, 0), (t, width)));
        q.submit(|cgh| {
            cgh.read(r, RangeMapper::Fixed(prev));
            cgh.read(vis, RangeMapper::All);
            cgh.write(r, RangeMapper::RowSlice(t));
            cgh.parallel_for("rsim_row", Range::d1(width))
                .work_per_item(t as f64 * width as f64);
        })?;
    }
    Ok((r, vis))
}

/// Pure-Rust kernels with ref.py numerics.
pub fn register_reference_kernels(registry: &Registry) {
    registry.register_kernel(
        "rsim_row",
        Arc::new(|ctx: &KernelCtx| {
            let prev = ctx.view(0); // rows [0, t)
            let vis = ctx.view(1); // (W, W), sliced columns
            let out = ctx.view(2); // row t
            let t = out.binding.region.bounding_box().min[0];
            let width = vis.binding.region.bounding_box().max[0];
            // s[w] = sum over valid history rows.
            let mut s = vec![0f32; width as usize];
            for k in 0..t {
                for w in 0..width {
                    s[w as usize] += prev.read_f32(Point::d2(k, w));
                }
            }
            let scale = RSIM_NORM / (t as f32).max(1.0);
            // The kernel index space covers the row columns; honour the
            // chunk so multi-device splits write disjoint column ranges.
            for j in ctx.chunk.min[0]..ctx.chunk.max[0] {
                let mut acc = 0f32;
                for w in 0..width {
                    acc += s[w as usize] * vis.read_f32(Point::d2(w, j));
                }
                out.write_f32(Point::d2(t, j), acc * scale);
            }
        }),
    );
    registry.register_kernel(
        "rsim_touch",
        Arc::new(|_ctx: &KernelCtx| {
            // No-op: only the implied allocation matters (§5.2 workaround).
        }),
    );
}

/// PJRT kernels executing the padded-history `rsim_row` artifact.
#[cfg(feature = "pjrt")]
pub fn register_pjrt_kernels(registry: &Registry, rt: &Arc<RuntimeClient>) {
    let row = rt.kernel("rsim_row").expect("artifact rsim_row");
    registry.register_kernel(
        "rsim_row",
        Arc::new(move |ctx: &KernelCtx| {
            let prev = ctx.view(0);
            let vis = ctx.view(1);
            let out = ctx.view(2);
            let t = out.binding.region.bounding_box().min[0] as i32;
            // History bytes, zero-padded to the artifact's (T_max, W).
            let prev_bytes = prev.read_region_bytes();
            let vis_bytes = vis.read_region_bytes();
            let result = row
                .call(&[
                    ArgBytes::Bytes(&prev_bytes),
                    ArgBytes::Bytes(&vis_bytes),
                    ArgBytes::ScalarI32(t),
                ])
                .expect("rsim_row execute");
            // The artifact returns the full row; scatter only this chunk's
            // columns (multi-device splits write disjoint column ranges).
            let cols = ctx.chunk.min[0]..ctx.chunk.max[0];
            for j in cols {
                let v = f32::from_ne_bytes(
                    result[0][j as usize * 4..j as usize * 4 + 4].try_into().expect("4-byte slice"),
                );
                out.write_f32(Point::d2(t as u64, j), v);
            }
        }),
    );
    registry.register_kernel("rsim_touch", Arc::new(|_ctx: &KernelCtx| {}));
}

/// Sequential golden model: the full (steps × width) radiosity history.
pub fn reference(steps: usize, width: usize) -> Vec<f32> {
    let (vis, row0) = initial_scene(width);
    let mut r = vec![0f32; steps * width];
    r[..width].copy_from_slice(&row0);
    for t in 1..steps {
        let mut s = vec![0f32; width];
        for k in 0..t {
            for w in 0..width {
                s[w] += r[k * width + w];
            }
        }
        let scale = RSIM_NORM / t as f32;
        for j in 0..width {
            let mut acc = 0f32;
            for w in 0..width {
                acc += s[w] * vis[w * width + j];
            }
            r[t * width + j] = acc * scale;
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::{run_cluster, ClusterConfig};
    use std::sync::Mutex;

    fn run(
        cfg: ClusterConfig,
        steps: u64,
        width: u64,
        workaround: bool,
    ) -> (Vec<Vec<f32>>, Vec<crate::driver::NodeReport>) {
        let results = Arc::new(Mutex::new(Vec::new()));
        let rc = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let (r, _) = submit(q, steps, width, workaround).expect("submit rsim");
            let got = q.fence(r).expect("fence");
            rc.lock().unwrap().push(got);
        });
        let r = std::mem::take(&mut *results.lock().unwrap());
        (r, reports)
    }

    #[test]
    fn cluster_matches_reference_single_node() {
        let registry = Registry::new();
        register_reference_kernels(&registry);
        let cfg = ClusterConfig { num_devices: 2, registry, ..Default::default() };
        let (results, reports) = run(cfg, 12, 16, false);
        assert!(reports[0].errors.is_empty(), "{:?}", reports[0].errors);
        let want = reference(12, 16);
        for got in &results {
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                    "i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn lookahead_eliminates_rsim_resizes_in_live_runtime() {
        // The paper's central RSim claim, on the real executor: with
        // lookahead no resizes; without it, one per step.
        let mk = |lookahead: bool| {
            let registry = Registry::new();
            register_reference_kernels(&registry);
            ClusterConfig { num_devices: 1, lookahead, registry, ..Default::default() }
        };
        let (_, with) = run(mk(true), 12, 16, false);
        let (_, without) = run(mk(false), 12, 16, false);
        assert_eq!(with[0].resizes_emitted, 0);
        assert!(without[0].resizes_emitted >= 9, "{}", without[0].resizes_emitted);
        assert!(with[0].bytes_allocated < without[0].bytes_allocated);
    }

    #[test]
    fn workaround_also_avoids_resizes_but_allocates_everything() {
        let registry = Registry::new();
        register_reference_kernels(&registry);
        let cfg = ClusterConfig {
            num_devices: 1,
            lookahead: false,
            registry,
            ..Default::default()
        };
        let (results, reports) = run(cfg, 12, 16, true);
        assert_eq!(reports[0].resizes_emitted, 0, "workaround pre-allocates");
        let want = reference(12, 16);
        for got in &results {
            for i in 0..want.len() {
                assert!((got[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0));
            }
        }
    }
}
