//! WaveSim: five-point wave-propagation stencil (§5).
//!
//! "Computationally inexpensive and only requires a neighborhood data
//! exchange, which makes it a good indicator for executor latency issues."
//! Three buffers rotate through the (prev, curr, next) roles each step.

use super::consts::WAVE_C;
use crate::buffer::Buffer;
use crate::driver::Queue;
use crate::executor::{KernelCtx, Registry};
use crate::grid::{Point, Range};
#[cfg(feature = "pjrt")]
use crate::runtime::{ArgBytes, RuntimeClient};
use crate::task::{QueueError, RangeMapper};
use std::sync::Arc;

/// Deterministic initial field: a centered Gaussian-ish impulse.
pub fn initial_field(rows: usize, cols: usize) -> Vec<f32> {
    let mut u = vec![0f32; rows * cols];
    let (cr, cc) = (rows as f32 / 2.0, cols as f32 / 2.0);
    for r in 0..rows {
        for c in 0..cols {
            let d2 = (r as f32 - cr).powi(2) + (c as f32 - cc).powi(2);
            u[r * cols + c] = (-d2 / 16.0).exp();
        }
    }
    u
}

/// Submit `steps` stencil iterations over an (rows × cols) field.
/// Returns the buffer holding the final field (depends on step parity).
pub fn submit(
    q: &mut Queue,
    rows: u64,
    cols: u64,
    steps: usize,
) -> Result<Buffer<f32>, QueueError> {
    let range = Range::d2(rows, cols);
    let u0 = initial_field(rows as usize, cols as usize);
    let zeros = vec![0f32; (rows * cols) as usize];
    let bufs = [
        q.create_buffer_init("U0", range, &u0)?,
        q.create_buffer_init("U1", range, &u0)?,
        q.create_buffer_init("U2", range, &zeros)?,
    ];
    for s in 0..steps {
        let prev = bufs[s % 3];
        let curr = bufs[(s + 1) % 3];
        let next = bufs[(s + 2) % 3];
        q.submit(|cgh| {
            // The artifact consumes haloed windows of both fields.
            cgh.read(prev, RangeMapper::Neighborhood(Range::d2(1, 0)));
            cgh.read(curr, RangeMapper::Neighborhood(Range::d2(1, 0)));
            cgh.write(next, RangeMapper::OneToOne);
            cgh.parallel_for("wavesim_step", range).work_per_item(10.0);
        })?;
    }
    Ok(bufs[(steps + 1) % 3])
}

/// Pure-Rust stencil with ref.py numerics (zero Dirichlet boundary).
pub fn register_reference_kernels(registry: &Registry) {
    registry.register_kernel(
        "wavesim_step",
        Arc::new(|ctx: &KernelCtx| {
            let prev = ctx.view(0);
            let curr = ctx.view(1);
            let next = ctx.view(2);
            let full_rows = prev.binding.region.bounding_box();
            let cols = full_rows.max[1];
            let at = |v: &crate::executor::BindingView, r: i64, c: i64| -> f32 {
                if r < 0 || c < 0 || c >= cols as i64 {
                    return 0.0;
                }
                let p = Point::d2(r as u64, c as u64);
                // Outside the declared (clamped) region = domain boundary → 0.
                if !v.binding.region.boxes().iter().any(|b| b.contains_point(p)) {
                    return 0.0;
                }
                v.read_f32(p)
            };
            for r in ctx.chunk.min[0]..ctx.chunk.max[0] {
                for c in ctx.chunk.min[1]..ctx.chunk.max[1] {
                    let (ri, ci) = (r as i64, c as i64);
                    let u = at(curr, ri, ci);
                    let lap = at(curr, ri - 1, ci)
                        + at(curr, ri + 1, ci)
                        + at(curr, ri, ci - 1)
                        + at(curr, ri, ci + 1)
                        - 4.0 * u;
                    let out = 2.0 * u - at(prev, ri, ci) + WAVE_C * lap;
                    next.write_f32(Point::d2(r, c), out);
                }
            }
        }),
    );
}

/// PJRT kernels executing the `wavesim_step` artifact. The artifact expects
/// fixed (rows+2, cols) windows; edge chunks (clamped neighborhoods) are
/// zero-padded to match — the zero Dirichlet boundary.
#[cfg(feature = "pjrt")]
pub fn register_pjrt_kernels(registry: &Registry, rt: &Arc<RuntimeClient>) {
    let step = rt.kernel("wavesim_step").expect("artifact wavesim_step");
    registry.register_kernel(
        "wavesim_step",
        Arc::new(move |ctx: &KernelCtx| {
            let prev = ctx.view(0);
            let curr = ctx.view(1);
            let next = ctx.view(2);
            let win_rows = step.inputs[0].dims[0]; // rows + 2
            let cols = step.inputs[0].dims[1];
            let chunk_rows = (ctx.chunk.max[0] - ctx.chunk.min[0]) as usize;
            assert_eq!(chunk_rows + 2, win_rows, "artifact shard shape mismatch");
            let pad = |v: &crate::executor::BindingView| -> Vec<u8> {
                let bytes = v.read_region_bytes();
                let row_bytes = cols * 4;
                let mut out = vec![0u8; win_rows * row_bytes];
                // The window's first row corresponds to chunk.min[0]-1.
                let lead_missing = if ctx.chunk.min[0] == 0 { 1 } else { 0 };
                let start = lead_missing * row_bytes;
                out[start..start + bytes.len()].copy_from_slice(&bytes);
                out
            };
            let p_bytes = pad(prev);
            let c_bytes = pad(curr);
            let out = step
                .call(&[ArgBytes::Bytes(&p_bytes), ArgBytes::Bytes(&c_bytes)])
                .expect("wavesim_step execute");
            next.write_region_bytes(&out[0]);
        }),
    );
}

/// Sequential golden model.
pub fn reference(rows: usize, cols: usize, steps: usize) -> Vec<f32> {
    let u0 = initial_field(rows, cols);
    let mut prev = u0.clone();
    let mut curr = u0;
    let at = |u: &[f32], r: i64, c: i64| -> f32 {
        if r < 0 || c < 0 || r >= rows as i64 || c >= cols as i64 {
            0.0
        } else {
            u[r as usize * cols + c as usize]
        }
    };
    for _ in 0..steps {
        let mut next = vec![0f32; rows * cols];
        for r in 0..rows as i64 {
            for c in 0..cols as i64 {
                let u = at(&curr, r, c);
                let lap = at(&curr, r - 1, c) + at(&curr, r + 1, c) + at(&curr, r, c - 1)
                    + at(&curr, r, c + 1)
                    - 4.0 * u;
                next[r as usize * cols + c as usize] = 2.0 * u - at(&prev, r, c) + WAVE_C * lap;
            }
        }
        prev = curr;
        curr = next;
    }
    curr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::{run_cluster, ClusterConfig};
    use std::sync::Mutex;

    #[test]
    fn cluster_matches_reference_2x2() {
        let registry = Registry::new();
        register_reference_kernels(&registry);
        let cfg = ClusterConfig {
            num_nodes: 2,
            num_devices: 2,
            registry,
            ..Default::default()
        };
        let results = Arc::new(Mutex::new(Vec::new()));
        let rc = results.clone();
        let reports = run_cluster(cfg, move |q| {
            let out = submit(q, 32, 16, 4).expect("submit wavesim");
            let got = q.fence(out).expect("fence");
            rc.lock().unwrap().push(got);
        });
        for r in &reports {
            assert!(r.errors.is_empty(), "{:?}", r.errors);
        }
        let want = reference(32, 16, 4);
        for got in results.lock().unwrap().iter() {
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() < 1e-4,
                    "i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn reference_impulse_spreads() {
        let out = reference(16, 16, 3);
        assert!(out.iter().all(|v| v.is_finite()));
        // Energy must have left the center cell.
        let center = out[8 * 16 + 8];
        assert!(center < 1.0);
    }
}
