//! The scheduler: combined CDAG + IDAG generation with lookahead (§4, §4.3).
//!
//! A dedicated scheduler thread receives task references from the main
//! thread over an spsc queue, generates the command graph and instruction
//! graph, and forwards executable instructions (plus pilot messages) to the
//! executor thread — all concurrently with both the user program and the
//! execution of earlier instructions (Fig 5).
//!
//! The *lookahead* mechanism (§4.3) postpones instruction generation while
//! changing allocation patterns are observed: once an *allocating command*
//! enters the command queue, instruction generation stops until two
//! horizons pass without another allocating command, at which point queued
//! requirements are merged into the next `alloc` instructions —
//! eliminating resize chains (*resize elision*).

mod thread;

pub use thread::{SchedulerHandle, SchedulerMsg, SchedulerOut, UserInit};

use crate::buffer::BufferPool;
use crate::command::{CdagGenerator, CommandKind, CommandRef, SplitHint};
use crate::grid::GridBox;
use crate::instruction::{IdagConfig, IdagGenerator, InstructionRef, Pilot};
use crate::task::TaskRef;
use crate::util::{BufferId, JobId, MemoryId, NodeId};
use std::collections::{HashMap, VecDeque};

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// The job this scheduler core compiles for. Multi-tenant clusters run
    /// one core per job inside the shared scheduler thread; the job id
    /// namespaces every command/instruction/allocation/message id the core
    /// emits. Job 0 is the single-tenant default.
    pub job: JobId,
    pub node: NodeId,
    pub num_nodes: u64,
    pub num_devices: u64,
    pub node_hint: SplitHint,
    pub device_hint: SplitHint,
    pub d2d: bool,
    /// Enable the lookahead mechanism (§4.3). Off = compile every command
    /// immediately (still IDAG scheduling, but resizes may occur).
    pub lookahead: bool,
    /// Flush the queue after this many horizons without an allocating
    /// command (the paper uses 2).
    pub horizon_flush: u32,
    /// Lower detected all-gather/broadcast patterns to collective commands
    /// (ring schedule) instead of O(n²) p2p push/await-push pairs. On by
    /// default; off reproduces the pure p2p protocol (identity tests,
    /// bench ablation).
    pub collectives: bool,
    /// Direct device transfers: elide the pinned-host (M1) staging hops on
    /// the p2p send/receive path when the data is device-resident and the
    /// consumer geometry is known. On by default; off (`--no-direct-comm`)
    /// reproduces the fully staged lowering (ablation).
    pub direct_comm: bool,
    /// Run the static instruction-graph verifier ([`crate::verify`]) over
    /// every emitted batch: race-freedom, allocation lifetime, coherence,
    /// pilot matching and structural invariants are checked as the graph is
    /// compiled, and violations surface through the §4.4 error stream. Off
    /// by default (`--verify`); when off the cost is one branch per batch.
    pub verify: bool,
    /// Keep a copy of every emitted instruction so the performance
    /// analyzer ([`crate::analyze`]) can run over the full stream after
    /// the run (`--analyze`). Off by default; the cost when on is one
    /// `Arc` clone per instruction.
    pub analyze: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            job: JobId(0),
            node: NodeId(0),
            num_nodes: 1,
            num_devices: 1,
            node_hint: SplitHint::D1,
            device_hint: SplitHint::D1,
            d2d: true,
            lookahead: true,
            horizon_flush: 2,
            collectives: true,
            direct_comm: true,
            verify: false,
            analyze: false,
        }
    }
}

/// Synchronous scheduler core: task in, instructions + pilots out.
/// [`SchedulerHandle`] wraps it in a dedicated thread.
pub struct Scheduler {
    cdag: CdagGenerator,
    idag: IdagGenerator,
    cfg: SchedulerConfig,
    /// Present iff `cfg.verify`: absorbs every emitted batch and reports
    /// ordering/lifetime/coherence violations as §4.4 errors.
    verifier: Option<crate::verify::Verifier>,
    /// The buffer pool in its most recently announced state (the analyzer
    /// prices transfers by element size).
    buffers: BufferPool,
    /// Every instruction emitted so far, in generation order — only kept
    /// when `cfg.analyze` (the `--analyze` post-run report).
    kept: Vec<InstructionRef>,
    /// The command queue of Fig 5 (only fills while lookahead holds).
    queue: VecDeque<CommandRef>,
    /// Bounding cover of requirements queued per (buffer, memory): a queued
    /// command whose needs are inside this cover is *not* allocating.
    queued_cover: HashMap<(BufferId, MemoryId), GridBox>,
    /// Whether an allocating command is currently queued.
    holding: bool,
    /// Horizons seen since the last allocating command.
    horizons_since_alloc: u32,
    /// Statistics.
    pub commands_generated: u64,
    pub instructions_generated: u64,
    pub max_queue_len: usize,
    pub flushes: u64,
    /// Wakeup batches processed (a batch = one [`Scheduler::process_batch`]
    /// call; the scheduler thread drains its task queue per wakeup).
    pub batches: u64,
    pub max_batch_tasks: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig, buffers: BufferPool) -> Self {
        let mut cdag = CdagGenerator::with_job(
            cfg.job,
            cfg.node,
            cfg.num_nodes,
            cfg.node_hint,
            buffers.clone(),
        );
        cdag.set_collectives(cfg.collectives);
        let idag = IdagGenerator::with_job(
            cfg.job,
            IdagConfig {
                node: cfg.node,
                num_nodes: cfg.num_nodes,
                num_devices: cfg.num_devices,
                node_hint: cfg.node_hint,
                device_hint: cfg.device_hint,
                d2d: cfg.d2d,
                direct_comm: cfg.direct_comm,
            },
            buffers.clone(),
        );
        // The in-core path runs the *incremental* verifier: tracker state
        // is compacted at verified boundaries, so per-batch re-check work
        // is proportional to the span since the last applied horizon —
        // cheap enough to leave `--verify` on under lookahead.
        let verifier = cfg
            .verify
            .then(|| crate::verify::Verifier::incremental(cfg.job, cfg.node, buffers.clone()));
        Scheduler {
            cdag,
            idag,
            cfg,
            verifier,
            buffers,
            kept: Vec::new(),
            queue: VecDeque::new(),
            queued_cover: HashMap::new(),
            holding: false,
            horizons_since_alloc: 0,
            commands_generated: 0,
            instructions_generated: 0,
            max_queue_len: 0,
            flushes: 0,
            batches: 0,
            max_batch_tasks: 0,
        }
    }

    /// Register newly created buffers.
    pub fn notify_buffers(&mut self, pool: BufferPool) {
        self.cdag.notify_buffers(pool.clone());
        if let Some(v) = &mut self.verifier {
            v.notify_buffers(pool.clone());
        }
        self.buffers = pool.clone();
        self.idag.notify_buffers(pool);
    }

    /// Process one task: returns the instructions (possibly none, while the
    /// lookahead holds) and pilot messages that became ready.
    pub fn process(&mut self, task: &TaskRef) -> (Vec<InstructionRef>, Vec<Pilot>) {
        self.process_batch(std::slice::from_ref(task))
    }

    /// Process a run of queued tasks in one wakeup (the batched pipeline):
    /// all commands are generated and fed through the lookahead window
    /// first, then the resulting instructions and pilots are drained as a
    /// single batch — amortizing outbox/channel traffic across the run.
    /// Equivalent to processing the tasks one by one and concatenating the
    /// results.
    pub fn process_batch(&mut self, tasks: &[TaskRef]) -> (Vec<InstructionRef>, Vec<Pilot>) {
        self.batches += 1;
        self.max_batch_tasks = self.max_batch_tasks.max(tasks.len());
        for task in tasks {
            self.cdag.compile(task);
        }
        let cmds = self.cdag.take_new_commands();
        self.commands_generated += cmds.len() as u64;
        for cmd in cmds {
            self.enqueue(cmd);
        }
        let instrs = self.idag.take_new_instructions();
        self.instructions_generated += instrs.len() as u64;
        let pilots = self.idag.take_pilots();
        if let Some(v) = &mut self.verifier {
            v.absorb_batch(&instrs, &pilots);
        }
        if self.cfg.analyze {
            self.kept.extend(instrs.iter().cloned());
        }
        (instrs, pilots)
    }

    /// Force-flush the command queue (used on shutdown).
    pub fn flush_now(&mut self) -> (Vec<InstructionRef>, Vec<Pilot>) {
        self.flush();
        let instrs = self.idag.take_new_instructions();
        self.instructions_generated += instrs.len() as u64;
        let pilots = self.idag.take_pilots();
        if let Some(v) = &mut self.verifier {
            v.absorb_batch(&instrs, &pilots);
        }
        if self.cfg.analyze {
            self.kept.extend(instrs.iter().cloned());
        }
        (instrs, pilots)
    }

    /// Scheduler errors from command generation (§4.4).
    pub fn take_errors(&mut self) -> Vec<crate::command::CommandError> {
        self.cdag.take_errors()
    }

    /// §4.4 errors from instruction generation (e.g. a push of a region no
    /// task ever wrote) — reported instead of panicking the scheduler
    /// thread, merged into `SchedulerOut.errors` alongside CDAG errors.
    pub fn take_idag_errors(&mut self) -> Vec<String> {
        self.idag.take_errors()
    }

    /// Violations found by the `--verify` static analysis since the last
    /// drain, rendered for the §4.4 error stream and attributed to the
    /// owning job (multi-tenant clusters share one stream). Empty when
    /// verification is off.
    pub fn take_verify_errors(&mut self) -> Vec<String> {
        let job = self.cfg.job;
        match &mut self.verifier {
            Some(v) => v
                .take_violations()
                .iter()
                .map(|v| crate::verify::attribute(job, v))
                .collect(),
            None => Vec::new(),
        }
    }

    /// Instructions absorbed by the verifier so far (0 when off).
    pub fn instructions_verified(&self) -> u64 {
        self.verifier.as_ref().map_or(0, |v| v.instructions_verified)
    }

    /// Run the performance analyzer ([`crate::analyze`]) over every
    /// instruction this core has emitted. Meaningful only with
    /// `cfg.analyze` (otherwise the kept stream is empty and the report is
    /// trivially clean); the driver calls this at shutdown for `--analyze`
    /// runs.
    pub fn analyze(&self, cfg: &crate::analyze::AnalyzeConfig) -> crate::analyze::Report {
        crate::analyze::analyze_stream(self.cfg.node, &self.buffers, &self.kept, cfg)
    }

    pub fn idag(&self) -> &IdagGenerator {
        &self.idag
    }

    /// The job this core compiles for.
    pub fn job(&self) -> JobId {
        self.cfg.job
    }

    pub fn cdag(&self) -> &CdagGenerator {
        &self.cdag
    }

    /// Current lookahead queue length (diagnostics; Fig 7 shows RSim
    /// queuing the entire command graph).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn enqueue(&mut self, cmd: CommandRef) {
        if !self.cfg.lookahead {
            self.compile_one(&cmd);
            return;
        }

        // Is this command allocating, accounting for requirements already
        // queued ahead of it? ("Whenever a new command has been generated,
        // the scheduler will inquire whether compiling it right away would
        // emit any alloc instructions" — §4.3.) The requirement set is
        // computed once and reused for the predicate, the queued-cover check
        // and the cover extension below.
        let reqs = self.idag.requirements(&cmd);
        let allocating = self.idag.would_allocate_reqs(&reqs)
            && reqs.iter().any(|(buf, mem, bbox)| {
                !self
                    .queued_cover
                    .get(&(*buf, *mem))
                    .is_some_and(|cover| cover.contains(bbox))
            });

        let is_horizon = matches!(cmd.kind, CommandKind::Horizon);
        let is_epoch = matches!(cmd.kind, CommandKind::Epoch(_));

        if allocating {
            self.holding = true;
            self.horizons_since_alloc = 0;
        }
        for (buf, mem, bbox) in &reqs {
            let e = self
                .queued_cover
                .entry((*buf, *mem))
                .or_insert(GridBox::EMPTY);
            *e = e.bounding_union(bbox);
        }

        if !self.holding {
            // Nothing allocating queued: pass-through compilation.
            debug_assert!(self.queue.is_empty());
            self.compile_one(&cmd);
            return;
        }

        self.queue.push_back(cmd);
        self.max_queue_len = self.max_queue_len.max(self.queue.len());

        if is_epoch {
            // Epochs synchronize with the main thread: always flush.
            self.flush();
        } else if is_horizon {
            self.horizons_since_alloc += 1;
            if self.horizons_since_alloc >= self.cfg.horizon_flush {
                self.flush();
            }
        }
    }

    /// Flush: announce the merged requirements of everything queued, then
    /// compile the queue in order. The first alloc emitted covers the whole
    /// observed requirement (§4.3 resize elision).
    fn flush(&mut self) {
        if !self.queue.is_empty() {
            self.flushes += 1;
        }
        let reqs: Vec<(BufferId, MemoryId, GridBox)> = self
            .queued_cover
            .iter()
            .map(|((b, m), bbox)| (*b, *m, *bbox))
            .collect();
        self.idag.announce(&reqs);
        while let Some(cmd) = self.queue.pop_front() {
            self.compile_one(&cmd);
        }
        self.queued_cover.clear();
        self.holding = false;
        self.horizons_since_alloc = 0;
    }

    fn compile_one(&mut self, cmd: &CommandRef) {
        self.idag.compile(cmd);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::{Range, Region};
    use crate::task::{RangeMapper, TaskDecl, TaskManager};

    /// Drive the scheduler with an RSim-like growing access pattern:
    /// step t writes row t of a (T × W) buffer and reads rows [0, t).
    fn rsim_tasks(tm: &mut TaskManager, steps: u64, width: u64) -> crate::util::BufferId {
        let b = tm.create_buffer::<f64>("R", Range::d2(steps, width), false).id();
        for t in 0..steps {
            let row =
                Region::from(GridBox::d2((t, 0), (t + 1, width)));
            let prev = Region::from(GridBox::d2((0, 0), (t.max(1), width)));
            let mut decl = TaskDecl::device("radiosity", Range::d1(width))
                .write(b, RangeMapper::Fixed(row));
            if t > 0 {
                decl = decl.read(b, RangeMapper::Fixed(prev));
            }
            tm.submit(decl);
        }
        b
    }

    /// Always-on verification in scheduler tests: every graph these tests
    /// compile is additionally audited by the static verifier.
    fn vcfg() -> SchedulerConfig {
        SchedulerConfig { verify: true, ..Default::default() }
    }

    fn run_scheduler(
        lookahead: bool,
        f: impl FnOnce(&mut TaskManager),
    ) -> (Scheduler, Vec<crate::instruction::InstructionRef>) {
        let mut tm = TaskManager::new();
        f(&mut tm);
        tm.shutdown();
        let tasks = tm.take_new_tasks();
        let cfg = SchedulerConfig { lookahead, ..vcfg() };
        let mut sched = Scheduler::new(cfg, tm.buffers().clone());
        let mut all = Vec::new();
        for t in &tasks {
            let (instrs, _) = sched.process(t);
            all.extend(instrs);
        }
        let (instrs, _) = sched.flush_now();
        all.extend(instrs);
        let violations = sched.take_verify_errors();
        assert!(violations.is_empty(), "verifier must pass clean: {violations:?}");
        assert_eq!(sched.instructions_verified() as usize, all.len());
        (sched, all)
    }

    #[test]
    fn rsim_lookahead_eliminates_resizes() {
        let (with, _) = run_scheduler(true, |tm| {
            rsim_tasks(tm, 32, 64);
        });
        let (without, _) = run_scheduler(false, |tm| {
            rsim_tasks(tm, 32, 64);
        });
        assert_eq!(with.idag().resizes_emitted, 0, "lookahead must elide all resizes");
        assert!(
            without.idag().resizes_emitted >= 30,
            "naive scheduling must resize nearly every step, got {}",
            without.idag().resizes_emitted
        );
        // And allocate far less total memory.
        assert!(with.idag().bytes_allocated < without.idag().bytes_allocated / 4);
    }

    #[test]
    fn rsim_queues_entire_program() {
        // §4.3: "for this pattern the horizon-based heuristic will never
        // flush the command queue" — the queue drains only at shutdown.
        let mut tm = TaskManager::new();
        rsim_tasks(&mut tm, 32, 64);
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(vcfg(), tm.buffers().clone());
        let mut emitted_before_end = 0;
        for t in &tasks {
            let (instrs, _) = sched.process(t);
            emitted_before_end += instrs.len();
        }
        assert_eq!(emitted_before_end, 1, "only the init epoch may compile early");
        assert!(sched.queue_len() > 30);
        let (instrs, _) = sched.flush_now();
        assert!(!instrs.is_empty());
    }

    #[test]
    fn steady_state_flushes_after_two_horizons() {
        // WaveSim-like steady pattern: allocating at step 1, then stable.
        // After two horizons, the scheduler must return to pass-through.
        let mut tm = TaskManager::with_horizon_step(2);
        let n = Range::d2(64, 64);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let tasks: Vec<_> = {
            for _ in 0..20 {
                tm.submit(
                    TaskDecl::device("s", n)
                        .read(a, RangeMapper::Neighborhood(Range::d2(1, 1)))
                        .write(b, RangeMapper::OneToOne),
                );
                tm.submit(
                    TaskDecl::device("s", n)
                        .read(b, RangeMapper::Neighborhood(Range::d2(1, 1)))
                        .write(a, RangeMapper::OneToOne),
                );
            }
            tm.take_new_tasks()
        };
        let mut sched = Scheduler::new(vcfg(), tm.buffers().clone());
        let mut tail_latency = Vec::new();
        for t in &tasks {
            let (instrs, _) = sched.process(t);
            tail_latency.push(instrs.len());
        }
        // The last quarter of tasks must compile immediately (pass-through):
        // once allocations are stable, *every* processed task emits its
        // instructions right away instead of queueing behind the lookahead.
        let tail = &tail_latency[tail_latency.len() - 10..];
        assert!(
            tail.iter().all(|&n| n > 0),
            "steady state must emit instructions on every task, got tail {tail:?}"
        );
        assert_eq!(sched.queue_len(), 0, "queue must be drained in steady state");
        assert_eq!(sched.idag().resizes_emitted, 0);
    }

    #[test]
    fn lookahead_off_still_correct_but_resizes() {
        let (sched, instrs) = run_scheduler(false, |tm| {
            rsim_tasks(tm, 8, 16);
        });
        assert!(sched.idag().resizes_emitted > 0);
        // Graph is still acyclic and complete.
        assert!(instrs.iter().any(|i| i.kind.mnemonic() == "device kernel"));
    }

    #[test]
    fn epoch_always_flushes() {
        let mut tm = TaskManager::new();
        rsim_tasks(&mut tm, 8, 16);
        tm.barrier();
        let tasks = tm.take_new_tasks();
        let mut sched = Scheduler::new(vcfg(), tm.buffers().clone());
        let mut total = 0;
        for t in &tasks {
            let (instrs, _) = sched.process(t);
            total += instrs.len();
        }
        assert_eq!(sched.queue_len(), 0, "barrier epoch must flush the queue");
        assert!(total > 8);
    }

    #[test]
    fn process_batch_matches_sequential_processing() {
        // The batched pipeline must be observationally identical to
        // one-task-at-a-time processing: same instructions, same resize
        // behavior — only the wakeup granularity differs.
        let build = |tm: &mut TaskManager| {
            rsim_tasks(tm, 24, 48);
            tm.shutdown();
        };
        let mut tm = TaskManager::new();
        build(&mut tm);
        let tasks = tm.take_new_tasks();

        let mut seq = Scheduler::new(vcfg(), tm.buffers().clone());
        let mut seq_instrs = Vec::new();
        for t in &tasks {
            let (i, _) = seq.process(t);
            seq_instrs.extend(i);
        }
        let (i, _) = seq.flush_now();
        seq_instrs.extend(i);

        let mut bat = Scheduler::new(vcfg(), tm.buffers().clone());
        let (mut bat_instrs, _) = bat.process_batch(&tasks);
        let (i, _) = bat.flush_now();
        bat_instrs.extend(i);

        assert_eq!(seq_instrs.len(), bat_instrs.len());
        assert!(seq_instrs
            .iter()
            .zip(&bat_instrs)
            .all(|(a, b)| a.id == b.id && a.kind.mnemonic() == b.kind.mnemonic()));
        assert_eq!(seq.idag().resizes_emitted, bat.idag().resizes_emitted);
        assert_eq!(bat.batches, 1);
        assert_eq!(bat.max_batch_tasks, tasks.len());
    }

    #[test]
    fn stats_track_generation() {
        let (sched, instrs) = run_scheduler(true, |tm| {
            rsim_tasks(tm, 8, 16);
        });
        assert_eq!(sched.instructions_generated as usize, instrs.len());
        assert!(sched.commands_generated >= 8);
        assert!(sched.max_queue_len >= 8);
    }

    /// Satellite regression: IDAG-level §4.4 errors (push of a never-
    /// written region) flow out through `take_idag_errors` — the scheduler
    /// thread forwards them in `SchedulerOut.errors` instead of dying.
    #[test]
    fn idag_errors_surface_through_scheduler() {
        let mut tm = TaskManager::new();
        let r = Range::d1(64);
        let a = tm.create_buffer::<f64>("A", r, false).id();
        tm.submit(TaskDecl::device("w", r).write(a, RangeMapper::OneToOne));
        let tasks = tm.take_new_tasks();
        let task = tasks.last().unwrap().clone();
        let mut sched = Scheduler::new(
            SchedulerConfig { num_nodes: 2, ..Default::default() },
            tm.buffers().clone(),
        );
        // Drive the pathological command straight into the scheduler's
        // IDAG (the CDAG never produces it for well-formed programs).
        sched.idag.compile(&crate::command::Command {
            id: crate::util::CommandId(1),
            task,
            kind: crate::command::CommandKind::Push {
                buffer: a,
                region: Region::from(GridBox::d1(0, 64)),
                target: crate::util::NodeId(1),
            },
            deps: vec![],
        });
        let errors = sched.take_idag_errors();
        assert_eq!(errors.len(), 1, "{errors:?}");
        assert!(errors[0].contains("never written"));
        assert!(sched.take_idag_errors().is_empty(), "drained");
    }
}
