//! The dedicated scheduler thread (Fig 5), shared by all jobs of a cluster.
//!
//! Multi-tenant operation: the thread owns one [`Scheduler`] core *per job*
//! and interleaves compilation across them. Messages arrive over a shared
//! mpsc inbox tagged with the originating [`JobId`]; per-wakeup task batches
//! are capped (and never span jobs), so a heavy job's compile backlog cannot
//! monopolize the thread — other jobs' tasks are compiled within one batch
//! window. Every [`SchedulerOut`] batch carries its job so the executor can
//! attribute errors and epochs to the right fence.

use super::{Scheduler, SchedulerConfig};
use crate::buffer::BufferPool;
use crate::grid::GridBox;
use crate::instruction::{InstructionRef, Pilot};
use crate::task::TaskRef;
use crate::util::{spsc, AllocationId, JobId};
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Host-initialized buffer contents, materialized in the executor's arena
/// as the buffer's user-memory (M0) allocation. Travels through the
/// scheduler pipeline so it is ordered before any instruction that reads
/// it.
#[derive(Debug)]
pub struct UserInit {
    pub alloc: AllocationId,
    pub covers: GridBox,
    pub elem_size: usize,
    /// Empty = zero-fill.
    pub bytes: Vec<u8>,
}

/// Messages from the main thread(s) to the scheduler thread. Each message
/// is sent as a `(JobId, SchedulerMsg)` pair; the per-job scheduler core is
/// created lazily on the job's first message.
pub enum SchedulerMsg {
    /// A buffer was created; snapshot of the job's updated pool.
    Buffers(BufferPool),
    /// Host-initialized buffer contents to forward to the executor.
    UserData(UserInit),
    /// A new task reference (user task, horizon or epoch).
    Task(TaskRef),
    /// Drain this job's queue and retire its scheduler core. The thread
    /// keeps running for other jobs; it exits when every sender is gone.
    Shutdown,
}

/// Output of the scheduler thread, consumed by the executor thread.
pub struct SchedulerOut {
    /// The job this batch belongs to. Instruction/pilot ids are tagged with
    /// the same job in their high bits; the explicit field spares the
    /// executor from deriving it and covers instruction-free batches
    /// (user inits, pure error batches).
    pub job: JobId,
    pub instructions: Vec<InstructionRef>,
    pub pilots: Vec<Pilot>,
    pub user_inits: Vec<UserInit>,
    /// §4.4 errors detected during command generation, forwarded through
    /// the executor's event stream to the owning job's queue — never to
    /// another job's fence.
    pub errors: Vec<String>,
}

impl SchedulerOut {
    pub fn batch(job: JobId, instructions: Vec<InstructionRef>, pilots: Vec<Pilot>) -> Self {
        SchedulerOut {
            job,
            instructions,
            pilots,
            user_inits: Vec::new(),
            errors: Vec::new(),
        }
    }
}

/// Upper bound on tasks compiled per wakeup. Draining amortizes channel
/// traffic, but an unbounded batch would delay the first instruction of a
/// large backlog behind the whole compile; the cap keeps time-to-first-
/// instruction bounded while still coalescing bursts. Batches never span
/// jobs, so the cap doubles as the scheduler-side fairness quantum.
const MAX_WAKEUP_BATCH: usize = 64;

/// Handle to a running scheduler thread. Cloning the sender (one clone per
/// job queue) is how multiple tenants feed one thread.
pub struct SchedulerHandle {
    tx: mpsc::Sender<(JobId, SchedulerMsg)>,
    join: JoinHandle<Vec<(JobId, Scheduler)>>,
}

impl SchedulerHandle {
    /// Spawn the scheduler thread. Emitted instruction batches flow into
    /// `out` (the executor's inbox). `cfg.job` is ignored: per-job cores
    /// derive their config from `cfg` with the job substituted.
    pub fn spawn(cfg: SchedulerConfig, out: spsc::Sender<SchedulerOut>) -> SchedulerHandle {
        let (tx, rx) = mpsc::channel::<(JobId, SchedulerMsg)>();
        let join = std::thread::Builder::new()
            .name(format!("celerity-sched-{}", cfg.node))
            .spawn(move || run_scheduler_thread(cfg, rx, out))
            .expect("spawn scheduler thread");
        SchedulerHandle { tx, join }
    }

    /// A sender clone for one job's queue.
    pub fn sender(&self) -> mpsc::Sender<(JobId, SchedulerMsg)> {
        self.tx.clone()
    }

    /// Send a message on behalf of `job`. A dead scheduler thread is
    /// reported, not propagated: the executor side observes the closed
    /// output channel and surfaces the failure through the §4.4 error
    /// stream, so panicking the *user* thread here would only mask it.
    pub fn send(&self, job: JobId, msg: SchedulerMsg) {
        if self.tx.send((job, msg)).is_err() {
            eprintln!("[celerity] scheduler thread is gone; dropping a {job} message");
        }
    }

    /// Drop the handle's sender and collect the retired per-job schedulers
    /// (statistics). Blocks until every other sender clone is gone. If the
    /// scheduler thread panicked, the panic is reported and an empty
    /// statistics list is returned — callers treat it like a thread that
    /// retired no cores.
    pub fn join(self) -> Vec<(JobId, Scheduler)> {
        drop(self.tx);
        match self.join.join() {
            Ok(retired) => retired,
            Err(panic) => {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                eprintln!("[celerity] scheduler thread panicked: {msg}");
                Vec::new()
            }
        }
    }
}

fn run_scheduler_thread(
    cfg: SchedulerConfig,
    rx: mpsc::Receiver<(JobId, SchedulerMsg)>,
    out: spsc::Sender<SchedulerOut>,
) -> Vec<(JobId, Scheduler)> {
    let cfg_node = cfg.node;
    let mut cores: HashMap<JobId, Scheduler> = HashMap::new();
    let mut retired: Vec<(JobId, Scheduler)> = Vec::new();
    // Non-task message (or other-job task) popped while draining a task
    // run; handled on the next loop iteration to preserve message order.
    let mut carry: Option<(JobId, SchedulerMsg)> = None;
    loop {
        let msg = match carry.take() {
            Some(m) => Ok(m),
            None => rx.recv().map_err(|_| ()),
        };
        let (job, msg) = match msg {
            Ok(m) => m,
            Err(()) => break, // every sender gone: drain and exit
        };
        let core = cores.entry(job).or_insert_with(|| {
            let mut c = cfg.clone();
            c.job = job;
            Scheduler::new(c, BufferPool::with_base(job.base()))
        });
        match msg {
            SchedulerMsg::Buffers(pool) => core.notify_buffers(pool),
            SchedulerMsg::UserData(init) => {
                let _ = out.send(SchedulerOut {
                    job,
                    instructions: vec![],
                    pilots: vec![],
                    user_inits: vec![init],
                    errors: vec![],
                });
            }
            SchedulerMsg::Task(task) => {
                // Batched wakeup: drain the run of *this job's* tasks already
                // queued behind this one and compile them in a single
                // pipeline pass; one SchedulerOut per wakeup amortizes
                // channel traffic and lets the lookahead see the whole
                // window at once (§4.3). A message for another job (or a
                // non-task message) ends the batch and is carried over, so
                // compilation interleaves across tenants.
                let mut tasks = vec![task];
                while tasks.len() < MAX_WAKEUP_BATCH {
                    match rx.try_recv() {
                        Ok((j, SchedulerMsg::Task(t))) if j == job => tasks.push(t),
                        Ok(other) => {
                            carry = Some(other);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                compile_batch(cfg_node.0, job, core, &tasks, &out);
            }
            SchedulerMsg::Shutdown => {
                // The entry() above created the core if it did not exist,
                // but stay defensive: a double shutdown must not kill the
                // thread the *other* jobs are still compiling on.
                if let Some(mut core) = cores.remove(&job) {
                    flush_core(cfg_node.0, job, &mut core, &out);
                    retired.push((job, core));
                }
            }
        }
    }
    // Channel disconnected with live cores (e.g. a queue dropped without
    // shutdown): flush them so the executor still drains and exits.
    let mut leftover: Vec<(JobId, Scheduler)> = cores.into_iter().collect();
    leftover.sort_by_key(|(j, _)| *j);
    for (job, mut core) in leftover {
        flush_core(cfg_node.0, job, &mut core, &out);
        retired.push((job, core));
    }
    crate::trace::flush_thread();
    retired.sort_by_key(|(j, _)| *j);
    retired
}

/// Compile one wakeup batch for `job` and ship the results.
fn compile_batch(
    node: u64,
    job: JobId,
    core: &mut Scheduler,
    tasks: &[TaskRef],
    out: &spsc::Sender<SchedulerOut>,
) {
    let trace = std::env::var_os("CELERITY_COMM_TRACE").is_some();
    if trace {
        eprintln!(
            "[sched {node} {job}] processing batch of {} (first: {} '{}')",
            tasks.len(),
            tasks[0].id,
            tasks[0].name
        );
    }
    let tracing = crate::trace::enabled();
    let t0 = if tracing { crate::trace::now_ns() } else { 0 };
    let flushes_before = core.flushes;
    let (instructions, pilots) = core.process_batch(tasks);
    if tracing {
        record_batch_trace(
            node,
            t0,
            tasks.len(),
            &instructions,
            core.queue_len(),
            core.flushes - flushes_before,
        );
    }
    if trace {
        eprintln!(
            "[sched {node} {job}] emitted {} instrs {} pilots (queue={})",
            instructions.len(),
            pilots.len(),
            core.queue_len()
        );
    }
    ship(job, core, instructions, pilots, out);
}

/// Final flush of one job's core (job shutdown or thread exit).
fn flush_core(node: u64, job: JobId, core: &mut Scheduler, out: &spsc::Sender<SchedulerOut>) {
    let tracing = crate::trace::enabled();
    let t0 = if tracing { crate::trace::now_ns() } else { 0 };
    let flushes_before = core.flushes;
    let (instructions, pilots) = core.flush_now();
    if tracing {
        record_batch_trace(
            node,
            t0,
            0,
            &instructions,
            core.queue_len(),
            core.flushes - flushes_before,
        );
    }
    ship(job, core, instructions, pilots, out);
}

fn ship(
    job: JobId,
    core: &mut Scheduler,
    instructions: Vec<InstructionRef>,
    pilots: Vec<Pilot>,
    out: &spsc::Sender<SchedulerOut>,
) {
    let mut errors: Vec<String> = core.take_errors().iter().map(|e| e.to_string()).collect();
    errors.extend(core.take_idag_errors());
    errors.extend(core.take_verify_errors());
    if !instructions.is_empty() || !pilots.is_empty() || !errors.is_empty() {
        let mut batch = SchedulerOut::batch(job, instructions, pilots);
        batch.errors = errors;
        let _ = out.send(batch);
    }
}

/// Record one wakeup into the trace: a `SchedBatch` span over the compile,
/// a `Compiled` instant per emitted instruction (carrying the IDAG edges
/// for the Graphviz export), and a `LookaheadFlush` instant per lookahead
/// flush the batch triggered. Only called with tracing enabled, so the
/// per-instruction dep vectors are never built on the normal path.
fn record_batch_trace(
    node: u64,
    t0: u64,
    tasks: usize,
    instructions: &[InstructionRef],
    queue_len: usize,
    flushes: u64,
) {
    use crate::trace::{self, EventKind, Track};
    trace::span(
        node,
        Track::Scheduler,
        t0,
        EventKind::SchedBatch {
            tasks: tasks as u64,
            instructions: instructions.len() as u64,
            queue_len: queue_len as u64,
        },
    );
    for i in instructions {
        trace::instant(
            node,
            Track::Scheduler,
            EventKind::Compiled {
                instr: i.id.0,
                mnemonic: i.kind.mnemonic(),
                deps: i.deps.iter().map(|(d, _)| d.0).collect(),
            },
        );
    }
    for _ in 0..flushes {
        trace::instant(node, Track::Scheduler, EventKind::LookaheadFlush);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Range;
    use crate::task::{RangeMapper, TaskDecl, TaskManager};

    #[test]
    fn thread_processes_and_flushes_on_shutdown() {
        let mut tm = TaskManager::new();
        let n = Range::d1(128);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        for _ in 0..4 {
            tm.submit(TaskDecl::device("w", n).read_write(a, RangeMapper::OneToOne));
        }
        tm.shutdown();
        let tasks = tm.take_new_tasks();

        let (out_tx, out_rx) = spsc::channel(1024);
        let h = SchedulerHandle::spawn(SchedulerConfig::default(), out_tx);
        h.send(JobId(0), SchedulerMsg::Buffers(tm.buffers().clone()));
        let n_tasks = tasks.len() as u64;
        for t in tasks {
            h.send(JobId(0), SchedulerMsg::Task(t));
        }
        h.send(JobId(0), SchedulerMsg::Shutdown);
        let mut scheds = h.join();
        assert_eq!(scheds.len(), 1);
        let (job, sched) = scheds.pop().unwrap();
        assert_eq!(job, JobId(0));
        let mut total = 0;
        let mut outs = 0u64;
        while let Ok(batch) = out_rx.recv() {
            assert_eq!(batch.job, JobId(0));
            total += batch.instructions.len();
            outs += 1;
        }
        assert_eq!(total as u64, sched.instructions_generated);
        assert!(total > 4);
        // Wakeup batching: every task was processed, in at most one batch
        // per task message (how runs coalesce depends on thread timing),
        // and output batches never exceed wakeups + the shutdown flush.
        assert!(sched.batches >= 1 && sched.batches <= n_tasks, "batches={}", sched.batches);
        assert!(outs <= sched.batches + 1, "outs={outs} batches={}", sched.batches);
    }

    /// Two jobs interleaved through one thread: every output batch carries
    /// its owning job, instruction ids live in the owning job's namespace,
    /// and each job's compiled stream is identical to a solo run.
    #[test]
    fn two_jobs_interleave_without_cross_talk() {
        let build = |job: JobId| {
            let mut tm = TaskManager::with_job(job);
            let n = Range::d1(128);
            let a = tm.create_buffer::<f64>("A", n, true).id();
            for _ in 0..6 {
                tm.submit(TaskDecl::device("w", n).read_write(a, RangeMapper::OneToOne));
            }
            tm.shutdown();
            (tm.buffers().clone(), tm.take_new_tasks())
        };
        let (pool1, tasks1) = build(JobId(1));
        let (pool2, tasks2) = build(JobId(2));

        let (out_tx, out_rx) = spsc::channel(1024);
        let h = SchedulerHandle::spawn(SchedulerConfig::default(), out_tx);
        h.send(JobId(1), SchedulerMsg::Buffers(pool1));
        h.send(JobId(2), SchedulerMsg::Buffers(pool2));
        // Interleave task submission across the two jobs.
        let mut it1 = tasks1.into_iter();
        let mut it2 = tasks2.into_iter();
        loop {
            let a = it1.next();
            let b = it2.next();
            if a.is_none() && b.is_none() {
                break;
            }
            if let Some(t) = a {
                h.send(JobId(1), SchedulerMsg::Task(t));
            }
            if let Some(t) = b {
                h.send(JobId(2), SchedulerMsg::Task(t));
            }
        }
        h.send(JobId(1), SchedulerMsg::Shutdown);
        h.send(JobId(2), SchedulerMsg::Shutdown);
        let scheds = h.join();
        assert_eq!(scheds.len(), 2);

        let mut per_job: HashMap<JobId, Vec<u64>> = HashMap::new();
        while let Ok(batch) = out_rx.recv() {
            for i in &batch.instructions {
                assert_eq!(
                    JobId::of(i.id.0),
                    batch.job,
                    "instruction {} in a batch of {}",
                    i.id,
                    batch.job
                );
                per_job.entry(batch.job).or_default().push(i.id.0);
            }
        }
        assert_eq!(per_job.len(), 2);
        // Same program → same per-job instruction stream, modulo the
        // namespace tag: stripping the job bits yields identical sequences.
        let strip = |ids: &[u64]| -> Vec<u64> {
            ids.iter().map(|id| id & ((1u64 << JobId::SHIFT) - 1)).collect::<Vec<_>>()
        };
        assert_eq!(strip(&per_job[&JobId(1)]), strip(&per_job[&JobId(2)]));
        assert!(!per_job[&JobId(1)].is_empty());
    }
}
