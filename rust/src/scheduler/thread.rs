//! The dedicated scheduler thread (Fig 5).

use super::{Scheduler, SchedulerConfig};
use crate::buffer::BufferPool;
use crate::grid::GridBox;
use crate::instruction::{InstructionRef, Pilot};
use crate::task::TaskRef;
use crate::util::{spsc, AllocationId};
use std::thread::JoinHandle;

/// Host-initialized buffer contents, materialized in the executor's arena
/// as the buffer's user-memory (M0) allocation. Travels through the
/// scheduler pipeline so it is ordered before any instruction that reads
/// it.
pub struct UserInit {
    pub alloc: AllocationId,
    pub covers: GridBox,
    pub elem_size: usize,
    /// Empty = zero-fill.
    pub bytes: Vec<u8>,
}

/// Messages from the main thread to the scheduler thread.
pub enum SchedulerMsg {
    /// A buffer was created; snapshot of the updated pool.
    Buffers(BufferPool),
    /// Host-initialized buffer contents to forward to the executor.
    UserData(UserInit),
    /// A new task reference (user task, horizon or epoch).
    Task(TaskRef),
    /// Drain everything and exit.
    Shutdown,
}

/// Output of the scheduler thread, consumed by the executor thread.
pub struct SchedulerOut {
    pub instructions: Vec<InstructionRef>,
    pub pilots: Vec<Pilot>,
    pub user_inits: Vec<UserInit>,
    /// §4.4 errors detected during command generation, forwarded through
    /// the executor's event stream to the user-facing queue.
    pub errors: Vec<String>,
}

impl SchedulerOut {
    pub fn batch(instructions: Vec<InstructionRef>, pilots: Vec<Pilot>) -> Self {
        SchedulerOut {
            instructions,
            pilots,
            user_inits: Vec::new(),
            errors: Vec::new(),
        }
    }
}

/// Upper bound on tasks compiled per wakeup. Draining amortizes channel
/// traffic, but an unbounded batch would delay the first instruction of a
/// large backlog behind the whole compile; the cap keeps time-to-first-
/// instruction bounded while still coalescing bursts.
const MAX_WAKEUP_BATCH: usize = 64;

/// Handle to a running scheduler thread.
pub struct SchedulerHandle {
    pub tx: spsc::Sender<SchedulerMsg>,
    join: JoinHandle<Scheduler>,
}

impl SchedulerHandle {
    /// Spawn the scheduler thread. Emitted instruction batches flow into
    /// `out` (the executor's inbox).
    pub fn spawn(
        cfg: SchedulerConfig,
        buffers: BufferPool,
        out: spsc::Sender<SchedulerOut>,
    ) -> SchedulerHandle {
        let (tx, rx) = spsc::channel::<SchedulerMsg>(1024);
        let join = std::thread::Builder::new()
            .name(format!("celerity-sched-{}", cfg.node))
            .spawn(move || {
                let cfg_node = cfg.node;
                let mut sched = Scheduler::new(cfg, buffers);
                // Non-task message popped while draining a task run; handled
                // on the next loop iteration to preserve message order.
                let mut carry: Option<SchedulerMsg> = None;
                loop {
                    let msg = match carry.take() {
                        Some(m) => Ok(m),
                        None => rx.recv().map_err(|_| ()),
                    };
                    match msg {
                        Ok(SchedulerMsg::Buffers(pool)) => sched.notify_buffers(pool),
                        Ok(SchedulerMsg::UserData(init)) => {
                            let _ = out.send(SchedulerOut {
                                instructions: vec![],
                                pilots: vec![],
                                user_inits: vec![init],
                                errors: vec![],
                            });
                        }
                        Ok(SchedulerMsg::Task(task)) => {
                            // Batched wakeup: drain the run of tasks already
                            // queued behind this one and compile them in a
                            // single pipeline pass; one SchedulerOut per
                            // wakeup amortizes channel traffic and lets the
                            // lookahead see the whole window at once (§4.3).
                            let mut tasks = vec![task];
                            while tasks.len() < MAX_WAKEUP_BATCH {
                                match rx.try_recv() {
                                    Ok(SchedulerMsg::Task(t)) => tasks.push(t),
                                    Ok(other) => {
                                        carry = Some(other);
                                        break;
                                    }
                                    Err(_) => break,
                                }
                            }
                            let trace = std::env::var_os("CELERITY_COMM_TRACE").is_some();
                            if trace {
                                eprintln!(
                                    "[sched {}] processing batch of {} (first: {} '{}')",
                                    cfg_node, tasks.len(), tasks[0].id, tasks[0].name
                                );
                            }
                            let tracing = crate::trace::enabled();
                            let t0 = if tracing { crate::trace::now_ns() } else { 0 };
                            let flushes_before = sched.flushes;
                            let (instructions, pilots) = sched.process_batch(&tasks);
                            if tracing {
                                record_batch_trace(
                                    cfg_node.0,
                                    t0,
                                    tasks.len(),
                                    &instructions,
                                    sched.queue_len(),
                                    sched.flushes - flushes_before,
                                );
                            }
                            if trace {
                                eprintln!(
                                    "[sched {}] emitted {} instrs {} pilots (queue={})",
                                    cfg_node, instructions.len(), pilots.len(), sched.queue_len()
                                );
                            }
                            let mut errors: Vec<String> =
                                sched.take_errors().iter().map(|e| e.to_string()).collect();
                            errors.extend(sched.take_idag_errors());
                            if !instructions.is_empty() || !pilots.is_empty() || !errors.is_empty()
                            {
                                let mut batch = SchedulerOut::batch(instructions, pilots);
                                batch.errors = errors;
                                let _ = out.send(batch);
                            }
                        }
                        Ok(SchedulerMsg::Shutdown) | Err(()) => {
                            let tracing = crate::trace::enabled();
                            let t0 = if tracing { crate::trace::now_ns() } else { 0 };
                            let flushes_before = sched.flushes;
                            let (instructions, pilots) = sched.flush_now();
                            if tracing {
                                record_batch_trace(
                                    cfg_node.0,
                                    t0,
                                    0,
                                    &instructions,
                                    sched.queue_len(),
                                    sched.flushes - flushes_before,
                                );
                            }
                            let mut errors: Vec<String> =
                                sched.take_errors().iter().map(|e| e.to_string()).collect();
                            errors.extend(sched.take_idag_errors());
                            if !instructions.is_empty() || !pilots.is_empty() || !errors.is_empty()
                            {
                                let mut batch = SchedulerOut::batch(instructions, pilots);
                                batch.errors = errors;
                                let _ = out.send(batch);
                            }
                            break;
                        }
                    }
                }
                crate::trace::flush_thread();
                sched
            })
            .expect("spawn scheduler thread");
        SchedulerHandle { tx, join }
    }

    /// Send a message to the scheduler thread.
    pub fn send(&self, msg: SchedulerMsg) {
        self.tx.send(msg).expect("scheduler thread alive");
    }

    /// Shut down and return the scheduler (for statistics).
    pub fn join(self) -> Scheduler {
        let _ = self.tx.send(SchedulerMsg::Shutdown);
        drop(self.tx);
        self.join.join().expect("scheduler thread panicked")
    }
}

/// Record one wakeup into the trace: a `SchedBatch` span over the compile,
/// a `Compiled` instant per emitted instruction (carrying the IDAG edges
/// for the Graphviz export), and a `LookaheadFlush` instant per lookahead
/// flush the batch triggered. Only called with tracing enabled, so the
/// per-instruction dep vectors are never built on the normal path.
fn record_batch_trace(
    node: u64,
    t0: u64,
    tasks: usize,
    instructions: &[InstructionRef],
    queue_len: usize,
    flushes: u64,
) {
    use crate::trace::{self, EventKind, Track};
    trace::span(
        node,
        Track::Scheduler,
        t0,
        EventKind::SchedBatch {
            tasks: tasks as u64,
            instructions: instructions.len() as u64,
            queue_len: queue_len as u64,
        },
    );
    for i in instructions {
        trace::instant(
            node,
            Track::Scheduler,
            EventKind::Compiled {
                instr: i.id.0,
                mnemonic: i.kind.mnemonic(),
                deps: i.deps.iter().map(|(d, _)| d.0).collect(),
            },
        );
    }
    for _ in 0..flushes {
        trace::instant(node, Track::Scheduler, EventKind::LookaheadFlush);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Range;
    use crate::task::{RangeMapper, TaskDecl, TaskManager};

    #[test]
    fn thread_processes_and_flushes_on_shutdown() {
        let mut tm = TaskManager::new();
        let n = Range::d1(128);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        for _ in 0..4 {
            tm.submit(TaskDecl::device("w", n).read_write(a, RangeMapper::OneToOne));
        }
        tm.shutdown();
        let tasks = tm.take_new_tasks();

        let (out_tx, out_rx) = spsc::channel(1024);
        let h = SchedulerHandle::spawn(
            SchedulerConfig::default(),
            tm.buffers().clone(),
            out_tx,
        );
        let n_tasks = tasks.len() as u64;
        for t in tasks {
            h.send(SchedulerMsg::Task(t));
        }
        let sched = h.join();
        let mut total = 0;
        let mut outs = 0u64;
        while let Ok(batch) = out_rx.recv() {
            total += batch.instructions.len();
            outs += 1;
        }
        assert_eq!(total as u64, sched.instructions_generated);
        assert!(total > 4);
        // Wakeup batching: every task was processed, in at most one batch
        // per task message (how runs coalesce depends on thread timing),
        // and output batches never exceed wakeups + the shutdown flush.
        assert!(sched.batches >= 1 && sched.batches <= n_tasks, "batches={}", sched.batches);
        assert!(outs <= sched.batches + 1, "outs={outs} batches={}", sched.batches);
    }
}
