//! Discrete-event cluster simulator for the strong-scaling study (Fig 6).
//!
//! The *real* scheduler stack — TDAG, CDAG, IDAG generation including the
//! lookahead heuristic — runs unmodified; only instruction *execution* is
//! virtual. Each node owns model resources (an executor dispatch loop,
//! per-device kernel/copy engines, host threads, a NIC) and instructions
//! acquire them in dependency order; sends and receives are matched across
//! nodes exactly like receive arbitration does at runtime.
//!
//! Two executor models reproduce the paper's comparison:
//!
//! - [`ExecModel::Idag`] — the proposed architecture: instructions dispatch
//!   out-of-order with a small per-instruction selection latency.
//! - [`ExecModel::Baseline`] — §2.5 ad-hoc memory management: each
//!   command's constituent instructions execute as one indivisible
//!   sequence, and the executor pays a dataflow-analysis latency per
//!   command on its critical path. No lookahead → RSim-style resizes occur.

use crate::buffer::BufferPool;
use crate::command::{CdagGenerator, SplitHint};
use crate::dag::DepKind;
use crate::grid::Region;
use crate::instruction::{IdagConfig, IdagGenerator, InstructionKind, InstructionRef};
use crate::scheduler::{Scheduler, SchedulerConfig};
use crate::task::{TaskManager, TaskRef};
use crate::util::{DeviceId, JobId, NodeId, TaskId};
use std::collections::HashMap;

/// Calibrated cost model. Defaults approximate one Leonardo booster node
/// (A100s, quad-HDR Infiniband) at the granularity the scheduling study
/// needs: relative magnitudes, not absolute TFLOPs.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Device throughput in work units/s (`work_per_item` × items).
    pub device_flops: f64,
    /// Host-task throughput in work units/s.
    pub host_flops: f64,
    /// Kernel launch overhead (s).
    pub kernel_launch: f64,
    /// Device/pinned allocation: base + per-byte page-mapping cost (§4.3:
    /// "memory allocations in GPU programs are typically very slow").
    pub alloc_base: f64,
    pub alloc_per_byte: f64,
    pub free_base: f64,
    /// Intra-node copy: latency + bandwidth by path.
    pub copy_latency: f64,
    pub d2d_bw: f64,
    pub h2d_bw: f64,
    pub d2h_bw: f64,
    pub h2h_bw: f64,
    /// Network: per-message latency + per-node NIC bandwidth.
    pub net_latency: f64,
    pub net_bw: f64,
    /// IDAG executor: instruction selection/polling latency (§4.1).
    pub dispatch_overhead: f64,
    /// Baseline executor: ad-hoc dataflow analysis per command (§2.5).
    pub baseline_cmd_overhead: f64,
    /// Scheduler thread: per-task graph-generation cost (drives
    /// availability times; Fig 7).
    pub sched_task_cost: f64,
    pub sched_instr_cost: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            device_flops: 10e12,
            host_flops: 50e9,
            kernel_launch: 6e-6,
            alloc_base: 10e-6,
            alloc_per_byte: 0.25e-9, // ~4 GB/s page mapping
            free_base: 4e-6,
            copy_latency: 6e-6,
            d2d_bw: 300e9, // NVLink-class
            h2d_bw: 25e9,  // PCIe-class
            d2h_bw: 25e9,
            h2h_bw: 50e9,
            net_latency: 5e-6,
            net_bw: 45e9, // quad-HDR per node, effective
            dispatch_overhead: 1.5e-6,
            baseline_cmd_overhead: 30e-6,
            sched_task_cost: 20e-6,
            sched_instr_cost: 1e-6,
        }
    }
}

impl CostModel {
    /// Price one instruction: the model duration (s) its execution
    /// occupies its resource, exclusive of dispatch overhead. This is the
    /// pricing *library* shared by the DES below and the static analyzer
    /// ([`crate::analyze`]) — both must agree on what an instruction
    /// costs, or the analyzer's critical path would diverge from the
    /// simulated makespan. Buffers missing from the pool (hand-built test
    /// streams) price at one byte per element.
    pub fn price(&self, kind: &InstructionKind, buffers: &BufferPool) -> f64 {
        let elem = |b: crate::util::BufferId| {
            buffers.try_get(b).map(|info| info.elem_size as u64).unwrap_or(1)
        };
        match kind {
            InstructionKind::Alloc { size_bytes, .. } => {
                self.alloc_base + *size_bytes as f64 * self.alloc_per_byte
            }
            InstructionKind::Free { .. } => self.free_base,
            InstructionKind::Copy { copy_box, src_memory, dst_memory, buffer, .. } => {
                let bytes = (copy_box.area() * elem(*buffer)) as f64;
                let bw = match (src_memory.to_device(), dst_memory.to_device()) {
                    (Some(_), Some(_)) => self.d2d_bw,
                    (None, Some(_)) => self.h2d_bw,
                    (Some(_), None) => self.d2h_bw,
                    (None, None) => self.h2h_bw,
                };
                self.copy_latency + bytes / bw
            }
            InstructionKind::DeviceKernel { chunk, work_per_item, .. } => {
                self.kernel_launch + chunk.area() as f64 * work_per_item / self.device_flops
            }
            InstructionKind::HostTask { chunk, work_per_item, .. } => {
                chunk.area() as f64 * work_per_item / self.host_flops
            }
            InstructionKind::Send { send_box, buffer, src_memory, .. } => {
                let bytes = (send_box.area() * elem(*buffer)) as f64;
                // A direct-from-device send streams over the device↔host
                // link into the NIC (GPUDirect-style): the staged d2h copy
                // hop is gone, but the effective bandwidth is the min of
                // the two links. Host-sourced sends see the NIC alone.
                let bw = if src_memory.is_device() {
                    self.net_bw.min(self.d2h_bw)
                } else {
                    self.net_bw
                };
                bytes / bw
            }
            InstructionKind::Receive { .. }
            | InstructionKind::SplitReceive { .. }
            | InstructionKind::AwaitReceive { .. } => 0.0,
            // Costed as n−1 serialized ring rounds.
            InstructionKind::Collective { region, buffer, slices, .. } => {
                let bytes = (region.area() * elem(*buffer)) as f64;
                let rounds = slices.len().saturating_sub(1) as f64;
                rounds * self.net_latency + bytes / self.net_bw
            }
            InstructionKind::Horizon | InstructionKind::Epoch(_) => 0.0,
        }
    }
}

/// Executor model under simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecModel {
    /// Proposed instruction-graph architecture (§3–4).
    Idag,
    /// Baseline Celerity with ad-hoc memory management (§2.5).
    Baseline,
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub num_nodes: u64,
    pub num_devices: u64,
    pub exec: ExecModel,
    /// Lookahead only applies to the IDAG executor; the baseline has no
    /// scheduler queue.
    pub lookahead: bool,
    /// Direct device transfers (p2p staging elision) — IDAG executor only;
    /// the §2.5 baseline always stages through pinned host memory.
    pub direct_comm: bool,
    pub hint: SplitHint,
    pub cost: CostModel,
    /// Record a per-instruction timeline (Fig 7).
    pub record_trace: bool,
    /// Run the static instruction-graph verifier over every node's compiled
    /// stream plus the cluster-level communication matching (`sim --verify`).
    /// Only meaningful for [`ExecModel::Idag`]: the §2.5 baseline sequences
    /// instructions through simulator-side chains rather than graph edges,
    /// so its streams are *expected* to be under-ordered.
    pub verify: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            num_nodes: 1,
            num_devices: 4,
            exec: ExecModel::Idag,
            lookahead: true,
            direct_comm: true,
            hint: SplitHint::D1,
            cost: CostModel::default(),
            record_trace: false,
            verify: false,
        }
    }
}

/// One timeline entry (Fig 7).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub node: u64,
    /// Resource label, e.g. "D0 kernel", "NIC", "host", "dispatch", "sched".
    pub resource: String,
    pub label: String,
    pub start: f64,
    pub end: f64,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Virtual makespan (s): epoch-to-epoch wall time of the whole cluster.
    pub makespan: f64,
    pub instructions: u64,
    pub comm_bytes: u64,
    pub resizes: u64,
    pub allocated_bytes: u64,
    pub trace: Vec<TraceEvent>,
    /// Rendered verifier violations (`--verify`; empty when off or clean).
    pub violations: Vec<String>,
}

impl SimResult {
    /// Lower the virtual-time timeline onto the shared trace schema, so a
    /// simulated run exports to the same Chrome-tracing JSON as a live one
    /// (`crate::trace::chrome::to_chrome_json`). Virtual seconds map to
    /// nanoseconds 1:1; resources become named tracks.
    pub fn to_trace(&self) -> crate::trace::Trace {
        let to_ns = |t: f64| (t.max(0.0) * 1e9) as u64;
        let mut events: Vec<crate::trace::Event> = self
            .trace
            .iter()
            .map(|e| crate::trace::Event {
                node: e.node,
                track: crate::trace::Track::Named(e.resource.clone()),
                start_ns: to_ns(e.start),
                end_ns: to_ns(e.end.max(e.start)),
                kind: crate::trace::EventKind::Span { label: e.label.clone() },
            })
            .collect();
        events.sort_by_key(|e| (e.start_ns, e.node));
        crate::trace::Trace { events }
    }
}

// ── internal DES machinery ────────────────────────────────────────────────

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Res {
    Dispatch,
    Kernel(DeviceId),
    CopyIn(DeviceId),
    CopyOut(DeviceId),
    Host(usize),
    Nic,
}

struct NodeSim {
    instrs: Vec<InstructionRef>,
    avail: HashMap<u64, f64>,
    /// extra sequential dependencies (baseline command chaining)
    extra_deps: HashMap<u64, Vec<u64>>,
    /// per-command overhead charged on dispatch (baseline)
    cmd_overhead: HashMap<u64, f64>,
}

/// Matched inbound transfer: (sender node, send instr id, bytes).
type SendMatch = (usize, u64, u64);

/// Run `build` on a fresh task manager, simulate on the configured cluster,
/// and return the virtual-time result.
pub fn simulate<F>(cfg: &SimConfig, build: F) -> SimResult
where
    F: Fn(&mut TaskManager),
{
    // 1. The TDAG is identical on all nodes: build once.
    let mut tm = TaskManager::new();
    build(&mut tm);
    tm.shutdown();
    let tasks: Vec<TaskRef> = tm.take_new_tasks();
    let buffers: BufferPool = tm.buffers().clone();

    // 2. Per node: real CDAG + IDAG generation (with or without lookahead),
    //    recording per-instruction availability times (scheduler model).
    let mut nodes: Vec<NodeSim> = Vec::new();
    let mut resizes = 0;
    let mut allocated = 0;
    let mut violations: Vec<String> = Vec::new();
    let mut verify_streams: Vec<crate::verify::NodeStream> = Vec::new();
    for nid in 0..cfg.num_nodes {
        let node = match cfg.exec {
            ExecModel::Idag => {
                let mut sched = Scheduler::new(
                    SchedulerConfig {
                        job: JobId(0),
                        node: NodeId(nid),
                        num_nodes: cfg.num_nodes,
                        num_devices: cfg.num_devices,
                        node_hint: cfg.hint,
                        device_hint: cfg.hint,
                        d2d: true,
                        lookahead: cfg.lookahead,
                        horizon_flush: 2,
                        // The DES matches sends and receives pairwise; ring
                        // rounds are finer-grained than its instruction-level
                        // cross-node coupling, so the simulator models the
                        // paper's original p2p protocol (the live executor
                        // defaults to collectives — see the strong_scaling
                        // bench ablation for the measured delta).
                        collectives: false,
                        direct_comm: cfg.direct_comm,
                        // `sim --verify` checks post-hoc over the complete
                        // streams (per-node + cluster matching) below;
                        // running the incremental in-core verifier too would
                        // double the work for identical verdicts.
                        verify: false,
                    },
                    buffers.clone(),
                );
                let mut instrs = Vec::new();
                let mut pilots = Vec::new();
                let mut avail = HashMap::new();
                let mut clock = 0.0;
                for t in &tasks {
                    // One task per wakeup: the simulated main thread submits
                    // concurrently with execution, so the scheduler never
                    // sees a queued run to batch — the worst case for
                    // per-wakeup overhead (the live thread drains runs via
                    // the same process_batch entry point).
                    clock += cfg.cost.sched_task_cost;
                    let (batch, ps) = sched.process_batch(std::slice::from_ref(t));
                    clock += cfg.cost.sched_instr_cost * batch.len() as f64;
                    pilots.extend(ps);
                    for i in batch {
                        avail.insert(i.id.0, clock);
                        instrs.push(i);
                    }
                }
                let (batch, ps) = sched.flush_now();
                clock += cfg.cost.sched_instr_cost * batch.len() as f64;
                pilots.extend(ps);
                for i in batch {
                    avail.insert(i.id.0, clock);
                    instrs.push(i);
                }
                resizes = resizes.max(sched.idag().resizes_emitted);
                allocated = allocated.max(sched.idag().bytes_allocated);
                if cfg.verify {
                    violations.extend(
                        crate::verify::verify_stream(
                            JobId(0),
                            NodeId(nid),
                            buffers.clone(),
                            &instrs,
                            &pilots,
                        )
                        .iter()
                        .map(|v| format!("node {nid}: {v}")),
                    );
                    verify_streams.push(crate::verify::NodeStream {
                        node: NodeId(nid),
                        instructions: instrs.clone(),
                        pilots,
                    });
                }
                NodeSim { instrs, avail, extra_deps: HashMap::new(), cmd_overhead: HashMap::new() }
            }
            ExecModel::Baseline => {
                // Direct generators; chain instructions per command and
                // charge the per-command analysis latency (§2.5).
                let mut cdag =
                    CdagGenerator::new(NodeId(nid), cfg.num_nodes, cfg.hint, buffers.clone());
                // Baseline Celerity (§2.5) predates collective lowering.
                cdag.set_collectives(false);
                let mut idag = IdagGenerator::new(
                    IdagConfig {
                        node: NodeId(nid),
                        num_nodes: cfg.num_nodes,
                        num_devices: cfg.num_devices,
                        node_hint: cfg.hint,
                        device_hint: cfg.hint,
                        d2d: true,
                        // §2.5 ad-hoc memory management predates the direct
                        // device path: every transfer stages through M1.
                        direct_comm: false,
                    },
                    buffers.clone(),
                );
                let mut instrs = Vec::new();
                let mut avail = HashMap::new();
                let mut extra_deps: HashMap<u64, Vec<u64>> = HashMap::new();
                let mut cmd_overhead = HashMap::new();
                let mut clock = 0.0;
                for t in &tasks {
                    clock += cfg.cost.sched_task_cost;
                    cdag.compile(t);
                    for cmd in cdag.take_new_commands() {
                        idag.compile(&cmd);
                        let batch = idag.take_new_instructions();
                        let _ = idag.take_pilots();
                        clock += cfg.cost.sched_instr_cost * batch.len() as f64;
                        // Indivisible sequence: chain batch members. The
                        // kernel may overlap with *unrelated* commands but
                        // not with its own memory operations.
                        for w in batch.windows(2) {
                            extra_deps.entry(w[1].id.0).or_default().push(w[0].id.0);
                        }
                        if let Some(first) = batch.first() {
                            cmd_overhead.insert(first.id.0, cfg.cost.baseline_cmd_overhead);
                        }
                        for i in batch {
                            avail.insert(i.id.0, clock);
                            instrs.push(i);
                        }
                    }
                }
                resizes = resizes.max(idag.resizes_emitted);
                allocated = allocated.max(idag.bytes_allocated);
                NodeSim { instrs, avail, extra_deps, cmd_overhead }
            }
        };
        nodes.push(node);
    }
    if cfg.verify && !verify_streams.is_empty() {
        violations.extend(
            crate::verify::verify_cluster(&verify_streams).iter().map(|v| v.to_string()),
        );
    }

    // 3. Cross-node transfer matching (virtual receive arbitration): for
    //    every receive/await-receive, find the matching sends by (target,
    //    buffer, transfer, box overlap).
    type SendKey = (usize, crate::util::BufferId, TaskId);
    let mut sends_by_key: HashMap<SendKey, Vec<(usize, u64, crate::grid::GridBox)>> =
        HashMap::new();
    let mut comm_bytes = 0u64;
    for (n, node) in nodes.iter().enumerate() {
        for i in &node.instrs {
            if let InstructionKind::Send { buffer, send_box, target, .. } = &i.kind {
                let tid = i.task.as_ref().map(|t| t.id).unwrap_or(TaskId(0));
                sends_by_key
                    .entry((target.0 as usize, *buffer, tid))
                    .or_default()
                    .push((n, i.id.0, *send_box));
                comm_bytes +=
                    send_box.area() * buffers.get(*buffer).elem_size as u64;
            }
        }
    }
    // receive instr (node, id) → matched sends
    let mut recv_matches: HashMap<(usize, u64), Vec<SendMatch>> = HashMap::new();
    for (n, node) in nodes.iter().enumerate() {
        for i in &node.instrs {
            let (region, transfer, buffer) = match &i.kind {
                InstructionKind::Receive { buffer, region, transfer, .. }
                | InstructionKind::SplitReceive { buffer, region, transfer, .. } => {
                    (region.clone(), *transfer, *buffer)
                }
                InstructionKind::AwaitReceive { buffer, region, .. } => {
                    let tid = i.task.as_ref().map(|t| t.id).unwrap_or(TaskId(0));
                    (region.clone(), tid, *buffer)
                }
                _ => continue,
            };
            let elem = buffers.get(buffer).elem_size as u64;
            let mut matches = Vec::new();
            if let Some(sends) = sends_by_key.get(&(n, buffer, transfer)) {
                for (sn, sid, sbox) in sends {
                    if region.intersects(&Region::from(*sbox)) {
                        matches.push((*sn, *sid, sbox.area() * elem));
                    }
                }
            }
            recv_matches.insert((n, i.id.0), matches);
        }
    }

    // 4. Event-driven execution. State per (node, instr).
    #[derive(Clone)]
    struct St {
        missing: usize,
        ready_at: f64,
        msgs_missing: usize,
        msg_ready: f64,
        done: bool,
    }
    let mut st: HashMap<(usize, u64), St> = HashMap::new();
    let mut dependents: HashMap<(usize, u64), Vec<u64>> = HashMap::new();
    for (n, node) in nodes.iter().enumerate() {
        for i in &node.instrs {
            let mut deps: Vec<u64> = i.deps.iter().map(|(d, _)| d.0).collect();
            if let Some(extra) = node.extra_deps.get(&i.id.0) {
                for d in extra {
                    if !deps.contains(d) {
                        deps.push(*d);
                    }
                }
            }
            // Split-receive deps already exist for await-receive via
            // instruction deps (Dataflow on split).
            let _ = DepKind::Dataflow;
            for d in &deps {
                dependents.entry((n, *d)).or_default().push(i.id.0);
            }
            let msgs = recv_matches.get(&(n, i.id.0)).map(|m| m.len()).unwrap_or(0);
            st.insert(
                (n, i.id.0),
                St {
                    missing: deps.len(),
                    ready_at: nodes[n].avail[&i.id.0],
                    msgs_missing: msgs,
                    msg_ready: 0.0,
                    done: false,
                },
            );
        }
    }
    // Reverse index: send (node, id) → receives waiting on it.
    let mut send_waiters: HashMap<(usize, u64), Vec<(usize, u64, u64)>> = HashMap::new();
    for ((rn, rid), matches) in &recv_matches {
        for (sn, sid, bytes) in matches {
            send_waiters.entry((*sn, *sid)).or_default().push((*rn, *rid, *bytes));
        }
    }

    // Resources.
    let mut res_free: HashMap<(usize, Res), f64> = HashMap::new();
    let host_lanes = 4usize;
    let instr_index: Vec<HashMap<u64, InstructionRef>> = nodes
        .iter()
        .map(|n| n.instrs.iter().map(|i| (i.id.0, i.clone())).collect())
        .collect();

    let cost = &cfg.cost;
    let mut trace: Vec<TraceEvent> = Vec::new();
    let mut makespan = 0.0f64;
    let mut total_instr = 0u64;

    // Ready queue ordered by ready time (deps + msgs satisfied).
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    #[derive(PartialEq)]
    struct Ev(f64, usize, u64);
    impl Eq for Ev {}
    impl PartialOrd for Ev {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Ev {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            self.0
                .partial_cmp(&o.0)
                .expect("sim timestamps are never NaN")
                .then(self.1.cmp(&o.1))
                .then(self.2.cmp(&o.2))
        }
    }
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    for (n, node) in nodes.iter().enumerate() {
        for i in &node.instrs {
            let s = &st[&(n, i.id.0)];
            if s.missing == 0 && s.msgs_missing == 0 {
                heap.push(Reverse(Ev(s.ready_at, n, i.id.0)));
            }
        }
    }

    while let Some(Reverse(Ev(ready, n, id))) = heap.pop() {
        let s = st.get_mut(&(n, id)).expect("sim tracks every emitted instruction");
        if s.done {
            continue;
        }
        s.done = true;
        let ready = ready.max(s.msg_ready);
        let instr = &instr_index[n][&id];

        // Executor dispatch (serial per node).
        let overhead = match cfg.exec {
            ExecModel::Idag => cost.dispatch_overhead,
            ExecModel::Baseline => {
                cost.dispatch_overhead
                    + nodes[n].cmd_overhead.get(&id).copied().unwrap_or(0.0)
            }
        };
        let dfree = res_free.entry((n, Res::Dispatch)).or_insert(0.0);
        let dispatch_start = ready.max(*dfree);
        let issue = dispatch_start + overhead;
        *dfree = issue;

        // Duration from the shared pricing library; resource placement is
        // DES-specific (engines, host lanes, the NIC).
        let dur = cost.price(&instr.kind, &buffers);
        let (res, label): (Option<Res>, &str) = match &instr.kind {
            InstructionKind::Alloc { .. } => (None, "alloc"),
            InstructionKind::Free { .. } => (None, "free"),
            InstructionKind::Copy { src_memory, dst_memory, .. } => {
                let r = match (src_memory.to_device(), dst_memory.to_device()) {
                    (Some(_), Some(d)) | (None, Some(d)) => Res::CopyIn(d),
                    (Some(d), None) => Res::CopyOut(d),
                    (None, None) => Res::Host((id as usize) % host_lanes),
                };
                (Some(r), "copy")
            }
            InstructionKind::DeviceKernel { device, .. } => {
                (Some(Res::Kernel(*device)), "kernel")
            }
            InstructionKind::HostTask { .. } => {
                (Some(Res::Host((id as usize) % host_lanes)), "host")
            }
            InstructionKind::Send { .. } => (Some(Res::Nic), "send"),
            InstructionKind::Receive { .. }
            | InstructionKind::SplitReceive { .. }
            | InstructionKind::AwaitReceive { .. } => (None, "receive"),
            // Not emitted by the sim's generators (collectives are disabled
            // above); priced for completeness.
            InstructionKind::Collective { .. } => (Some(Res::Nic), "collective"),
            InstructionKind::Horizon => (None, "horizon"),
            InstructionKind::Epoch(_) => (None, "epoch"),
        };

        let (start, end) = match res {
            Some(r) => {
                let free = if let Res::Host(_) = r {
                    // k-server host pool: pick the earliest-free lane.
                    let mut best = (Res::Host(0), f64::MAX);
                    for l in 0..host_lanes {
                        let f = *res_free.entry((n, Res::Host(l))).or_insert(0.0);
                        if f < best.1 {
                            best = (Res::Host(l), f);
                        }
                    }
                    best.0
                } else {
                    r
                };
                let rf = res_free.entry((n, free)).or_insert(0.0);
                let start = issue.max(*rf);
                let end = start + dur;
                *rf = end;
                if cfg.record_trace {
                    trace.push(TraceEvent {
                        node: n as u64,
                        resource: format!("{free:?}"),
                        label: format!("{label} {}", instr.label()),
                        start,
                        end,
                    });
                }
                (start, end)
            }
            None => {
                let end = issue + dur;
                if cfg.record_trace && dur > 0.0 {
                    trace.push(TraceEvent {
                        node: n as u64,
                        resource: "dispatch".into(),
                        label: label.into(),
                        start: issue,
                        end,
                    });
                }
                (issue, end)
            }
        };
        let _ = start;
        makespan = makespan.max(end);
        total_instr += 1;

        // Notify intra-node dependents.
        if let Some(deps) = dependents.get(&(n, id)).cloned() {
            for did in deps {
                let ds = st.get_mut(&(n, did)).expect("sim tracks every emitted instruction");
                ds.missing -= 1;
                ds.ready_at = ds.ready_at.max(end);
                if ds.missing == 0 && ds.msgs_missing == 0 && !ds.done {
                    heap.push(Reverse(Ev(ds.ready_at.max(ds.msg_ready), n, did)));
                }
            }
        }
        // Notify cross-node receivers (send completion → arrival).
        if let Some(waiters) = send_waiters.get(&(n, id)).cloned() {
            for (rn, rid, bytes) in waiters {
                let arrival = end + cost.net_latency + bytes as f64 / cost.net_bw;
                let rs = st.get_mut(&(rn, rid)).expect("sim tracks every emitted instruction");
                rs.msgs_missing -= 1;
                rs.msg_ready = rs.msg_ready.max(arrival);
                if rs.missing == 0 && rs.msgs_missing == 0 && !rs.done {
                    heap.push(Reverse(Ev(rs.ready_at.max(rs.msg_ready), rn, rid)));
                }
            }
        }
    }

    SimResult {
        makespan,
        instructions: total_instr,
        comm_bytes,
        resizes,
        allocated_bytes: allocated,
        trace,
        violations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    fn nbody_build(n: u64, steps: usize) -> impl Fn(&mut TaskManager) {
        move |tm: &mut TaskManager| {
            let range = crate::grid::Range::d1(n);
            let p = tm.create_buffer::<[f32; 3]>("P", range, true).id();
            let v = tm.create_buffer::<[f32; 3]>("V", range, true).id();
            for _ in 0..steps {
                tm.submit(
                    crate::task::TaskDecl::device("timestep", range)
                        .read(p, crate::task::RangeMapper::All)
                        .read_write(v, crate::task::RangeMapper::OneToOne)
                        .work_per_item(n as f64 * 20.0),
                );
                tm.submit(
                    crate::task::TaskDecl::device("update", range)
                        .read(v, crate::task::RangeMapper::OneToOne)
                        .read_write(p, crate::task::RangeMapper::OneToOne)
                        .work_per_item(2.0),
                );
            }
        }
    }

    #[test]
    fn all_instructions_complete() {
        let cfg = SimConfig { num_nodes: 2, num_devices: 2, ..Default::default() };
        let r = simulate(&cfg, nbody_build(1 << 12, 3));
        assert!(r.makespan > 0.0);
        assert!(r.instructions > 20);
        assert!(r.comm_bytes > 0, "all-gather must communicate");
    }

    #[test]
    fn simulated_graphs_verify_clean() {
        // `sim --verify`: the per-node streams and their cross-node
        // matching must pass the static verifier for every node count the
        // Fig-6 study sweeps.
        for nodes in [1, 2, 4] {
            let cfg = SimConfig {
                num_nodes: nodes,
                num_devices: 2,
                verify: true,
                ..Default::default()
            };
            let r = simulate(&cfg, nbody_build(1 << 10, 3));
            assert_eq!(r.violations, Vec::<String>::new(), "{nodes} nodes");
        }
    }

    #[test]
    fn more_gpus_speed_up_compute_bound_nbody() {
        let mk = |nodes, devs| SimConfig {
            num_nodes: nodes,
            num_devices: devs,
            ..Default::default()
        };
        let t1 = simulate(&mk(1, 4), nbody_build(1 << 16, 4)).makespan;
        let t4 = simulate(&mk(4, 4), nbody_build(1 << 16, 4)).makespan;
        assert!(
            t4 < t1 * 0.5,
            "16 GPUs should be >2x faster than 4: t1={t1:.4} t4={t4:.4}"
        );
    }

    #[test]
    fn idag_beats_baseline() {
        // The paper's headline: instruction-graph scheduling dominates the
        // ad-hoc baseline, especially as kernels shrink.
        let idag = SimConfig { num_nodes: 4, num_devices: 4, ..Default::default() };
        let base = SimConfig { exec: ExecModel::Baseline, ..idag.clone() };
        let ti = simulate(&idag, nbody_build(1 << 12, 10)).makespan;
        let tb = simulate(&base, nbody_build(1 << 12, 10)).makespan;
        assert!(ti < tb, "idag {ti:.5} vs baseline {tb:.5}");
    }

    #[test]
    fn rsim_lookahead_beats_naive_in_time_and_memory() {
        let build = |tm: &mut TaskManager| {
            // Paper regime: device allocations are expensive relative to
            // kernels (§4.3) — the growing buffer makes the naive schedule
            // pay a resize whose cost grows linearly every step.
            let steps = 128u64;
            let width = 8192u64;
            let r = tm.create_buffer::<f32>("R", crate::grid::Range::d2(steps, width), true).id();
            let vis =
                tm.create_buffer::<f32>("VIS", crate::grid::Range::d2(width, 64), true).id();
            for t in 1..steps {
                let prev = Region::from(crate::grid::GridBox::d2((0, 0), (t, width)));
                tm.submit(
                    crate::task::TaskDecl::device("radiosity", crate::grid::Range::d1(width))
                        .read(r, crate::task::RangeMapper::Fixed(prev))
                        .read(vis, crate::task::RangeMapper::All)
                        .write(r, crate::task::RangeMapper::RowSlice(t))
                        .work_per_item(t as f64 * 10.0),
                );
            }
        };
        let with = SimConfig { num_nodes: 1, num_devices: 4, ..Default::default() };
        // IDAG without lookahead: resizes occur (memory blow-up), though
        // out-of-order dispatch hides much of their latency.
        let no_la = SimConfig { lookahead: false, ..with.clone() };
        // The paper's Fig-6 comparator: the baseline executor, where the
        // resize chain sits on each command's indivisible sequence.
        let baseline = SimConfig { exec: ExecModel::Baseline, ..with.clone() };
        let rw = simulate(&with, build);
        let rn = simulate(&no_la, build);
        let rb = simulate(&baseline, build);
        assert_eq!(rw.resizes, 0);
        assert!(rn.resizes > 50 && rb.resizes > 50);
        assert!(rw.allocated_bytes < rn.allocated_bytes);
        // Headline: IDAG + lookahead beats the ad-hoc baseline.
        assert!(
            rw.makespan < rb.makespan,
            "idag {} vs baseline {}",
            rw.makespan,
            rb.makespan
        );
        // And even without lookahead, the OoO engine keeps the IDAG ahead.
        assert!(rn.makespan < rb.makespan, "{} vs {}", rn.makespan, rb.makespan);
    }

    /// Direct device transfers drop the staged d2h/h2d hops from the
    /// simulated instruction stream: same wire bytes, fewer instructions.
    #[test]
    fn direct_transfers_elide_staging_in_the_cost_model() {
        let direct = SimConfig { num_nodes: 2, num_devices: 2, ..Default::default() };
        let staged = SimConfig { direct_comm: false, ..direct.clone() };
        let rd = simulate(&direct, nbody_build(1 << 12, 4));
        let rs = simulate(&staged, nbody_build(1 << 12, 4));
        assert_eq!(rd.comm_bytes, rs.comm_bytes, "the wire traffic is unchanged");
        assert!(
            rd.instructions < rs.instructions,
            "staging copies must disappear: direct={} staged={}",
            rd.instructions,
            rs.instructions
        );
    }

    #[test]
    fn trace_records_kernels() {
        let cfg = SimConfig { record_trace: true, ..Default::default() };
        let r = simulate(&cfg, nbody_build(1 << 10, 2));
        assert!(r.trace.iter().any(|e| e.resource.contains("Kernel")));
        assert!(r.trace.iter().all(|e| e.end >= e.start));
    }

    /// The simulator timeline lowers onto the shared trace schema and
    /// exports through the same Chrome-JSON path as a live run.
    #[test]
    fn sim_timeline_exports_as_shared_trace() {
        let cfg = SimConfig { num_nodes: 2, record_trace: true, ..Default::default() };
        let r = simulate(&cfg, nbody_build(1 << 10, 2));
        let tr = r.to_trace();
        assert!(!tr.is_empty());
        tr.validate().expect("sim trace must satisfy the schema");
        let json = crate::trace::chrome::to_chrome_json(&tr);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("Kernel"));
    }

    #[test]
    fn apps_module_workloads_simulate() {
        // Smoke: the real app submit functions drive the simulator via a
        // plain TaskManager (no executor).
        let _ = apps::consts::DT;
        let cfg = SimConfig::default();
        let r = simulate(&cfg, |tm| {
            let range = crate::grid::Range::d2(64, 64);
            let a = tm.create_buffer::<f32>("A", range, true).id();
            let b = tm.create_buffer::<f32>("B", range, true).id();
            for _ in 0..4 {
                tm.submit(
                    crate::task::TaskDecl::device("s", range)
                        .read(a, crate::task::RangeMapper::Neighborhood(crate::grid::Range::d2(1, 0)))
                        .write(b, crate::task::RangeMapper::OneToOne)
                        .work_per_item(10.0),
                );
                tm.submit(
                    crate::task::TaskDecl::device("s", range)
                        .read(b, crate::task::RangeMapper::Neighborhood(crate::grid::Range::d2(1, 0)))
                        .write(a, crate::task::RangeMapper::OneToOne)
                        .work_per_item(10.0),
                );
            }
        });
        assert!(r.makespan > 0.0);
    }
}
